"""Anytime serving demo: deadline-aware depth control vs fixed-depth EDF.

Zygarde's imprecise-computation idea applied to the big-model configs:
a transformer's layer stack becomes mandatory + optional units with
early-exit heads, and the zeta_I scheduler decides *per request, per
token* how deep to run.  Under a tight latency budget the continuous
batch moves at the pace of its deepest request, so cutting optional
depth on high-margin tokens buys the whole batch slack that fixed-depth
EDF cannot:

1. Train a tiny qwen1.5-family transformer until its early units agree
   with the full stack (the exit margins become informative).
2. Calibrate per-unit exit thresholds against full-depth agreement.
3. Serve one overloaded request trace twice — fixed-depth EDF vs
   anytime zeta_I — and compare tardiness + on-time-agreement score.

The final comparison is asserted (anytime must win on both axes); CI
runs this script as part of the bench smoke lane.

    PYTHONPATH=src python examples/anytime_serve.py [--train-steps 80]
"""
import argparse
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import anytime as A
from repro.models import transformer as T
from repro.serve import AnytimeConfig, AnytimeRequest, AnytimeServeEngine
from repro.train import make_train_step
from repro.train.optimizer import adamw_init


def tiny_trained_model(train_steps: int, seed: int):
    """A 4-unit qwen1.5-family model trained on a modular-counting task
    until every unit predicts like the full stack."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=4, vocab=64, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, exit_every=1)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    B, S = 16, 16
    rng = np.random.default_rng(seed)
    for i in range(train_steps):
        start = rng.integers(0, cfg.vocab, size=(B, 1))
        toks = (start + np.arange(S + 1)) % cfg.vocab
        params, opt, metrics = step(params, opt,
                                    {"tokens": jnp.asarray(toks)})
        if i % 20 == 0 or i == train_steps - 1:
            print(f"  train step {i:3d}  loss "
                  f"{float(metrics['loss']):.4f}")
    return cfg, params


def calibrated_knobs(cfg, params, engine, seed: int):
    """Exit thresholds from full-depth agreement on held-out sequences."""
    rng = np.random.default_rng(seed + 1)
    start = rng.integers(0, cfg.vocab, size=(8, 1))
    toks = (start + np.arange(17)) % cfg.vocab
    unit_logits = jax.jit(
        lambda b: A.anytime_forward(cfg, params, engine.heads, b)
    )({"tokens": jnp.asarray(toks)})
    U, Bc, Sc, V = unit_logits.shape
    exit_thr, use = A.calibrate_thresholds(
        unit_logits.reshape(U, Bc * Sc, V), target_agreement=0.98)
    print(f"  calibrated thresholds: "
          f"{[round(float(t), 2) for t in exit_thr]} "
          f"(enabled: {[bool(u) for u in use]})")
    return engine.default_knobs(exit_thr=exit_thr,
                                use_exit_thr=use.astype(jnp.float32))


def make_workload(cfg, n_requests: int, seed: int):
    """An overloaded trace: arrivals outpace full-depth service."""
    rng = np.random.default_rng(seed + 2)
    reqs = []
    for i in range(n_requests):
        start = int(rng.integers(0, cfg.vocab))
        release = 0.25 * i
        reqs.append(AnytimeRequest(
            prompt=[start, (start + 1) % cfg.vocab], n_tokens=6,
            release=release, deadline=release + 1.6))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser(
        description="anytime zeta_I depth control vs fixed-depth EDF")
    ap.add_argument("--train-steps", type=int, default=80)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("training the tiny anytime model ...")
    cfg, params = tiny_trained_model(args.train_steps, args.seed)
    reqs = make_workload(cfg, args.requests, args.seed)
    results = {}
    for policy in ("edf", "anytime"):
        serve_cfg = AnytimeConfig(policy=policy, batch_slots=4,
                                  max_steps=256, prompt_len=2,
                                  max_new_tokens=8)
        engine = AnytimeServeEngine(cfg, params, serve_cfg=serve_cfg,
                                    seed=args.seed)
        knobs = calibrated_knobs(cfg, params, engine, args.seed) \
            if policy == "anytime" else engine.default_knobs()
        res = engine.run(reqs, knobs=knobs)
        results[policy] = res
        print(f"{policy:>8}: on-time {res.on_time}/{res.n_requests}, "
              f"mean depth {res.mean_depth:.2f}/{cfg.n_units}, "
              f"tardiness {res.mean_tardiness:.3f}s, "
              f"agreement {res.agreement:.2%}, score {res.score:.3f}")

    edf, anytime = results["edf"], results["anytime"]
    assert anytime.mean_tardiness < edf.mean_tardiness, (
        f"anytime tardiness {anytime.mean_tardiness:.3f} not below "
        f"EDF {edf.mean_tardiness:.3f}")
    assert anytime.score > edf.score, (
        f"anytime score {anytime.score:.3f} not above "
        f"EDF {edf.score:.3f}")
    print("anytime depth control beats fixed-depth EDF on tardiness "
          "and on-time agreement ✓")


if __name__ == "__main__":
    main()
