"""Programmatic multi-pod dry-run: lower dbrx-132b's train step onto the
2 x 16 x 16 production mesh and print the roofline terms.

This is the library API behind ``python -m repro.launch.dryrun`` — useful
when embedding the lowering/analysis into notebooks or CI.

    PYTHONPATH=src python examples/multipod_lowering.py [--arch dbrx-132b]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.lowering import analyze, lower_step  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=not args.single_pod)
    print(f"lowering {cfg.name} x {args.shape} on mesh "
          f"{dict(mesh.shape)} ({mesh.size} chips) ...")
    result = lower_step(cfg, args.shape, mesh)
    record = analyze(result)

    r = record["roofline"]
    print(json.dumps({k: record[k] for k in (
        "arch", "shape", "step_kind", "n_devices",
        "hlo_flops_per_device", "hlo_bytes_per_device",
        "useful_flops_ratio",
    )}, indent=2))
    print(f"roofline: compute {r['compute_s']:.3e}s | "
          f"memory {r['memory_s']:.3e}s | "
          f"collective {r['collective_s']:.3e}s  "
          f"-> bound by {r['dominant']}")
    print("collectives:", json.dumps(record["collectives"]["counts"]))
    mem = record["memory"]
    print(f"per-device HBM: args "
          f"{mem['argument_size_in_bytes'] / 2**30:.2f} GiB + temps "
          f"{mem['temp_size_in_bytes'] / 2**30:.2f} GiB")


if __name__ == "__main__":
    main()
