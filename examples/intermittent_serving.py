"""End-to-end driver (assignment deliverable b): intermittent multi-task
serving with batched requests — the paper's §9.2 visual-sensing experiment,
reproduced with the live ServeEngine.

Two visual tasks share one batteryless device: "sign recognition" (bigger
CNN, longer deadline) and "shape recognition" (smaller CNN, tighter
deadline).  Requests arrive as a camera stream; a solar harvester powers
the device.  Zygarde's unit-granular imprecise scheduling is compared with
SONIC-style EDF and round-robin — the paper's claims:

  * EDF starves the longer task; RR wastes time and schedules very little;
  * Zygarde re-prioritises at unit boundaries and schedules the most jobs,
    with accuracy within ~2% of end-to-end execution.

    PYTHONPATH=src python examples/intermittent_serving.py
"""
import numpy as np

from repro.core import energy
from repro.core.agile import AgileCNN
from repro.data import make_dataset
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import train_agile_cnn

N_REQ = 25


def build(name: str, seed: int):
    ds = make_dataset(name, n_train=384, n_test=128, seed=seed)
    t = train_agile_cnn(ds, epochs=3, n_pairs=768, seed=seed)
    return ds, AgileCNN(t.cfg, t.params, t.bank)


def main() -> None:
    print("training the two visual tasks ...")
    # cifar100 (5-way) plays the sign recogniser; vww (2-way) the shapes
    sign_ds, sign = build("cifar100", seed=0)
    shape_ds, shape = build("vww", seed=1)

    harvester = energy.calibrate_harvester(0.71, 0.35, name="solar")

    def requests(ds, n=N_REQ, period=1.0):
        return [
            Request(ds.x_test[i], int(ds.y_test[i]), release=i * period)
            for i in range(n)
        ]

    print(f"\nserving 2 tasks x {N_REQ} requests on solar (eta=0.71)")
    print("policy      scheduled  correct  optional  reboots  idle-s")
    results = {}
    for policy in ("edf", "rr", "zygarde"):
        engine = ServeEngine(
            [sign, shape], harvester, eta=0.71,
            config=ServeConfig(
                policy=policy, period=1.0, deadline=2.0,
                horizon=N_REQ + 5.0, adapt=(policy == "zygarde"),
                unit_time=np.full(max(sign.n_units, shape.n_units), 0.22),
                unit_energy=np.full(max(sign.n_units, shape.n_units), 7e-3),
                seed=3,
            ),
        )
        res = engine.run([requests(sign_ds), requests(shape_ds)])
        results[policy] = res
        print(f"{policy:10s} {res.scheduled:6d}/{res.released:<4d} "
              f"{res.correct:7d} {res.optional_units:9d} "
              f"{res.reboots:8d} {res.idle_no_energy:7.1f}")

    zyg, edf, rr = results["zygarde"], results["edf"], results["rr"]
    print(f"\nZygarde schedules {zyg.scheduled - edf.scheduled:+d} jobs vs "
          f"EDF and {zyg.scheduled - rr.scheduled:+d} vs RR "
          f"(paper §9.2: 93% vs 55% vs 11% of entered jobs)")


if __name__ == "__main__":
    main()
