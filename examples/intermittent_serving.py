"""End-to-end driver (assignment deliverable b): intermittent multi-task
serving with batched requests — the paper's §9.2 visual-sensing experiment,
reproduced with the live ServeEngine.

Two visual tasks share one batteryless device: "sign recognition" (bigger
CNN, longer deadline) and "shape recognition" (smaller CNN, tighter
deadline).  Requests arrive as a camera stream; a solar harvester powers
the device.  Zygarde's unit-granular imprecise scheduling is compared with
SONIC-style EDF and round-robin — the paper's claims:

  * EDF starves the longer task; RR wastes time and schedules very little;
  * Zygarde re-prioritises at unit boundaries and schedules the most jobs,
    with accuracy within ~2% of end-to-end execution.

A second act scales the same two-task workload to a 64-device fleet:
the replay fleet (precomputed job profiles through ``fleet.simulate``)
and the *live* fleet (:class:`FleetServeEngine` — real unit execution +
online centroid adaptation inside one jitted scan) are raced against the
scalar event loop, printing jobs/sec for all three.

    PYTHONPATH=src python examples/intermittent_serving.py
"""
import time

import argparse

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.agile import AgileCNN
from repro.core.scheduler import TaskSpec
from repro.data import make_dataset
from repro.serve import FleetServeEngine, Request, ServeConfig, ServeEngine
from repro.train import train_agile_cnn

N_REQ = 25
N_DEV = 64


def build(name: str, seed: int):
    ds = make_dataset(name, n_train=384, n_test=128, seed=seed)
    t = train_agile_cnn(ds, epochs=3, n_pairs=768, seed=seed)
    return ds, AgileCNN(t.cfg, t.params, t.bank)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="§9.2 visual-sensing serving: zygarde vs edf vs rr")
    ap.add_argument("--requests", type=int, default=N_REQ)
    args = ap.parse_args()
    n_req = args.requests
    print("training the two visual tasks ...")
    # cifar100 (5-way) plays the sign recogniser; vww (2-way) the shapes
    sign_ds, sign = build("cifar100", seed=0)
    shape_ds, shape = build("vww", seed=1)

    harvester = energy.calibrate_harvester(0.71, 0.35, name="solar")

    def requests(ds, n=n_req, period=1.0):
        return [
            Request(ds.x_test[i], int(ds.y_test[i]), release=i * period)
            for i in range(n)
        ]

    def config(policy):
        return ServeConfig(
            policy=policy, period=1.0, deadline=2.0,
            horizon=n_req + 5.0, adapt=(policy == "zygarde"),
            unit_time=np.full(max(sign.n_units, shape.n_units), 0.22),
            unit_energy=np.full(max(sign.n_units, shape.n_units), 7e-3),
            seed=3,
        )

    print(f"\nserving 2 tasks x {n_req} requests on solar (eta=0.71)")
    print("policy      scheduled  correct  optional  reboots  idle-s")
    results = {}
    scalar_rate = 0.0
    for policy in ("edf", "rr", "zygarde"):
        engine = ServeEngine([sign, shape], harvester, eta=0.71,
                             config=config(policy))
        t0 = time.perf_counter()
        res = engine.run([requests(sign_ds), requests(shape_ds)])
        if policy == "zygarde":
            scalar_rate = res.released / (time.perf_counter() - t0)
        results[policy] = res
        print(f"{policy:10s} {res.scheduled:6d}/{res.released:<4d} "
              f"{res.correct:7d} {res.optional_units:9d} "
              f"{res.reboots:8d} {res.idle_no_energy:7.1f}")

    zyg, edf, rr = results["zygarde"], results["edf"], results["rr"]
    print(f"\nZygarde schedules {zyg.scheduled - edf.scheduled:+d} jobs vs "
          f"EDF and {zyg.scheduled - rr.scheduled:+d} vs RR "
          f"(paper §9.2: 93% vs 55% vs 11% of entered jobs)")

    # ---- act two: the same workload at fleet scale ----------------------
    print(f"\nscaling to {N_DEV} devices (zygarde, per-device solar seeds)")
    seeds = list(range(N_DEV))

    # replay fleet: precomputed job profiles through the batched simulator
    def replay_task(model, ds, tid):
        profs = model.profile_batch(ds.x_test[:n_req], ds.y_test[:n_req])
        return TaskSpec(
            task_id=tid, period=1.0, deadline=2.0,
            unit_time=np.full(model.n_units, 0.22),
            unit_energy=np.full(model.n_units, 7e-3),
            profiles=list(profs),
        )

    grid = fleet.SweepGrid(
        task=(replay_task(sign, sign_ds, 0), replay_task(shape, shape_ds, 1)),
        policies=("zygarde",), etas=(0.71,), harvesters=(harvester,),
        capacitors=(energy.Capacitor(),), seeds=tuple(seeds),
        horizon=n_req + 5.0,
    )
    rcfg, statics, _ = fleet.build(grid)
    fleet.simulate_fleet(rcfg, statics).released.block_until_ready()
    t0 = time.perf_counter()
    rres = fleet.simulate_fleet(rcfg, statics)
    rres.released.block_until_ready()
    replay_rate = float(np.asarray(rres.released).sum()) / (
        time.perf_counter() - t0)

    # live fleet: real unit execution + centroid adaptation in the scan
    feng = FleetServeEngine([sign, shape], harvester, eta=0.71,
                            config=config("zygarde"))
    streams = [requests(sign_ds), requests(shape_ds)]
    feng.run(streams, n_devices=N_DEV, seeds=seeds)       # warm-up: compile
    fres = feng.run(streams, n_devices=N_DEV, seeds=seeds)
    live_rate = fres.jobs_per_sec

    print(f"{'scalar live loop':18s} {scalar_rate:10.1f} jobs/s  (1 device)")
    print(f"{'fleet replay':18s} {replay_rate:10.1f} jobs/s  "
          f"({N_DEV} devices)")
    print(f"{'fleet live':18s} {live_rate:10.1f} jobs/s  "
          f"({N_DEV} devices, adapt on)")
    assert live_rate > scalar_rate and replay_rate > scalar_rate, \
        "fleet paths should outrun the scalar event loop"
    assert int(np.asarray(fres.fleet.scheduled).sum()) > 0


if __name__ == "__main__":
    main()
