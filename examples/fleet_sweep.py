"""Fleet sweep quickstart: the paper's scheduler grid in one jitted call.

Reproduces the shape of Figs. 17-20 (policy comparison) and Fig. 25 (eta
sensitivity) by simulating a policy × eta × seed grid of intermittently
powered devices with :func:`repro.fleet.sweep`, then prints the
scheduled-job rate per (policy, eta) cell averaged over seeds.

Run: ``PYTHONPATH=src python examples/fleet_sweep.py``
"""
from __future__ import annotations

import argparse
from collections import defaultdict

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec


def make_task(n_jobs=40, n_units=4, exit_at=1):
    """Periodic sensing task: 4-unit agile DNN, utility test passes after
    unit `exit_at` (so 1 unit is mandatory, the rest optional)."""
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def main() -> None:
    ap = argparse.ArgumentParser(
        description="policy × eta × seed fleet sweep in one jitted call")
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--horizon", type=float, default=40.0)
    args = ap.parse_args()
    grid = fleet.SweepGrid(
        task=make_task(),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.2, 0.5, 0.8, 1.0),
        harvesters=(energy.Harvester("solar", 0.95, 0.95, 0.08),),
        seeds=tuple(range(args.seeds)),
        horizon=args.horizon,
    )
    res, meta = fleet.sweep(grid)
    print(f"simulated {len(meta)} devices in one jitted call")

    cells = defaultdict(list)
    for i, m in enumerate(meta):
        rate = float(res.scheduled[i]) / max(float(res.released[i]), 1.0)
        cells[(m["policy"], m["eta"])].append(rate)

    print(f"{'policy':>8} " + " ".join(f"eta={e:<4}" for e in grid.etas))
    for pol in grid.policies:
        row = [np.mean(cells[(pol, e)]) for e in grid.etas]
        print(f"{pol:>8} " + " ".join(f"{r:7.2f}" for r in row))


if __name__ == "__main__":
    main()
