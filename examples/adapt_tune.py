"""Online policy search: tune Zygarde's scheduler knobs per deployment.

The paper's scheduler ships constants — eta measured once from the
harvester trace, E_opt fixed at 70% of capacity.  This example closes the
loop instead: ``repro.adapt`` treats the vectorized fleet simulator as a
batched objective (one jitted call scores a whole candidate population
against a seeded 3-harvester-pattern × seed grid) and searches the
(eta, E_opt-fraction) space with an evolution strategy.  The tuned point
beats the paper-default constants on fleet-simulated on-time accuracy.

Run: ``PYTHONPATH=src python examples/adapt_tune.py``
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import adapt
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec


def make_task(n_jobs=30, n_units=4, exit_at=1, correct_from=2):
    """Periodic sensing task with accuracy headroom: the utility test is
    willing to exit after unit 1, but predictions only become correct from
    unit 2 — running optional units buys accuracy when energy allows, so
    the energy gate's aggressiveness genuinely matters."""
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    prof = JobProfile(margins, passes, correct)
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def main() -> None:
    ap = argparse.ArgumentParser(
        description="tune (eta, E_opt) with the fleet-batched objective")
    ap.add_argument("--budget", type=int, default=128)
    ap.add_argument("--driver", default="es",
                    choices=sorted(adapt.DRIVERS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    problem = adapt.TuneProblem(
        task=make_task(),
        harvesters=(energy.Harvester("solar", 0.95, 0.95, 0.08),
                    energy.Harvester("rf", 0.85, 0.85, 0.05),
                    energy.Harvester("piezo", 0.90, 0.90, 0.06)),
        seeds=(0, 1),
        horizon=30.0,
    )
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))

    default = problem.default_params()
    default_score = problem.score(default)
    print(f"paper defaults  eta={default['eta']:.3f} "
          f"e_opt_fraction={default['e_opt_fraction']:.2f}  "
          f"on-time accuracy={default_score:.4f}")

    result = adapt.tune(problem.objective(), space, budget=args.budget,
                        driver=args.driver, seed=args.seed)
    print(f"ES-tuned        eta={result.best_params['eta']:.3f} "
          f"e_opt_fraction={result.best_params['e_opt_fraction']:.2f}  "
          f"on-time accuracy={result.best_score:.4f} "
          f"({result.n_evals} fleet-evaluated candidates)")
    gain = result.best_score - default_score
    print(f"gain: +{gain:.4f} on-time accuracy "
          f"({100 * gain / max(default_score, 1e-9):.1f}% relative)")
    assert result.best_score > default_score

    print("\nsearch trajectory (best score after each objective call):")
    for h in result.history:
        print(f"  evals={h['n_evals']:>4}  best={h['best_score']:.4f}  "
              f"block_mean={h['block_mean']:.4f}")


if __name__ == "__main__":
    main()
