"""Quickstart: the full Zygarde pipeline in one script.

1. Train an agile CNN (siamese + layer-aware loss) on synthetic MNIST.
2. Build the per-unit semi-supervised k-means classifier bank and calibrate
   the utility thresholds.
3. Run early-exit inference with runtime centroid adaptation.
4. Schedule a job stream under intermittent power with the zeta_I scheduler
   and compare against EDF.

    PYTHONPATH=src python examples/quickstart.py
"""
import argparse

import numpy as np

from repro.core import energy
from repro.core.agile import AgileCNN
from repro.core.scheduler import SimConfig, TaskSpec, simulate
from repro.data import make_dataset
from repro.train import train_agile_cnn


def main() -> None:
    ap = argparse.ArgumentParser(
        description="full Zygarde pipeline: train, bank, infer, schedule")
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()
    # 1-2: network trainer (paper §6.1): train -> bank -> thresholds
    ds = make_dataset("mnist", n_train=384, n_test=192)
    print("training agile CNN (layer-aware loss) ...")
    trained = train_agile_cnn(ds, epochs=args.epochs, n_pairs=768,
                              batch_size=32)
    print(f"  loss: {trained.history[0]:.3f} -> {trained.history[-1]:.3f}")

    model = AgileCNN(trained.cfg, trained.params, trained.bank)

    # 3: early-exit inference + adaptation
    r = model.infer(ds.x_test[0], adapt=True)
    print(f"sample 0: predicted {r.prediction} (true {ds.y_test[0]}), "
          f"exited after {r.units_executed}/{model.n_units} units "
          f"(margin {r.margin:.3f}, adapted={r.adapted})")

    profiles = model.profile_batch(ds.x_test, ds.y_test)
    mand = np.array([p.mandatory_units() for p in profiles])
    acc = np.mean([p.correct[m - 1] for p, m in zip(profiles, mand)])
    print(f"test set: early-exit accuracy {acc:.2%}, "
          f"mean mandatory units {mand.mean():.2f}/{model.n_units} "
          f"({1 - mand.mean() / model.n_units:.0%} execution saved)")

    # 4: real-time scheduling under intermittent power
    n_units = model.n_units
    # full execution U = 0.9 on persistent power; the intermittent energy is
    # what pushes the effective utilisation past 1 (paper Figs 17-20 regime)
    task = TaskSpec(
        task_id=0, period=0.4, deadline=0.96,
        unit_time=np.full(n_units, 0.36 / n_units),
        unit_energy=np.full(n_units, 4e-3),
        profiles=profiles,
    )
    harvester = energy.calibrate_harvester(0.71, 0.4, name="solar")
    print("\npolicy      scheduled  correct  optional-units  reboots")
    for policy in ("edf", "edf-m", "zygarde"):
        res = simulate(
            [task], harvester, eta=0.71,
            sim=SimConfig(policy=policy,
                          horizon=len(profiles) * 0.4 + 3.0, seed=1),
        )
        print(f"{policy:10s} {res.scheduled:6d}/{res.released:<4d} "
              f"{res.correct:7d} {res.optional_units:15d} {res.reboots:8d}")


if __name__ == "__main__":
    main()
