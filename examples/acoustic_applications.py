"""Paper §9.1 — six real-life acoustic event-detection applications.

Each application is a binary acoustic event detector (target class vs
background) served by the Zygarde engine on its own harvester setup from
the paper's Table 6:

    app              source  placement/intermittence        eta
    car-detector     solar   roadside, passing clouds       0.80
    dog-monitor      solar   backyard, people block sun     0.60
    people-detector  solar   window, evening falloff        0.70
    baby-monitor     rf      bedroom, distance varies       0.65
    laundry-monitor  rf      utility room                   0.55
    printer-monitor  rf      office, heavy interference     0.40

Reproduced observations (paper Fig. 22): more intermittence => more missed
events and deadline misses; classification errors come from the classifier
and the utility test, event/deadline misses from the harvested energy.

    PYTHONPATH=src python examples/acoustic_applications.py
"""
import argparse

import numpy as np

from repro.core import energy
from repro.core.agile import AgileCNN
from repro.data import make_dataset
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import train_agile_cnn

APPS = (
    ("car-detector", "solar", 0.80, 0.50),
    ("dog-monitor", "solar", 0.60, 0.22),
    ("people-detector", "solar", 0.70, 0.38),
    ("baby-monitor", "rf", 0.65, 0.080),
    ("laundry-monitor", "rf", 0.55, 0.055),
    ("printer-monitor", "rf", 0.40, 0.040),
)

N_EVENTS = 30


def main() -> None:
    ap = argparse.ArgumentParser(
        description="paper §9.1 acoustic applications on six harvester setups")
    ap.add_argument("--events", type=int, default=N_EVENTS)
    args = ap.parse_args()
    n_events = args.events
    # one shared acoustic frontend: ESC-10-shaped binary event detector
    ds = make_dataset("vww", n_train=384, n_test=256, separability=1.2)
    print("training the acoustic event detector ...")
    trained = train_agile_cnn(ds, epochs=3, n_pairs=768)
    print(f"\n{'application':17s} {'src':5s} {'eta':4s} "
          f"sched  correct  misses  reboots")
    rows = []
    for i, (app, source, eta, power) in enumerate(APPS):
        model = AgileCNN(trained.cfg, trained.params, list(trained.bank))
        harv = energy.calibrate_harvester(eta, power, name=source)
        reqs = [
            Request(ds.x_test[j], int(ds.y_test[j]), release=j * 2.0)
            for j in range(n_events)
        ]
        engine = ServeEngine(
            [model], harv, eta,
            config=ServeConfig(
                policy="zygarde", period=2.0, deadline=3.0,
                horizon=n_events * 2.0 + 5.0, seed=100 + i,
                unit_time=np.full(model.n_units, 0.4),
                unit_energy=np.full(model.n_units, 8e-3),
            ),
        )
        res = engine.run([reqs])
        rows.append((app, eta, res))
        print(f"{app:17s} {source:5s} {eta:.2f} "
              f"{res.scheduled:3d}/{res.released:<3d} {res.correct:7d} "
              f"{res.deadline_misses:7d} {res.reboots:8d}")

    # paper Fig 22 observation: lower-eta / weaker harvesters miss more
    by_eta = sorted(rows, key=lambda r: r[1])
    worst, best = by_eta[0][2], by_eta[-1][2]
    print(f"\nmost intermittent app misses {worst.deadline_misses} vs "
          f"{best.deadline_misses} for the steadiest "
          f"(paper: shorter continuous energy => more deadline misses)")


if __name__ == "__main__":
    main()
