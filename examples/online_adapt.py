"""Online eta re-estimation on a nonstationary harvester, mid-trajectory.

The paper's deployment story: a batteryless device ships with constants —
eta measured from a reference trace, E_opt fixed at 70% of capacity — but
the harvesting pattern it actually meets is *nonstationary*.  This demo
drives one simulated device through three repeating supply regimes:

* **solar**  — steady full-power sun: predictable and rich.  Optional DNN
  units are free accuracy; the gate should be wide open.
* **RF**     — choppy ambient RF at ~30% duty: unpredictable, supply just
  covers the mandatory units.  Every optional unit is paid for out of the
  capacitor reserve that the next regime will need.
* **occluded** — near-blackout (rare sparse bursts): the device lives off
  whatever reserve it banked; each wasted optional tail converts
  one-for-one into deadline misses.

A static (eta, E_opt) point cannot be right in all three regimes: the
aggressive corner wins solar but bleeds the reserve, the conservative
corner protects the reserve but forfeits solar accuracy, and — because the
capacitor is large relative to the RF bursts — no stored-energy threshold
can tell "full because the sun is out" from "momentarily full before an
outage".  The online loop (:class:`repro.adapt.OnlineAdapter` on
:func:`repro.fleet.run_segments`) re-estimates eta from the observed trace
(EWMA over per-segment Eq. 3 measurements) and re-tunes E_opt from the
observed harvest-rate headroom and miss statistics, segment by segment,
*inside* the trajectory — and beats every constant on the tuned 10 x 10
(eta, E_opt-fraction) grid.

The **forecast arm** goes one step further: the feedback controller is
reactive (it follows the observed supply with an EWMA, paying for every
regime change at least once), while the repeating solar -> RF -> occluded
cycle is *predictable*.  :class:`repro.adapt.ForecastController` clusters
the observed supply windows online, learns each regime's duration and
successor, and sets E_opt — plus the per-unit exit thresholds — from the
*predicted* next window: the optional-unit gate closes and the mandatory
prefix shrinks *before* the blackout arrives, so the banked reserve covers
it.  On this trace the forecast arm must beat the feedback-only arm (the
assertion CI runs).

Run: ``PYTHONPATH=src python examples/online_adapt.py``
"""
from __future__ import annotations

import argparse

import numpy as np

from repro import adapt, fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec
from repro.core.utility import scalarized_objective
from repro.fleet import grid as fgrid

SEED = 11
P_ON = 0.06                  # harvest power in the ON state (W)
SOLAR_S, RF_S, OCC_S = 32, 40, 34   # seconds per regime
CYCLES = 3
HORIZON = float((SOLAR_S + RF_S + OCC_S) * CYCLES)
CAPACITANCE_F = 0.1          # large: RF bursts cannot fill it
MISS_WEIGHT = 1.5            # scalarization: a miss costs 1.5 corrects
SEGMENT_S = 2.5              # online adaptation period
FORECAST_WINDOW_S = 8.0      # clustering window (resolves the 3 regimes)
FORECAST_HORIZON_S = 10.0    # look-ahead the E_opt/exit_thr control plans for


def make_task() -> TaskSpec:
    """One periodic sensing task whose accuracy lives in the optional tail:
    the utility test is willing to exit after unit 1 (cheap mandatory
    part), but predictions only become correct at full depth — running the
    optional units is pure accuracy when energy allows, pure waste when it
    doesn't."""
    n_units = 5
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[1:] = True                  # utility test passes after unit 1
    correct = np.zeros(n_units, bool)
    correct[n_units - 1:] = True       # correct only at full depth
    prof = JobProfile(margins, passes, correct)
    return TaskSpec(
        task_id=0, period=1.0, deadline=1.3,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * (int(HORIZON) + 2),
    )


def nonstationary_trace(seed: int) -> np.ndarray:
    """solar -> RF -> occluded, repeated; one slot per second (+2 pad)."""
    rng = np.random.default_rng(seed)
    rf = energy.Harvester("rf", 0.50, 0.72, P_ON)        # ~30% duty, choppy
    occ = energy.Harvester("occluded", 0.20, 0.97, P_ON)  # rare sparse bursts
    segs = []
    for _ in range(CYCLES):
        segs.append(np.ones(SOLAR_S))
        segs.append(rf.sample_events(rng, RF_S, init=1))
        segs.append(occ.sample_events(rng, OCC_S, init=0))
    segs.append(np.zeros(2))
    return np.concatenate(segs).astype(np.float32)


def build_fleet(points, events) -> tuple:
    """One device per (eta, e_opt_fraction) point, all on the same trace."""
    task = make_task()
    cap = energy.Capacitor(capacitance_f=CAPACITANCE_F)
    # the Harvester here only contributes power_on/slot_s metadata — the
    # actual supply is the explicit nonstationary `events` trace
    harv = energy.Harvester("nonstationary", 0.5, 0.5, P_ON)
    devices = [
        fgrid.device_config(task, harv, eta, cap, policy="zygarde",
                            horizon=HORIZON, events=events,
                            e_opt_fraction=frac)
        for eta, frac in points
    ]
    statics = fleet.FleetStatics(queue_size=3, dt=0.025, horizon=HORIZON,
                                 slot_s=1.0)
    return fgrid.stack_configs(devices), statics


def score(res) -> np.ndarray:
    """On-time accuracy with the deadline-miss penalty (higher is better)."""
    return np.asarray(scalarized_objective(
        res.correct, res.released, res.deadline_misses,
        miss_weight=MISS_WEIGHT))


def run_demo(seed: int = SEED, verbose: bool = False) -> dict:
    events = nonstationary_trace(seed)

    # --- best static constants: tune (eta, E_opt) on the full trace ------- #
    grid_pts = [(eta, frac)
                for eta in np.linspace(0.1, 1.0, 10)
                for frac in np.linspace(0.05, 0.95, 10)]
    cfg, statics = build_fleet(grid_pts, events)
    static_res = fleet.simulate_fleet(cfg, statics)   # one jitted call
    static_scores = score(static_res)
    best = int(np.argmax(static_scores))

    # --- paper defaults: eta measured offline on the whole trace ---------- #
    eta0 = max(energy.eta_factor((events > 0).astype(np.int8)), 0.05)
    default_pt = (eta0, adapt.PAPER_E_OPT_FRACTION)
    cfg1, statics1 = build_fleet([default_pt], events)
    default_score = float(score(fleet.simulate_fleet(cfg1, statics1))[0])

    # --- online: same starting point, adapted mid-trajectory -------------- #
    adapter = adapt.OnlineAdapter(statics1, cfg1, rho=0.5, window_s=20.0,
                                  n_max=4, supply_window_s=5.0,
                                  supply_rho=0.7, e_opt_bounds=(0.05, 0.95),
                                  miss_target=0.1)
    online_res, _ = fleet.run_segments(
        cfg1, statics1, int(HORIZON / SEGMENT_S), hook=adapter.hook)
    online_score = float(score(online_res)[0])

    # --- forecast arm: anticipate the next regime, not just track it ------ #
    fc_adapter = adapt.OnlineAdapter(statics1, cfg1, controllers=[
        adapt.EtaController(rho=0.5, window_s=20.0, n_max=4),
        adapt.ForecastController(
            window_s=FORECAST_WINDOW_S, horizon_s=FORECAST_HORIZON_S,
            n_clusters=4, supply_window_s=5.0, supply_rho=0.7,
            e_opt_bounds=(0.05, 0.95), miss_target=0.1),
    ])
    forecast_res, _ = fleet.run_segments(
        cfg1, statics1, int(HORIZON / SEGMENT_S), hook=fc_adapter.hook)
    forecast_score = float(score(forecast_res)[0])

    out = dict(
        best_static=dict(eta=grid_pts[best][0], e_opt_fraction=grid_pts[best][1],
                         score=float(static_scores[best]),
                         correct=int(static_res.correct[best]),
                         misses=int(static_res.deadline_misses[best])),
        default=dict(eta=eta0, e_opt_fraction=adapt.PAPER_E_OPT_FRACTION,
                     score=default_score),
        online=dict(score=online_score,
                    correct=int(online_res.correct[0]),
                    misses=int(online_res.deadline_misses[0])),
        forecast=dict(score=forecast_score,
                      correct=int(forecast_res.correct[0]),
                      misses=int(forecast_res.deadline_misses[0])),
        released=int(online_res.released[0]),
        history=adapter.history,
        forecast_history=fc_adapter.history,
    )
    if verbose:
        b, o, f = out["best_static"], out["online"], out["forecast"]
        print(f"trace: {CYCLES} x (solar {SOLAR_S}s -> rf {RF_S}s -> "
              f"occluded {OCC_S}s), {out['released']} jobs")
        print(f"paper defaults  eta={eta0:.3f} e_opt=0.70       "
              f"score={default_score:+.4f}")
        print(f"best static     eta={b['eta']:.2f}  e_opt={b['e_opt_fraction']:.2f}   "
              f"score={b['score']:+.4f}  (correct={b['correct']}, "
              f"misses={b['misses']}; best of {len(grid_pts)} tuned points)")
        print(f"online feedback (starts at defaults)    "
              f"score={o['score']:+.4f}  (correct={o['correct']}, "
              f"misses={o['misses']})")
        print(f"online forecast (starts at defaults)    "
              f"score={f['score']:+.4f}  (correct={f['correct']}, "
              f"misses={f['misses']})")
        print(f"feedback - best static: {o['score'] - b['score']:+.4f}")
        print(f"forecast - feedback:    {f['score'] - o['score']:+.4f}")
        print("\nforecast trajectory (every 8th segment):")
        for h in out["forecast_history"][::8]:
            frac = h["e_opt_frac"]
            print(f"  t={h['t_end']:5.1f}s  eta_hat={h['eta_hat'][0]:.2f}  "
                  f"cluster={h['cluster'][0]}  conf={h['confidence'][0]:.2f}  "
                  f"pred_supply={h['pred_supply'][0]:.3f}  "
                  f"e_opt_frac={frac[0]:.2f}  depth={h['depth'][0]:.2f}  "
                  f"miss_rate={h['miss_rate'][0]:.2f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(
        description="online (eta, E_opt) re-estimation on a "
                    "nonstationary harvest trace")
    ap.add_argument("--seed", type=int, default=SEED)
    args = ap.parse_args()
    out = run_demo(seed=args.seed, verbose=True)
    assert out["online"]["score"] > out["best_static"]["score"], (
        "online adaptation should beat the best static constants")
    assert out["online"]["score"] > out["default"]["score"]
    assert out["forecast"]["score"] >= out["online"]["score"], (
        "the forecast-aware controller should beat the feedback-only one")
    print("\nonline re-estimation beats every static (eta, E_opt) constant "
          "on this nonstationary trace; anticipating the next regime beats "
          "reacting to the current one")


if __name__ == "__main__":
    main()
