"""Train an assigned-architecture transformer as an *agile* model.

Shows the framework's transformer path end to end on CPU:
1. LM-pretrain a reduced qwen1.5-0.5b for a few hundred steps
   (``repro.launch.train`` machinery, single host device).
2. Fit the per-unit k-means bank over mean-pooled hidden states on a
   synthetic sequence-classification task; calibrate utility thresholds.
3. Run early-exit inference through AgileTransformer — the same imprecise
   execution the serving engine schedules.

    PYTHONPATH=src python examples/train_agile_lm.py [--steps 200]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import kmeans as km
from repro.core import utility as util
from repro.core.agile import AgileTransformer
from repro.data import make_lm_tokens, make_token_dataset
from repro.models import transformer as T
from repro.train import make_train_step
from repro.train.optimizer import adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b").reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced) — {n / 1e6:.2f}M params, "
          f"{cfg.n_layers} layers, {cfg.n_units} Zygarde units")

    # 1. LM pre-training
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    stream = make_lm_tokens(cfg.vocab, args.seq, args.batch * args.steps)
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(
            stream[i * args.batch:(i + 1) * args.batch]
        )}
        params, opt, metrics = step(params, opt, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  lm-loss {float(metrics['loss']):.4f}")

    # 2. classifier bank on a 4-way sequence-classification task
    all_toks, all_y = make_token_dataset(cfg.vocab, args.seq, 4, 240,
                                         separability=3.0)
    toks, y = all_toks[:192], all_y[:192]
    test_toks, test_y = all_toks[192:], all_y[192:]
    feats = []
    x, enc = T.embed_inputs(cfg, params, {"tokens": jnp.asarray(toks)})
    for u in range(cfg.n_units):
        x, pooled = T.unit_forward(cfg, params, x, u, enc_out=enc)
        feats.append(np.asarray(pooled))
    bank = km.fit_bank(feats, y, n_sel=64)
    bank = util.calibrate_bank_thresholds(bank, feats, y, min_accuracy=0.9)
    accs = km.bank_accuracy(bank, feats, y)
    print("per-unit bank accuracy:", [round(a, 3) for a in accs])

    # 3. early-exit inference (held-out split of the same task)
    model = AgileTransformer(cfg, params, bank)
    units, correct = [], []
    for i in range(len(test_y)):
        r = model.infer(test_toks[i:i + 1], adapt=False)
        units.append(r.units_executed)
        correct.append(r.prediction == int(test_y[i]))
    print(f"early-exit: acc {np.mean(correct):.2%}, "
          f"mean units {np.mean(units):.2f}/{cfg.n_units} "
          f"({1 - np.mean(units) / cfg.n_units:.0%} compute saved)")


if __name__ == "__main__":
    main()
