"""Multi-task fleet sweep: two contending DNN streams per device.

Models the paper's multi-app deployments (§3, §5): an audio-style task
(fast period, tight deadline, shallow 3-unit network) and a camera-style
task (slower period, loose deadline, deeper 5-unit network) share one
harvested-energy budget on every device.  A policy × eta sweep then prints
the per-task on-time rate per policy — the ``FleetResult.task_*``
breakdown the task-set axis added — showing how the imprecise policies
protect the tight audio deadlines by sacrificing the camera task's
optional units, where EDF (full execution, no early exit) lets both
streams starve.

Run: ``PYTHONPATH=src python examples/fleet_multitask.py``
"""
from __future__ import annotations

import argparse
from collections import defaultdict

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec


def make_task(task_id, name, period, deadline, n_units, unit_t, exit_at,
              n_jobs=40):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    task = TaskSpec(
        task_id=task_id, period=period, deadline=deadline,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )
    return name, task


def main() -> None:
    ap = argparse.ArgumentParser(
        description="two-task fleet sweep: policy × eta grid")
    ap.add_argument("--seeds", type=int, default=6)
    ap.add_argument("--horizon", type=float, default=30.0)
    args = ap.parse_args()
    names_tasks = (
        # audio: keyword spotting — fast period, tight deadline, shallow net
        make_task(0, "audio", period=0.6, deadline=1.0, n_units=3,
                  unit_t=0.1, exit_at=0, n_jobs=60),
        # camera: image classification — slow, slack-rich, deep net
        make_task(1, "camera", period=1.6, deadline=4.0, n_units=5,
                  unit_t=0.15, exit_at=1),
    )
    names = [n for n, _ in names_tasks]
    grid = fleet.SweepGrid(
        task=[t for _, t in names_tasks],
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 0.8, 1.0),
        harvesters=(energy.Harvester("solar", 0.95, 0.95, 0.08),),
        seeds=tuple(range(args.seeds)),
        horizon=args.horizon,
    )
    res, meta = fleet.sweep(grid)
    print(f"simulated {len(meta)} devices × {meta[0]['n_tasks']} tasks "
          "in one jitted call\n")

    released = np.asarray(res.task_released, np.float64)
    scheduled = np.asarray(res.task_scheduled, np.float64)
    on_time = scheduled / np.maximum(released, 1.0)      # (D, K)

    cells = defaultdict(list)
    for i, m in enumerate(meta):
        cells[m["policy"]].append(on_time[i])

    header = " ".join(f"{n:>8}" for n in names)
    print(f"{'policy':>8} {header}   (per-task on-time rate, "
          "mean over eta × seed)")
    for pol in grid.policies:
        rates = np.mean(cells[pol], axis=0)
        row = " ".join(f"{r:8.2f}" for r in rates)
        print(f"{pol:>8} {row}")

    zyg = np.mean(cells["zygarde"], axis=0)
    edf = np.mean(cells["edf"], axis=0)
    print(f"\nzygarde keeps the tight {names[0]} deadlines at "
          f"{zyg[0]:.2f} on-time vs edf's {edf[0]:.2f} by exiting the "
          f"{names[1]} stream early when energy is scarce.")


if __name__ == "__main__":
    main()
