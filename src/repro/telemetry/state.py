"""The jit-safe telemetry carry: fixed-size ring buffers + counters.

Zygarde's claims are *rate* claims — tasks scheduled on time, misses
avoided, accuracy per joule — but the scan frontends only expose end-of-run
aggregates, and everything the adaptation controllers react to vanishes
when the segment scan completes.  This module defines the observability
state that rides *alongside* the simulation carry through every scan:

* :class:`TelemetryConfig` — hashable static configuration (a ``jax.jit``
  static argument).  Passing ``None`` wherever a config is accepted keeps
  the instrumented code paths compiled out entirely: the disabled hot path
  is byte-for-byte the pre-telemetry program.
* :class:`Telemetry` — the per-device pytree of counters, running
  sums/extrema, an exit-depth histogram, and one fixed-size event ring
  buffer.  No device axis; ``jax.vmap`` adds it, exactly like
  :class:`repro.core.step.DeviceCarry` — so the fleet telemetry is a
  ``(D, ...)`` pytree that checkpoints and shards like a segment carry
  (:func:`repro.launch.sharding.shard_fleet_carry` applies unchanged).
* :func:`record_step` — folds one transition's
  :class:`repro.core.step.StepEvents` into the telemetry.  Strictly
  read-only with respect to the simulation: events are derived from carry
  *deltas* (:func:`repro.core.step.step_events`), so enabling telemetry
  cannot change a single bit of ``FleetResult`` — the parity tests in
  ``tests/test_telemetry.py`` assert exact equality, not tolerances.

Ring-buffer semantics: ``ring_head`` counts every event ever pushed; the
write index is ``head % ring_size``, so overflow overwrites the oldest
entry while the head keeps the true total (the host export reports how many
were dropped).  At most one event per kind is pushed per step, carrying the
step's aggregate as its value — misses this step, mean completion slack,
capacitor energy at power-down.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import step as S

_F32 = jnp.float32
_I32 = jnp.int32

#: event kinds recorded in the ring buffer (ring_kind values)
EVENT_KINDS = {
    "miss": 0,         # val = deadline misses this step
    "complete": 1,     # val = mean deadline slack of this step's completions
    "power_fail": 2,   # val = capacitor energy at the power-down
    "reboot": 3,       # val = reboots this step
    "knob_update": 4,  # val = 1.0; host-pushed at adaptation boundaries
}
EVENT_NAMES = {v: k for k, v in EVENT_KINDS.items()}


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Hashable static telemetry configuration (jit static argument).

    ``level`` selects the collection tier:

    * ``"counters"`` (default) — the always-on tier: event counters,
      occupancy and energy running stats.  Everything is either telescoped
      from the simulation carry's own accumulators or read from registers
      the step already produced, so the scan gains three narrow output
      columns and nothing else — measured indistinguishable from the
      uninstrumented scan (and gated < 5% in CI).  Retirement slack, the
      exit histogram, and the event rings stay at their init values.
    * ``"full"`` — additionally collects per-retirement slack statistics,
      the exit-depth histogram, and the event ring buffers.  This tier
      needs per-step event descriptors and costs a measured double-digit
      percentage on the vmap fleet path (reported as
      ``telemetry_full_overhead_pct`` by ``benchmarks/bench_fleet.py``);
      use it for debugging and trace export, not always-on monitoring.

    ``ring_size`` bounds the per-device event ring; counters and histograms
    are unaffected by it.  ``None`` (no config at all) — not a field here —
    is how callers disable telemetry; a constructed config is always "on".
    """

    ring_size: int = 256
    level: str = "counters"

    def __post_init__(self):
        if self.ring_size < 1:
            raise ValueError(
                f"ring_size must be >= 1, got {self.ring_size}")
        if self.level not in ("counters", "full"):
            raise ValueError(
                f"level must be 'counters' or 'full', got {self.level!r}")


class Telemetry(NamedTuple):
    """Per-device telemetry carry (no device axis; vmap adds it).

    Counters accumulate the same deltas the step core's ``m_*`` metric
    accumulators do, so cumulative telemetry reconciles exactly against the
    carry's accumulators (``sum(m_misses)`` etc.) at any segment boundary.
    (The finalized :class:`repro.core.step.StepResult` additionally flushes
    still-in-flight jobs and never-released jobs at the horizon, which no
    step-wise counter can see.)
    """

    # event counters (i32 scalars)
    c_release: jax.Array     # jobs released
    c_miss: jax.Array        # deadline misses
    c_sched: jax.Array       # on-time completions
    c_retired: jax.Array     # queue slots retired (completed or expired)
    c_power_fail: jax.Array  # run -> off transitions (capacitor exhausted)
    c_reboot: jax.Array      # reboots after a power-down
    c_knob: jax.Array        # controller knob updates (host-pushed)
    # deadline slack at retirement (f32; slack < 0 means the job missed)
    slack_sum: jax.Array
    slack_min: jax.Array     # +inf until the first retirement
    # exit-depth histogram over retired jobs, (U + 1,) i32:
    # bins 0..U-1 = utility-test exit at that unit, bin U = never exited
    exit_hist: jax.Array
    # queue occupancy / capacitor energy running stats
    occ_sum: jax.Array       # i32: sum over steps of active slots
    occ_max: jax.Array       # i32
    energy_sum: jax.Array    # f32: sum over steps of capacitor energy
    energy_min: jax.Array    # f32
    n_steps: jax.Array       # i32: steps observed
    # the event ring buffer, (R,) each + the monotone head counter
    ring_t: jax.Array        # f32 event times
    ring_kind: jax.Array     # i32 EVENT_KINDS values
    ring_val: jax.Array      # f32 per-kind payload
    ring_head: jax.Array     # i32: total events ever pushed


def init_telemetry(tcfg: TelemetryConfig, n_units: int) -> Telemetry:
    """The t=0 telemetry for ONE device (``n_units`` = padded unit depth U;
    the exit histogram gets U+1 bins, the last one for never-exited jobs).
    Call under ``vmap`` — or broadcast via :func:`init_fleet_telemetry` —
    for a fleet."""
    r = tcfg.ring_size
    zero_i = jnp.zeros((), _I32)
    zero_f = jnp.zeros((), _F32)
    return Telemetry(
        c_release=zero_i, c_miss=zero_i, c_sched=zero_i, c_retired=zero_i,
        c_power_fail=zero_i, c_reboot=zero_i, c_knob=zero_i,
        slack_sum=zero_f,
        slack_min=jnp.full((), jnp.inf, _F32),
        exit_hist=jnp.zeros((n_units + 1,), _I32),
        occ_sum=zero_i, occ_max=zero_i,
        energy_sum=zero_f,
        energy_min=jnp.full((), jnp.inf, _F32),
        n_steps=zero_i,
        ring_t=jnp.zeros((r,), _F32),
        ring_kind=jnp.full((r,), -1, _I32),
        ring_val=jnp.zeros((r,), _F32),
        ring_head=zero_i,
    )


def init_fleet_telemetry(tcfg: TelemetryConfig,
                         cfg: S.StepParams) -> Telemetry:
    """Stacked ``(D, ...)`` telemetry for every device in a fleet config."""
    tel = init_telemetry(tcfg, int(cfg.unit_time.shape[-1]))
    d = cfg.n_devices
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (d,) + leaf.shape), tel)


def _push(tel: Telemetry, mask, kind: int, val, t) -> Telemetry:
    """Append one event to the ring where ``mask`` holds (jit-safe: the
    write is a masked self-assignment when it doesn't)."""
    idx = jnp.mod(tel.ring_head, tel.ring_t.shape[0])
    return tel._replace(
        ring_t=tel.ring_t.at[idx].set(
            jnp.where(mask, jnp.asarray(t, _F32), tel.ring_t[idx])),
        ring_kind=tel.ring_kind.at[idx].set(
            jnp.where(mask, kind, tel.ring_kind[idx])),
        ring_val=tel.ring_val.at[idx].set(
            jnp.where(mask, jnp.asarray(val, _F32), tel.ring_val[idx])),
        ring_head=tel.ring_head + mask.astype(_I32),
    )


def record_step(tel: Telemetry, ev: S.StepEvents, t) -> Telemetry:
    """Fold one transition's events into the telemetry (per device).

    Pure accumulation — no reads of the simulation carry, so the step
    numerics cannot be perturbed.  Rings receive at most one event per kind
    per step, carrying the step aggregate as the payload.
    """
    n_bins = tel.exit_hist.shape[0]
    depth = jnp.where(ev.exit_depth >= 0,
                      jnp.clip(ev.exit_depth, 0, n_bins - 2), n_bins - 1)
    hist_inc = jnp.sum(
        ev.retired[:, None] & (depth[:, None] == jnp.arange(n_bins)[None, :]),
        axis=0).astype(_I32)
    n_retired = jnp.sum(ev.retired).astype(_I32)
    slack_step = jnp.sum(jnp.where(ev.retired, ev.slack, 0.0))
    slack_min_step = jnp.min(jnp.where(ev.retired, ev.slack, jnp.inf))

    tel = tel._replace(
        c_release=tel.c_release + ev.releases,
        c_miss=tel.c_miss + ev.misses,
        c_sched=tel.c_sched + ev.scheduled,
        c_retired=tel.c_retired + n_retired,
        c_power_fail=tel.c_power_fail + ev.power_fail.astype(_I32),
        c_reboot=tel.c_reboot + ev.reboots,
        slack_sum=tel.slack_sum + slack_step,
        slack_min=jnp.minimum(tel.slack_min, slack_min_step),
        exit_hist=tel.exit_hist + hist_inc,
        occ_sum=tel.occ_sum + ev.queue_occ,
        occ_max=jnp.maximum(tel.occ_max, ev.queue_occ),
        energy_sum=tel.energy_sum + ev.energy,
        energy_min=jnp.minimum(tel.energy_min, ev.energy),
        n_steps=tel.n_steps + 1,
    )
    mean_slack = slack_step / jnp.maximum(n_retired, 1)
    tel = _push(tel, ev.misses > 0, EVENT_KINDS["miss"],
                ev.misses.astype(_F32), t)
    tel = _push(tel, n_retired > 0, EVENT_KINDS["complete"], mean_slack, t)
    tel = _push(tel, ev.power_fail, EVENT_KINDS["power_fail"], ev.energy, t)
    tel = _push(tel, ev.reboots > 0, EVENT_KINDS["reboot"],
                ev.reboots.astype(_F32), t)
    return tel


def record_anytime_step(tel: Telemetry, *, releases, misses, scheduled,
                        retired, slack_sum, slack_min, depth_hist,
                        occupancy, energy, t) -> Telemetry:
    """Fold one anytime-serving engine step into the telemetry.

    The continuous-batching engine (:mod:`repro.serve.anytime`) has no
    :class:`repro.core.step.StepEvents` — its transition produces the
    aggregates directly: ``releases`` = admissions, ``scheduled`` /
    ``misses`` = on-time / late completions, ``depth_hist`` = a
    ``(U + 1,)`` i32 increment of per-*token* selected depths (bins
    0..U-1 = exited at that unit, bin U = ran full depth), ``slack_*``
    over this step's completions (``slack_min = +inf`` when none),
    ``occupancy`` = busy batch slots.  Same ring semantics as
    :func:`record_step`: at most one event per kind per step.
    """
    releases = jnp.asarray(releases, _I32)
    misses = jnp.asarray(misses, _I32)
    scheduled = jnp.asarray(scheduled, _I32)
    retired = jnp.asarray(retired, _I32)
    occupancy = jnp.asarray(occupancy, _I32)
    tel = tel._replace(
        c_release=tel.c_release + releases,
        c_miss=tel.c_miss + misses,
        c_sched=tel.c_sched + scheduled,
        c_retired=tel.c_retired + retired,
        slack_sum=tel.slack_sum + jnp.asarray(slack_sum, _F32),
        slack_min=jnp.minimum(tel.slack_min,
                              jnp.asarray(slack_min, _F32)),
        exit_hist=tel.exit_hist + jnp.asarray(depth_hist, _I32),
        occ_sum=tel.occ_sum + occupancy,
        occ_max=jnp.maximum(tel.occ_max, occupancy),
        energy_sum=tel.energy_sum + jnp.asarray(energy, _F32),
        energy_min=jnp.minimum(tel.energy_min,
                               jnp.asarray(energy, _F32)),
        n_steps=tel.n_steps + 1,
    )
    mean_slack = jnp.asarray(slack_sum, _F32) / jnp.maximum(retired, 1)
    tel = _push(tel, misses > 0, EVENT_KINDS["miss"],
                misses.astype(_F32), t)
    tel = _push(tel, retired > 0, EVENT_KINDS["complete"], mean_slack, t)
    return tel


@jax.jit
def record_knob_updates(tel: Telemetry, changed, t) -> Telemetry:
    """Host-boundary event: an adaptation hook rewrote the tunable config
    fields of the devices in ``changed`` (a ``(D,)`` bool mask).  Pushed by
    :func:`repro.fleet.simulator.run_segments` after each hook call."""
    def per_device(tl, ch):
        tl = tl._replace(c_knob=tl.c_knob + ch.astype(_I32))
        return _push(tl, ch, EVENT_KINDS["knob_update"], 1.0, t)

    return jax.vmap(per_device, in_axes=(0, 0))(
        tel, jnp.asarray(changed, bool))
