"""Low-overhead collection paths behind the telemetry-enabled scans.

The naive way to collect telemetry is to fold
:func:`~repro.telemetry.state.record_step` inside the scan, but its
per-step ring scatters and event reductions cost multiples of the
simulation itself on the vmap fleet path.  On a CPU backend the scan is
dispatch-bound, not byte-bound: every extra unfused kernel inside the
``lax.scan`` body costs roughly the same handful of microseconds per step
regardless of how little data it touches, so the only thing that matters
is how few extra operations and output columns the instrumented scan
carries.  This module implements the two collection tiers of
:class:`~repro.telemetry.state.TelemetryConfig` accordingly:

* ``"counters"`` — the scan emits three registers the step already
  computed (capacitor energy, active-slot count, the off-state flag) and
  every counter is either telescoped from the carry's own monotone
  accumulators (summing per-step deltas of an accumulator collapses to
  end-minus-start) or reduced from those columns once per segment,
  outside the scan body.  Measured indistinguishable from the
  uninstrumented scan.
* ``"full"`` — the scan additionally runs the descriptor-emitting step
  twin (:class:`repro.core.step.StepTrace`) and bit-packs every per-step
  event scalar into one or two ``int32`` columns (:class:`PackSpec`),
  plus two f32 slack columns.  Dense statistics reduce once per segment
  inside the same jit; the rare ring/histogram events are appended
  host-side by a sparse ``np.nonzero``-driven fold — O(events), not
  O(T·D).

Slack columns carry raw ``q_deadline`` register reads (summed / min'd
over the step's retirement channels); the ``- t_end`` normalisation is
applied in the segment reduction.  ``min`` commutes with the subtraction
exactly (float rounding of a monotone shift preserves order), and the
sum differs from the reference only by summation order.

The result is equivalent to folding ``record_step`` every step — ints
exact, float accumulators to summation-order tolerance — which
``tests/test_telemetry.py`` pins against the in-scan reference fold.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax.numpy as jnp

from .state import Telemetry

_F32 = jnp.float32
_I32 = jnp.int32

#: low bits of a descriptor word: exited + 2 (0 = no event)
_EXIT_MASK = 0x3F
#: per-step per-device miss/reboot ring payloads are packed in 4 bits
_EVB = 4


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static bit layout of the full-tier descriptor columns.

    Column 0 always holds the header — power-fail flag, retirement count,
    misses, reboots, occupancy — followed by one ``depth`` field per
    retirement channel (``2K + 1`` channels: the job-done completion plus
    a per-task eviction and expiry; each holds ``exit depth + 1``, 0 = no
    event).  Depth fields that do not fit in the 31 usable bits of a
    column spill into further columns.
    """

    n_tasks: int
    n_bins: int
    b_nret: int
    b_occ: int
    b_depth: int
    off_miss: int
    off_dreb: int
    off_occ: int
    #: per retirement channel: (column index, bit offset)
    depth_fields: tuple
    n_cols: int

    @property
    def n_channels(self) -> int:
        return 2 * self.n_tasks + 1


@functools.lru_cache(maxsize=None)
def make_pack_spec(n_tasks: int, queue_size: int, n_bins: int) -> PackSpec:
    if 2 * n_tasks >= (1 << _EVB):
        raise ValueError(
            f"per-step miss payload needs more than {_EVB} bits "
            f"for {n_tasks} tasks")
    b_nret = max(1, int(np.ceil(np.log2(2 * n_tasks + 2))))
    b_occ = max(1, int(np.ceil(np.log2(queue_size + 1))))
    b_depth = max(1, int(np.ceil(np.log2(n_bins + 1))))
    off_miss = 1 + b_nret
    off_dreb = off_miss + _EVB
    off_occ = off_dreb + _EVB
    col, off = 0, off_occ + b_occ
    fields = []
    for _ in range(2 * n_tasks + 1):
        if off + b_depth > 31:
            col, off = col + 1, 0
        fields.append((col, off))
        off += b_depth
    return PackSpec(n_tasks=n_tasks, n_bins=n_bins, b_nret=b_nret,
                    b_occ=b_occ, b_depth=b_depth, off_miss=off_miss,
                    off_dreb=off_dreb, off_occ=off_occ,
                    depth_fields=tuple(fields), n_cols=col + 1)


def _telescope(tel: Telemetry, st0, st1, n_steps: int) -> Telemetry:
    """Counters the carry already accumulates: per-step deltas sum to
    end-minus-start, so these cost nothing inside the scan."""
    def tele(a1, a0):
        d = a1 - a0
        return (d if d.ndim == 1 else d.sum(-1)).astype(_I32)

    return tel._replace(
        c_release=tel.c_release + tele(st1.next_rel, st0.next_rel),
        c_miss=tel.c_miss + tele(st1.m_misses, st0.m_misses),
        c_sched=tel.c_sched + tele(st1.m_scheduled, st0.m_scheduled),
        c_reboot=tel.c_reboot + tele(st1.m_reboots, st0.m_reboots),
        n_steps=tel.n_steps + jnp.int32(n_steps),
    )


# --------------------------------------------------------------------- #
# "counters" tier
# --------------------------------------------------------------------- #

def emit_counters(new):
    """Per-step columns for the counters tier — registers the step body
    already produced (the occupancy sum fuses into it)."""
    occ = jnp.sum(new.q_active, axis=-1).astype(jnp.int8)
    return new.energy.astype(_F32), occ, new.was_off


def reduce_counters(tel: Telemetry, st0, st1, ys, n_steps: int) -> Telemetry:
    """Segment reduction for the counters tier (traced, post-scan)."""
    en, occ, woff = ys
    pf_first = (woff[0] & ~st0.was_off).astype(_I32)
    pf_rest = jnp.sum(woff[1:] & ~woff[:-1], axis=0).astype(_I32)
    tel = _telescope(tel, st0, st1, n_steps)
    return tel._replace(
        c_power_fail=tel.c_power_fail + pf_first + pf_rest,
        occ_sum=tel.occ_sum + jnp.sum(occ.astype(_I32), axis=0),
        occ_max=jnp.maximum(tel.occ_max, jnp.max(occ, axis=0).astype(_I32)),
        energy_sum=tel.energy_sum + jnp.sum(en, axis=0),
        energy_min=jnp.minimum(tel.energy_min, jnp.min(en, axis=0)),
    )


# --------------------------------------------------------------------- #
# "full" tier
# --------------------------------------------------------------------- #

def emit_full(spec: PackSpec, tr, st0, new):
    """Per-step full-tier columns: the packed descriptor ints plus the raw
    slack accumulators (sum / min of retiring ``q_deadline`` registers)."""
    channels = [(tr.complete > 0, tr.complete_dl, tr.complete)]
    for k in range(spec.n_tasks):
        channels.append((tr.evict[..., k] > 0, tr.evict_dl[..., k],
                         tr.evict[..., k]))
        channels.append((tr.expire[..., k] > 0, tr.expire_dl[..., k],
                         tr.expire[..., k]))
    nb = spec.n_bins
    nret = jnp.zeros(tr.complete.shape, _I32)
    ssum = jnp.zeros(tr.complete.shape, _F32)
    smin = jnp.full(tr.complete.shape, jnp.inf, _F32)
    depths = []
    for valid, dl, word in channels:
        exited = (word & _EXIT_MASK) - 2
        depth = jnp.where(exited >= 0, jnp.clip(exited, 0, nb - 2), nb - 1)
        depths.append(jnp.where(valid, depth + 1, 0))
        nret = nret + valid
        ssum = ssum + jnp.where(valid, dl, 0.0)
        smin = jnp.minimum(smin, jnp.where(valid, dl, jnp.inf))
    occ = jnp.sum(new.q_active, axis=-1).astype(_I32)
    miss = jnp.minimum(
        jnp.sum(new.m_misses - st0.m_misses, axis=-1).astype(_I32),
        (1 << _EVB) - 1)
    dreb = jnp.minimum((new.m_reboots - st0.m_reboots).astype(_I32),
                       (1 << _EVB) - 1)
    pf = (new.was_off & ~st0.was_off).astype(_I32)
    cols = [jnp.zeros(tr.complete.shape, _I32)
            for _ in range(spec.n_cols)]
    cols[0] = (pf | (nret << 1) | (miss << spec.off_miss)
               | (dreb << spec.off_dreb) | (occ << spec.off_occ))
    for dth, (ci, off) in zip(depths, spec.depth_fields):
        cols[ci] = cols[ci] | (dth << off)
    return (*[c.astype(_I32) for c in cols], ssum, smin,
            new.energy.astype(_F32))


def reduce_full(spec: PackSpec, tel: Telemetry, st0, st1, ys, i0,
                n_steps: int, dt: float):
    """Segment reduction for the full tier (traced, post-scan).  Returns
    the advanced telemetry plus the ``(T, D)`` ring-ingredient columns for
    :func:`fold_events_host` (the histogram is folded there too — retire
    events are rare, so the sparse host fold beats ``2K + 1`` extra dense
    reduction passes per histogram bin)."""
    *cols, ssum, smin, en = ys
    pk = cols[0]
    t_end = ((i0 + jnp.arange(n_steps)).astype(_F32) * dt + dt)[:, None]
    nret = (pk >> 1) & ((1 << spec.b_nret) - 1)
    occ = (pk >> spec.off_occ) & ((1 << spec.b_occ) - 1)
    evm = (1 << _EVB) - 1
    evt = (((pk >> spec.off_miss) & evm > 0).astype(jnp.int8)
           | ((nret > 0).astype(jnp.int8) << 1)
           | (pk & 1).astype(jnp.int8) << 2
           | ((pk >> spec.off_dreb) & evm > 0).astype(jnp.int8) << 3)
    tel = _telescope(tel, st0, st1, n_steps)
    tel = tel._replace(
        c_retired=tel.c_retired + jnp.sum(nret, axis=0),
        c_power_fail=tel.c_power_fail + jnp.sum(pk & 1, axis=0),
        slack_sum=tel.slack_sum
        + jnp.sum(ssum - nret.astype(_F32) * t_end, axis=0),
        slack_min=jnp.minimum(tel.slack_min, jnp.min(smin - t_end, axis=0)),
        occ_sum=tel.occ_sum + jnp.sum(occ, axis=0),
        occ_max=jnp.maximum(tel.occ_max, jnp.max(occ, axis=0)),
        energy_sum=tel.energy_sum + jnp.sum(en, axis=0),
        energy_min=jnp.minimum(tel.energy_min, jnp.min(en, axis=0)),
    )
    return tel, (*cols, ssum, en, evt)


def fold_events_host(spec: PackSpec, tel: Telemetry, ring_np, i0,
                     dt: float) -> Telemetry:
    """Sparse host-side fold of the rare per-step events into the ring
    buffers and the exit histogram.  ``ring_np`` holds the numpy ``(T, D)``
    packed columns + slack-sum + energy columns from :func:`reduce_full`.
    Cost is O(events) after one ``np.nonzero`` pass over the event bytes.
    """
    *cols, ssum, en, evt = ring_np
    tz, dz = np.nonzero(evt)
    w = evt[tz, dz]
    pk_e = cols[0][tz, dz]
    nret_e = (pk_e >> 1) & ((1 << spec.b_nret) - 1)
    miss_e = (pk_e >> spec.off_miss) & ((1 << _EVB) - 1)
    dreb_e = (pk_e >> spec.off_dreb) & ((1 << _EVB) - 1)

    ssum_e = ssum[tz, dz]
    en_e = en[tz, dz]

    # exit histogram from the depth fields of retire events
    hist = np.asarray(tel.exit_hist).copy()
    rmask = (w & 2) > 0
    rd_ = dz[rmask]
    dmask = (1 << spec.b_depth) - 1
    for ci, off in spec.depth_fields:
        dth = ((pk_e[rmask] if ci == 0
                else cols[ci][tz, dz][rmask]) >> off) & dmask
        has = dth > 0
        np.add.at(hist, (rd_[has], dth[has] - 1), 1)

    # ring append, preserving the reference push order: device-major,
    # then step, then kind (miss, complete, power_fail, reboot)
    kk, tk, dk, ei = [], [], [], []
    idx = np.arange(w.shape[0])
    for k in range(4):
        m = (w >> k) & 1 > 0
        kk.append(np.full(int(m.sum()), k, np.int64))
        tk.append(tz[m])
        dk.append(dz[m])
        ei.append(idx[m])
    kk, tk, dk, ei = map(np.concatenate, (kk, tk, dk, ei))
    order = np.lexsort((kk, tk, dk))
    kk, tk, dk, ei = kk[order], tk[order], dk[order], ei[order]

    head0 = np.asarray(tel.ring_head).astype(np.int64)
    rt = np.asarray(tel.ring_t).copy()
    rk = np.asarray(tel.ring_kind).copy()
    rv = np.asarray(tel.ring_val).copy()
    R = rt.shape[1]
    cnt = np.bincount(dk, minlength=head0.shape[0])
    starts = np.cumsum(cnt) - cnt
    j = head0[dk] + (np.arange(dk.shape[0]) - starts[dk])
    new_head = head0 + cnt
    keep = j >= new_head[dk] - R
    nr = nret_e[ei]
    t_end = (tk + int(i0)).astype(np.float32) * np.float32(dt) + np.float32(dt)
    valc = (ssum_e[ei] - nr * t_end) / np.maximum(nr, 1).astype(np.float32)
    val = np.select(
        [kk == 0, kk == 1, kk == 2],
        [miss_e[ei].astype(np.float32), valc, en_e[ei]],
        dreb_e[ei].astype(np.float32))
    dkk, slot = dk[keep], j[keep] % R
    rt[dkk, slot] = np.float32(tk[keep] + int(i0)) * np.float32(dt)
    rk[dkk, slot] = kk[keep]
    rv[dkk, slot] = val[keep]
    return tel._replace(exit_hist=hist, ring_t=rt, ring_kind=rk,
                        ring_val=rv, ring_head=new_head.astype(np.int32))
