"""Jit-safe observability for the fleet, serve, and adaptation paths.

The measurement substrate every perf PR measures itself against:

* :class:`Telemetry` — the per-device pytree of counters, extrema, an
  exit-depth histogram and a fixed-size event ring buffer, carried
  alongside :class:`repro.core.step.DeviceCarry` through the scan
  frontends.  Enabling it is numerics-neutral (events are derived from
  carry deltas); disabling it (``telemetry=None``, the default everywhere)
  compiles every instrumented branch out of the hot path entirely.
* :class:`TelemetryConfig` — hashable static config; pass it to
  ``fleet.simulate_fleet`` / ``fleet.run_segments`` /
  ``FleetServeEngine.run`` as ``telemetry=``.
* :func:`summarize` / :class:`TelemetrySummary` — host-side per-segment
  reduction, the structured replacement for ad-hoc carry diffing in
  :class:`repro.adapt.online.OnlineAdapter`.
* :class:`TelemetryLogger` / :func:`read_jsonl` — structured JSONL event
  streams, rendered by ``python -m repro.telemetry.report``.

Usage::

    tcfg = TelemetryConfig(ring_size=512)
    res, carry, tel = fleet.run_segments(cfg, statics, n_segments=8,
                                         telemetry=tcfg)
    summary = summarize(tel, statics.horizon)
    summary.miss_rate, summary.exit_hist, summary.energy_min
"""
from .export import (  # noqa: F401
    TelemetryLogger,
    TelemetrySummary,
    read_jsonl,
    summarize,
)
from .state import (  # noqa: F401
    EVENT_KINDS,
    EVENT_NAMES,
    Telemetry,
    TelemetryConfig,
    init_fleet_telemetry,
    init_telemetry,
    record_anytime_step,
    record_knob_updates,
    record_step,
)
