"""Host-side telemetry reduction and JSONL export.

Two consumers pull telemetry off the device:

* **adaptation** — :func:`summarize` reduces a ``(D, ...)``
  :class:`repro.telemetry.state.Telemetry` pytree into a
  :class:`TelemetrySummary` of numpy arrays at each segment boundary;
  :meth:`TelemetrySummary.delta` diffs two cumulative summaries into the
  per-segment view the :class:`repro.adapt.online.OnlineAdapter`
  controllers consume (its ``miss_rate`` reproduces the adapter's legacy
  carry-diff measurement exactly, because both difference the same step
  counters).
* **offline analysis** — :class:`TelemetryLogger` streams structured JSONL:
  one ``meta`` line, one ``summary`` line per segment, and one line per
  drained ring event (``miss`` / ``complete`` / ``power_fail`` / ``reboot``
  / ``knob_update`` with device id, time, value).  The stream is rendered
  by ``python -m repro.telemetry.report`` and round-trips through
  :func:`read_jsonl` (``tests/test_telemetry.py``).

Ring draining is incremental: the logger remembers each device's last seen
``ring_head`` and emits only newer events, so per-segment logging never
duplicates.  When more events arrived than the ring holds, the oldest are
gone — the ``dropped`` field on the summary line reports exactly how many.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import IO, Optional

import numpy as np

from .state import EVENT_NAMES, Telemetry, TelemetryConfig

_COUNTERS = ("releases", "misses", "scheduled", "retired", "power_fails",
             "reboots", "knob_updates", "steps", "events_seen")


@dataclasses.dataclass(frozen=True)
class TelemetrySummary:
    """Numpy reduction of a fleet's telemetry at one point in time.

    Counter fields are cumulative since t=0 (or since the summary this one
    was :meth:`delta`-ed against); extrema (``slack_min`` / ``occ_max`` /
    ``energy_min``) are always cumulative over the whole run.  All
    per-device fields are ``(D,)`` (histogram: ``(D, U+1)``).
    """

    t_end: float
    steps: np.ndarray
    releases: np.ndarray
    misses: np.ndarray
    scheduled: np.ndarray
    retired: np.ndarray
    power_fails: np.ndarray
    reboots: np.ndarray
    knob_updates: np.ndarray
    slack_mean: np.ndarray       # mean deadline slack at retirement (s)
    slack_min: np.ndarray
    exit_hist: np.ndarray        # (D, U+1); last bin = never exited
    occ_mean: np.ndarray
    occ_max: np.ndarray
    energy_mean: np.ndarray
    energy_min: np.ndarray
    events_seen: np.ndarray      # total ring events ever pushed
    events_dropped: np.ndarray   # overwritten before any drain saw them

    @property
    def n_devices(self) -> int:
        return int(self.steps.shape[0])

    @property
    def miss_rate(self) -> np.ndarray:
        """Per-device missed fraction of the jobs released in this
        summary's window — the adaptation controllers' feedback signal."""
        return self.misses / np.maximum(self.releases, 1.0)

    def delta(self, prev: Optional["TelemetrySummary"]) -> "TelemetrySummary":
        """This summary's counters minus ``prev``'s (per-segment view).
        Extrema and means stay cumulative — they cannot be un-aggregated.
        ``prev=None`` returns self (the first segment is its own delta)."""
        if prev is None:
            return self
        diffs = {k: getattr(self, k) - getattr(prev, k) for k in _COUNTERS}
        diffs["exit_hist"] = self.exit_hist - prev.exit_hist
        return dataclasses.replace(self, **diffs)

    def as_dict(self, per_device: bool = False) -> dict:
        """JSON-serializable export: cohort aggregates, plus the full
        per-device columns when ``per_device`` is set."""
        out = {
            "t_end": float(self.t_end),
            "n_devices": self.n_devices,
            "releases": int(self.releases.sum()),
            "misses": int(self.misses.sum()),
            "scheduled": int(self.scheduled.sum()),
            "retired": int(self.retired.sum()),
            "power_fails": int(self.power_fails.sum()),
            "reboots": int(self.reboots.sum()),
            "knob_updates": int(self.knob_updates.sum()),
            "miss_rate": float(np.mean(self.miss_rate)),
            "slack_mean": float(np.mean(self.slack_mean)),
            "slack_min": _finite(float(np.min(self.slack_min))),
            "exit_hist": self.exit_hist.sum(axis=0).tolist(),
            "occ_mean": float(np.mean(self.occ_mean)),
            "occ_max": int(np.max(self.occ_max)),
            "energy_mean": float(np.mean(self.energy_mean)),
            "energy_min": _finite(float(np.min(self.energy_min))),
            "events_seen": int(self.events_seen.sum()),
            "events_dropped": int(self.events_dropped.sum()),
        }
        if per_device:
            out["per_device"] = {
                "miss_rate": np.round(self.miss_rate, 6).tolist(),
                "misses": self.misses.tolist(),
                "releases": self.releases.tolist(),
                "energy_mean": np.round(self.energy_mean, 6).tolist(),
                "occ_mean": np.round(self.occ_mean, 4).tolist(),
            }
        return out


def _finite(x: float, fallback: float = 0.0) -> float:
    return x if np.isfinite(x) else fallback


def summarize(tel: Telemetry, t_end: float,
              ring_size: Optional[int] = None) -> TelemetrySummary:
    """Reduce a stacked ``(D, ...)`` telemetry pytree host-side."""
    as_np = {k: np.asarray(v) for k, v in tel._asdict().items()}
    steps = as_np["n_steps"].astype(np.int64)
    retired = as_np["c_retired"].astype(np.int64)
    r = int(ring_size if ring_size is not None else as_np["ring_t"].shape[-1])
    head = as_np["ring_head"].astype(np.int64)
    return TelemetrySummary(
        t_end=float(t_end),
        steps=steps,
        releases=as_np["c_release"].astype(np.int64),
        misses=as_np["c_miss"].astype(np.int64),
        scheduled=as_np["c_sched"].astype(np.int64),
        retired=retired,
        power_fails=as_np["c_power_fail"].astype(np.int64),
        reboots=as_np["c_reboot"].astype(np.int64),
        knob_updates=as_np["c_knob"].astype(np.int64),
        slack_mean=as_np["slack_sum"] / np.maximum(retired, 1),
        slack_min=as_np["slack_min"],
        exit_hist=as_np["exit_hist"].astype(np.int64),
        occ_mean=as_np["occ_sum"] / np.maximum(steps, 1),
        occ_max=as_np["occ_max"].astype(np.int64),
        energy_mean=as_np["energy_sum"] / np.maximum(steps, 1),
        energy_min=as_np["energy_min"],
        events_seen=head,
        events_dropped=np.maximum(head - r, 0),
    )


class TelemetryLogger:
    """Streaming JSONL writer for one telemetry-enabled run.

    Usage (what :mod:`benchmarks.bench_fleet` and the ``run_segments``
    integration do)::

        with TelemetryLogger(path, label="fleet") as log:
            log.meta(statics, tcfg, n_devices=D)
            ...                      # after each segment:
            log.segment(seg, summarize(tel, t_end), tel)
    """

    def __init__(self, path, label: str = "run", per_device: bool = False):
        self.path = Path(path)
        self.label = label
        self.per_device = per_device
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f: Optional[IO[str]] = open(self.path, "w")
        self._drained: Optional[np.ndarray] = None  # per-device ring head
        self._prev: Optional[TelemetrySummary] = None

    # ------------------------------------------------------------------ #
    def _write(self, obj: dict) -> None:
        assert self._f is not None, "logger already closed"
        self._f.write(json.dumps(obj) + "\n")

    def meta(self, statics, tcfg: TelemetryConfig, n_devices: int) -> None:
        self._write({
            "event": "meta", "label": self.label, "n_devices": n_devices,
            "dt": float(statics.dt), "horizon": float(statics.horizon),
            "queue_size": int(statics.queue_size),
            "ring_size": int(tcfg.ring_size),
        })

    def segment(self, seg: int, summary: TelemetrySummary,
                tel: Optional[Telemetry] = None) -> None:
        """One segment boundary: the cumulative-minus-previous summary
        line, then every ring event that arrived since the last drain."""
        delta = summary.delta(self._prev)
        self._prev = summary
        row = {"event": "summary", "seg": int(seg), **delta.as_dict(
            per_device=self.per_device)}
        self._write(row)
        if tel is not None:
            self.drain_rings(tel)

    def drain_rings(self, tel: Telemetry) -> int:
        """Emit ring events newer than the previous drain; returns the
        number of lines written.  Events lost to overflow between drains
        are skipped (counted in the summary's ``events_dropped``)."""
        t = np.asarray(tel.ring_t)
        kind = np.asarray(tel.ring_kind)
        val = np.asarray(tel.ring_val)
        head = np.asarray(tel.ring_head).astype(np.int64)
        r = t.shape[-1]
        if self._drained is None:
            self._drained = np.zeros_like(head)
        n = 0
        for d in range(head.shape[0]):
            start = max(int(self._drained[d]), int(head[d]) - r)
            for i in range(start, int(head[d])):
                j = i % r
                self._write({
                    "event": EVENT_NAMES.get(int(kind[d, j]), "unknown"),
                    "device": d, "t": round(float(t[d, j]), 6),
                    "val": round(float(val[d, j]), 6),
                })
                n += 1
        self._drained = head
        return n

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TelemetryLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path) -> list[dict]:
    """Parse a telemetry JSONL stream back into a list of event dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
