"""Text dashboard over a telemetry JSONL stream.

Renders the event stream a :class:`repro.telemetry.export.TelemetryLogger`
wrote — per-segment miss/occupancy/energy trajectories, the exit-depth
histogram, and per-device-cohort event timelines — as plain text::

    PYTHONPATH=src python -m repro.telemetry.report experiments/telemetry_fleet.jsonl
    PYTHONPATH=src python -m repro.telemetry.report run.jsonl --cohorts 8 --width 64

Devices are grouped into ``--cohorts`` contiguous index ranges (fleet grids
stack related configs contiguously, so cohorts line up with sweep cells);
each cohort gets one timeline row per event kind, binned over the run
horizon and drawn with density glyphs.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from .export import read_jsonl

_SPARK = " .:-=+*#%@"
_TIMELINE_KINDS = ("miss", "power_fail", "complete", "knob_update")


def _spark(values, lo=None, hi=None) -> str:
    """Density string: one glyph per value, scaled over [lo, hi]."""
    vals = list(values)
    if not vals:
        return ""
    lo = min(vals) if lo is None else lo
    hi = max(vals) if hi is None else hi
    span = (hi - lo) or 1.0
    out = []
    for v in vals:
        i = int((v - lo) / span * (len(_SPARK) - 1))
        out.append(_SPARK[max(0, min(i, len(_SPARK) - 1))])
    return "".join(out)


def _bin_events(events, t_max: float, width: int):
    """events [(t, val)] -> per-bin counts over [0, t_max]."""
    bins = [0.0] * width
    for t, _ in events:
        i = int(t / t_max * width) if t_max > 0 else 0
        bins[max(0, min(i, width - 1))] += 1
    return bins


def _cohort_of(device: int, n_devices: int, n_cohorts: int) -> int:
    per = max(1, -(-n_devices // n_cohorts))     # ceil division
    return min(device // per, n_cohorts - 1)


def render(path, out=sys.stdout, *, cohorts: int = 4,
           width: int = 60) -> None:
    records = read_jsonl(path)
    meta = next((r for r in records if r.get("event") == "meta"), {})
    summaries = [r for r in records if r.get("event") == "summary"]
    ring = [r for r in records if r.get("event") in _TIMELINE_KINDS
            or r.get("event") == "reboot"]
    n_devices = int(meta.get("n_devices", 1))
    horizon = float(meta.get("horizon", 0.0)) or max(
        [r.get("t", 0.0) for r in ring] + [1.0])
    n_cohorts = max(1, min(cohorts, n_devices))

    w = out.write
    w(f"telemetry report — {meta.get('label', path)}\n")
    w(f"  devices={n_devices}  dt={meta.get('dt', '?')}  "
      f"horizon={horizon}  ring_size={meta.get('ring_size', '?')}\n")

    if summaries:
        w(f"\nper-segment trajectory ({len(summaries)} segments)\n")
        header = (f"  {'seg':>4} {'t_end':>8} {'released':>9} "
                  f"{'missed':>7} {'miss_rate':>9} {'occ':>6} "
                  f"{'energy':>9} {'pwr_fail':>8} {'knobs':>6}\n")
        w(header)
        for s in summaries:
            w(f"  {s['seg']:>4} {s['t_end']:>8.2f} {s['releases']:>9} "
              f"{s['misses']:>7} {s['miss_rate']:>9.3f} "
              f"{s['occ_mean']:>6.2f} {s['energy_mean']:>9.4f} "
              f"{s['power_fails']:>8} {s['knob_updates']:>6}\n")
        w("  miss_rate   |" + _spark(
            [s["miss_rate"] for s in summaries], lo=0.0) + "|\n")
        w("  occupancy   |" + _spark(
            [s["occ_mean"] for s in summaries], lo=0.0) + "|\n")
        w("  energy_mean |" + _spark(
            [s["energy_mean"] for s in summaries], lo=0.0) + "|\n")

        last = summaries[-1]
        hist = [0] * len(last.get("exit_hist", []))
        for s in summaries:                     # summaries are per-segment
            for i, v in enumerate(s.get("exit_hist", [])):
                hist[i] += v
        if hist:
            w("\nexit-depth histogram (retired jobs; last bin = no exit)\n")
            top = max(hist) or 1
            for i, v in enumerate(hist):
                label = f"unit {i}" if i < len(hist) - 1 else "no-exit"
                bar = "#" * int(round(40 * v / top))
                w(f"  {label:>8} {v:>8} |{bar}\n")
        dropped = sum(s.get("events_dropped", 0) for s in summaries)
        if dropped:
            w(f"\n  note: {dropped} ring events overwritten before drain "
              f"(raise TelemetryConfig.ring_size to keep them)\n")

    if ring:
        w(f"\nevent timelines — {n_cohorts} cohort(s) of "
          f"~{-(-n_devices // n_cohorts)} device(s), "
          f"{width} bins over [0, {horizon:g}]s\n")
        by_kind_cohort = defaultdict(list)
        for r in ring:
            c = _cohort_of(int(r.get("device", 0)), n_devices, n_cohorts)
            by_kind_cohort[(r["event"], c)].append(
                (float(r.get("t", 0.0)), float(r.get("val", 0.0))))
        for kind in _TIMELINE_KINDS:
            rows = [(c, by_kind_cohort.get((kind, c), []))
                    for c in range(n_cohorts)]
            if not any(ev for _, ev in rows):
                continue
            w(f"  {kind}\n")
            for c, ev in rows:
                bins = _bin_events(ev, horizon, width)
                w(f"    cohort {c:>2} ({len(ev):>5} ev) |"
                  + _spark(bins, lo=0.0) + "|\n")
    out.flush()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a telemetry JSONL stream as a text dashboard")
    ap.add_argument("path", help="telemetry .jsonl written by "
                                 "repro.telemetry.TelemetryLogger")
    ap.add_argument("--cohorts", type=int, default=4,
                    help="device cohorts (contiguous index ranges)")
    ap.add_argument("--width", type=int, default=60,
                    help="timeline bins")
    args = ap.parse_args(argv)
    render(args.path, cohorts=args.cohorts, width=args.width)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
