"""Checkpointing: pytree <-> .npz with path-flattened keys."""
from __future__ import annotations

import os
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # npz has no bf16; f32 is lossless
        flat[key] = arr
    return flat


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems
        )
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
