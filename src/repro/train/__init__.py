from .optimizer import adamw_init, adamw_update  # noqa: F401
from .trainer import train_agile_cnn, train_step_lm, make_train_step  # noqa: F401
from .checkpoint import save_checkpoint, load_checkpoint  # noqa: F401
