"""AdamW (decoupled weight decay) over arbitrary pytrees — pure JAX."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float = 1.0,
):
    step = state.step + 1
    if grad_clip:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.mu, grads,
    )
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu)
