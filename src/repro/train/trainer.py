"""Training: (a) the Zygarde network-trainer pipeline for agile CNNs
(siamese + layer-aware loss -> k-means bank -> utility thresholds, paper §6),
and (b) the LM train_step for the assigned architectures (dry-run target).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import kmeans as km
from repro.core import losses
from repro.core import utility as util
from repro.data import make_siamese_pairs, siamese_batches
from repro.models import cnn as cnn_mod
from repro.models import transformer as tfm
from repro.models.common import shard

from .optimizer import adamw_init, adamw_update


# --------------------------------------------------------------------------- #
# (a) Agile-CNN network trainer (paper §6.1).
# --------------------------------------------------------------------------- #


@dataclass
class TrainedAgileCNN:
    cfg: cnn_mod.CNNConfig
    params: dict
    bank: list
    history: list


def _cnn_feats(cfg, params, x):
    return cnn_mod.cnn_forward_all(cfg, params, x)


def train_agile_cnn(
    dataset,
    *,
    loss: str = "layer_aware",          # layer_aware | contrastive | cross_entropy
    epochs: int = 5,
    batch_size: int = 32,
    n_pairs: int = 2048,
    lr: float = 1e-3,
    margin: float = 1.0,
    layer_coeffs: Optional[Sequence[float]] = None,
    min_exit_accuracy: float = 0.9,
    n_sel: int = 150,
    seed: int = 0,
) -> TrainedAgileCNN:
    """Full network-trainer pipeline: train -> fit bank -> calibrate
    thresholds.  ``loss`` selects the paper's layer-aware loss or the two
    baselines of Fig. 15."""
    cfg = cnn_mod.PAPER_CNNS[dataset.name]
    key = jax.random.PRNGKey(seed)
    params = cnn_mod.init_cnn_params(cfg, key)
    history = []

    if loss == "cross_entropy":
        # CE baseline needs a classification head on the last feature layer
        feat_dim = cnn_mod._feature_sizes(cfg)[-1]
        head = {
            "w": jax.random.normal(key, (feat_dim, dataset.n_classes)) * 0.02,
            "b": jnp.zeros((dataset.n_classes,)),
        }
        full = {"net": params, "head": head}

        @jax.jit
        def step(full, opt, x, y):
            def loss_fn(full):
                feats = _cnn_feats(cfg, full["net"], x)
                logits = feats[-1] @ full["head"]["w"] + full["head"]["b"]
                return losses.cross_entropy(logits, y)

            l, g = jax.value_and_grad(loss_fn)(full)
            full, opt = adamw_update(full, g, opt, lr=lr)
            return full, opt, l

        opt = adamw_init(full)
        from repro.data import batches as data_batches

        for x, y in data_batches(
            dataset.x_train, dataset.y_train, batch_size,
            seed=seed, epochs=epochs,
        ):
            full, opt, l = step(full, opt, jnp.asarray(x), jnp.asarray(y))
            history.append(float(l))
        params = full["net"]
    else:
        x1, x2, diff = make_siamese_pairs(
            dataset.x_train, dataset.y_train, n_pairs, seed=seed
        )

        loss_fn_sel = {
            "layer_aware": functools.partial(
                losses.layer_aware_loss, coeffs=layer_coeffs, margin=margin
            ),
            "contrastive": functools.partial(
                losses.final_layer_contrastive, margin=margin
            ),
        }[loss]

        @jax.jit
        def step(params, opt, a, b, d):
            def loss_fn(params):
                fa = _cnn_feats(cfg, params, a)
                fb = _cnn_feats(cfg, params, b)
                # normalise per-layer features so losses are comparable
                fa = [f / (jnp.abs(f).mean() + 1e-6) for f in fa]
                fb = [f / (jnp.abs(f).mean() + 1e-6) for f in fb]
                return loss_fn_sel(fa, fb, d)

            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt = adamw_update(params, g, opt, lr=lr)
            return params, opt, l

        opt = adamw_init(params)
        for a, b, d in siamese_batches(
            x1, x2, diff, batch_size, seed=seed, epochs=epochs
        ):
            params, opt, l = step(
                params, opt, jnp.asarray(a), jnp.asarray(b), jnp.asarray(d)
            )
            history.append(float(l))

    # ---- k-means bank + thresholds ----------------------------------------- #
    # Bank fitted on the fit split; utility thresholds calibrated on a
    # HELD-OUT quarter — calibrating on the fit data makes every unit look
    # perfect and drives thresholds to zero (premature exits at deploy).
    n = len(dataset.x_train)
    n_cal = max(32, n // 4)
    fit_x, fit_y = dataset.x_train[: n - n_cal], dataset.y_train[: n - n_cal]
    cal_x, cal_y = dataset.x_train[n - n_cal:], dataset.y_train[n - n_cal:]
    feats = [
        np.asarray(f) for f in _cnn_feats(cfg, params, jnp.asarray(fit_x))
    ]
    bank = km.fit_bank(feats, fit_y, n_sel=n_sel, seed=seed)
    cal_feats = [
        np.asarray(f) for f in _cnn_feats(cfg, params, jnp.asarray(cal_x))
    ]
    bank = util.calibrate_bank_thresholds(
        bank, cal_feats, cal_y, min_accuracy=min_exit_accuracy
    )
    return TrainedAgileCNN(cfg, params, bank, history)


# --------------------------------------------------------------------------- #
# (b) LM training step for the assigned architectures.
# --------------------------------------------------------------------------- #


def train_step_lm(cfg, params, opt_state, batch, *, lr: float = 3e-4,
                  window: Optional[int] = None,
                  microbatches: Optional[int] = None):
    """One LM step: next-token CE + MoE aux loss, AdamW update.

    ``microbatches > 1`` scans gradient accumulation over splits of the
    global batch — activation temps scale with the microbatch, which is how
    the 235B/132B train_4k shapes fit 16 GiB HBM (§Perf P1-H3).  Grads
    accumulate in f32; the result is bit-comparable to the fused step up to
    sum-order.
    """
    mb = microbatches or cfg.train_microbatches

    def loss_fn(params, batch):
        logits, aux = tfm.forward(cfg, params, batch, window=window)
        S = batch["tokens"].shape[1]
        logits = logits[:, -S:]  # VLM: score only the text positions
        l = losses.lm_loss(logits, batch["tokens"])
        return l + cfg.router_aux_weight * aux, (l, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if mb <= 1:
        (total, (l, aux)), grads = grad_fn(params, batch)
    else:
        B = batch["tokens"].shape[0]
        assert B % mb == 0, (B, mb)
        split = jax.tree.map(
            lambda a: a.reshape(mb, B // mb, *a.shape[1:]), batch
        )

        def body(acc, mbatch):
            g_acc, l_acc, a_acc, t_acc = acc
            (t, (l, a)), g = grad_fn(params, mbatch)
            g_acc = jax.tree.map(
                lambda A, G: A + G.astype(jnp.float32), g_acc, g
            )
            return (g_acc, l_acc + l, a_acc + a, t_acc + t), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (g32, l, aux, total), _ = jax.lax.scan(
            body, (zeros, 0.0, jnp.float32(0.0), 0.0), split
        )
        grads = jax.tree.map(
            lambda G, p: (G / mb).astype(p.dtype), g32, params
        )
        l, aux, total = l / mb, aux / mb, total / mb

    params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
    return params, opt_state, {"loss": l, "aux": aux, "total": total}


def make_train_step(cfg, *, lr: float = 3e-4, window: Optional[int] = None,
                    microbatches: Optional[int] = None):
    """jit-able closure used by the launcher and the dry-run."""

    def step(params, opt_state, batch):
        return train_step_lm(cfg, params, opt_state, batch, lr=lr,
                             window=window, microbatches=microbatches)

    return step
