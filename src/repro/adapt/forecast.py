"""Harvest-pattern forecasting: cluster observed supply windows, predict
the next one, adapt the scheduler *before* the pattern changes.

The paper's premise is that harvested energy is *patterned* — §3 models a
trace by its conditional-event curve h(N) and compresses it into eta — and
PR 4's :class:`repro.adapt.online.OnlineAdapter` already re-estimates that
pattern statistic mid-trajectory.  But its E_opt law is purely *reactive*:
it follows the observed supply with an EWMA and only snaps conservative
after a missy segment, so every regime change is paid for at least once.
This module adds the anticipatory half:

* :func:`window_features` turns each observed trace window into a small
  feature vector — observed eta (Eq. 3), duty cycle, mean event amplitude,
  ON/OFF run-length statistics (the event inter-arrival structure), and
  the raw Kantorovich-Wasserstein distance of the window's h(N) curve from
  the persistent ideal (:mod:`repro.core.energy`);
* :class:`HarvestForecaster` clusters those windows *online* with the
  semi-supervised k-means machinery of :mod:`repro.core.kmeans` — L1
  classify + weighted-average centroid adaptation, dispatched through the
  fleet-shaped Pallas wrappers (``fleet_l1_topk2`` / ``fleet_centroid_update``
  in :mod:`repro.kernels.ops`, with :func:`repro.kernels.ops.pairwise_l1`
  seeding the table farthest-point-first) so a whole ``(D, W, F)`` fleet
  batch classifies in one kernel call — and learns, per cluster, the mean
  (eta, supply) of its member windows, the empirical *duration* of stays,
  and the successor-transition counts between clusters (a duration-explicit
  semi-Markov chain over harvest regimes);
* :meth:`HarvestForecaster.predict` combines them: if the device's current
  regime still has expected life left, predict its own statistics; as the
  stay approaches the cluster's learned duration, shift prediction mass to
  the expected successor — with a confidence score that stays 0 until the
  statistics exist;
* :class:`ForecastController` plugs the prediction into the online
  adaptation loop: E_opt interpolates over the *predicted* next-window
  supply headroom (blended with the PR-4 feedback law by confidence, so an
  unconfident forecaster degrades exactly to feedback), and — once
  confident — the per-unit ``exit_thr`` tables move the mandatory/optional
  boundary with the same headroom: rich forecast -> deeper mandatory
  prefixes, lean forecast -> exit at the first unit and save the reserve
  for the outage the transition model says is coming.

``examples/online_adapt.py`` pits this controller against the PR-4
feedback law on the seeded nonstationary solar -> RF -> occluded trace;
the forecast arm must win (pinned by ``tests/test_forecast.py`` and the CI
bench-smoke lane).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax.numpy as jnp

from ..core import kmeans
from ..core.energy import h_curve, ideal_h_curve, kw_distance, eta_factor
from ..fleet.state import FleetConfig, FleetStatics
from ..kernels import ops
from .online import (
    Controller,
    Observation,
    ewma_supply,
    headroom_e_opt_fraction,
    workload_demand,
)

_F32 = np.float32

#: Feature order of :func:`window_features` (F = 6).
FEATURES = ("eta", "duty", "amp", "on_run", "off_run", "h_dist")
F_ETA, F_DUTY, F_AMP, F_ON_RUN, F_OFF_RUN, F_H_DIST = range(len(FEATURES))


# --------------------------------------------------------------------------- #
# Window featurization.
# --------------------------------------------------------------------------- #


def _run_stats(binary: np.ndarray) -> tuple[float, float]:
    """(mean ON-run, mean OFF-run) lengths of a binary row, in slots (0.0
    where a state never occurs) — the event inter-arrival structure."""
    if binary.size == 0:
        return 0.0, 0.0
    edges = np.flatnonzero(np.diff(binary)) + 1
    runs = np.diff(np.concatenate([[0], edges, [binary.size]]))
    values = binary[np.concatenate([[0], edges])]
    on = runs[values > 0]
    off = runs[values == 0]
    return (float(on.mean()) if on.size else 0.0,
            float(off.mean()) if off.size else 0.0)


def window_features(events: np.ndarray, t_end: float, slot_s: float,
                    window_s: float, *, n_max: int = 4, n_windows: int = 1,
                    stride_s: Optional[float] = None) -> np.ndarray:
    """Featurize the trailing windows of every device's observed trace.

    ``events`` is the ``(D, S)`` FleetConfig event stream; like
    :func:`repro.adapt.online.observed_eta`, only slots strictly before
    ``t_end`` participate.  Returns a ``(D, W, F)`` float32 batch — the
    ``n_windows`` trailing windows (oldest first, each ``window_s`` seconds,
    spaced ``stride_s`` apart, the last one ending at ``t_end``) × the
    :data:`FEATURES` columns.  Windows with fewer than two observed slots
    are all-zero (the patternless prior).  Run lengths are normalised by
    the window length so every feature is O(1) and the L1 metric weighs
    them comparably.
    """
    events = np.atleast_2d(np.asarray(events))
    d_dev, n_slots = events.shape
    stride = window_s if stride_s is None else stride_s
    window = max(int(round(window_s / slot_s)), 2)
    ideal = ideal_h_curve(n_max)
    out = np.zeros((d_dev, n_windows, len(FEATURES)), _F32)
    for w in range(n_windows):
        w_end = t_end - (n_windows - 1 - w) * stride
        # clamp at zero: a window ending before the trace starts is empty
        # (a negative slice end would wrap around and leak *future* slots)
        n_seen = max(int(min(w_end / slot_s, n_slots)), 0)
        seen = events[:, max(0, n_seen - window):n_seen]
        if seen.shape[1] < 2:
            continue
        for d in range(d_dev):
            row = seen[d]
            binary = (row > 0.0).astype(np.int8)
            on_run, off_run = _run_stats(binary)
            h = h_curve(binary, n_max)
            obs = np.isfinite(h)
            out[d, w] = (
                eta_factor(binary, n_max=n_max),
                binary.mean(),
                row.mean(),
                on_run / binary.size,
                off_run / binary.size,
                kw_distance(h, np.where(obs, ideal, np.nan)),
            )
    return out


# --------------------------------------------------------------------------- #
# The online forecaster.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class HarvestForecaster:
    """Online clustering of harvest windows + a duration-explicit
    transition model over the clusters.

    State is host-side numpy; the cluster table is shared across the whole
    fleet (devices pool their pattern statistics), while the regime
    bookkeeping — current cluster, age of the stay — is per device.
    Classify/adapt dispatch to the Pallas k-means kernels through
    :func:`repro.core.kmeans.classify_batch` /
    :func:`repro.core.kmeans.online_update`, so one call ingests a whole
    ``(D, W, F)`` window batch.

    * ``weight`` — centroid inertia of the online update (paper §11.3's
      outlier guard); larger values adapt the table more slowly.
    * ``smoothing`` — Laplace mass spread over *observed* successor
      clusters when normalising transition rows.
    * ``conf_n0`` — confidence half-life: a statistic backed by ``n``
      observations gets weight ``n / (n + conf_n0)``.
    """

    n_clusters: int = 4
    weight: float = 8.0
    smoothing: float = 0.25
    conf_n0: float = 2.0
    spawn_radius: float = 0.75

    #: placeholder feature value for unborn centroid rows — far enough (in
    #: L1 over O(1) features) that a live centroid always wins the argmin
    _PLACEHOLDER = 1e6

    def __post_init__(self):
        k = self.n_clusters
        if k < 1:
            raise ValueError(f"n_clusters must be >= 1, got {k}")
        self.centroids: Optional[np.ndarray] = None   # (k, F)
        self.born = np.zeros(k, bool)
        self.counts = np.zeros(k, _F32)
        self.stats_sum = np.zeros((k, 2))             # [eta, supply] sums
        self.stats_n = np.zeros(k)
        self.trans = np.zeros((k, k))                 # successor counts
        self.dur_sum = np.zeros(k)                    # completed stays (obs)
        self.dur_n = np.zeros(k)
        self.cur_cluster: Optional[np.ndarray] = None  # (D,) int
        self.cur_age: Optional[np.ndarray] = None      # (D,) float
        self.n_obs = 0

    @property
    def n_born(self) -> int:
        """How many clusters have been spawned so far (<= ``n_clusters``)."""
        return int(self.born.sum())

    # -- construction ------------------------------------------------------ #

    def _init_centroids(self, flat: np.ndarray) -> None:
        """Seed the table farthest-point-first from the first window batch
        (ties to the all-pairs L1 kernel): centroid 0 is the first window,
        further seeds are added while the most isolated window is more than
        ``spawn_radius`` from every seed.  Remaining rows stay *unborn*
        (placeholder coordinates) until :meth:`observe` spawns them on a
        window outside every live centroid's radius — leader-style online
        k-means, so distinct harvest regimes get distinct clusters instead
        of splitting one seed's jittered copies."""
        k = self.n_clusters
        self.centroids = np.full((k, flat.shape[1]), self._PLACEHOLDER,
                                 _F32)
        chosen = [0]
        if flat.shape[0] > 1:
            dist = np.asarray(ops.pairwise_l1(
                jnp.asarray(flat), jnp.asarray(flat)))
            while len(chosen) < min(k, flat.shape[0]):
                mind = dist[:, chosen].min(axis=1)
                mind[chosen] = -1.0
                nxt = int(np.argmax(mind))
                if mind[nxt] <= self.spawn_radius:
                    break
                chosen.append(nxt)
        for j, i in enumerate(chosen):
            self.centroids[j] = flat[i]
            self.born[j] = True

    # -- online ingestion -------------------------------------------------- #

    def observe(self, feats: np.ndarray, eta: np.ndarray,
                supply: np.ndarray) -> np.ndarray:
        """Ingest one window batch: classify, adapt centroids, update the
        per-cluster (eta, supply) statistics and the duration/transition
        model.

        ``feats``: ``(D, F)`` or ``(D, W, F)`` (windows oldest first);
        ``eta`` / ``supply``: matching ``(D,)`` or ``(D, W)`` per-window
        statistics to learn as predictors.  Returns the assigned cluster
        ids, shaped like ``eta``.
        """
        feats = np.asarray(feats, _F32)
        squeeze = feats.ndim == 2
        if squeeze:
            feats = feats[:, None, :]
        eta = np.asarray(eta, np.float64).reshape(feats.shape[:2])
        supply = np.asarray(supply, np.float64).reshape(feats.shape[:2])
        d_dev, n_win, _ = feats.shape
        flat_feats = feats.reshape(-1, feats.shape[-1])
        if self.centroids is None:
            self._init_centroids(flat_feats)
        idx, d1, _, _ = kmeans.classify_batch(
            jnp.asarray(self.centroids), jnp.asarray(feats))
        idx, d1 = np.asarray(idx), np.asarray(d1)
        # leader-style spawning: a window outside every live centroid's
        # radius births the next unborn cluster at its own coordinates
        # (re-classifying, so other far windows can join the new cluster)
        while (not self.born.all()) and d1.max() > self.spawn_radius:
            far = int(np.argmax(d1.reshape(-1)))
            slot = int(np.argmin(self.born))
            self.centroids[slot] = flat_feats[far]
            self.born[slot] = True
            idx, d1, _, _ = kmeans.classify_batch(
                jnp.asarray(self.centroids), jnp.asarray(feats))
            idx, d1 = np.asarray(idx), np.asarray(d1)
        # (D, W) assignments
        new_c, new_n = kmeans.online_update(
            jnp.asarray(self.centroids), jnp.asarray(self.counts),
            jnp.asarray(feats), jnp.asarray(idx), self.weight)
        # np.array (not asarray): jax outputs are read-only views and the
        # spawn path writes centroid rows in place
        self.centroids = np.array(new_c)
        self.counts = np.array(new_n)
        flat = idx.reshape(-1)
        np.add.at(self.stats_sum, flat,
                  np.stack([eta.reshape(-1), supply.reshape(-1)], axis=-1))
        np.add.at(self.stats_n, flat, 1.0)
        for w in range(n_win):
            self._advance(idx[:, w])
        self.n_obs += d_dev * n_win
        return idx[:, -1] if squeeze else idx

    def _advance(self, cur: np.ndarray) -> None:
        """One step of the per-device regime bookkeeping: ages stays, and
        on a cluster change records the completed stay's duration and the
        successor transition."""
        if self.cur_cluster is None:
            self.cur_cluster = cur.astype(np.int64).copy()
            self.cur_age = np.ones(cur.shape[0])
            return
        same = cur == self.cur_cluster
        if not same.all():
            old = self.cur_cluster[~same]
            new = cur[~same]
            np.add.at(self.dur_sum, old, self.cur_age[~same])
            np.add.at(self.dur_n, old, 1.0)
            np.add.at(self.trans, (old, new), 1.0)
        self.cur_age = np.where(same, self.cur_age + 1.0, 1.0)
        self.cur_cluster = cur.astype(np.int64).copy()

    # -- prediction -------------------------------------------------------- #

    def predict(self, horizon: float = 1.0) -> dict:
        """Predict the next window's (eta, supply) per device.

        ``horizon`` is the look-ahead in *observations* (window strides).
        Per device with current cluster ``c``: while the stay's expected
        remaining life covers the horizon, predict ``c``'s own mean
        statistics; as it runs out, blend toward the expected successor's
        (transition-count weighted over clusters with statistics).  Both
        halves are convex combinations of observed per-window (eta, supply)
        values, so predictions never leave the observed envelope
        (``tests/test_forecast.py`` pins this).

        Returns ``{"eta", "supply", "confidence", "w_stay", "cluster"}``,
        each ``(D,)``; confidence is 0 until the statistics exist (and the
        whole dict is zeros before the first :meth:`observe`).
        """
        if self.cur_cluster is None:
            return {key: np.zeros(0) for key in
                    ("eta", "supply", "confidence", "w_stay", "cluster")}
        k = self.n_clusters
        c = self.cur_cluster
        have = self.stats_n > 0
        means = np.where(have[:, None],
                         self.stats_sum / np.maximum(self.stats_n, 1.0)[:, None],
                         0.0)                                   # (k, 2)
        stay = means[c]                                          # (D, 2)
        mean_dur = np.where(self.dur_n > 0,
                            self.dur_sum / np.maximum(self.dur_n, 1.0),
                            np.inf)
        remaining = mean_dur[c] - self.cur_age
        w_stay = np.where(np.isfinite(remaining),
                          np.clip(remaining / max(horizon, 1e-9), 0.0, 1.0),
                          1.0)
        # successor distribution: observed transition counts (self excluded
        # by construction) + Laplace mass over clusters that have statistics
        trans = self.trans * have[None, :]
        has_succ = trans.sum(axis=1) > 0
        smooth = (self.smoothing * have[None, :]
                  * (~np.eye(k, dtype=bool))
                  * has_succ[:, None])
        p = trans + smooth
        p = p / np.maximum(p.sum(axis=1, keepdims=True), 1e-12)
        succ_means = p @ means                                   # (k, 2)
        succ = np.where(has_succ[c][:, None], succ_means[c], stay)
        w2 = w_stay[:, None]
        pred = w2 * stay + (1.0 - w2) * succ
        n0 = self.conf_n0
        # a single member window is no evidence beyond what a reactive
        # supply estimate already sees — confidence starts at the second
        ns = np.maximum(self.stats_n[c] - 1.0, 0.0)
        conf_stay = ns / (ns + n0)
        conf_switch = np.where(has_succ[c],
                               self.dur_n[c] / (self.dur_n[c] + n0), 0.0)
        conf = w_stay * conf_stay + (1.0 - w_stay) * conf_switch
        return dict(eta=pred[:, 0], supply=pred[:, 1], confidence=conf,
                    w_stay=w_stay, cluster=c.copy())


# --------------------------------------------------------------------------- #
# The forecast-aware controller.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class ForecastController(Controller):
    """Anticipatory E_opt + ``exit_thr`` control from the harvest forecast.

    Per segment it featurizes the trailing ``window_s`` seconds of every
    device's observed trace, feeds the window to the
    :class:`HarvestForecaster`, and asks for the expected supply over the
    next ``horizon_s`` seconds.  The E_opt fraction then interpolates over
    the *predicted* energy headroom exactly as the PR-4 feedback law does
    over the observed one — the two supplies are blended by the
    forecaster's confidence, so with no learned statistics the controller
    degrades bit-for-bit to :class:`repro.adapt.online.FeedbackController`
    (same EWMA, same bounds, same miss fast-attack).

    Once confident (``confidence >= conf_min``) it additionally drives the
    per-unit utility-test thresholds through the tunable
    ``exit_thr``/``use_exit_thr`` substrate: the predicted headroom maps
    into ``depth_bounds`` and the per-task threshold sweeps the workload's
    margin range — 0 sits below every margin (exit at the first unit:
    minimal mandatory demand for the lean window ahead), 1 above every
    margin (the whole DNN becomes mandatory).  A missy segment snaps the
    depth to its floor alongside the E_opt fast-attack.
    """

    window_s: float = 8.0
    n_max: int = 4
    horizon_s: Optional[float] = None      # default: 4 segment lengths
    n_clusters: int = 4
    cluster_weight: float = 8.0
    spawn_radius: float = 0.75
    supply_window_s: float = 5.0
    supply_rho: float = 0.7
    e_opt_bounds: tuple[float, float] = (0.05, 0.95)
    miss_target: float = 0.1
    adapt_exit_thr: bool = True
    depth_bounds: tuple[float, float] = (0.0, 0.5)
    conf_min: float = 0.3
    #: pass an explicit forecaster to carry learned regime statistics into
    #: this trajectory (e.g. from a previous deployment of the same fleet);
    #: left None, a fresh one is built at every reset()
    forecaster: Optional[HarvestForecaster] = None

    def __post_init__(self):
        self._own_forecaster = self.forecaster is None
        if self._own_forecaster:
            self.forecaster = self._fresh_forecaster()

    def _fresh_forecaster(self) -> HarvestForecaster:
        return HarvestForecaster(
            n_clusters=self.n_clusters, weight=self.cluster_weight,
            spawn_radius=self.spawn_radius)

    def reset(self, cfg: Optional[FleetConfig],
              statics: FleetStatics) -> None:
        if self._own_forecaster:
            self.forecaster = self._fresh_forecaster()
        self._demand = workload_demand(cfg) if cfg is not None else None
        self._supply_hat: Optional[np.ndarray] = None
        self._prev_t: Optional[float] = None
        self._thr_lo: Optional[np.ndarray] = None
        if cfg is not None:
            self._init_thresholds(cfg)

    def _init_thresholds(self, cfg: FleetConfig) -> None:
        """Anchor the depth sweep on the workload's margin tables: per
        (device, task), thresholds just below the smallest / above the
        largest live-unit margin reach 'exit at unit 0' / 'full depth
        mandatory' respectively."""
        margins = np.asarray(cfg.margins, np.float64)  # (D, K, J, U)
        n_units = np.asarray(cfg.n_units)              # (D, K)
        live = (np.arange(margins.shape[-1])[None, None, :]
                < n_units[:, :, None])                 # (D, K, U)
        m = np.where(live[:, :, None, :], margins, np.nan)
        mlo = np.nanmin(m, axis=(2, 3))
        mhi = np.nanmax(m, axis=(2, 3))
        span = np.maximum(mhi - mlo, 1e-3)
        self._thr_lo = mlo - 0.05 * span
        self._thr_hi = mhi + 0.05 * span
        self._base_use = np.asarray(cfg.use_exit_thr)
        self._base_thr = np.asarray(cfg.exit_thr)

    def update(self, obs: Observation) -> tuple[dict, dict]:
        ctx = obs.ctx
        if self._demand is None:
            self._demand = workload_demand(obs.cfg)
        if self._thr_lo is None:
            self._init_thresholds(obs.cfg)
        seg_s = obs.t_end - (self._prev_t if self._prev_t is not None
                             else 0.0)
        self._prev_t = obs.t_end
        seg_s = max(seg_s, 1e-9)

        feats = window_features(ctx.events, obs.t_end, ctx.statics.slot_s,
                                self.window_s, n_max=self.n_max)[:, 0, :]
        supply_w = feats[:, F_AMP].astype(np.float64) * ctx.power_on
        first = self.forecaster.n_obs == 0
        self.forecaster.observe(feats, feats[:, F_ETA], supply_w)
        horizon = (self.horizon_s if self.horizon_s is not None
                   else 4.0 * seg_s) / seg_s
        pred = self.forecaster.predict(horizon)
        if first:
            # the opening segment has no history to predict from: degrade
            # exactly to the feedback law (tests pin this fallback)
            pred["confidence"] = np.zeros_like(pred["confidence"])

        # the PR-4 feedback law's supply tracker as the low-confidence
        # fallback, then the shared E_opt law over the blended supply —
        # with confidence 0 this is the feedback controller by construction
        self._supply_hat = ewma_supply(self._supply_hat, ctx, obs.t_end,
                                       self.supply_window_s, self.supply_rho)
        conf = pred["confidence"]
        supply_eff = conf * pred["supply"] + (1.0 - conf) * self._supply_hat
        frac, headroom = headroom_e_opt_fraction(
            supply_eff, self._demand, self.e_opt_bounds,
            obs.miss_rate, self.miss_target)
        upd = dict(e_opt=jnp.asarray((frac * ctx.capacity).astype(_F32)))
        log = dict(supply_hat=self._supply_hat.copy(), e_opt_frac=frac.copy(),
                   cluster=pred["cluster"].copy(), confidence=conf.copy(),
                   pred_supply=pred["supply"].copy(),
                   pred_eta=pred["eta"].copy())
        if self.adapt_exit_thr:
            dlo, dhi = self.depth_bounds
            depth = dlo + (dhi - dlo) * np.clip(headroom, 0.0, 1.0)
            depth = np.where(obs.miss_rate > self.miss_target, dlo, depth)
            thr = self._thr_lo + depth[:, None] * (self._thr_hi
                                                   - self._thr_lo)  # (D, K)
            enable = conf >= self.conf_min
            table = np.where(enable[:, None, None],
                             np.broadcast_to(thr[:, :, None],
                                             self._base_thr.shape),
                             self._base_thr)
            upd["use_exit_thr"] = jnp.asarray(
                np.where(enable, True, self._base_use))
            upd["exit_thr"] = jnp.asarray(table.astype(_F32))
            log["depth"] = depth.copy()
        return upd, log
