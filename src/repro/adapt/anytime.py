"""Tuning the anytime serving engine's knobs with ``repro.adapt.tune``.

The anytime engine's exit thresholds, energy gate and eta factor are
dynamic arguments of one compiled scan
(:meth:`repro.serve.anytime.AnytimeServeEngine.score_fn`), so a candidate
*population* maps onto a ``jax.vmap`` axis: one jitted call scores every
candidate against the same request trace + supply trace — the same
population-is-the-batch trick :class:`repro.adapt.objective.TuneProblem`
plays with the fleet simulator, now over the continuous-batching LLM
engine.

Knob names (the ``SearchSpace`` vocabulary, matching the fleet tuner):

* ``exit_threshold``  — one margin threshold broadcast over all units;
* ``exit_thr_<u>``    — per-unit thresholds (overrides the broadcast);
* ``e_opt_fraction``  — the Eq. 7 energy gate as a fraction of the
  capacitor capacity;
* ``eta``             — the harvest-predictability factor.

Usage::

    from repro import adapt
    from repro.adapt.anytime import anytime_space, make_anytime_objective

    objective = make_anytime_objective(engine, requests)
    result = adapt.tune(objective, anytime_space(engine), budget=64)
    knobs = knobs_from_params(engine, result.best_params)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..serve.anytime import AnytimeKnobs, AnytimeServeEngine, AnytimeTables
from .space import SearchSpace

__all__ = ["anytime_space", "make_anytime_objective", "knobs_from_params"]


def anytime_space(engine: AnytimeServeEngine, *, per_unit: bool = False,
                  thr_range=(0.0, 10.0), eta_range=None,
                  e_opt_range=(0.05, 0.95)) -> SearchSpace:
    """The default knob space for one engine.

    ``per_unit=True`` searches an independent threshold per non-final
    unit (``exit_thr_<u>``) instead of one shared ``exit_threshold``;
    ``eta_range=None`` leaves eta out of the search (it is a *measured*
    property of the harvester in the paper — tune it only for
    sensitivity studies).
    """
    bounds = {}
    if per_unit:
        for u in range(engine.n_units - 1):
            bounds[f"exit_thr_{u}"] = thr_range
    else:
        bounds["exit_threshold"] = thr_range
    bounds["e_opt_fraction"] = e_opt_range
    if eta_range is not None:
        bounds["eta"] = eta_range
    return SearchSpace.of(**bounds)


def knobs_from_params(engine: AnytimeServeEngine, params: dict,
                      base: Optional[AnytimeKnobs] = None) -> AnytimeKnobs:
    """Materialise a scalar parameter dict (e.g. ``TuneResult
    .best_params``) into :class:`AnytimeKnobs`; unnamed knobs keep their
    ``base`` (default) values."""
    batched = _knob_batch(
        engine, {k: jnp.asarray([v], jnp.float32)
                 for k, v in params.items()}, 1, base)
    return jax.tree.map(lambda a: a[0], batched)


def _knob_batch(engine: AnytimeServeEngine, cand: dict, n: int,
                base: Optional[AnytimeKnobs]) -> AnytimeKnobs:
    """Map ``{name: (N,)}`` candidate columns onto an (N,)-batched
    :class:`AnytimeKnobs`."""
    U = engine.n_units
    k = base if base is not None else engine.default_knobs()
    exit_thr = jnp.broadcast_to(k.exit_thr, (n, U))
    if "exit_threshold" in cand:
        exit_thr = jnp.broadcast_to(
            jnp.asarray(cand["exit_threshold"], jnp.float32)[:, None],
            (n, U))
    for u in range(U):
        name = f"exit_thr_{u}"
        if name in cand:
            exit_thr = exit_thr.at[:, u].set(
                jnp.asarray(cand[name], jnp.float32))
    use = jnp.broadcast_to(k.use_exit_thr, (n, U))
    eta = (jnp.asarray(cand["eta"], jnp.float32) if "eta" in cand
           else jnp.broadcast_to(k.eta, (n,)))
    e_opt = (jnp.asarray(cand["e_opt_fraction"], jnp.float32)
             * engine.scfg.capacity if "e_opt_fraction" in cand
             else jnp.broadcast_to(k.e_opt, (n,)))
    return AnytimeKnobs(exit_thr=exit_thr, use_exit_thr=use, eta=eta,
                        e_opt=e_opt)


def make_anytime_objective(engine: AnytimeServeEngine, requests, *,
                           tardiness_weight: float = 0.0,
                           base_knobs: Optional[AnytimeKnobs] = None):
    """An ``{name: (N,) array} -> (N,) scores`` objective over the
    engine's deterministic score (on-time agreed-token fraction minus a
    tardiness penalty) — plug straight into :func:`repro.adapt.tune`.
    One compiled vmap evaluates the whole candidate population."""
    tables = (requests if isinstance(requests, AnytimeTables)
              else engine.pack(requests))
    score = engine.score_fn(tables, tardiness_weight=tardiness_weight)
    batched = jax.jit(jax.vmap(score))

    def objective(cand: dict):
        cols = {k: jnp.asarray(v, jnp.float32) for k, v in cand.items()}
        n = next(iter(cols.values())).shape[0]
        return jax.device_get(batched(_knob_batch(
            engine, cols, n, base_knobs)))

    return objective
