"""In-trajectory online adaptation: the paper's runtime eta loop.

Zygarde's headline contribution is that the scheduler *re-estimates* eta —
the harvesting-pattern predictability factor of Eq. 3 — from the pattern it
actually observes while deployed, instead of shipping a constant measured
offline.  This module implements that loop on top of segmented fleet
simulation (:func:`repro.fleet.run_segments`) as a composition of pluggable
**controllers**: after every segment the host hook measures shared
statistics (per-segment deadline-miss rate, plus whatever trace windows
each controller asks for) and hands an :class:`Observation` to each
controller in turn; every controller returns updates for the *tunable*
:class:`repro.fleet.state.FleetConfig` array fields (``eta``, ``e_opt``,
``exit_thr``/``use_exit_thr``, ``persistent``) that the priority math in
:mod:`repro.core.policy` reads live — no recompilation, the next segment's
scan just sees new arrays.

Built-in controllers:

* :class:`EtaController` — measures eta over the trailing window of the
  *observed* harvest trace (exactly :func:`repro.core.energy.eta_factor`,
  the offline estimator, applied online to the prefix the device has lived
  through) and smooths the per-segment measurements with an EWMA or
  rolling-quantile estimator — by construction the estimate never leaves
  the envelope of the measurements it has seen, and converges geometrically
  on a stationary trace (``tests/test_online.py`` pins both properties).
* :class:`FeedbackController` — the PR-4 E_opt strategy: re-tunes the
  threshold from two observed statistics, the *harvest-rate headroom*
  (observed supply vs the task set's mandatory/full-execution demand, a
  feedforward signal that closes the optional-unit gate before a lean
  phase can drain the reserve) and the per-segment *deadline-miss rate*
  (a fast-attack feedback override — any missy segment snaps the threshold
  to its conservative bound).
* :class:`repro.adapt.forecast.ForecastController` — the anticipatory
  strategy: clusters observed harvest windows online, predicts the *next*
  window's supply from per-cluster duration/transition statistics, and
  sets both E_opt and the per-unit ``exit_thr`` tables from the prediction
  (falling back to the feedback law until the forecaster is confident).

Usage::

    adapter = OnlineAdapter(statics, cfg)          # eta + feedback E_opt
    res, carry = fleet.run_segments(cfg, statics, n_segments=128,
                                    hook=adapter.hook)
    adapter.history[-1]["eta_hat"]      # the estimator's trajectory

    # explicit composition (the forecast-aware arm):
    adapter = OnlineAdapter(statics, cfg, controllers=[
        EtaController(rho=0.5, window_s=20.0),
        forecast.ForecastController(window_s=8.0),
    ])

``examples/online_adapt.py`` runs this loop on a nonstationary
(solar -> RF -> occluded) trace where it beats the best static tuned
(eta, E_opt) constants.  The measurements loop over devices in python
(``eta_factor`` is a host-side numpy routine), so the hook is meant for
the adaptation regime — one to a few hundred devices — not for
10^5-device throughput sweeps; those keep the monolithic scan.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

import jax.numpy as jnp

from ..core.energy import eta_factor
from ..fleet.state import DeviceState, FleetConfig, FleetStatics
from ..telemetry.export import TelemetrySummary

_F32 = np.float32


# --------------------------------------------------------------------------- #
# Estimators: smooth per-segment measurements into a running estimate.
# --------------------------------------------------------------------------- #


class EwmaEstimator:
    """Exponentially-weighted moving average over measurement vectors.

    The first measurement initialises the estimate; each later one moves it
    by ``rho`` of the residual.  Two properties the online loop relies on
    (and the hypothesis tests in ``tests/test_online.py`` verify):

    * **envelope**: for ``rho`` in (0, 1] the estimate is a convex
      combination of past measurements, so it always stays within
      ``[min, max]`` of the measurements seen so far;
    * **convergence**: on a stationary stream (constant measurement ``m``)
      the error contracts geometrically,
      ``|est - m| <= (1 - rho)^n |e0 - m|``.
    """

    def __init__(self, rho: float = 0.5):
        if not 0.0 < rho <= 1.0:
            raise ValueError(f"rho must be in (0, 1], got {rho}")
        self.rho = float(rho)
        self.estimate: Optional[np.ndarray] = None

    def update(self, measurement: np.ndarray) -> np.ndarray:
        m = np.asarray(measurement, np.float64)
        if self.estimate is None:
            self.estimate = m.copy()
        else:
            self.estimate = self.estimate + self.rho * (m - self.estimate)
        return self.estimate


class QuantileEstimator:
    """Rolling-window quantile over the last ``window`` measurements.

    ``q = 0.5`` is a robust (median) alternative to the EWMA when single
    segments can produce outlier eta measurements (very short windows, or a
    burst boundary splitting a segment).  A quantile of observed values
    lies between the window's min and max, so the same envelope property
    holds.
    """

    def __init__(self, q: float = 0.5, window: int = 8):
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.q = float(q)
        self.measurements: deque = deque(maxlen=int(window))
        self.estimate: Optional[np.ndarray] = None

    def update(self, measurement: np.ndarray) -> np.ndarray:
        self.measurements.append(np.asarray(measurement, np.float64))
        self.estimate = np.quantile(
            np.stack(tuple(self.measurements)), self.q, axis=0)
        return self.estimate


ESTIMATORS = {"ewma": EwmaEstimator, "quantile": QuantileEstimator}


# --------------------------------------------------------------------------- #
# Per-segment observed statistics.
# --------------------------------------------------------------------------- #


def observed_eta(events: np.ndarray, t_end: float, slot_s: float,
                 window_s: float, n_max: int = 5) -> np.ndarray:
    """Measure eta per device from the harvest trace observed so far.

    ``events`` is the ``(D, S)`` FleetConfig event stream (0/1 flags or
    fractional amplitudes); only slots strictly before ``t_end`` — the part
    of the trace the device has actually lived through — participate, and
    of those only the trailing ``window_s`` seconds, so the estimate tracks
    a *nonstationary* supply instead of averaging over the whole past.
    Returns ``(D,)`` eta values via :func:`repro.core.energy.eta_factor`
    (Eq. 3) on the binarized window.
    """
    events = np.atleast_2d(np.asarray(events))
    n_seen = int(min(t_end / slot_s, events.shape[1]))
    window = max(int(round(window_s / slot_s)), 2)
    seen = events[:, max(0, n_seen - window):n_seen]
    if seen.shape[1] < 2:
        # nothing observed yet: a patternless prior
        return np.zeros(events.shape[0])
    binary = (seen > 0.0).astype(np.int8)
    return np.array([eta_factor(row, n_max=n_max) for row in binary])


def observed_supply(events: np.ndarray, power_on: np.ndarray, t_end: float,
                    slot_s: float, window_s: float) -> np.ndarray:
    """Mean observed harvest power (W) per device over the trailing
    ``window_s`` seconds before ``t_end`` — the abundance statistic that
    complements :func:`observed_eta`'s predictability statistic."""
    events = np.atleast_2d(np.asarray(events))
    n_seen = int(min(t_end / slot_s, events.shape[1]))
    window = max(int(round(window_s / slot_s)), 1)
    seen = events[:, max(0, n_seen - window):n_seen]
    if seen.shape[1] == 0:
        return np.zeros(events.shape[0])
    return seen.mean(axis=1) * np.asarray(power_on, np.float64)


def workload_demand(cfg: FleetConfig) -> tuple[np.ndarray, np.ndarray]:
    """Per-device (mandatory_rate, full_rate) power demand in watts.

    ``mandatory_rate`` averages each task's mandatory depth over its job
    profiles (first unit whose utility test passes, else the full depth);
    ``full_rate`` assumes every unit of every task runs.  Both are static
    workload facts the deployed scheduler knows, used by the E_opt
    controllers to turn a supply rate into an energy-headroom fraction.
    """
    ue = np.asarray(cfg.unit_energy)           # (D, K, U)
    nu = np.asarray(cfg.n_units)               # (D, K)
    period = np.asarray(cfg.period)            # (D, K)
    passes = np.asarray(cfg.passes)            # (D, K, J, U)
    n_rel = np.asarray(cfg.n_releases)         # (D, K)
    d_dev, k_task, _ = ue.shape
    mand = np.zeros(d_dev)
    full = np.zeros(d_dev)
    for d in range(d_dev):
        for k in range(k_task):
            n = int(nu[d, k])
            full[d] += ue[d, k, :n].sum() / period[d, k]
            depths = [
                (int(np.flatnonzero(passes[d, k, j, :n])[0]) + 1
                 if passes[d, k, j, :n].any() else n)
                for j in range(int(n_rel[d, k]))
            ]
            if depths:
                mand[d] += np.mean(
                    [ue[d, k, :dd].sum() for dd in depths]) / period[d, k]
    return mand, full


def miss_rate(carry: DeviceState, prev: Optional[DeviceState]) -> np.ndarray:
    """Per-device deadline-miss fraction of the jobs released during the
    last segment (difference of the carry's cumulative counters)."""
    miss = np.asarray(carry.m_misses, np.float64).sum(axis=-1)
    rel = np.asarray(carry.next_rel, np.float64).sum(axis=-1)
    if prev is not None:
        miss = miss - np.asarray(prev.m_misses, np.float64).sum(axis=-1)
        rel = rel - np.asarray(prev.next_rel, np.float64).sum(axis=-1)
    return miss / np.maximum(rel, 1.0)


def ewma_supply(prev: Optional[np.ndarray], ctx: "AdapterContext",
                t_end: float, window_s: float, rho: float) -> np.ndarray:
    """One step of the supply tracker shared by the E_opt controllers:
    measure the trailing-window supply and fold it into the running EWMA
    (the first measurement initialises it)."""
    supply = observed_supply(ctx.events, ctx.power_on, t_end,
                             ctx.statics.slot_s, window_s)
    return supply if prev is None else prev + rho * (supply - prev)


def headroom_e_opt_fraction(
    supply: np.ndarray, demand: tuple[np.ndarray, np.ndarray],
    e_opt_bounds: tuple[float, float], miss_rate: np.ndarray,
    miss_target: float,
) -> tuple[np.ndarray, np.ndarray]:
    """The E_opt law shared by the feedback and forecast controllers:
    interpolate the fraction over the energy headroom
    ``(supply - mandatory) / (full - mandatory)`` within ``e_opt_bounds``,
    with the miss fast-attack snapping any missy device to the
    conservative upper bound.  Returns ``(frac, headroom)``; keeping one
    implementation makes the forecast controller's low-confidence
    degradation to the feedback law exact by construction."""
    mand, full = demand
    headroom = (supply - mand) / np.maximum(full - mand, 1e-9)
    lo, hi = e_opt_bounds
    frac = np.clip(hi - (hi - lo) * headroom, lo, hi)
    return np.where(miss_rate > miss_target, hi, frac), headroom


# --------------------------------------------------------------------------- #
# The controller substrate.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class AdapterContext:
    """Host-side snapshots of the run the controllers read but never
    rewrite, fetched from device once at the first segment boundary
    (``events`` is the largest leaf)."""

    statics: FleetStatics
    events: np.ndarray          # (D, S)
    power_on: np.ndarray        # (D,)
    capacity: np.ndarray        # (D,) float64
    base_persistent: np.ndarray  # (D,) bool — the builder's harvester half


@dataclasses.dataclass(frozen=True)
class Observation:
    """What every controller sees at a segment boundary."""

    seg: int
    t_end: float
    cfg: FleetConfig
    carry: DeviceState
    miss_rate: np.ndarray       # (D,) — jobs missed during the last segment
    ctx: AdapterContext
    #: the last segment's telemetry (counters already delta-ed against the
    #: previous boundary) when the run threads ``telemetry=``; None otherwise
    telemetry: Optional[TelemetrySummary] = None


class Controller:
    """One adaptation strategy composed into an :class:`OnlineAdapter`.

    ``update`` returns ``(updates, log)``: ``updates`` maps tunable
    FleetConfig field names to new ``(D, ...)`` arrays (merged across
    controllers, later controllers win on conflicts) and ``log`` is merged
    into the adapter's per-segment history entry.
    """

    def reset(self, cfg: Optional[FleetConfig],
              statics: FleetStatics) -> None:
        """Called once at adapter construction (``cfg`` may be None when
        the adapter was built without one; derive lazily in update)."""

    def update(self, obs: Observation) -> tuple[dict, dict]:
        raise NotImplementedError


@dataclasses.dataclass
class EtaController(Controller):
    """Runtime eta re-estimation (the paper's Eq. 3 loop, applied online).

    * ``estimator`` — ``"ewma"`` (weight ``rho``) or ``"quantile"``
      (``q``/``window`` segments), per :data:`ESTIMATORS`; smooths the
      per-segment eta measurements.
    * ``window_s`` / ``n_max`` — trailing trace window and h(N) depth for
      the per-segment :func:`observed_eta`; shorter windows track faster
      but measure noisier.
    """

    estimator: str = "ewma"
    rho: float = 0.5
    q: float = 0.5
    window: int = 8
    window_s: float = 20.0
    n_max: int = 4

    def __post_init__(self):
        if self.estimator not in ESTIMATORS:
            raise ValueError(
                f"unknown estimator {self.estimator!r}; "
                f"choose from {sorted(ESTIMATORS)}")
        self._build_estimator()

    def _build_estimator(self) -> None:
        if self.estimator == "ewma":
            self._est = EwmaEstimator(self.rho)
        else:
            self._est = QuantileEstimator(self.q, self.window)

    def reset(self, cfg: Optional[FleetConfig],
              statics: FleetStatics) -> None:
        # fresh estimator per trajectory, so one controller list can be
        # reused across adapters without leaking the previous eta_hat
        self._build_estimator()

    @property
    def eta_hat(self) -> Optional[np.ndarray]:
        return self._est.estimate

    def update(self, obs: Observation) -> tuple[dict, dict]:
        ctx = obs.ctx
        measured = observed_eta(ctx.events, obs.t_end, ctx.statics.slot_s,
                                self.window_s, self.n_max)
        eta_hat = np.clip(self._est.update(measured), 0.0, 1.0)
        upd = dict(
            eta=jnp.asarray(eta_hat.astype(_F32)),
            # the Eq. 6 fast path needs BOTH a persistent harvester and a
            # saturated eta estimate (mirrors adapt.objective.apply_params)
            persistent=jnp.asarray(ctx.base_persistent & (eta_hat >= 1.0)),
        )
        return upd, dict(measured=measured.copy(), eta_hat=eta_hat.copy())


@dataclasses.dataclass
class FeedbackController(Controller):
    """The PR-4 E_opt strategy: feedforward supply headroom + miss feedback.

    The E_opt fraction interpolates between ``e_opt_bounds`` by the
    observed *energy headroom* ``(supply - mandatory) / (full - mandatory)``
    (supply EWMA-smoothed with ``supply_rho`` over ``supply_window_s``
    trailing seconds), and any segment whose miss fraction exceeds
    ``miss_target`` snaps it to the conservative upper bound.
    """

    supply_window_s: float = 5.0
    supply_rho: float = 0.7
    e_opt_bounds: tuple[float, float] = (0.05, 0.95)
    miss_target: float = 0.1

    def reset(self, cfg: Optional[FleetConfig],
              statics: FleetStatics) -> None:
        self._demand = workload_demand(cfg) if cfg is not None else None
        self._supply_hat: Optional[np.ndarray] = None

    def update(self, obs: Observation) -> tuple[dict, dict]:
        if self._demand is None:
            self._demand = workload_demand(obs.cfg)
        self._supply_hat = ewma_supply(self._supply_hat, obs.ctx, obs.t_end,
                                       self.supply_window_s, self.supply_rho)
        frac, _ = headroom_e_opt_fraction(
            self._supply_hat, self._demand, self.e_opt_bounds,
            obs.miss_rate, self.miss_target)
        upd = dict(e_opt=jnp.asarray((frac * obs.ctx.capacity).astype(_F32)))
        return upd, dict(supply_hat=self._supply_hat.copy(),
                         e_opt_frac=frac.copy())


# --------------------------------------------------------------------------- #
# The adaptation hook.
# --------------------------------------------------------------------------- #


# history keys every entry carries (controllers may add more)
_LOG_DEFAULTS = ("measured", "eta_hat", "supply_hat", "e_opt_frac")


@dataclasses.dataclass
class OnlineAdapter:
    """Controller composition driven as a :func:`repro.fleet.run_segments`
    hook.

    Construct one per trajectory (it carries mutable estimator state),
    passing the run's ``statics`` and the initial ``cfg`` (for the workload
    demand rates), then hand ``adapter.hook`` to ``run_segments``.

    By default the adapter composes the paper's runtime loop —
    ``[EtaController(...), FeedbackController(...)]`` built from the scalar
    fields below (``adapt_e_opt=False`` drops the E_opt strategy); pass
    ``controllers=[...]`` to compose explicitly, e.g. swapping the feedback
    E_opt law for the anticipatory
    :class:`repro.adapt.forecast.ForecastController`.  Updates from later
    controllers override earlier ones on conflicting config fields.
    """

    statics: FleetStatics
    cfg: dataclasses.InitVar[Optional[FleetConfig]] = None
    estimator: str = "ewma"
    rho: float = 0.5
    q: float = 0.5
    window: int = 8
    window_s: float = 20.0
    n_max: int = 4
    adapt_e_opt: bool = True
    supply_window_s: float = 5.0
    supply_rho: float = 0.7
    e_opt_bounds: tuple[float, float] = (0.05, 0.95)
    miss_target: float = 0.1
    controllers: Optional[Sequence[Controller]] = None
    history: list = dataclasses.field(default_factory=list)

    def __post_init__(self, cfg: Optional[FleetConfig]):
        if self.controllers is None:
            self.controllers = [EtaController(
                estimator=self.estimator, rho=self.rho, q=self.q,
                window=self.window, window_s=self.window_s,
                n_max=self.n_max)]
            if self.adapt_e_opt:
                self.controllers.append(FeedbackController(
                    supply_window_s=self.supply_window_s,
                    supply_rho=self.supply_rho,
                    e_opt_bounds=self.e_opt_bounds,
                    miss_target=self.miss_target))
        self.controllers = list(self.controllers)
        for c in self.controllers:
            c.reset(cfg, self.statics)
        self._ctx: Optional[AdapterContext] = None
        self._prev_carry: Optional[DeviceState] = None
        self._prev_summary: Optional[TelemetrySummary] = None

    @property
    def eta_hat(self) -> Optional[np.ndarray]:
        """The current ``(D,)`` eta estimate (None before the first hook,
        or when no :class:`EtaController` is composed)."""
        for c in self.controllers:
            if isinstance(c, EtaController):
                return c.eta_hat
        return None

    def hook(self, seg: int, t_end: float, cfg: FleetConfig,
             carry: DeviceState,
             telemetry: Optional[TelemetrySummary] = None) -> FleetConfig:
        """``run_segments`` hook: measure, run every controller, rewrite the
        tunable config fields for the next segment.

        When the run threads ``telemetry=`` the hook receives the cumulative
        :class:`TelemetrySummary`; the miss-rate measurement then comes from
        the summary's segment delta — identical to the legacy carry diff
        (both difference the same step counters), but without fetching the
        ``(D, K)`` accumulator leaves a second time, and the controllers see
        the full summary (slack, occupancy, exit depths) via
        ``Observation.telemetry``."""
        if self._ctx is None:
            self._ctx = AdapterContext(
                statics=self.statics,
                events=np.asarray(cfg.events),
                power_on=np.asarray(cfg.power_on),
                capacity=np.asarray(cfg.capacity, np.float64),
                # the builder's persistent flag conflates harvester and eta;
                # remember the harvester half so a recovering eta can
                # re-widen it
                base_persistent=np.asarray(cfg.persistent),
            )
        seg_summary = None
        if telemetry is not None:
            seg_summary = telemetry.delta(self._prev_summary)
            self._prev_summary = telemetry
            rate = seg_summary.miss_rate
        else:
            rate = miss_rate(carry, self._prev_carry)
        obs = Observation(seg=seg, t_end=float(t_end), cfg=cfg, carry=carry,
                          miss_rate=rate, ctx=self._ctx,
                          telemetry=seg_summary)
        upd: dict = {}
        entry: dict = dict(seg=seg, t_end=float(t_end),
                           miss_rate=rate.copy(),
                           **{k: None for k in _LOG_DEFAULTS})
        for c in self.controllers:
            c_upd, c_log = c.update(obs)
            upd.update(c_upd)
            entry.update(c_log)
        self._prev_carry = carry
        self.history.append(entry)
        return cfg._replace(**upd)
