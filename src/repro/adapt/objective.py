"""Batched fleet-sweep objectives for scheduler-parameter tuning.

:class:`TuneProblem` freezes everything about the deployment that is *not*
being tuned — the task workload, the harvester patterns, capacitor, seeds,
horizon — and exposes :meth:`TuneProblem.objective`: a callable that scores a
whole population of candidate scheduler parameters with ONE jitted
:func:`repro.fleet.simulator.simulate_fleet` call.

The trick is the same FleetConfig stacking the sweep grids use: the base
config holds one device per (harvester pattern × seed) cell; a population of
N candidates tiles it to ``N * cells`` devices, overrides the tuned fields
(eta, E_opt, per-unit exit thresholds) per candidate, simulates the whole
block, and reduces each candidate's cells to a scalar with
:func:`repro.core.utility.scalarized_objective`.  The population axis is
therefore the fleet device axis — which is also what lets ``mesh=`` shard a
candidate population across backends via
:func:`repro.launch.sharding.shard_fleet_config` semantics
(``with_sharding_constraint`` inside the jitted evaluator).

Recognised parameter names:

* ``eta``             — the Eq. 7 energy-gate weight.
* ``e_opt_fraction``  — E_opt as a fraction of capacitor capacity.
* ``exit_threshold``  — one utility-test threshold shared by all units of
  every task.
* ``exit_thr_<u>``        — unit-``u`` threshold, shared by every task.
* ``exit_thr_t<k>``       — one threshold for all units of task ``k``.
* ``exit_thr_t<k>_u<u>``  — the (task ``k``, unit ``u``) threshold cell.

Unset cells fall back to the base config's threshold table.  The per-task
names are what lets :func:`repro.adapt.search.tune` trade tasks off against
each other — e.g. raise the slack-rich task's exit threshold (sacrificing
its optional units) to buy the tight task's deadlines.  ``task_weights``
scalarizes the per-task metric columns instead of the aggregate counts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core.energy import Capacitor, Harvester, eta_factor
from ..core.scheduler import TaskSpec
from ..core.utility import scalarized_objective
from ..fleet import grid as fgrid
from ..fleet.simulator import simulate_fleet
from ..fleet.state import FleetConfig, FleetStatics

# The constants the paper (and this repo's SimConfig) defaults to: E_opt at
# 70% of capacity, eta measured from the harvester trace (Eq. 3).
PAPER_E_OPT_FRACTION = 0.7

Objective = Callable[[Mapping[str, np.ndarray]], np.ndarray]


def _parse_exit_thr_name(suffix: str) -> tuple[Optional[int], Optional[int]]:
    """``exit_thr_`` suffix -> (task, unit); None selects the whole axis.

    ``"2"`` -> (None, 2); ``"t1"`` -> (1, None); ``"t1_u3"`` -> (1, 3).
    """
    if suffix.isdigit():
        return None, int(suffix)
    if suffix.startswith("t"):
        task_part, _, unit_part = suffix[1:].partition("_")
        if task_part.isdigit() and not unit_part:
            return int(task_part), None
        if (task_part.isdigit() and unit_part.startswith("u")
                and unit_part[1:].isdigit()):
            return int(task_part), int(unit_part[1:])
    raise KeyError(f"malformed exit_thr parameter suffix {suffix!r}")


def apply_params(cfg: FleetConfig, params: Mapping[str, jax.Array]
                 ) -> FleetConfig:
    """Thread tuned parameter arrays into a FleetConfig, one value per
    device.  This is the array-typed counterpart of the python scalars in
    :func:`repro.fleet.grid.device_config` — the priority math in
    :mod:`repro.core.policy` consumes the resulting ``(D,)`` fields
    unchanged.  Exit-threshold names address cells of the ``(D, K, U)``
    per-task threshold table (see the module docstring).
    """
    upd: dict = {}
    exit_thr = cfg.exit_thr
    tune_thr = False
    for name, v in params.items():
        v = jnp.asarray(v, jnp.float32)
        if name == "eta":
            eta = jnp.broadcast_to(v, cfg.eta.shape)
            upd["eta"] = eta
            # the persistent fast path (Eq. 6) requires BOTH a persistent
            # harvester and eta >= 1; the base flag already encodes the
            # harvester half, so a tuned eta can only narrow it
            upd["persistent"] = cfg.persistent & (eta >= 1.0)
        elif name == "e_opt_fraction":
            upd["e_opt"] = jnp.broadcast_to(v, cfg.eta.shape) * cfg.capacity
        elif name == "exit_threshold":
            exit_thr = jnp.broadcast_to(v[..., None, None], exit_thr.shape)
            tune_thr = True
        elif name.startswith("exit_thr_"):
            task, unit = _parse_exit_thr_name(name[len("exit_thr_"):])
            if task is None:
                exit_thr = exit_thr.at[:, :, unit].set(v[:, None])
            elif unit is None:
                exit_thr = exit_thr.at[:, task, :].set(v[:, None])
            else:
                exit_thr = exit_thr.at[:, task, unit].set(v)
            tune_thr = True
        else:
            raise KeyError(f"unknown tunable parameter {name!r}")
    if tune_thr:
        upd["exit_thr"] = exit_thr
        upd["use_exit_thr"] = jnp.ones_like(cfg.use_exit_thr)
    return cfg._replace(**upd)


@dataclasses.dataclass(frozen=True)
class TuneProblem:
    """A fixed deployment whose scheduler parameters are to be tuned.

    ``task`` accepts one :class:`TaskSpec` or a whole task set (any
    sequence) — each simulated device then runs all ``K`` streams against
    one shared energy budget, and ``task_weights`` (length ``K``) switches
    the objective from the aggregate on-time accuracy to a weighted mean of
    the per-task accuracies, so ``tune()`` can trade tasks off against each
    other."""

    task: fgrid.TaskSet
    harvesters: Sequence[Harvester]
    capacitor: Capacitor = dataclasses.field(default_factory=Capacitor)
    seeds: Sequence[int] = (0, 1)
    policy: str = "zygarde"
    horizon: float = 60.0
    queue_size: int = 3
    dt: Optional[float] = None          # default: one fragment time
    start_charged: bool = False
    clock_drift: float = 0.0            # fleet CHRT drift rate
    miss_weight: float = 0.0            # scalarization penalties
    optional_weight: float = 0.0
    # per-task scalarization weights, (K,); None = aggregate counts
    task_weights: Optional[Sequence[float]] = None
    # base per-unit utility-test thresholds, (U,) shared or (K, U) per task.
    # Candidates that tune only some `exit_thr_*` cells inherit the rest
    # from here; None keeps the workload's precomputed `passes` table for
    # un-tuned candidates (and zeros as the inherited cells).
    exit_thresholds: Optional[Sequence[float]] = None
    mesh: Optional[object] = None       # jax Mesh: shard the population

    @property
    def tasks(self) -> tuple[TaskSpec, ...]:
        return fgrid.as_task_set(self.task)

    @property
    def n_cells(self) -> int:
        return len(self.harvesters) * len(self.seeds)

    @functools.cached_property
    def _base(self) -> tuple[FleetConfig, FleetStatics]:
        """One device per (harvester, seed) cell, paper-default parameters."""
        if not self.harvesters:
            raise ValueError("TuneProblem needs at least one harvester")
        tasks = self.tasks
        if self.task_weights is not None and (
                len(self.task_weights) != len(tasks)):
            raise ValueError("task_weights length must match the task set")
        slot_lens = {h.slot_s for h in self.harvesters}
        if len(slot_lens) != 1:
            raise ValueError("all harvesters in one problem must share slot_s")
        dt = self.dt
        if dt is None:
            dt = min(float(np.min(np.asarray(t.unit_time))
                           / t.fragments_per_unit) for t in tasks)
        # paper-default eta per harvester, so knobs the search space omits
        # sit at the measured operating point rather than a hardcoded
        # constant (it also keeps the derived `persistent` flag honest:
        # eta_factor is 1.0 exactly for persistent harvesters)
        etas = self._measured_etas()
        devices = []
        for h, eta in zip(self.harvesters, etas):
            for s in self.seeds:
                devices.append(fgrid.device_config(
                    tasks, h, eta, self.capacitor,
                    policy=self.policy, horizon=self.horizon,
                    events=fgrid.sample_events(h, self.horizon, s),
                    e_opt_fraction=PAPER_E_OPT_FRACTION,
                    start_charged=self.start_charged,
                    clock_drift=self.clock_drift,
                    exit_thresholds=self.exit_thresholds,
                ))
        statics = FleetStatics(queue_size=self.queue_size, dt=dt,
                               horizon=self.horizon, slot_s=slot_lens.pop())
        return fgrid.stack_configs(devices), statics

    def _measured_etas(self) -> list[float]:
        """Eq. 3 eta measured from each harvester's event stream."""
        return [
            eta_factor(h.sample_events(np.random.default_rng(0), 4000,
                                       init=1))
            for h in self.harvesters
        ]

    def default_params(self) -> dict[str, float]:
        """The paper-default operating point: eta measured from the
        harvester event streams (Eq. 3, averaged over patterns — one
        constant for the deployment) and E_opt = 0.7 × capacity."""
        return {"eta": float(np.mean(self._measured_etas())),
                "e_opt_fraction": PAPER_E_OPT_FRACTION}

    def objective(self) -> Objective:
        """The batched objective: ``{name: (N,)} -> (N,) scores`` (higher is
        better), one fleet simulation per call.  Cached, so repeated calls
        share one jitted evaluator."""
        return self._objective_fn

    @functools.cached_property
    def _objective_fn(self) -> Objective:
        base, statics = self._base
        d0 = base.n_devices
        mesh = self.mesh
        miss_w, opt_w = self.miss_weight, self.optional_weight
        task_w = None
        if self.task_weights is not None:
            w = jnp.asarray(self.task_weights, jnp.float32)
            task_w = w / jnp.sum(w)

        @jax.jit
        def _eval(params):
            n = jax.tree.leaves(params)[0].shape[0]
            cfg = jax.tree.map(
                lambda l: jnp.broadcast_to(
                    l[None], (n,) + l.shape).reshape((n * d0,) + l.shape[1:]),
                base)
            cfg = apply_params(
                cfg, {k: jnp.repeat(v.astype(jnp.float32), d0)
                      for k, v in params.items()})
            if mesh is not None:
                from jax.sharding import NamedSharding
                from ..launch.sharding import fleet_specs
                cfg = jax.tree.map(
                    lambda l, s: jax.lax.with_sharding_constraint(
                        l, NamedSharding(mesh, s)),
                    cfg, fleet_specs(mesh, cfg))
            res = simulate_fleet(cfg, statics)
            if task_w is None:
                score = scalarized_objective(
                    res.correct, res.released, res.deadline_misses,
                    res.optional_units, res.units_executed,
                    miss_weight=miss_w, optional_weight=opt_w)
            else:
                # per-task reward columns (D, K), weighted across the task
                # set — the multi-task trade-off surface tune() climbs
                per_task = scalarized_objective(
                    res.task_correct, res.task_released, res.task_misses,
                    res.task_optional, res.task_units,
                    miss_weight=miss_w, optional_weight=opt_w)
                score = jnp.sum(per_task * task_w[None, :], axis=1)
            return score.reshape(n, d0).mean(axis=1)

        def objective_fn(params: Mapping[str, np.ndarray]) -> np.ndarray:
            arrs = {k: np.atleast_1d(np.asarray(v, np.float32))
                    for k, v in params.items()}
            n = next(iter(arrs.values())).shape[0]
            # bucket block sizes to powers of two: the jitted evaluator
            # compiles per distinct size, and drivers produce ragged blocks
            # (warmups, tail blocks, single-point score() calls)
            n_pad = 1 << (n - 1).bit_length() if n > 1 else 1
            if mesh is not None:
                while (n_pad * d0) % mesh.size:
                    n_pad += 1
            if n_pad != n:
                arrs = {k: np.concatenate([v, np.repeat(v[:1], n_pad - n)])
                        for k, v in arrs.items()}
            return np.asarray(_eval(arrs))[:n]

        objective_fn.problem = self
        return objective_fn

    def score(self, params: Mapping[str, float]) -> float:
        """Score one operating point (e.g. :meth:`default_params`)."""
        return float(self.objective()(
            {k: np.asarray([v], np.float32) for k, v in params.items()})[0])
