"""Search drivers: ``adapt.tune(objective, space, budget)``.

Every driver treats the objective as a *batched* black box — one call scores
a whole ``(N, P)`` candidate block (one fleet simulation when the objective
comes from :meth:`repro.adapt.objective.TuneProblem.objective`) — and spends
at most ``budget`` candidate evaluations.  All randomness flows from the
``seed`` argument, so runs are reproducible.

Drivers
-------
``random``   uniform sampling in blocks of ``pop_size``.
``grid``     the largest full-factorial lattice that fits the budget.
``es``       (mu + lambda) evolution strategy: Gaussian offspring around the
             elite mean with a geometrically-annealed step size;
             plus-selection keeps the best-so-far monotone.
``es-grad``  antithetic-perturbation ES gradient ascent on the continuous
             knobs: ``g ~ E[(f(x+s e) - f(x-s e)) / 2s * e]`` — the
             smoothed-objective gradient the differentiable-friendly
             scalarization in :func:`repro.core.utility.scalarized_objective`
             is designed for.
``cma``      full-covariance CMA-ES (rank-1 + rank-mu updates, cumulative
             step-size adaptation): learns the coupling between knobs —
             e.g. eta and the e_opt fraction trade off through the same
             energy budget — that the isotropic ``es`` ignores.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional

import numpy as np

from .space import SearchSpace


@dataclasses.dataclass
class TuneResult:
    driver: str
    best_params: dict
    best_score: float
    n_evals: int
    history: list   # per-block dicts: iteration, n_evals, best_score, ...

    def __repr__(self) -> str:  # compact: history can be long
        p = {k: round(v, 4) for k, v in self.best_params.items()}
        return (f"TuneResult(driver={self.driver!r}, best_score="
                f"{self.best_score:.4f}, best_params={p}, "
                f"n_evals={self.n_evals})")


class _Tracker:
    """Best-so-far bookkeeping shared by every driver."""

    def __init__(self, objective, space: SearchSpace):
        self._obj = objective
        self._space = space
        self.best_x: Optional[np.ndarray] = None
        self.best_score = -np.inf
        self.n_evals = 0
        self.history: list[dict] = []

    def evaluate(self, x: np.ndarray) -> np.ndarray:
        x = np.atleast_2d(x)
        scores = np.asarray(self._obj(self._space.to_dict(x)),
                            np.float64).reshape(-1)
        if scores.shape[0] != x.shape[0]:
            raise ValueError("objective returned wrong number of scores")
        self.n_evals += x.shape[0]
        i = int(np.argmax(scores))
        if scores[i] > self.best_score:
            self.best_score = float(scores[i])
            self.best_x = x[i].copy()
        self.history.append(dict(
            iteration=len(self.history), n_evals=self.n_evals,
            best_score=self.best_score,
            block_mean=float(scores.mean()), block_max=float(scores.max()),
        ))
        return scores

    def result(self, driver: str) -> TuneResult:
        params = {}
        if self.best_x is not None:
            # integer knobs (Param.integer) come back as python ints so the
            # winning point can be splatted straight into constructors like
            # ForecastController(n_clusters=...)
            params = {p.name: (int(round(v)) if p.integer else float(v))
                      for p, v in zip(self._space.params, self.best_x)}
        return TuneResult(driver=driver, best_params=params,
                          best_score=float(self.best_score),
                          n_evals=self.n_evals, history=self.history)


# --------------------------------------------------------------------------- #
# Drivers.
# --------------------------------------------------------------------------- #


def _random(tr: _Tracker, space, budget, rng, pop, **_):
    while tr.n_evals < budget:
        n = min(pop, budget - tr.n_evals)
        tr.evaluate(space.sample(rng, n))


def _grid(tr: _Tracker, space, budget, rng, pop, **_):
    # space.grid floors at 2 points/dim, which can overshoot tiny budgets —
    # truncate so the at-most-budget contract holds
    lattice = space.grid(budget)[:budget]
    for i in range(0, len(lattice), pop):
        tr.evaluate(lattice[i:i + pop])


def _es(tr: _Tracker, space, budget, rng, pop, *, sigma0=0.25,
        sigma_decay=0.85, elite_frac=0.25, **_):
    lam = min(pop, budget)
    mu = max(1, int(round(lam * elite_frac)))
    x = space.sample(rng, lam)
    s = tr.evaluate(x)
    order = np.argsort(s)[::-1][:mu]
    px, ps = x[order], s[order]
    gen = 0
    while tr.n_evals + lam <= budget:
        gen += 1
        mean = px.mean(axis=0)
        sigma = sigma0 * space.widths * sigma_decay ** gen
        off = space.clip(mean + rng.normal(size=(lam, space.n_dims)) * sigma)
        so = tr.evaluate(off)
        # plus-selection over parents + offspring: elites never regress
        allx = np.concatenate([px, off])
        alls = np.concatenate([ps, so])
        order = np.argsort(alls)[::-1][:mu]
        px, ps = allx[order], alls[order]


def _es_grad(tr: _Tracker, space, budget, rng, pop, *, sigma0=0.15,
             sigma_decay=0.9, lr=0.2, warmup_frac=0.25, **_):
    half = max(1, min(pop, budget) // 2)
    # short random warmup picks the start point (gradient ascent from the
    # space center can sit on a plateau of the energy gate)
    n_warm = max(half, int(budget * warmup_frac)) if budget >= 4 * half else 0
    if n_warm:
        tr.evaluate(space.sample(rng, n_warm))
    theta = (tr.best_x.copy() if tr.best_x is not None else space.center())
    gen = 0
    while tr.n_evals + 2 * half <= budget:
        sigma = sigma0 * space.widths * sigma_decay ** gen
        eps = rng.normal(size=(half, space.n_dims))
        xp = space.clip(theta + sigma * eps)
        xm = space.clip(theta - sigma * eps)
        s = tr.evaluate(np.concatenate([xp, xm]))
        adv = s[:half] - s[half:]
        if np.ptp(s) > 0:   # rank-free normalization for step-size control
            adv = adv / (np.abs(adv).max() + 1e-12)
        grad = (adv[:, None] * eps).mean(axis=0)
        norm = np.linalg.norm(grad)
        if norm > 1e-12:
            step = lr * space.widths * sigma_decay ** gen
            theta = space.clip(theta + step * grad / norm)
        gen += 1
    # ascend from, but never return worse than, the best evaluated point
    if tr.n_evals < budget:
        tr.evaluate(theta[None])


def _cma(tr: _Tracker, space, budget, rng, pop, *, sigma0=0.3, **_):
    """Full-covariance CMA-ES (Hansen's tutorial constants).

    Works in width-normalised coordinates (``x = z * widths``) so one
    relative ``sigma0`` fits heterogeneous knob ranges; the covariance then
    learns the *residual* correlations between knobs.  Selection feeds back
    the *clipped* candidates, so the distribution contracts into the box
    rather than repeatedly sampling outside it.
    """
    n = space.n_dims
    lam = max(4, min(pop, budget))
    mu = lam // 2
    w = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
    w = w / w.sum()
    mu_eff = 1.0 / np.sum(w ** 2)
    cc = (4 + mu_eff / n) / (n + 4 + 2 * mu_eff / n)
    cs = (mu_eff + 2) / (n + mu_eff + 5)
    c1 = 2 / ((n + 1.3) ** 2 + mu_eff)
    cmu = min(1 - c1,
              2 * (mu_eff - 2 + 1 / mu_eff) / ((n + 2) ** 2 + mu_eff))
    damps = 1 + 2 * max(0.0, np.sqrt((mu_eff - 1) / (n + 1)) - 1) + cs
    chi_n = np.sqrt(n) * (1 - 1 / (4 * n) + 1 / (21 * n ** 2))

    scale = space.widths
    m = space.center() / scale
    sigma = float(sigma0)
    C = np.eye(n)
    pc = np.zeros(n)
    ps = np.zeros(n)
    gen = 0
    while tr.n_evals + lam <= budget:
        gen += 1
        C = (C + C.T) / 2
        evals, B = np.linalg.eigh(C)
        evals = np.maximum(evals, 1e-20)
        D = np.sqrt(evals)
        z = rng.normal(size=(lam, n))
        y = z @ (B * D).T                      # y ~ N(0, C)
        x = space.clip((m + sigma * y) * scale)
        s = tr.evaluate(x)
        order = np.argsort(s)[::-1][:mu]
        y_sel = (x[order] / scale - m) / sigma  # post-clip steps
        y_w = w @ y_sel
        m = m + sigma * y_w
        c_invsqrt = (B / D) @ B.T
        ps = (1 - cs) * ps + np.sqrt(cs * (2 - cs) * mu_eff) * (
            c_invsqrt @ y_w)
        h_sig = (np.linalg.norm(ps)
                 / np.sqrt(1 - (1 - cs) ** (2 * gen)) / chi_n
                 < 1.4 + 2 / (n + 1))
        pc = (1 - cc) * pc + h_sig * np.sqrt(cc * (2 - cc) * mu_eff) * y_w
        rank_mu = (y_sel * w[:, None]).T @ y_sel
        C = ((1 - c1 - cmu) * C
             + c1 * (np.outer(pc, pc) + (1 - h_sig) * cc * (2 - cc) * C)
             + cmu * rank_mu)
        sigma *= float(np.exp((cs / damps)
                              * (np.linalg.norm(ps) / chi_n - 1)))
        sigma = float(np.clip(sigma, 1e-12, 1e3))


DRIVERS: Mapping[str, Callable] = {
    "random": _random,
    "grid": _grid,
    "es": _es,
    "es-grad": _es_grad,
    "cma": _cma,
}


def tune(objective, space: SearchSpace, budget: int, *,
         driver: str = "es", seed: int = 0, pop_size: Optional[int] = None,
         **driver_kwargs) -> TuneResult:
    """Search ``space`` for the parameters maximising ``objective``.

    objective : ``{name: (N,) array} -> (N,) scores`` (higher is better),
        e.g. :meth:`repro.adapt.objective.TuneProblem.objective`.
    space     : the bounded knobs to search.
    budget    : total candidate evaluations across all blocks.
    driver    : one of ``random | grid | es | es-grad | cma``.
    pop_size  : candidates per objective call (the fleet batch); default
        ``min(16, budget)``.
    """
    if driver not in DRIVERS:
        raise KeyError(f"unknown driver {driver!r}; have {sorted(DRIVERS)}")
    if budget < 1:
        raise ValueError("budget must be >= 1")
    pop = pop_size or min(16, budget)
    tr = _Tracker(objective, space)
    DRIVERS[driver](tr, space, budget, np.random.default_rng(seed), pop,
                    **driver_kwargs)
    return tr.result(driver)
