"""Online policy search: closed-loop tuning of Zygarde's scheduler knobs.

The paper's headline is *adaptation* — the scheduler should fit its
energy gate (eta), optional-unit target (E_opt) and utility thresholds to
the deployment's harvesting pattern, not run fixed constants.  This
subsystem turns the vectorized fleet simulator (:mod:`repro.fleet`) into the
inner loop of that adaptation: a candidate *population* becomes the fleet
device axis, so one jitted call scores every candidate against every
harvester pattern × seed cell (and ``mesh=`` shards the population across
backends).

Public API::

    from repro import adapt

    problem = adapt.TuneProblem(task=task, harvesters=(h1, h2, h3))
    space = adapt.SearchSpace.of(eta=(0.05, 1.0), e_opt_fraction=(0.05, 0.95))
    result = adapt.tune(problem.objective(), space, budget=256, driver="es")
    result.best_params                     # {"eta": ..., "e_opt_fraction": ...}
    problem.score(problem.default_params())  # the paper-default baseline

Drivers: ``random`` / ``grid`` (vectorized one-shot search), ``es``
((mu+lambda) evolution strategy), ``es-grad`` (antithetic-perturbation ES
gradients) — see :mod:`repro.adapt.search`.

Offline tuning picks constants *between* runs; :mod:`repro.adapt.online`
closes the loop *inside* a run — an :class:`OnlineAdapter` composes
pluggable controllers into a :func:`repro.fleet.run_segments` hook that
rewrites the tunable FleetConfig fields mid-trajectory.  The default
composition is the paper's runtime loop (an :class:`EtaController`
re-estimating eta from the observed pattern + the reactive
:class:`FeedbackController` for E_opt); :mod:`repro.adapt.forecast` adds
the anticipatory :class:`ForecastController`, which clusters observed
harvest windows online (k-means over window features, Pallas-kernel
classify/adapt), learns per-cluster duration/transition statistics, and
sets E_opt and the per-unit exit thresholds from the *predicted* next
window::

    adapter = adapt.OnlineAdapter(statics, cfg)          # eta + feedback
    adapter = adapt.OnlineAdapter(statics, cfg, controllers=[
        adapt.EtaController(window_s=20.0),
        adapt.ForecastController(window_s=8.0),          # forecast-aware
    ])
    res, carry = fleet.run_segments(cfg, statics, n_segments=24,
                                    hook=adapter.hook)
"""
from .forecast import (  # noqa: F401
    FEATURES,
    ForecastController,
    HarvestForecaster,
    window_features,
)
from .objective import (  # noqa: F401
    PAPER_E_OPT_FRACTION,
    Objective,
    TuneProblem,
    apply_params,
)
from .online import (  # noqa: F401
    ESTIMATORS,
    Controller,
    EtaController,
    EwmaEstimator,
    FeedbackController,
    Observation,
    OnlineAdapter,
    QuantileEstimator,
    miss_rate,
    observed_eta,
    observed_supply,
    workload_demand,
)
from .anytime import (  # noqa: F401
    anytime_space,
    knobs_from_params,
    make_anytime_objective,
)
from .search import DRIVERS, TuneResult, tune  # noqa: F401
from .space import Param, SearchSpace  # noqa: F401
