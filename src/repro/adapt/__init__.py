"""Online policy search: closed-loop tuning of Zygarde's scheduler knobs.

The paper's headline is *adaptation* — the scheduler should fit its
energy gate (eta), optional-unit target (E_opt) and utility thresholds to
the deployment's harvesting pattern, not run fixed constants.  This
subsystem turns the vectorized fleet simulator (:mod:`repro.fleet`) into the
inner loop of that adaptation: a candidate *population* becomes the fleet
device axis, so one jitted call scores every candidate against every
harvester pattern × seed cell (and ``mesh=`` shards the population across
backends).

Public API::

    from repro import adapt

    problem = adapt.TuneProblem(task=task, harvesters=(h1, h2, h3))
    space = adapt.SearchSpace.of(eta=(0.05, 1.0), e_opt_fraction=(0.05, 0.95))
    result = adapt.tune(problem.objective(), space, budget=256, driver="es")
    result.best_params                     # {"eta": ..., "e_opt_fraction": ...}
    problem.score(problem.default_params())  # the paper-default baseline

Drivers: ``random`` / ``grid`` (vectorized one-shot search), ``es``
((mu+lambda) evolution strategy), ``es-grad`` (antithetic-perturbation ES
gradients) — see :mod:`repro.adapt.search`.

Offline tuning picks constants *between* runs; :mod:`repro.adapt.online`
closes the loop *inside* a run — an :class:`OnlineAdapter` hook on
:func:`repro.fleet.run_segments` re-estimates eta from the observed
harvest pattern (EWMA / rolling quantile over per-segment Eq. 3
measurements) and rewrites the tunable FleetConfig fields mid-trajectory::

    adapter = adapt.OnlineAdapter(statics)
    res, carry = fleet.run_segments(cfg, statics, n_segments=24,
                                    hook=adapter.hook)
"""
from .objective import (  # noqa: F401
    PAPER_E_OPT_FRACTION,
    Objective,
    TuneProblem,
    apply_params,
)
from .online import (  # noqa: F401
    ESTIMATORS,
    EwmaEstimator,
    OnlineAdapter,
    QuantileEstimator,
    miss_rate,
    observed_eta,
    observed_supply,
    workload_demand,
)
from .search import DRIVERS, TuneResult, tune  # noqa: F401
from .space import Param, SearchSpace  # noqa: F401
