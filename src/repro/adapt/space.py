"""Search-space description for scheduler-parameter tuning.

A :class:`SearchSpace` is an ordered tuple of bounded continuous
:class:`Param` knobs.  Candidates travel through the search drivers as
``(N, P)`` float arrays (one row per candidate, one column per knob) and are
handed to objectives as ``{name: (N,) array}`` dicts — the representation
:func:`repro.adapt.objective.apply_params` maps onto
:class:`repro.fleet.state.FleetConfig` fields.

Recognised names (see :mod:`repro.adapt.objective`): ``eta``,
``e_opt_fraction``, ``exit_threshold`` (shared across tasks and units),
``exit_thr_<u>`` (unit column, all tasks), ``exit_thr_t<k>`` (all units of
task ``k``) and ``exit_thr_t<k>_u<u>`` (one task/unit cell) — the last two
address the task-set axis of multi-task devices.  The space itself is
name-agnostic, so synthetic objectives can use any names.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    """One bounded knob: continuous by default, integer-valued with
    ``integer=True`` (candidates snap to whole numbers in :meth:`clip`, so
    the continuous drivers — Gaussian ES offspring included — search the
    lattice transparently; cluster counts and window lengths of the
    forecast controller are the motivating knobs)."""

    name: str
    low: float
    high: float
    integer: bool = False

    def __post_init__(self):
        if not self.high > self.low:
            raise ValueError(f"{self.name}: high must exceed low")
        if self.integer and np.floor(self.high) < np.ceil(self.low):
            raise ValueError(
                f"{self.name}: no integer lies in [{self.low}, {self.high}]")


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    params: Tuple[Param, ...]

    @classmethod
    def of(cls, **bounds: Sequence[float]) -> "SearchSpace":
        """``SearchSpace.of(eta=(0.05, 1.0), e_opt_fraction=(0.05, 0.95),
        n_clusters=(2, 6, int))`` — a third ``int`` (or ``"int"``) element
        marks an integer knob."""
        params = []
        for k, bound in bounds.items():
            lo, hi = bound[0], bound[1]
            integer = len(bound) > 2 and bound[2] in (int, "int")
            params.append(Param(k, float(lo), float(hi), integer=integer))
        return cls(tuple(params))

    @property
    def _integer_mask(self) -> np.ndarray:
        return np.array([p.integer for p in self.params], bool)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.params)

    @property
    def n_dims(self) -> int:
        return len(self.params)

    @property
    def lows(self) -> np.ndarray:
        return np.array([p.low for p in self.params], np.float64)

    @property
    def highs(self) -> np.ndarray:
        return np.array([p.high for p in self.params], np.float64)

    @property
    def widths(self) -> np.ndarray:
        return self.highs - self.lows

    def center(self) -> np.ndarray:
        return 0.5 * (self.lows + self.highs)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """(n, P) uniform candidates (integer dims snap to the lattice)."""
        return self.clip(rng.uniform(self.lows, self.highs,
                                     size=(n, self.n_dims)))

    def clip(self, x: np.ndarray) -> np.ndarray:
        x = np.clip(x, self.lows, self.highs)
        mask = self._integer_mask
        if mask.any():
            # snap to the integer lattice *inside* the bounds — rounding a
            # clipped value can escape a fractional bound (5.4 in (2, 5.5)
            # would round to 6), so clamp to [ceil(low), floor(high)]
            snapped = np.clip(np.round(x), np.ceil(self.lows),
                              np.floor(self.highs))
            x = np.where(mask[None, :] if x.ndim == 2 else mask, snapped, x)
        return x

    def grid(self, budget: int) -> np.ndarray:
        """The largest full-factorial lattice that fits in ``budget``
        evaluations: ``r = floor(budget ** (1/P))`` points per dim
        (integer dims enumerate at most their whole-number lattice)."""
        r = max(2, int(np.floor(budget ** (1.0 / self.n_dims))))
        axes = []
        for p in self.params:
            if p.integer:
                ilo, ihi = np.ceil(p.low), np.floor(p.high)
                n_int = int(ihi - ilo) + 1
                axes.append(np.unique(np.round(
                    np.linspace(ilo, ihi, min(r, max(n_int, 2))))))
            else:
                axes.append(np.linspace(p.low, p.high, r))
        mesh = np.meshgrid(*axes, indexing="ij")
        return np.stack([m.ravel() for m in mesh], axis=1)

    def to_dict(self, x: np.ndarray) -> Mapping[str, np.ndarray]:
        """(N, P) candidate block -> {name: (N,) column} for objectives."""
        x = np.atleast_2d(np.asarray(x, np.float64))
        return {p.name: x[:, i] for i, p in enumerate(self.params)}
