"""Pallas TPU kernel: flash (fused online-softmax) GQA attention forward.

This is the §Perf P1 traffic target: the pure-XLA chunked attention
materialises ~S^2/2-sized f32 score/probability tensors in HBM per layer;
this kernel keeps the whole softmax in VMEM, touching HBM only for
q/k/v/o — the memory roofline drops from O(S^2) to O(S·d) per head.

Grid: (batch·kv-head, q-block, kv-block) with the kv axis innermost
(sequential), running max / denominator / accumulator in VMEM scratch.
Causal + sliding-window masking is applied per tile from block offsets;
fully-masked tiles still execute (the grid is static) but cost no HBM.
Q heads sharing a KV head (GQA) are processed together so each k/v tile
loads once per group.

Block shapes default to (128, 128) — MXU-aligned on the (q, kv) dims; the
head dim rides along unblocked (<= 256 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._tiling import choose_block, pad_axis

NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, n_kv_blocks, block_q, block_k, causal, window, q_offset, kv_len,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]  # (1, block_q, G, hd)
    k = k_ref[...]  # (1, block_k, hd)
    v = v_ref[...]
    hd = q.shape[-1]
    s = jnp.einsum(
        "bqgh,bkh->bqgk", q, k, preferred_element_type=jnp.float32
    ) * hd ** -0.5

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    ) + q_offset
    kpos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos <= window
    if kv_len:  # kv axis was padded to a block multiple: mask padded keys
        mask &= kpos < kv_len
    s = jnp.where(mask[None, :, None, :], s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[..., None] + jnp.einsum(
        "bqgk,bkh->bqgh", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        o_ref[...] = acc_new / jnp.maximum(l_new[..., None], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: (B, S, H, hd); k/v: (B, Skv, KV, hd) -> (B, S, H, hd) f32.

    GQA: H query heads grouped over KV heads.  ``q_offset`` shifts query
    positions (cross-attention prefix / continued decode)."""
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    # pad the tiled sequence axes to block multiples instead of shrinking
    # the blocks (odd/prime lengths would collapse to 1-row tiles).  Padded
    # query rows are garbage and sliced off; padded kv positions are masked
    # inside the kernel (``kpos < kv_len``) so real rows stay bit-exact.
    bQ, Sp = choose_block(S, block_q)
    bK, Skvp = choose_block(Skv, block_k)
    n_kv_blocks = Skvp // bK

    # (B*KV, S, G, hd) so one grid axis covers batch x kv-head
    qg = (
        q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4)
        .reshape(B * KV, S, G, hd)
    )
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    if Sp != S:
        qg = pad_axis(qg, 1, bQ)
    if Skvp != Skv:
        kg = pad_axis(kg, 1, bK)
        vg = pad_axis(vg, 1, bK)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, n_kv_blocks=n_kv_blocks, block_q=bQ, block_k=bK,
            causal=causal, window=window, q_offset=q_offset,
            kv_len=Skv if Skvp != Skv else 0,
        ),
        grid=(B * KV, Sp // bQ, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bQ, G, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, bK, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bK, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bQ, G, hd), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sp, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, bQ, G), jnp.float32),
            pltpu.VMEM((1, bQ, G), jnp.float32),
            pltpu.VMEM((1, bQ, G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return (
        out[:, :S].reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4)
        .reshape(B, S, H, hd)
    )
