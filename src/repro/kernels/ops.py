"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops — which validates tiling/indexing
logic against the pure-jnp oracles in :mod:`repro.kernels.ref`.  On a real
TPU backend the same calls compile to Mosaic.

The ``fleet_*`` wrappers add *fleet-shaped* dispatch for the k-means
kernels: the online harvest-pattern forecaster (:mod:`repro.adapt.forecast`)
classifies and adapts over ``(D, W, F)`` window batches — ``D`` devices ×
``W`` trailing windows × ``F`` features — so the wrappers flatten the
leading batch axes, pad the feature (lane) dimension to a multiple of 128
and the row (sublane) dimension to a tile multiple, run the 2-D kernel
once over the whole fleet, and restore the batch shape.  L1 distances are
invariant to zero-padded feature columns (both operands gain the same
zeros), and padded rows carry assignment ``-1`` whose one-hot is all-zero,
so the padding never leaks into results.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ._tiling import pad_axis as _pad_axis  # noqa: F401  (public via ops)
from .centroid_update import centroid_update as _centroid_update
from .decode_gqa import decode_gqa as _decode_gqa
from .flash_attn import flash_attention as _flash_attention
from .fleet_priority import fleet_priority as _fleet_priority
from .fleet_step import fleet_fused_steps as _fleet_fused_steps
from .fleet_step import serve_fused_steps as _serve_fused_steps
from .l1_topk2 import l1_topk2 as _l1_topk2
from .pairwise_l1 import pairwise_l1 as _pairwise_l1
from .rglru_scan import rglru_scan as _rglru_scan


@functools.lru_cache(maxsize=1)
def _interpret() -> bool:
    """Should Pallas run in interpret mode on this backend?

    Pallas compiles natively on TPU (Mosaic) *and* GPU (Triton); only
    plain-CPU backends need interpret mode.  Cached — the backend cannot
    change within a process.  ``REPRO_PALLAS_INTERPRET=1`` (or ``0``)
    overrides the autodetection either way, for debugging compiled-path
    issues without editing call sites.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env:
        return env not in ("0", "false", "no", "off")
    return jax.default_backend() not in ("tpu", "gpu")


def l1_topk2(x, centroids, **kw):
    kw.setdefault("interpret", _interpret())
    return _l1_topk2(x, centroids, **kw)


def pairwise_l1(x, y, **kw):
    kw.setdefault("interpret", _interpret())
    return _pairwise_l1(x, y, **kw)


def centroid_update(centroids, x, assign, weight, **kw):
    kw.setdefault("interpret", _interpret())
    return _centroid_update(centroids, x, assign, weight, **kw)


def rglru_scan(a, b, h0, **kw):
    kw.setdefault("interpret", _interpret())
    return _rglru_scan(a, b, h0, **kw)


def decode_gqa(q, k_cache, v_cache, slot_pos, my_pos, **kw):
    kw.setdefault("interpret", _interpret())
    return _decode_gqa(q, k_cache, v_cache, slot_pos, my_pos, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_attention(q, k, v, **kw)


def fleet_l1_topk2(x, centroids, *, block_b: int = 256, lane: int = 128,
                   **kw):
    """:func:`l1_topk2` over fleet-batched windows.

    ``x``: ``(..., F)`` feature windows with any leading batch shape (the
    forecaster passes ``(D, W, F)`` or ``(D, F)``); ``centroids``: ``(k, F)``.
    Returns ``(d1, d2, idx)`` each shaped like the batch ``(...,)``.  Rows
    are flattened and tile-padded, features are zero-padded to a lane
    multiple — L1 distances are unchanged because both operands gain the
    same zero columns.
    """
    x = jnp.asarray(x, jnp.float32)
    centroids = jnp.asarray(centroids, jnp.float32)
    batch = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1]))
    n_rows = flat.shape[0]
    flat = _pad_axis(_pad_axis(flat, 1, lane), 0, min(block_b, 8))
    cents = _pad_axis(centroids, 1, lane)
    d1, d2, idx = l1_topk2(flat, cents, block_b=block_b, **kw)
    return (d1[:n_rows].reshape(batch), d2[:n_rows].reshape(batch),
            idx[:n_rows].reshape(batch))


def fleet_centroid_update(centroids, x, assign, weight, *, lane: int = 128,
                          **kw):
    """:func:`centroid_update` over fleet-batched windows.

    ``x``: ``(..., F)``, ``assign``: ``(...,)`` int32 cluster ids (rows with
    ``assign < 0`` are ignored — their one-hot is all-zero), ``centroids``:
    ``(k, F)``.  Flattens the batch, pads rows with ``assign = -1`` and
    features with zeros, and slices the padded columns back off the
    ``(k, F)`` result.
    """
    centroids = jnp.asarray(centroids, jnp.float32)
    k, f = centroids.shape
    flat = jnp.asarray(x, jnp.float32).reshape((-1, f))
    aflat = jnp.asarray(assign, jnp.int32).reshape((-1,))
    flat = _pad_axis(_pad_axis(flat, 1, lane), 0, 8)
    aflat = _pad_axis(aflat, 0, 8, value=-1)
    new_c = centroid_update(_pad_axis(centroids, 1, lane), flat, aflat,
                            weight, **kw)
    return new_c[:, :f]


def fleet_priority(policy, active, laxity, release, utility, mandatory,
                   alpha, beta, eta, persistent, energy, e_opt, charge,
                   capacity, gate_e, drain, forced, task, rr_cursor, *,
                   n_tasks=1, **kw):
    """Batched scheduler pick + capacitor update over a task-set workload;
    returns jnp-typed flags (``sel`` int32, ``picked``/``run`` bool,
    ``e_new`` f32).  ``task``/``rr_cursor`` feed the in-kernel round-robin
    task rotation (``n_tasks`` is static)."""
    kw.setdefault("interpret", _interpret())
    sel, picked, run, e_new = _fleet_priority(
        policy, active, laxity, release, utility, mandatory, alpha, beta,
        eta, persistent, energy, e_opt, charge, capacity, gate_e, drain,
        forced, task, rr_cursor, n_tasks=n_tasks, **kw)
    return sel, picked.astype(bool), run.astype(bool), e_new


def fleet_fused_steps(cfg, carry, i0, *, statics, n_steps, **kw):
    """Whole-segment fused device-step: advance every device ``n_steps``
    timesteps in ONE ``pallas_call`` with the carry tile VMEM-resident
    (:mod:`repro.kernels.fleet_step`).  Bit-exact vs the vmap scan —
    the kernel body IS :func:`repro.core.step.device_step`."""
    kw.setdefault("interpret", _interpret())
    return _fleet_fused_steps(cfg, carry, i0, statics=statics,
                              n_steps=n_steps, **kw)


def serve_fused_steps(cfg, carry, tables, i0, job0, *, statics, n_steps,
                      **kw):
    """Whole-segment fused LIVE serving: advance every device ``n_steps``
    timesteps in ONE ``pallas_call`` with the L1-top-2 classify +
    live-register update in-tile and the centroid bank VMEM-resident
    (:mod:`repro.kernels.fleet_step`).  Bit-exact vs the serve scan —
    the kernel body IS :func:`repro.serve.fleet_engine.serve_step`."""
    kw.setdefault("interpret", _interpret())
    return _serve_fused_steps(cfg, carry, tables, i0, job0,
                              statics=statics, n_steps=n_steps, **kw)
