"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops — which validates tiling/indexing
logic against the pure-jnp oracles in :mod:`repro.kernels.ref`.  On a real
TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import jax

from .centroid_update import centroid_update as _centroid_update
from .decode_gqa import decode_gqa as _decode_gqa
from .flash_attn import flash_attention as _flash_attention
from .fleet_priority import fleet_priority as _fleet_priority
from .l1_topk2 import l1_topk2 as _l1_topk2
from .pairwise_l1 import pairwise_l1 as _pairwise_l1
from .rglru_scan import rglru_scan as _rglru_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def l1_topk2(x, centroids, **kw):
    kw.setdefault("interpret", _interpret())
    return _l1_topk2(x, centroids, **kw)


def pairwise_l1(x, y, **kw):
    kw.setdefault("interpret", _interpret())
    return _pairwise_l1(x, y, **kw)


def centroid_update(centroids, x, assign, weight, **kw):
    kw.setdefault("interpret", _interpret())
    return _centroid_update(centroids, x, assign, weight, **kw)


def rglru_scan(a, b, h0, **kw):
    kw.setdefault("interpret", _interpret())
    return _rglru_scan(a, b, h0, **kw)


def decode_gqa(q, k_cache, v_cache, slot_pos, my_pos, **kw):
    kw.setdefault("interpret", _interpret())
    return _decode_gqa(q, k_cache, v_cache, slot_pos, my_pos, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_attention(q, k, v, **kw)


def fleet_priority(policy, active, laxity, release, utility, mandatory,
                   alpha, beta, eta, persistent, energy, e_opt, charge,
                   capacity, gate_e, drain, forced, task, rr_cursor, *,
                   n_tasks=1, **kw):
    """Batched scheduler pick + capacitor update over a task-set workload;
    returns jnp-typed flags (``sel`` int32, ``picked``/``run`` bool,
    ``e_new`` f32).  ``task``/``rr_cursor`` feed the in-kernel round-robin
    task rotation (``n_tasks`` is static)."""
    kw.setdefault("interpret", _interpret())
    sel, picked, run, e_new = _fleet_priority(
        policy, active, laxity, release, utility, mandatory, alpha, beta,
        eta, persistent, energy, e_opt, charge, capacity, gate_e, drain,
        forced, task, rr_cursor, n_tasks=n_tasks, **kw)
    return sel, picked.astype(bool), run.astype(bool), e_new
