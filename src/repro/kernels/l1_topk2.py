"""Pallas TPU kernel: batched L1 distance to k centroids + top-2 margins.

This is Zygarde's inner loop: every unit boundary runs the k-means classify +
utility test, which needs, for each feature vector, the two smallest L1
distances to the k cluster centroids (Delta_1, Delta_2) and the argmin.

TPU adaptation (vs the MCU's add-only rationale): the computation is
bandwidth-bound (centroids re-read per feature tile), so the kernel tiles the
feature batch into VMEM-resident blocks of ``block_b`` rows while keeping the
full (k, d) centroid table resident in VMEM across the batch grid — one HBM
read of the centroids per call instead of per row.  The lane dimension d is
padded to a multiple of 128 by the wrapper (ops.py) so VREG lanes are full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import choose_block, pad_axis

POS = 1e30  # python scalar: jnp constants would be captured consts in pallas


def _l1_topk2_kernel(x_ref, c_ref, d1_ref, d2_ref, idx_ref):
    """x: (bB, d) VMEM; c: (k, d) VMEM; outputs (bB,) each."""
    x = x_ref[...]  # (bB, d)
    c = c_ref[...]  # (k, d)
    # distances: (bB, k) — elementwise |x - c| reduced over d, k unrolled-free
    d = jnp.sum(jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1)
    d1 = jnp.min(d, axis=1)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    k = d.shape[1]
    masked = jnp.where(
        jax.nn.one_hot(idx, k, dtype=jnp.bool_), POS, d
    )
    d2 = jnp.min(masked, axis=1)
    d1_ref[...] = d1
    d2_ref[...] = d2
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def l1_topk2(
    x: jax.Array,
    centroids: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = False,
):
    """x: (B, d) f32, centroids: (k, d) f32 -> (d1 (B,), d2 (B,), idx (B,))."""
    B, d = x.shape
    k = centroids.shape[0]
    # pad the row axis to a block multiple instead of shrinking the block
    # (halving collapses odd/prime B to 1-row tiles); padded rows compute
    # garbage distances that are sliced off below
    block_b, Bp = choose_block(B, block_b)
    if Bp != B:
        x = pad_axis(x, 0, block_b)
    grid = (Bp // block_b,)
    d1, d2, idx = pl.pallas_call(
        _l1_topk2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centroids resident
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids)
    return d1[:B], d2[:B], idx[:B]
