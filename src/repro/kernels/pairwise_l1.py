"""Pallas TPU kernel: all-pairs L1 distance matrix.

Used by siamese/contrastive training (layer-aware loss, paper Eq. 4-5) and by
k-means (re)initialisation.  Grid tiles (B1, B2, d); the d axis is innermost
and accumulated into the output block, which stays VMEM-resident across the
d iterations (standard reduce-into-output pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import choose_block, pad_axis


def _pairwise_l1_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (b1, bd)
    y = y_ref[...]  # (b2, bd)
    o_ref[...] += jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_b1", "block_b2", "block_d", "interpret")
)
def pairwise_l1(
    x: jax.Array,
    y: jax.Array,
    *,
    block_b1: int = 128,
    block_b2: int = 128,
    block_d: int = 512,
    interpret: bool = False,
):
    """x: (B1, d), y: (B2, d) -> (B1, B2) L1 distances, f32."""
    B1, d = x.shape
    B2 = y.shape[0]
    # pad every tiled axis to its block multiple instead of shrinking the
    # blocks (odd/prime sizes would collapse to 1-row tiles).  Zero feature
    # columns contribute |0 - 0| = 0 to the reduction, so real entries stay
    # bit-exact; padded rows/cols are sliced off.
    b1, B1p = choose_block(B1, block_b1)
    b2, B2p = choose_block(B2, block_b2)
    bd, dp = choose_block(d, block_d)
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if dp != d:
        x, y = pad_axis(x, 1, bd), pad_axis(y, 1, bd)
    if B1p != B1:
        x = pad_axis(x, 0, b1)
    if B2p != B2:
        y = pad_axis(y, 0, b2)
    grid = (B1p // b1, B2p // b2, dp // bd)
    out = pl.pallas_call(
        _pairwise_l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b1, bd), lambda i, j, l: (i, l)),
            pl.BlockSpec((b2, bd), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((b1, b2), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B1p, B2p), jnp.float32),
        interpret=interpret,
    )(x, y)
    return out[:B1, :B2]
