"""Pallas TPU kernel: all-pairs L1 distance matrix.

Used by siamese/contrastive training (layer-aware loss, paper Eq. 4-5) and by
k-means (re)initialisation.  Grid tiles (B1, B2, d); the d axis is innermost
and accumulated into the output block, which stays VMEM-resident across the
d iterations (standard reduce-into-output pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_l1_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (b1, bd)
    y = y_ref[...]  # (b2, bd)
    o_ref[...] += jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_b1", "block_b2", "block_d", "interpret")
)
def pairwise_l1(
    x: jax.Array,
    y: jax.Array,
    *,
    block_b1: int = 128,
    block_b2: int = 128,
    block_d: int = 512,
    interpret: bool = False,
):
    """x: (B1, d), y: (B2, d) -> (B1, B2) L1 distances, f32."""
    B1, d = x.shape
    B2 = y.shape[0]
    b1, b2, bd = min(block_b1, B1), min(block_b2, B2), min(block_d, d)
    while B1 % b1:
        b1 //= 2
    while B2 % b2:
        b2 //= 2
    while d % bd:
        bd //= 2
    grid = (B1 // b1, B2 // b2, d // bd)
    return pl.pallas_call(
        _pairwise_l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b1, bd), lambda i, j, l: (i, l)),
            pl.BlockSpec((b2, bd), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((b1, b2), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B1, B2), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
