"""Pallas TPU kernel: the ENTIRE fleet time loop fused into one kernel.

The previous "kernel mode" (:mod:`repro.kernels.fleet_priority`) only ran
the pick stage in-tile: every timestep still dispatched one ``pallas_call``
from inside the scan, bouncing the whole carry through HBM between the
admit/expire/apply stages — a measured 5-7x *slowdown* over plain ``vmap``.
This kernel inverts the loop structure: a ``block_d``-row tile of the full
:class:`repro.core.step.DeviceCarry` (queue slots, energy, rr cursor, live
registers, metric accumulators) is held in VMEM while a ``lax.fori_loop``
runs ``n_steps`` timesteps per tile, evaluating the *entire*
admit -> expire -> pick -> apply transition per step — ONE ``pallas_call``
per segment instead of one per step, with zero HBM round-trips inside the
horizon chunk.

The transition body is :func:`repro.core.step.device_step` itself — the
step core is written batch-polymorphic and gather-free (one-hot iota
contractions instead of dynamic indexing, trailing-axis reductions), so the
kernel and the ``vmap`` frontend share literally one implementation and the
results are bit-exact against each other (asserted across the full parity
matrix in ``tests/test_parity.py``).

Dtype packing: Mosaic refs carry ``f32``/``i32``; boolean params/carry
leaves ride as ``i32`` 0/1 masks and are re-materialized as bools in-tile
(``!= 0``) and on the way out (:func:`pack_tree`/:func:`unpack_tree`, also
exposed as ``repro.fleet.state.pack_carry``/``unpack_carry`` for
checkpointing).  The device axis is padded to a block multiple
(:mod:`repro.kernels._tiling`); padded devices have ``n_releases == 0`` so
they never release work, and their rows are sliced off the outputs.

On this CPU container the kernel executes in interpret mode — it validates
the fused semantics (and the one-call-per-segment dispatch shape) rather
than racing the vmap path; on a TPU backend the same call compiles to
Mosaic with the carry VMEM-resident across the whole segment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..core.step import (DeviceCarry, StepParams, StepStatics, device_step,
                         onehot_lowering)
from ..fleet.state import ServeBank, ServeCarry, ServeLog
from ._tiling import choose_block, pad_axis, pad_tree

#: StepParams / DeviceCarry leaves that are booleans in the pytree but ride
#: through Pallas refs as int32 0/1 masks (TPU-friendly dtypes).
BOOL_PARAM_FIELDS = ("imprecise", "is_edfm", "persistent", "use_exit_thr",
                     "passes", "correct")
BOOL_CARRY_FIELDS = ("was_off", "q_active", "q_correct", "q_apass")
#: ServeLog leaves that are booleans (packed the same way for the fused
#: serve kernel).
BOOL_LOG_FIELDS = ("correct", "sched")


def pack_tree(nt, bool_fields):
    """Cast the named boolean leaves of a NamedTuple pytree to int32."""
    return type(nt)(*[
        v.astype(jnp.int32) if f in bool_fields else v
        for f, v in zip(nt._fields, nt)
    ])


def unpack_tree(nt, bool_fields):
    """Re-materialize the named int32 0/1 leaves as booleans."""
    return type(nt)(*[
        (v != 0) if f in bool_fields else v
        for f, v in zip(nt._fields, nt)
    ])


_N_PARAMS = len(StepParams._fields)
_N_CARRY = len(DeviceCarry._fields)


def _fleet_step_kernel(*refs, statics: StepStatics, n_steps: int):
    """One device tile: reconstruct the pytrees from the packed refs, run
    the whole segment's time loop in VMEM, write the carry back."""
    i0_ref = refs[0]
    p_refs = refs[1:1 + _N_PARAMS]
    c_refs = refs[1 + _N_PARAMS:1 + _N_PARAMS + _N_CARRY]
    o_refs = refs[1 + _N_PARAMS + _N_CARRY:]

    params = unpack_tree(StepParams(*[r[...] for r in p_refs]),
                         BOOL_PARAM_FIELDS)
    st = unpack_tree(DeviceCarry(*[r[...] for r in c_refs]),
                     BOOL_CARRY_FIELDS)
    i0 = i0_ref[0]

    def body(s, st):
        # the shared clock: t = step_index * dt and t_end = (index+1) * dt,
        # the same expressions as the vmap path's scan.  Both are single
        # multiplies — always correctly rounded — so every frontend
        # produces identical bits.  (A ``t + dt`` form would invite the
        # backend to contract the mul+add into a single-rounding FMA in
        # one program but not another, a 1-ulp drift that breaks parity.)
        t = (i0 + s).astype(jnp.float32) * statics.dt
        t_end = (i0 + s + 1).astype(jnp.float32) * statics.dt
        return device_step(params, st, t, statics, t_end=t_end)

    # Mosaic has no gather: trace the whole in-tile loop with table lookups
    # lowered as one-hot iota contractions instead of ``take_along_axis``.
    with onehot_lowering():
        st = lax.fori_loop(0, n_steps, body, st)
    for ref, v in zip(o_refs, pack_tree(st, BOOL_CARRY_FIELDS)):
        ref[...] = v


@functools.partial(
    jax.jit, static_argnames=("statics", "n_steps", "block_d", "interpret"))
def fleet_fused_steps(
    cfg: StepParams,        # every leaf (D, ...)
    carry: DeviceCarry,     # every leaf (D, ...)
    i0,                     # i32 scalar: first step index of this segment
    *,
    statics: StepStatics,
    n_steps: int,
    block_d: int = 128,
    interpret: bool = False,
) -> DeviceCarry:
    """Advance the whole fleet ``n_steps`` timesteps in ONE ``pallas_call``.

    Drop-in replacement for the vmap path's ``scan`` over
    :func:`repro.core.step.device_step` — same carry in, same carry out,
    bit-exact.  ``n_steps`` is static (a segment length); ``i0`` is traced,
    so equal-length segments share one compilation.
    """
    D = cfg.policy.shape[0]
    bd, Dp = choose_block(D, block_d)
    p = pack_tree(cfg, BOOL_PARAM_FIELDS)
    c = pack_tree(carry, BOOL_CARRY_FIELDS)
    if Dp != D:
        # padded devices are all-zero configs: n_releases == 0 means they
        # never admit work and their garbage metrics are sliced off below
        p = StepParams(*[pad_axis(l, 0, bd) for l in p])
        c = DeviceCarry(*[pad_axis(l, 0, bd) for l in c])

    def spec(leaf):
        nz = leaf.ndim - 1
        return pl.BlockSpec((bd,) + leaf.shape[1:],
                            lambda i, _nz=nz: (i,) + (0,) * _nz)

    outs = pl.pallas_call(
        functools.partial(_fleet_step_kernel, statics=statics,
                          n_steps=n_steps),
        grid=(Dp // bd,),
        in_specs=([pl.BlockSpec((1,), lambda i: (0,))]
                  + [spec(l) for l in p] + [spec(l) for l in c]),
        out_specs=[spec(l) for l in c],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in c],
        interpret=interpret,
    )(jnp.asarray(i0, jnp.int32).reshape(1), *p, *c)
    new = unpack_tree(DeviceCarry(*outs), BOOL_CARRY_FIELDS)
    if Dp != D:
        new = jax.tree.map(lambda l: l[:D], new)
    return new


# --------------------------------------------------------------------- #
# Fused live serving: classify + live-register update in-tile.
# --------------------------------------------------------------------- #

_N_BANK = len(ServeBank._fields)
_N_LOG = len(ServeLog._fields)
_N_TABLES = 5   # sel_feats, labels, clabels, fidx, thr


def _serve_step_kernel(*refs, statics: StepStatics, n_steps: int):
    """One device tile of live serving: rebuild the pytrees from the packed
    refs, run the whole segment's serve loop in VMEM — the per-step body IS
    :func:`repro.serve.fleet_engine.serve_step`, the exact trace the XLA
    scan path runs, lowered with one-hot gathers — and write the device
    carry + outcome log back.  The centroid bank tile is read-only
    (adaptation is fleet-level and compiled out in fused mode)."""
    # lazy: the serve engine imports this package's public wrappers
    from ..serve.fleet_engine import ServeTables, serve_step

    i0 = refs[0][0]
    off = 2
    p_refs = refs[off:off + _N_PARAMS]
    off += _N_PARAMS
    c_refs = refs[off:off + _N_CARRY]
    off += _N_CARRY
    b_refs = refs[off:off + _N_BANK]
    off += _N_BANK
    l_refs = refs[off:off + _N_LOG]
    off += _N_LOG
    t_refs = refs[off:off + _N_TABLES]
    off += _N_TABLES
    o_refs = refs[off:]

    params = unpack_tree(StepParams(*[r[...] for r in p_refs]),
                         BOOL_PARAM_FIELDS)
    dev = unpack_tree(DeviceCarry(*[r[...] for r in c_refs]),
                      BOOL_CARRY_FIELDS)
    bank = ServeBank(*[r[...] for r in b_refs])
    log = unpack_tree(ServeLog(*[r[...] for r in l_refs]), BOOL_LOG_FIELDS)
    sel_f, labels, clabels, fidx, thr = [r[...] for r in t_refs]
    # full_feats is adaptation-only (never read with adapt compiled out);
    # alias the selected table so the pytree stays total
    tables = ServeTables(sel_feats=sel_f, full_feats=sel_f, labels=labels,
                         clabels=clabels, fidx=fidx, thr=thr)
    job0 = refs[1][...]

    def body(s, dl):
        d, lg = dl
        t = (i0 + s).astype(jnp.float32) * statics.dt
        d, lg, _ = serve_step(params, tables, d, bank, lg, t, job0,
                              statics=statics)
        return (d, lg)

    with onehot_lowering():
        dev, log = lax.fori_loop(0, n_steps, body, (dev, log))
    outs = (list(pack_tree(dev, BOOL_CARRY_FIELDS))
            + list(pack_tree(log, BOOL_LOG_FIELDS)))
    for ref, v in zip(o_refs, outs):
        ref[...] = v


@functools.partial(
    jax.jit, static_argnames=("statics", "n_steps", "block_d", "interpret",
                              "shared_bank", "per_dev_tables"))
def serve_fused_steps(
    cfg: StepParams,         # every leaf (D, ...)
    carry: ServeCarry,       # dev/log leaves (D, ...); bank per mode
    tables,                  # ServeTables; feature leaves (D, ...) if
                             # per_dev_tables else shared
    i0,                      # i32 scalar: first step index of this segment
    job0,                    # (K,) i32: global job id of window row 0
    *,
    statics: StepStatics,
    n_steps: int,
    block_d: int = 128,
    interpret: bool = False,
    shared_bank: bool = False,
    per_dev_tables: bool = False,
) -> ServeCarry:
    """Advance live serving ``n_steps`` timesteps in ONE ``pallas_call``.

    The L1-top-2 classify + live-register update run in-tile with the
    centroid bank VMEM-resident: a ``block_d``-row tile of the device
    carry, outcome log, bank (unless ``shared_bank``) and feature tables
    (if ``per_dev_tables``) is held while a ``fori_loop`` evaluates the
    full admit → expire → pick → classify → apply transition per step.
    Bit-exact vs :meth:`FleetServeEngine._scan_steps` — the kernel body is
    the same :func:`serve_step` trace.  Requires ``adapt=False`` (bank
    adaptation is fleet-level); the bank passes through unchanged.
    """
    D = cfg.policy.shape[0]
    bd, Dp = choose_block(D, block_d)
    p = pack_tree(cfg, BOOL_PARAM_FIELDS)
    c = pack_tree(carry.dev, BOOL_CARRY_FIELDS)
    lg = pack_tree(carry.log, BOOL_LOG_FIELDS)
    b = carry.bank
    sel_f, labels = tables.sel_feats, tables.labels
    if Dp != D:
        p = pad_tree(p, bd)
        c = pad_tree(c, bd)
        lg = pad_tree(lg, bd)
        if not shared_bank:
            b = pad_tree(b, bd)
        if per_dev_tables:
            sel_f = pad_axis(sel_f, 0, bd)
            labels = pad_axis(labels, 0, bd)

    def bspec(leaf):
        nz = leaf.ndim - 1
        return pl.BlockSpec((bd,) + leaf.shape[1:],
                            lambda i, _nz=nz: (i,) + (0,) * _nz)

    def wspec(leaf):
        nz = leaf.ndim
        return pl.BlockSpec(leaf.shape, lambda i, _nz=nz: (0,) * _nz)

    job0 = jnp.asarray(job0, jnp.int32)
    bank_spec = bspec if not shared_bank else wspec
    tab_spec = bspec if per_dev_tables else wspec
    tab_list = [sel_f, labels, tables.clabels, tables.fidx, tables.thr]
    tab_specs = [tab_spec(sel_f), tab_spec(labels),
                 wspec(tables.clabels), wspec(tables.fidx),
                 wspec(tables.thr)]
    out_tmpl = list(c) + list(lg)

    outs = pl.pallas_call(
        functools.partial(_serve_step_kernel, statics=statics,
                          n_steps=n_steps),
        grid=(Dp // bd,),
        in_specs=([pl.BlockSpec((1,), lambda i: (0,)), wspec(job0)]
                  + [bspec(l) for l in p] + [bspec(l) for l in c]
                  + [bank_spec(l) for l in b] + [bspec(l) for l in lg]
                  + tab_specs),
        out_specs=[bspec(l) for l in out_tmpl],
        out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype)
                   for l in out_tmpl],
        interpret=interpret,
    )(jnp.asarray(i0, jnp.int32).reshape(1), job0, *p, *c, *b, *lg,
      *tab_list)
    new_dev = unpack_tree(DeviceCarry(*outs[:_N_CARRY]), BOOL_CARRY_FIELDS)
    new_log = unpack_tree(ServeLog(*outs[_N_CARRY:]), BOOL_LOG_FIELDS)
    if Dp != D:
        new_dev = jax.tree.map(lambda l: l[:D], new_dev)
        new_log = jax.tree.map(lambda l: l[:D], new_log)
    return ServeCarry(dev=new_dev, bank=carry.bank, log=new_log)
