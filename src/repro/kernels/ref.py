"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l1_topk2_ref(x: jax.Array, centroids: jax.Array):
    d = jnp.sum(
        jnp.abs(x[:, None, :].astype(jnp.float32) -
                centroids[None, :, :].astype(jnp.float32)),
        axis=-1,
    )
    d1 = jnp.min(d, axis=1)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    masked = jnp.where(jax.nn.one_hot(idx, d.shape[1], dtype=bool), 1e30, d)
    d2 = jnp.min(masked, axis=1)
    return d1, d2, idx


def pairwise_l1_ref(x: jax.Array, y: jax.Array):
    return jnp.sum(
        jnp.abs(x[:, None, :].astype(jnp.float32) -
                y[None, :, :].astype(jnp.float32)),
        axis=-1,
    )


def centroid_update_ref(centroids, x, assign, weight):
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    sums = onehot.T @ x.astype(jnp.float32)
    counts = onehot.sum(0)[:, None]
    return (weight * centroids.astype(jnp.float32) + sums) / (weight + counts)


def rglru_scan_ref(a, b, h0):
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    hlast, hs = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (a.swapaxes(0, 1).astype(jnp.float32),
         b.swapaxes(0, 1).astype(jnp.float32)),
    )
    return hs.swapaxes(0, 1), hlast


def decode_gqa_ref(q, k_cache, v_cache, slot_pos, my_pos, window=0):
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bckh->bkgc", qg, k_cache.astype(jnp.float32))
    s = s * hd ** -0.5
    valid = (slot_pos >= 0) & (slot_pos <= my_pos[:, None])
    if window:
        valid &= my_pos[:, None] - slot_pos <= window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd)


def flash_attention_ref(q, k, v, causal=True, window=0, q_offset=0):
    """Dense masked softmax attention (oracle for the flash kernel)."""
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k.astype(jnp.float32))
    s = s * hd ** -0.5
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos <= window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd)
