"""Pallas TPU kernel: blocked RG-LRU diagonal linear recurrence.

Evaluates h_t = a_t * h_{t-1} + b_t over the sequence axis with the state
carried in a VMEM scratch buffer across sequence-grid steps (TPU grids are
sequential, so the scratch persists between iterations of the innermost
axis).  The (batch, width) tile stays VREG-friendly: width is tiled in
multiples of 128 lanes, the time loop runs inside the block.

The gates a, b are precomputed by the caller (they are elementwise matmul
products — MXU work best left to XLA); the kernel only implements the part
XLA serialises badly: the length-S dependent scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import choose_block, pad_axis


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, *, n_seq_blocks):
    s_idx = pl.program_id(2)

    a = a_ref[...]  # (bB, bS, bW)
    b = b_ref[...]

    @pl.when(s_idx == 0)
    def _init():
        hlast_ref[...] = h0_ref[...]

    h = hlast_ref[...]  # carried state (bB, bW)

    bS = a.shape[1]

    def step(t, carry):
        h, out = carry
        h = a[:, t, :] * h + b[:, t, :]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 1)
        return h, out

    h, out = jax.lax.fori_loop(0, bS, step, (h, jnp.zeros_like(a)))
    o_ref[...] = out
    hlast_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_s", "block_w", "interpret")
)
def rglru_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
):
    """a, b: (B, S, W) f32; h0: (B, W) f32 -> (h (B, S, W), h_last (B, W))."""
    B, S, W = a.shape
    # pad every tiled axis to its block multiple instead of shrinking the
    # blocks (odd/prime sizes would collapse to 1-row tiles).  Padded batch
    # rows / width lanes are zeros (garbage, sliced off); padded sequence
    # steps run the identity recurrence ``h = 1*h + 0`` so ``h_last`` stays
    # bit-exact through them.
    bB, Bp = choose_block(B, block_b)
    bS, Sp = choose_block(S, block_s)
    bW, Wp = choose_block(W, block_w)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)
    if Sp != S:
        a = pad_axis(a, 1, bS, value=1.0)
        b = pad_axis(b, 1, bS)
    if Bp != B:
        a, b = pad_axis(a, 0, bB), pad_axis(b, 0, bB)
        h0 = pad_axis(h0, 0, bB)
    if Wp != W:
        a, b = pad_axis(a, 2, bW), pad_axis(b, 2, bW)
        h0 = pad_axis(h0, 1, bW)
    grid = (Bp // bB, Wp // bW, Sp // bS)  # sequence innermost (sequential)
    out, hlast = pl.pallas_call(
        functools.partial(_rglru_kernel, n_seq_blocks=Sp // bS),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bW), lambda i, j, s: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bW), lambda i, j, s: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Sp, Wp), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Wp), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, h0)
    return out[:B, :S, :W], hlast[:B, :W]
