"""Pallas TPU kernel: blocked RG-LRU diagonal linear recurrence.

Evaluates h_t = a_t * h_{t-1} + b_t over the sequence axis with the state
carried in a VMEM scratch buffer across sequence-grid steps (TPU grids are
sequential, so the scratch persists between iterations of the innermost
axis).  The (batch, width) tile stays VREG-friendly: width is tiled in
multiples of 128 lanes, the time loop runs inside the block.

The gates a, b are precomputed by the caller (they are elementwise matmul
products — MXU work best left to XLA); the kernel only implements the part
XLA serialises badly: the length-S dependent scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, *, n_seq_blocks):
    s_idx = pl.program_id(2)

    a = a_ref[...]  # (bB, bS, bW)
    b = b_ref[...]

    @pl.when(s_idx == 0)
    def _init():
        hlast_ref[...] = h0_ref[...]

    h = hlast_ref[...]  # carried state (bB, bW)

    bS = a.shape[1]

    def step(t, carry):
        h, out = carry
        h = a[:, t, :] * h + b[:, t, :]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 1)
        return h, out

    h, out = jax.lax.fori_loop(0, bS, step, (h, jnp.zeros_like(a)))
    o_ref[...] = out
    hlast_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_s", "block_w", "interpret")
)
def rglru_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array,
    *,
    block_b: int = 8,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
):
    """a, b: (B, S, W) f32; h0: (B, W) f32 -> (h (B, S, W), h_last (B, W))."""
    B, S, W = a.shape
    bB, bS, bW = min(block_b, B), min(block_s, S), min(block_w, W)
    while B % bB:
        bB //= 2
    while S % bS:
        bS //= 2
    while W % bW:
        bW //= 2
    grid = (B // bB, W // bW, S // bS)  # sequence innermost (sequential)
    out, hlast = pl.pallas_call(
        functools.partial(_rglru_kernel, n_seq_blocks=S // bS),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bW), lambda i, j, s: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bB, bS, bW), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bB, bW), lambda i, j, s: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
    return out, hlast
