"""Pallas TPU kernel: single-token GQA attention against a ring-buffer KV
cache (the serving hot loop for decode_32k / long_500k).

Online-softmax accumulation over KV-cache tiles: the cache's sequence axis is
the innermost (sequential) grid axis; running max / denominator / accumulator
live in VMEM scratch.  Slot validity (ring buffer occupancy + sliding window)
is applied as a mask per tile.  Query heads are grouped per KV head (GQA) so
each cache tile is read once for all G query heads that share it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._tiling import choose_block, pad_axis

NEG = -1e30  # python scalar: jnp constants would be captured consts in pallas


def _decode_gqa_kernel(
    q_ref, k_ref, v_ref, slot_ref, pos_ref, o_ref,
    m_scr, l_scr, acc_scr, *, n_kv_blocks, window,
):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]        # (bB, KV, G, hd)
    k = k_ref[...]        # (bB, bC, KV, hd)
    v = v_ref[...]
    slot = slot_ref[...]  # (bB, bC)
    pos = pos_ref[...]    # (bB,)

    hd = q.shape[-1]
    s = jnp.einsum("bkgh,bckh->bkgc", q, k) * hd ** -0.5
    valid = (slot >= 0) & (slot <= pos[:, None])
    if window:
        valid &= pos[:, None] - slot <= window
    s = jnp.where(valid[:, None, None, :], s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[..., None] + jnp.einsum("bkgc,bckh->bkgh", p, v)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(c_idx == n_kv_blocks - 1)
    def _finish():
        o_ref[...] = acc_new / jnp.maximum(l_new[..., None], 1e-30)


@functools.partial(
    jax.jit, static_argnames=("window", "block_b", "block_c", "interpret")
)
def decode_gqa(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    my_pos: jax.Array,
    *,
    window: int = 0,
    block_b: int = 8,
    block_c: int = 512,
    interpret: bool = False,
):
    """q: (B, H, hd); caches: (B, C, KV, hd); slot_pos: (B, C); my_pos: (B,).

    Returns (B, H, hd) f32 attention output.
    """
    B, H, hd = q.shape
    C, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    # pad both tiled axes to block multiples instead of shrinking the
    # blocks: padded cache slots carry ``slot_pos = -1`` (always invalid,
    # masked to NEG -> exp underflows to exactly 0, so real rows are
    # bit-exact); padded batch rows are garbage and sliced off
    bB, Bp = choose_block(B, block_b)
    bC, Cp = choose_block(C, block_c)
    k_cache, v_cache = jnp.asarray(k_cache), jnp.asarray(v_cache)
    slot_pos, my_pos = jnp.asarray(slot_pos), jnp.asarray(my_pos)
    if Cp != C:
        k_cache = pad_axis(k_cache, 1, bC)
        v_cache = pad_axis(v_cache, 1, bC)
        slot_pos = pad_axis(slot_pos, 1, bC, value=-1)
    if Bp != B:
        q = pad_axis(jnp.asarray(q), 0, bB)
        k_cache = pad_axis(k_cache, 0, bB)
        v_cache = pad_axis(v_cache, 0, bB)
        slot_pos = pad_axis(slot_pos, 0, bB, value=-1)
        my_pos = pad_axis(my_pos, 0, bB)
    n_kv_blocks = Cp // bC

    qg = q.reshape(Bp, KV, G, hd).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(
            _decode_gqa_kernel, n_kv_blocks=n_kv_blocks, window=window
        ),
        grid=(Bp // bB, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((bB, KV, G, hd), lambda i, c: (i, 0, 0, 0)),
            pl.BlockSpec((bB, bC, KV, hd), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((bB, bC, KV, hd), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((bB, bC), lambda i, c: (i, c)),
            pl.BlockSpec((bB,), lambda i, c: (i,)),
        ],
        out_specs=pl.BlockSpec((bB, KV, G, hd), lambda i, c: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bB, KV, G), jnp.float32),
            pltpu.VMEM((bB, KV, G), jnp.float32),
            pltpu.VMEM((bB, KV, G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(
        qg,
        k_cache.astype(jnp.float32),
        v_cache.astype(jnp.float32),
        slot_pos,
        my_pos,
    )
    return out[:B].reshape(B, H, hd)
