"""Pallas TPU kernel: semi-supervised k-means centroid adaptation (paper §4.3).

Batched weighted-average update: for a batch of features with hard cluster
assignments, each centroid moves toward the mean of its assigned features

    c_j <- (w * c_j + sum_{i: a_i = j} x_i) / (w + count_j)

``w`` (the paper's "weight of the current centroid") guards against outliers.
Formulated as a one-hot matmul so the MXU does the scatter-reduce; grid tiles
the feature dim (centroid table is small and stays resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import choose_block, pad_axis


def _centroid_update_kernel(x_ref, onehot_ref, c_ref, w_ref, o_ref):
    x = x_ref[...]           # (B, bd)
    oh = onehot_ref[...]     # (B, k)
    c = c_ref[...]           # (k, bd)
    w = w_ref[0]
    sums = jnp.dot(oh.T, x, preferred_element_type=jnp.float32)  # (k, bd)
    counts = jnp.sum(oh, axis=0)[:, None]  # (k, 1)
    o_ref[...] = (w * c + sums) / (w + counts)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def centroid_update(
    centroids: jax.Array,
    x: jax.Array,
    assign: jax.Array,
    weight: jax.Array | float,
    *,
    block_d: int = 512,
    interpret: bool = False,
):
    """centroids: (k, d), x: (B, d), assign: (B,) int32 -> new (k, d)."""
    k, d = centroids.shape
    B = x.shape[0]
    # pad the tiled feature axis to a block multiple (odd/prime d would
    # otherwise collapse to 1-column tiles); zero feature columns update to
    # (w*0 + 0)/(w + count) and are sliced back off
    bd, dp = choose_block(d, block_d)
    x = jnp.asarray(x, jnp.float32)
    centroids = jnp.asarray(centroids, jnp.float32)
    if dp != d:
        x = pad_axis(x, 1, bd)
        centroids = pad_axis(centroids, 1, bd)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    w = jnp.asarray([weight], jnp.float32)
    out = pl.pallas_call(
        _centroid_update_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((B, bd), lambda i: (0, i)),
            pl.BlockSpec((B, k), lambda i: (0, 0)),
            pl.BlockSpec((k, bd), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((k, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, dp), jnp.float32),
        interpret=interpret,
    )(x, onehot, centroids, w)
    return out[:, :d]
