"""Pallas TPU kernel: the fleet simulator's hot inner step.

For every device in a batch, in one fused pass over the (devices × queue)
matrix: evaluate the scheduling policy's priority scores (zeta / zeta_I /
EDF / EDF-M / RR — the same pure functions from :mod:`repro.core.policy`
the scalar simulator uses), argmax the queue, gate on stored energy, and
apply the capacitor charge/discharge update for this timestep.

The queue axis (a handful of slots) rides the lane dimension; the device
axis is tiled into ``block_d``-row VMEM blocks, so the whole step is one
VPU sweep per tile with no HBM round-trips between the score, argmax and
energy stages.  Per-slot gather ingredients (laxity, utility, gate/drain
energies) are precomputed by the caller — gathers from the (D, K, J, U)
profile tables stay outside the kernel.  The task-set axis enters the tile
as each slot's task id plus the per-device round-robin cursor: the RR task
rotation rank is computed in VMEM, right next to the priority-argmax
(``n_tasks`` is a compile-time constant).

The post-score selection — forced-slot override, threshold test, energy
gate, fused capacitor charge/discharge — is
:func:`repro.core.step.select_and_charge`, imported from the unified step
core and evaluated directly on the VMEM tiles (it is written gather-free,
iota-only, for exactly this reason), so the kernel's in-tile reference
semantics can never drift from what the scalar-stepped and vmap frontends
execute.

Boolean operands are passed as f32 0/1 masks and the flag outputs returned
as int32 (TPU-friendly dtypes); :mod:`repro.kernels.ops` re-casts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import policy as P
from ..core.step import select_and_charge
from ._tiling import choose_block, pad_axis


def _fleet_priority_kernel(
    policy_ref, active_ref, laxity_ref, release_ref, utility_ref,
    mandatory_ref, alpha_ref, beta_ref, eta_ref, persistent_ref,
    energy_ref, e_opt_ref, charge_ref, capacity_ref, gate_ref, drain_ref,
    forced_ref, task_ref, cursor_ref,
    sel_ref, picked_ref, run_ref, e_new_ref,
    *, n_tasks: int,
):
    pol = policy_ref[...][:, None]          # (bd, 1) i32
    energy = energy_ref[...]                # (bd,)

    # task-set rotation rank inside the tile: (task - cursor) mod n_tasks on
    # small f32 integers (exact); identically 0 for single-task devices
    task = task_ref[...]                    # (bd, Q) f32 task ids
    cursor = cursor_ref[...][:, None]       # (bd, 1) f32
    diff = task - cursor
    task_rank = jnp.where(diff < 0.0, diff + n_tasks, diff)

    scores, thr = P.policy_scores(
        pol, active_ref[...], laxity_ref[...], release_ref[...],
        utility_ref[...], mandatory_ref[...],
        alpha_ref[...][:, None], beta_ref[...][:, None],
        eta_ref[...][:, None], energy[:, None], e_opt_ref[...][:, None],
        persistent_ref[...][:, None],
        task_rank,
    )
    # limited preemption (forced slot), threshold test, energy gate and the
    # fused capacitor update: the step core's shared selection semantics,
    # evaluated in-tile
    sel, picked, run, e_new = select_and_charge(
        scores, thr[:, 0], forced_ref[...], energy, charge_ref[...],
        capacity_ref[...], gate_ref[...], drain_ref[...])
    sel_ref[...] = sel
    picked_ref[...] = picked.astype(jnp.int32)
    run_ref[...] = run.astype(jnp.int32)
    e_new_ref[...] = e_new


@functools.partial(jax.jit,
                   static_argnames=("n_tasks", "block_d", "interpret"))
def fleet_priority(
    policy: jax.Array,      # (D,) i32
    active: jax.Array,      # (D, Q) f32 0/1
    laxity: jax.Array,      # (D, Q) f32, deadline - t
    release: jax.Array,     # (D, Q) f32
    utility: jax.Array,     # (D, Q) f32
    mandatory: jax.Array,   # (D, Q) f32 0/1
    alpha: jax.Array,       # (D,) f32
    beta: jax.Array,        # (D,) f32
    eta: jax.Array,         # (D,) f32
    persistent: jax.Array,  # (D,) f32 0/1
    energy: jax.Array,      # (D,) f32
    e_opt: jax.Array,       # (D,) f32
    charge: jax.Array,      # (D,) f32, harvested energy this step
    capacity: jax.Array,    # (D,) f32
    gate_e: jax.Array,      # (D, Q) f32, min energy to run the slot's unit
    drain: jax.Array,       # (D, Q) f32, energy drained per step if run
    forced: jax.Array,      # (D,) i32, locked slot mid-unit (-1 = none)
    task: jax.Array,        # (D, Q) i32, each slot's task id in [0, K)
    rr_cursor: jax.Array,   # (D,) i32, round-robin task cursor
    *,
    n_tasks: int = 1,
    block_d: int = 256,
    interpret: bool = False,
):
    """Returns ``(sel (D,) i32, picked (D,) i32, run (D,) i32, e_new (D,) f32)``."""
    D, Q = active.shape
    # pad the device axis to a block multiple instead of shrinking the block
    # (odd/prime fleet sizes would collapse to 1-row tiles).  Padded devices
    # are all-zero rows — no cross-device ops exist, so real rows stay
    # bit-exact; their outputs are sliced off below.
    bd, Dp = choose_block(D, block_d)
    grid = (Dp // bd,)
    f32 = jnp.float32
    row = pl.BlockSpec((bd, Q), lambda i: (i, 0))
    vec = pl.BlockSpec((bd,), lambda i: (i,))
    ins = (
        policy.astype(jnp.int32), active.astype(f32), laxity.astype(f32),
        release.astype(f32), utility.astype(f32), mandatory.astype(f32),
        alpha.astype(f32), beta.astype(f32), eta.astype(f32),
        persistent.astype(f32), energy.astype(f32), e_opt.astype(f32),
        charge.astype(f32), capacity.astype(f32), gate_e.astype(f32),
        drain.astype(f32), forced.astype(jnp.int32), task.astype(f32),
        rr_cursor.astype(f32),
    )
    if Dp != D:
        ins = tuple(pad_axis(a, 0, bd) for a in ins)
    sel, picked, run, e_new = pl.pallas_call(
        functools.partial(_fleet_priority_kernel, n_tasks=n_tasks),
        grid=grid,
        in_specs=[vec, row, row, row, row, row, vec, vec, vec, vec, vec,
                  vec, vec, vec, row, row, vec, row, vec],
        out_specs=[vec, vec, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((Dp,), jnp.int32),
            jax.ShapeDtypeStruct((Dp,), jnp.int32),
            jax.ShapeDtypeStruct((Dp,), jnp.int32),
            jax.ShapeDtypeStruct((Dp,), f32),
        ],
        interpret=interpret,
    )(*ins)
    return sel[:D], picked[:D], run[:D], e_new[:D]
