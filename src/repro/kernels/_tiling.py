"""Shared tile-size / padding helpers for the Pallas kernel wrappers.

Every kernel in this package tiles one or more axes into VMEM-resident
blocks.  When an axis size is not a multiple of the block, the kernels used
to *shrink* the block (halve until divisible) — which silently collapses to
1-row tiles for odd/prime sizes (D=999 -> 999 single-row grid steps, a
catastrophic slowdown).  The fix is the same pad-and-slice idiom the fleet
k-means wrappers in :mod:`repro.kernels.ops` already use: keep the block,
pad the axis up to the next block multiple with values that cannot leak
into real rows (zeros / identity gates / invalid sentinels, chosen per
kernel), and slice the outputs back.

Lives in its own leaf module so the kernel implementations can import it
without pulling in :mod:`repro.kernels.ops` (which imports the kernels —
the other direction would be circular).
"""
from __future__ import annotations

import jax.numpy as jnp


def pad_axis(a, axis: int, multiple: int, value=0.0):
    """Constant-pad ``a`` along ``axis`` up to the next ``multiple``."""
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths, constant_values=value)


def pad_tree(nt, multiple: int, axis: int = 0, value=0.0):
    """:func:`pad_axis` applied to every leaf of a NamedTuple pytree.

    The whole-segment kernels (:mod:`repro.kernels.fleet_step`) tile the
    leading device axis of several pytrees at once (params, carry, bank,
    log) — all of them pad with the same block multiple, and padded rows
    are inert by construction (``n_releases == 0`` configs) and sliced
    back off the outputs.
    """
    return type(nt)(*[pad_axis(l, axis, multiple, value) for l in nt])


def choose_block(size: int, block: int) -> tuple[int, int]:
    """Tile size and padded axis length for tiling ``size`` rows in blocks
    of (at most) ``block``.

    Returns ``(bd, padded)`` with ``padded % bd == 0`` and
    ``padded - size < bd``: callers pad the axis to ``padded``
    (:func:`pad_axis`) and slice kernel outputs back to ``size``.  When
    ``size`` is already a block multiple this is the identity
    (``padded == size``), so divisible shapes keep their exact program.
    """
    bd = min(block, size)
    padded = -(-size // bd) * bd
    return bd, padded
