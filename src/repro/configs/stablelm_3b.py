"""StableLM-3B — dense, MHA (kv=heads). [hf:stabilityai/stablelm-2-1_6b]"""
from .base import ModelConfig, register

STABLELM_3B = register(
    ModelConfig(
        name="stablelm-3b",
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab=50304,
        act="swiglu",
        norm="layernorm",
        rope_theta=10_000.0,
        train_microbatches=4,
        exit_every=4,
        long_context="window",
        long_window=4096,
    )
)
