"""Architecture registry.

Every assigned architecture has one module here; ``get_config(name)`` /
``--arch <name>`` resolve through the registry in :mod:`repro.configs.base`.
"""
from __future__ import annotations

import importlib

from .base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    list_configs,
    register,
)

_ARCH_MODULES = (
    "dbrx_132b",
    "minitron_8b",
    "qwen3_moe_235b_a22b",
    "recurrentgemma_9b",
    "internvl2_2b",
    "stablelm_3b",
    "xlstm_125m",
    "glm4_9b",
    "qwen1_5_0_5b",
    "seamless_m4t_medium",
    "paper_cnns",
)

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")


# canonical --arch ids (the registry also contains the 4 paper CNNs)
ASSIGNED_ARCHS = (
    "dbrx-132b",
    "minitron-8b",
    "qwen3-moe-235b-a22b",
    "recurrentgemma-9b",
    "internvl2-2b",
    "stablelm-3b",
    "xlstm-125m",
    "glm4-9b",
    "qwen1.5-0.5b",
    "seamless-m4t-medium",
)
