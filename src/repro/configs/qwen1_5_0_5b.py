"""Qwen1.5-0.5B — dense MHA with QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ModelConfig, register

QWEN15_05B = register(
    ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        source="hf:Qwen/Qwen1.5-0.5B",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151936,
        qkv_bias=True,
        act="swiglu",
        rope_theta=1_000_000.0,
        exit_every=3,
        mandatory_units=2,
        long_context="window",
        long_window=4096,
    )
)
