"""xLSTM-125M — alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]

``d_ff=0``: xLSTM blocks carry their own internal up-projection (factor 2)
instead of a separate FFN.  State is O(1) per layer, so ``long_500k`` runs
natively.
"""
from .base import ModelConfig, register

XLSTM_125M = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        source="arXiv:2405.04517",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        block_pattern=("mlstm", "slstm"),
        act="gelu",
        norm="layernorm",
        train_microbatches=4,
        exit_every=2,
        mandatory_units=2,
        long_context="native",
    )
)
