"""Minitron-8B — width/depth-pruned Nemotron-4. [arXiv:2407.14679]"""
from .base import ModelConfig, register

MINITRON_8B = register(
    ModelConfig(
        name="minitron-8b",
        family="dense",
        source="arXiv:2407.14679",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
        act="relu2",  # Nemotron uses squared-ReLU MLPs
        rope_theta=10_000.0,
        train_microbatches=4,
        exit_every=4,
        long_context="window",
        long_window=4096,
    )
)
