"""GLM4-9B — dense, RoPE, aggressive GQA (kv=2). [hf:THUDM/glm-4-9b]"""
from .base import ModelConfig, register

GLM4_9B = register(
    ModelConfig(
        name="glm4-9b",
        family="dense",
        source="hf:THUDM/glm-4-9b",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab=151552,
        act="swiglu",
        rope_theta=10_000.0,
        train_microbatches=4,
        exit_every=4,
        mandatory_units=3,
        long_context="window",
        long_window=4096,
    )
)
