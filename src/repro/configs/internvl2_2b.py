"""InternVL2-2B — InternViT frontend (stubbed) + InternLM2-1.8B backbone.
[arXiv:2404.16821]

Per the assignment carve-out, the ViT vision encoder + projector is a stub:
``input_specs()`` provides precomputed patch embeddings of the right shape
(``n_frontend_tokens`` x ``d_model``) which are prepended to the text
sequence.  The config below describes the *language* backbone.
"""
from .base import ModelConfig, register

INTERNVL2_2B = register(
    ModelConfig(
        name="internvl2-2b",
        family="vlm",
        source="arXiv:2404.16821",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92553,
        n_frontend_tokens=256,  # ViT patch embeddings per image (stub)
        act="swiglu",
        rope_theta=1_000_000.0,
        train_microbatches=2,
        exit_every=3,
        long_context="window",
        long_window=4096,
    )
)
