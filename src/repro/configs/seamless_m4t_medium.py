"""SeamlessM4T-medium — encoder-decoder, multimodal. [arXiv:2308.11596]

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a stub: ``input_specs()`` provides precomputed frame embeddings
(``n_enc_tokens`` x ``d_model``) consumed by the (bidirectional) encoder.  The
schedulable Zygarde units are the *decoder* blocks; the encoder runs once per
job as the first mandatory unit (see DESIGN.md §4).

``long_500k`` is SKIPPED for this architecture (full-attention enc-dec; a
524k-step speech/text decode is outside the family's operating range) — see
DESIGN.md §4.
"""
from .base import ModelConfig, register

SEAMLESS_M4T_MEDIUM = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        source="arXiv:2308.11596",
        n_layers=12,  # decoder blocks (the schedulable stack)
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=256206,
        is_encoder_decoder=True,
        n_enc_layers=12,
        n_enc_tokens=1024,  # stubbed audio frame embeddings per utterance
        act="gelu",
        norm="layernorm",
        train_microbatches=2,
        exit_every=2,
        long_context="skip",
    )
)
