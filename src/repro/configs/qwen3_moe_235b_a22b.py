"""Qwen3-MoE-235B-A22B — 128 experts top-8, fine-grained. [hf:Qwen/Qwen3-30B-A3B]"""
from .base import ModelConfig, register

QWEN3_MOE_235B = register(
    ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,  # Qwen3 uses head_dim 128 (not d_model/n_heads)
        d_ff=1536,  # per-expert (fine-grained experts)
        vocab=151936,
        n_experts=128,
        top_k=8,
        act="swiglu",
        rope_theta=1_000_000.0,
        train_microbatches=8,
        exit_every=8,  # 12 Zygarde units (94 layers)
        long_context="window",
        long_window=4096,
    )
)
