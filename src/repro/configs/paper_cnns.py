"""The paper's own four DNNs (Table 3) — MNIST / ESC-10 / CIFAR-100 / VWW.

These are CNN feature extractors, not transformers, so they live in their own
registry (:data:`repro.models.cnn.PAPER_CNNS`) rather than the transformer
``ModelConfig`` registry.  This module re-exports them so that
``--arch paper-mnist`` etc. resolve through the configs package.
"""
from repro.models.cnn import PAPER_CNNS, CNNConfig  # noqa: F401


def get_cnn_config(name: str) -> CNNConfig:
    key = name.removeprefix("paper-")
    try:
        return PAPER_CNNS[key]
    except KeyError:
        raise KeyError(
            f"unknown paper CNN {name!r}; available: "
            f"{['paper-' + k for k in sorted(PAPER_CNNS)]}"
        ) from None
