"""Configuration system for the Zygarde-JAX framework.

A single frozen dataclass, ``ModelConfig``, describes every supported
architecture family (dense / MoE / hybrid-recurrent / xLSTM / VLM / audio
enc-dec) plus the Zygarde "agile" (early-exit) settings.  Architecture files
in this package instantiate one config each and register it; ``reduced()``
derives the CPU-smoke-test variant mandated by the assignment (<=2 layers,
d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# --------------------------------------------------------------------------- #
# Input shapes assigned to this paper.
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------- #
# Model configuration.
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------- #
    name: str
    family: str  # "dense" | "moe" | "hybrid" | "ssm" | "vlm" | "audio"
    source: str  # citation (paper / model card)

    # transformer dimensions ------------------------------------------------ #
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0  # 0 => d_model // n_heads

    # MoE ------------------------------------------------------------------- #
    n_experts: int = 0  # 0 => dense FFN
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group_size: int = 512  # tokens per dispatch group

    # hybrid / recurrent ---------------------------------------------------- #
    # block pattern, repeated cyclically over layers; entries:
    #   "attn" | "rec" (RG-LRU) | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    rglru_width: int = 0  # 0 => d_model
    conv1d_width: int = 4

    # attention ------------------------------------------------------------- #
    window: int = 0  # 0 = full causal; >0 = sliding window (tokens)
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_chunk: int = 1024  # KV/query chunk for memory-efficient attention

    # encoder-decoder (audio) ------------------------------------------------ #
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    n_enc_tokens: int = 0  # frontend frames consumed by the encoder

    # modality frontend stub (VLM patches prepended to the LM sequence) ----- #
    n_frontend_tokens: int = 0

    # activation / norm ------------------------------------------------------ #
    act: str = "swiglu"  # "swiglu" | "gelu" | "relu2"
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # vocab padding (embedding/lm-head dims rounded up so the vocab dim is
    # both MXU-aligned and divisible by the 16-way model mesh axis; logits
    # over pad columns are trained-through, MaxText-style).  reduced() sets
    # this to 1 so smoke tests see exact shapes.
    vocab_pad: int = 128

    # nested remat of the attention op: backward recomputes the chunked
    # softmax instead of carrying ~S^2/2-sized f32 saves through the layer
    # scan (§Perf P1-H1); costs one extra attention forward per backward.
    remat_attention: bool = True

    # checkpoint granularity: one activation save per `remat_every` scanned
    # period-groups (k=4 cuts the 94-layer qwen3 save stack from 47 GiB to
    # 12 GiB per device at ~2x in-group recompute — §Perf P1-H2).
    remat_every: int = 4

    # gradient-accumulation splits of the global train batch; activation
    # temps scale with the microbatch (§Perf P1-H3 — how the 100B+ configs
    # fit train_4k in 16 GiB HBM).
    train_microbatches: int = 1

    # Zygarde agile (early-exit) settings ------------------------------------ #
    exit_every: int = 4  # one schedulable *unit* per this many layers
    mandatory_units: int = 1  # imprecise-computation mandatory prefix (units)
    n_clusters: int = 16  # k for the per-unit k-means classifier bank
    feature_dim: int = 128  # selected feature dims fed to the classifier
    utility_threshold: float = 0.1  # default margin threshold (per-unit at runtime)

    # shape coverage --------------------------------------------------------- #
    # How `long_500k` is supported:
    #   "native"  : sub-quadratic as-configured (SSM / hybrid local-attn)
    #   "window"  : lowered with an explicit sliding-window override
    #   "skip"    : documented skip (see DESIGN.md)
    long_context: str = "window"
    long_window: int = 4096  # window used when long_context == "window"

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad) * self.vocab_pad

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_rglru_width(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def n_units(self) -> int:
        """Number of schedulable Zygarde units (layer groups)."""
        return -(-self.n_layers // self.exit_every)

    @property
    def resolved_mandatory_units(self) -> int:
        """Mandatory prefix clamped to [1, n_units] (a config whose layer
        count shrank — e.g. ``reduced()`` — keeps a valid prefix)."""
        return max(1, min(self.mandatory_units, self.n_units))

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % self.pattern_period]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        total = emb + head
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            total += self._block_params(kind)
        if self.is_encoder_decoder:
            for i in range(self.n_enc_layers):
                total += self._block_params("attn")  # bidirectional enc block
                # decoder blocks additionally carry cross-attention
            total += self.n_layers * self._xattn_params()
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _xattn_params(self) -> int:
        return self._attn_params()

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.n_experts:
            per = self.d_ff * d * (3 if self.act == "swiglu" else 2)
            router = d * self.n_experts
            return self.n_experts * per + router
        mult = 3 if self.act == "swiglu" else 2
        return mult * d * self.d_ff

    def _block_params(self) -> int:  # pragma: no cover - overload shim
        raise TypeError

    def _block_params(self, kind: str) -> int:  # noqa: F811
        d = self.d_model
        norms = 2 * d
        if kind == "attn":
            return self._attn_params() + self._ffn_params() + norms
        if kind == "rec":
            w = self.resolved_rglru_width
            # in/out proj + block-diagonal gates (input & recurrence,
            # n_heads blocks — Griffin appendix A) + conv1d + Lambda
            gates = 2 * w * (w // self.n_heads)
            core = 2 * d * w + gates + self.conv1d_width * w + w
            return core + self._ffn_params() + norms
        if kind in ("mlstm", "slstm"):
            w = 2 * d  # internal up-projection factor 2
            qkv = 3 * d * w
            gates = 2 * d * w + 2 * w
            out = w * d
            return qkv + gates + out + norms
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        per_expert = self.d_ff * self.d_model * (3 if self.act == "swiglu" else 2)
        inactive = self.n_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/topology, tiny dims."""
        period = self.pattern_period
        n_layers = max(2, period)  # keep at least one full pattern period
        if n_layers > 4:
            n_layers = period  # patterns longer than 4 keep one period
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # no token dropping in smoke variants: keeps the per-token output
            # independent of dispatch grouping (prefill/decode consistency)
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            rglru_width=min(self.resolved_rglru_width, d_model) if self.rglru_width else 0,
            window=min(self.window, 64) if self.window else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            n_enc_tokens=min(self.n_enc_tokens, 32) if self.n_enc_tokens else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16)
            if self.n_frontend_tokens
            else 0,
            exit_every=1,
            mandatory_units=1,
            n_clusters=4,
            feature_dim=min(self.feature_dim, 32),
            moe_group_size=64,
            attn_chunk=64,
            long_window=64,
            vocab_pad=1,
            train_microbatches=1,
            dtype="float32",
        )

    def with_window(self, window: int) -> "ModelConfig":
        """Sliding-window override used for the `long_500k` dense variant."""
        return dataclasses.replace(self, window=window)


# --------------------------------------------------------------------------- #
# Registry.
# --------------------------------------------------------------------------- #

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # lazy, avoids import cycles

    _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)
