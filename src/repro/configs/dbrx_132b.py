"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from .base import ModelConfig, register

DBRX_132B = register(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        source="hf:databricks/dbrx-base",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,  # per-expert FFN width
        vocab=100352,
        n_experts=16,
        top_k=4,
        act="swiglu",
        rope_theta=500_000.0,
        train_microbatches=8,
        exit_every=4,  # 10 Zygarde units of 4 blocks each
        long_context="window",  # full-attention MoE: long_500k via sliding window
        long_window=4096,
    )
)
