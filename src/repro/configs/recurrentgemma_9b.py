"""RecurrentGemma-9B — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427 (Griffin)]"""
from .base import ModelConfig, register

RECURRENTGEMMA_9B = register(
    ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        source="arXiv:2402.19427",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,  # MQA on the attention layers
        d_ff=12288,
        vocab=256000,
        block_pattern=("rec", "rec", "attn"),
        rglru_width=4096,
        conv1d_width=4,
        window=2048,  # local attention window (native sub-quadratic)
        act="swiglu",
        train_microbatches=8,
        exit_every=4,
        long_context="native",
    )
)
