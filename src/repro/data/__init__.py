from .synthetic import (  # noqa: F401
    Dataset,
    make_dataset,
    make_lm_tokens,
    make_siamese_pairs,
    make_token_dataset,
)
from .pipeline import batches, siamese_batches  # noqa: F401
