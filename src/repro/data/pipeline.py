"""Minimal deterministic input pipeline: shuffled epoch batching."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def batches(
    x: np.ndarray, y: np.ndarray, batch_size: int, *, seed: int = 0,
    epochs: int = 1, drop_remainder: bool = True,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    n = len(x)
    for e in range(epochs):
        rng = np.random.default_rng(seed + e)
        order = rng.permutation(n)
        stop = (n // batch_size) * batch_size if drop_remainder else n
        for i in range(0, stop, batch_size):
            idx = order[i : i + batch_size]
            yield x[idx], y[idx]


def siamese_batches(
    x1: np.ndarray, x2: np.ndarray, diff: np.ndarray, batch_size: int,
    *, seed: int = 0, epochs: int = 1,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    n = len(x1)
    for e in range(epochs):
        rng = np.random.default_rng(seed + e)
        order = rng.permutation(n)
        stop = (n // batch_size) * batch_size
        for i in range(0, stop, batch_size):
            idx = order[i : i + batch_size]
            yield x1[idx], x2[idx], diff[idx]
