"""Deterministic synthetic datasets (shape-matched to the paper's four).

The container has no network access, so MNIST / ESC-10 / CIFAR-100 / VWW are
replaced by class-structured Gaussian-prototype generators with the same
input shapes and class counts.  ``separability`` controls the SNR, and
``environment`` applies a smooth domain shift (per-environment bias + gain)
— used to reproduce the paper's Fig. 24 adaptation experiment, where the
classifier is trained in one environment and deployed in others.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.models.cnn import PAPER_CNNS


@dataclass(frozen=True)
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_classes(self) -> int:
        return int(self.y_train.max()) + 1


def _smooth_prototype(rng: np.random.Generator, shape) -> np.ndarray:
    """Low-frequency class prototype (so conv layers have structure to use)."""
    h, w, c = shape
    coarse = rng.normal(size=(max(2, h // 4), max(2, w // 4), c))
    out = np.kron(coarse, np.ones((4, 4, 1)))[:h, :w, :c]
    return out


def make_dataset(
    name: str,
    n_train: int = 512,
    n_test: int = 256,
    *,
    separability: float = 2.0,
    environment: int = 0,
    seed: int = 0,
) -> Dataset:
    cfg = PAPER_CNNS[name]
    rng = np.random.default_rng(seed)
    protos = np.stack(
        [_smooth_prototype(rng, cfg.input_shape) for _ in range(cfg.n_classes)]
    )

    def sample(n, split_seed):
        r = np.random.default_rng(split_seed)
        y = r.integers(0, cfg.n_classes, n)
        # per-sample amplitude + a cross-class confuser component: iid pixel
        # noise alone integrates away over ~1k pixels, which would make every
        # class trivially separable regardless of `separability`
        amp = r.uniform(0.6, 1.3, size=(n, 1, 1, 1))
        other = (y + 1 + r.integers(0, cfg.n_classes - 1, n)) % cfg.n_classes
        conf = r.uniform(0.0, 0.7, size=(n, 1, 1, 1))
        x = separability * (amp * protos[y] + conf * protos[other])
        x = x + r.normal(size=(n, *cfg.input_shape))
        if environment:
            er = np.random.default_rng(1000 + environment)
            # domain shift scales with the class-signal strength so a shift
            # meaningfully overlaps the class structure (paper Fig. 24:
            # lab -> hall -> office recordings lose ~8% accuracy)
            bias = er.normal(scale=0.5 * separability, size=cfg.input_shape)
            gain = 1.0 + er.normal(scale=0.2)
            x = gain * x + bias
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train, seed * 7 + 1)
    x_te, y_te = sample(n_test, seed * 7 + 2)
    return Dataset(name, x_tr, y_tr, x_te, y_te)


def make_siamese_pairs(
    x: np.ndarray, y: np.ndarray, n_pairs: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """50% same-class / 50% different-class pairs (paper §4.2).

    Returns (x1, x2, different) where different=1 for cross-class pairs.
    """
    rng = np.random.default_rng(seed)
    by_class = {c: np.flatnonzero(y == c) for c in np.unique(y)}
    classes = sorted(by_class)
    i1 = np.empty(n_pairs, np.int64)
    i2 = np.empty(n_pairs, np.int64)
    diff = np.zeros(n_pairs, np.int32)
    for p in range(n_pairs):
        if p % 2 == 0:  # same class
            c = classes[rng.integers(len(classes))]
            a, b = rng.choice(by_class[c], 2, replace=True)
        else:
            c1, c2 = rng.choice(len(classes), 2, replace=False)
            a = rng.choice(by_class[classes[c1]])
            b = rng.choice(by_class[classes[c2]])
            diff[p] = 1
        i1[p], i2[p] = a, b
    return x[i1], x[i2], diff


def make_token_dataset(
    vocab: int,
    seq_len: int,
    n_classes: int,
    n_samples: int,
    *,
    separability: float = 1.5,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Sequence-classification tokens: each class has a biased unigram
    distribution over a class-specific vocabulary slice."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n_samples).astype(np.int32)
    logits = rng.normal(size=(n_classes, vocab))
    for c in range(n_classes):
        lo = (c * vocab) // n_classes
        hi = ((c + 1) * vocab) // n_classes
        logits[c, lo:hi] += separability
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    toks = np.stack(
        [rng.choice(vocab, seq_len, p=probs[c]) for c in y]
    ).astype(np.int32)
    return toks, y


def make_lm_tokens(
    vocab: int, seq_len: int, n_samples: int, seed: int = 0
) -> np.ndarray:
    """Markov-ish token streams for LM training demos."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, (n_samples, seq_len))
    # short-range structure: next token correlated with previous
    for t in range(1, seq_len):
        copy = rng.random(n_samples) < 0.3
        base[copy, t] = (base[copy, t - 1] + 1) % vocab
    return base.astype(np.int32)
