"""Vectorized fleet simulator: batched scheduler simulation across devices.

One jitted call simulates thousands of independent intermittently-powered
devices — the policy × eta × harvester × capacitor × seed grids behind the
paper's Figs. 17-21 / 24-25 — with the whole simulation state in a single
pytree stepped by ``jax.lax.scan`` and batched by ``jax.vmap`` (optionally
with the Pallas ``fleet_priority`` kernel as the hot inner step).  Each
device runs a *task set*: K periodic DNN streams contending for one
harvested-energy budget, with per-task ``(D, K)`` metrics in the result.

The per-device transition itself lives in :mod:`repro.core.step`; this
package adds the device batching, the grid builders, and segmented
execution (``run_segments``) whose carry pytree a host hook can adapt
mid-trajectory (:mod:`repro.adapt.online`).

Public API::

    result, meta = fleet.sweep(fleet.SweepGrid(task=..., policies=(...)))
    result = fleet.simulate_fleet(cfg, statics)          # pre-built configs
    result, carry = fleet.run_segments(cfg, statics, n_segments=8, hook=...)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, cap, sim)
    result.task_scheduled / result.task_released         # (D, K) on-time

Observability (``repro.telemetry``): pass ``telemetry=TelemetryConfig()``
to ``simulate_fleet`` / ``run_segments`` to additionally return a
``(D, ...)`` ``Telemetry`` pytree of in-scan counters, histograms, and
event rings — bit-exact against the uninstrumented run by construction.
"""
from .grid import (  # noqa: F401
    SweepGrid,
    as_task_set,
    build,
    device_config,
    from_sim_config,
    sample_events,
    stack_configs,
    sweep,
)
from .simulator import (  # noqa: F401
    FLEET_MODES,
    finalize_fleet,
    init_fleet,
    run_segments,
    simulate_fleet,
    simulate_fleet_sharded,
)
from .state import (  # noqa: F401
    DeviceState,
    FleetConfig,
    FleetResult,
    FleetStatics,
    pack_carry,
    unpack_carry,
)
