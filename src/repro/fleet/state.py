"""Pytree containers for the vectorized fleet simulator.

These are the *fleet-level names* for the unified step core's containers in
:mod:`repro.core.step` — the fleet path is literally ``jax.vmap`` over the
same pytrees, so the classes are shared (aliased, not copied):

* :class:`FleetConfig` (= :class:`repro.core.step.StepParams`) — immutable
  per-device configuration with one leading ``D`` (device) axis over the
  sweep grid (policy × eta × harvester × capacitor × seed), plus the
  per-task workload tables and pre-sampled harvester event streams.
* :class:`DeviceState` (= :class:`repro.core.step.DeviceCarry`) — the
  mutable simulation state for ONE device (``jax.vmap`` adds the device
  axis): capacitor energy, the fixed-size job queue as parallel arrays, and
  the metric accumulators.  This is the *segment carry*:
  :func:`repro.fleet.simulator.run_segments` returns/accepts it between
  horizon chunks, and :func:`repro.launch.sharding.shard_fleet_carry`
  shards it exactly like a FleetConfig.
* :class:`FleetResult` (= :class:`repro.core.step.StepResult`) — stacked
  per-device results: ``(D,)`` aggregates plus ``(D, K)`` per-task
  breakdowns, with ``.device(i)`` / ``.as_dict()`` dict exports mirroring
  ``SimResult.as_dict``.

Shapes use ``D`` devices, ``K`` tasks per device (the task-set axis: each
device runs ``K`` periodic DNN task streams contending for one harvested
energy budget, paper §3/§5's multi-app deployments), ``Q`` queue slots,
``U`` units per job, ``J`` jobs per task, ``S`` harvester slots.  Task sets
of heterogeneous depth/length are padded to common ``U``/``J`` by the grid
builder; per-task ``n_units``/``n_releases`` bound the live region.  Static
(python) dimensions and step sizes live in the hashable
:class:`FleetStatics` (= :class:`repro.core.step.StepStatics`), a
``jax.jit`` static argument.
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from ..core.step import (
    DeviceCarry,
    StepParams,
    StepResult,
    StepStatics,
    init_carry,
)

FleetStatics = StepStatics
FleetConfig = StepParams
DeviceState = DeviceCarry
FleetResult = StepResult
init_state = init_carry


def pack_carry(carry: DeviceCarry) -> DeviceCarry:
    """Cast the carry's boolean leaves to int32 0/1 masks — the TPU-friendly
    dtype layout the fused kernel (:mod:`repro.kernels.fleet_step`) moves
    through its refs, and a stable layout for checkpoint serialization.
    Structure-preserving: the result is still a ``DeviceState`` pytree and
    round-trips exactly through :func:`unpack_carry`."""
    # local import: keep repro.fleet importable without pulling in pallas
    from ..kernels.fleet_step import BOOL_CARRY_FIELDS, pack_tree

    return pack_tree(carry, BOOL_CARRY_FIELDS)


def unpack_carry(carry: DeviceCarry) -> DeviceCarry:
    """Inverse of :func:`pack_carry`: re-materialize the int32 0/1 leaves
    as booleans (``!= 0``)."""
    from ..kernels.fleet_step import BOOL_CARRY_FIELDS, unpack_tree

    return unpack_tree(carry, BOOL_CARRY_FIELDS)


# --------------------------------------------------------------------------- #
# Live-serving carry (repro.serve.fleet_engine).
#
# The vectorized serving engine extends the fleet carry with the runtime
# k-means state and a per-job outcome log.  Everything is a flat NamedTuple
# of arrays, so the combined :class:`ServeCarry` stays a checkpointable
# pytree: it round-trips through segment boundaries exactly like
# ``DeviceState`` does in :func:`repro.fleet.simulator.run_segments`, and
# :func:`repro.launch.sharding.shard_serve_carry` places it on a mesh.
# --------------------------------------------------------------------------- #


class ServeBank(NamedTuple):
    """Stacked centroid bank — the *mutable* half of the classifier state.

    Cluster labels / feature selections / thresholds never change online, so
    they ride in the engine's read-only feature tables; only centroids and
    member counts (the paper's ``r``) adapt.  Shapes are padded to common
    ``(K tasks, U units, C clusters, F features)``: padded cluster rows sit
    at a huge constant (never in the L1 top-2) and padded feature columns
    are zero in rows and queries alike (L1-invariant).  In ``per-device``
    bank mode every leaf gains a leading ``D`` axis and shards with the
    fleet; in ``shared`` mode the single bank is replicated and every
    device's exits adapt it collaboratively.
    """

    centroids: jax.Array     # ([D,] K, U, C, F) f32
    counts: jax.Array        # ([D,] K, U, C) f32


class ServeLog(NamedTuple):
    """Per-job outcome log, ``(D, K, J)`` each — the live analogue of the
    replay path's precomputed profile tables, written as units complete.
    ``pred``/``correct``/``margin`` reflect the *deepest executed* unit;
    ``exit_unit`` is where the bank utility test first passed (-1 = never);
    ``sched`` mirrors the step core's mandatory-before-deadline test."""

    units: jax.Array         # int32, units executed
    pred: jax.Array          # int32, last prediction (-1 = never classified)
    correct: jax.Array       # bool
    margin: jax.Array        # f32
    exit_unit: jax.Array     # int32
    sched: jax.Array         # bool


class ServeCarry(NamedTuple):
    """Full live-serving scan carry: device scheduling state + centroid
    bank + job log.  Checkpointable between segments like ``DeviceState``."""

    dev: DeviceCarry         # every leaf (D, ...)
    bank: ServeBank
    log: ServeLog


__all__ = [
    "DeviceState",
    "FleetConfig",
    "FleetResult",
    "FleetStatics",
    "ServeBank",
    "ServeCarry",
    "ServeLog",
    "init_state",
    "pack_carry",
    "unpack_carry",
]
