"""Pytree containers for the vectorized fleet simulator.

Everything the fixed-timestep simulator touches lives in two NamedTuple
pytrees of arrays:

* :class:`FleetConfig` — immutable per-device configuration: one leading
  ``D`` (device) axis over the sweep grid (policy × eta × harvester ×
  capacitor × seed), plus the shared workload tables and pre-sampled
  harvester event streams.
* :class:`DeviceState` — the mutable simulation state for ONE device
  (``jax.vmap`` adds the device axis): capacitor energy, the fixed-size job
  queue as parallel arrays, and the metric accumulators.

Shapes use ``D`` devices, ``Q`` queue slots, ``U`` units per job, ``J`` jobs
per device, ``S`` harvester slots.  Static (python) dimensions and step
sizes live in the hashable :class:`FleetStatics`, which is a ``jax.jit``
static argument.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FleetStatics:
    """Hashable static configuration (jit static argument)."""

    queue_size: int = 3
    dt: float = 0.025            # fixed timestep (s); keep <= min unit_time
    horizon: float = 600.0
    slot_s: float = 1.0          # harvester slot length (s)

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon / self.dt))


class FleetConfig(NamedTuple):
    """Per-device configuration arrays (leading axis: D devices)."""

    # scheduler / energy scalars, (D,)
    policy: jax.Array        # int32, repro.core.policy.POLICY_IDS
    imprecise: jax.Array     # bool: early exit enabled (zygarde, edf-m)
    is_edfm: jax.Array       # bool: EDF-M never runs optional units
    eta: jax.Array           # f32
    alpha: jax.Array         # f32, 1 / max relative deadline
    beta: jax.Array          # f32
    persistent: jax.Array    # bool: use zeta (Eq. 6) instead of zeta_I (Eq. 7)
    capacity: jax.Array      # f32, usable capacitor energy (J)
    start_energy: jax.Array  # f32; negative = cold-boot dead-zone debt
    e_man: jax.Array         # f32, minimum energy to run a fragment
    e_opt: jax.Array         # f32, Eq. 7 optional-unit energy threshold
    power_on: jax.Array      # f32, harvester power in the ON state (W)
    # task stream, (D,)
    # timekeeping: deterministic linear clock drift (fleet-path CHRT model;
    # the scalar CHRTClock's random per-read offset has no batched
    # equivalent, so the fleet models the *accumulated* error as a rate:
    # t_read = t * (1 + clock_drift))
    clock_drift: jax.Array   # f32, (D,); 0 = exact RTC
    # tunable per-unit utility-test thresholds (repro.adapt): when
    # use_exit_thr is set the utility test compares the live margin against
    # exit_thr instead of the precomputed `passes` table
    use_exit_thr: jax.Array  # bool, (D,)
    exit_thr: jax.Array      # (D, U) f32
    period: jax.Array        # f32
    rel_deadline: jax.Array  # f32, relative deadline
    fragments: jax.Array     # f32, fragments per unit
    n_units: jax.Array       # int32, <= U
    n_releases: jax.Array    # int32, jobs released within the horizon (<= J)
    # workload tables
    unit_time: jax.Array     # (D, U) f32, seconds per unit
    unit_energy: jax.Array   # (D, U) f32, joules per unit
    margins: jax.Array       # (D, J, U) f32, utility-test margins
    passes: jax.Array        # (D, J, U) bool, utility test passes after unit
    correct: jax.Array       # (D, J, U) bool, unit prediction correct
    # harvester event stream, (D, S) f32 in {0, 1}
    events: jax.Array

    @property
    def n_devices(self) -> int:
        return self.policy.shape[0]


class DeviceState(NamedTuple):
    """Mutable per-device simulation state (no device axis; vmap adds it)."""

    energy: jax.Array        # f32 scalar; < 0 while paying cold-boot debt
    was_off: jax.Array       # bool scalar: last activity was a power-down
    next_rel: jax.Array      # int32 scalar: next job index to release
    # limited preemption (paper §4.1): once a unit starts, it runs to its
    # boundary — the scheduler only re-picks between units.  lock_job guards
    # against the slot being recycled for a new job while locked.
    lock_slot: jax.Array     # int32 scalar: queue slot mid-unit, -1 if none
    lock_job: jax.Array      # int32 scalar: job id the lock belongs to
    # fixed-size job queue, (Q,) each
    q_active: jax.Array      # bool
    q_release: jax.Array     # f32
    q_deadline: jax.Array    # f32 (absolute)
    q_job: jax.Array         # int32, index into the (J, U) profile tables
    q_unit: jax.Array        # int32, next unit to execute
    q_time_left: jax.Array   # f32, seconds left in the current unit
    q_exited: jax.Array      # int32, unit where the utility test passed (-1)
    q_last_pred: jax.Array   # int32, deepest executed unit (-1)
    q_mand_time: jax.Array   # f32, mandatory-completion time (-1)
    # metric accumulators (mirror scheduler.SimResult)
    m_scheduled: jax.Array   # int32
    m_correct: jax.Array     # int32
    m_misses: jax.Array      # int32
    m_units: jax.Array       # int32
    m_optional: jax.Array    # int32
    m_reboots: jax.Array     # int32
    m_busy: jax.Array        # f32
    m_idle: jax.Array        # f32
    m_wasted: jax.Array      # f32


class FleetResult(NamedTuple):
    """Stacked per-device results, (D,) each — SimResult over the fleet."""

    released: jax.Array
    scheduled: jax.Array
    correct: jax.Array
    deadline_misses: jax.Array
    units_executed: jax.Array
    optional_units: jax.Array
    busy_time: jax.Array
    idle_no_energy: jax.Array
    reboots: jax.Array
    wasted_reexec: jax.Array
    sim_time: jax.Array

    def device(self, i: int) -> dict:
        """Metrics of device ``i`` as a python dict (SimResult field names)."""
        return {k: v[i].item() for k, v in self._asdict().items()}

    def as_dict(self) -> dict:
        return {k: jnp.asarray(v) for k, v in self._asdict().items()}


def init_state(cfg: FleetConfig, statics: FleetStatics) -> DeviceState:
    """Initial state for one device (call under vmap over cfg)."""
    q = statics.queue_size
    f32 = jnp.float32
    i32 = jnp.int32
    zero_i = jnp.zeros((), i32)
    return DeviceState(
        energy=cfg.start_energy.astype(f32),
        was_off=jnp.zeros((), bool),
        next_rel=zero_i,
        lock_slot=jnp.full((), -1, i32),
        lock_job=jnp.full((), -1, i32),
        q_active=jnp.zeros((q,), bool),
        q_release=jnp.zeros((q,), f32),
        q_deadline=jnp.zeros((q,), f32),
        q_job=jnp.zeros((q,), i32),
        q_unit=jnp.zeros((q,), i32),
        q_time_left=jnp.zeros((q,), f32),
        q_exited=jnp.full((q,), -1, i32),
        q_last_pred=jnp.full((q,), -1, i32),
        q_mand_time=jnp.full((q,), -1.0, f32),
        m_scheduled=zero_i,
        m_correct=zero_i,
        m_misses=zero_i,
        m_units=zero_i,
        m_optional=zero_i,
        m_reboots=zero_i,
        m_busy=jnp.zeros((), f32),
        m_idle=jnp.zeros((), f32),
        m_wasted=jnp.zeros((), f32),
    )
