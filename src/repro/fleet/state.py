"""Pytree containers for the vectorized fleet simulator.

These are the *fleet-level names* for the unified step core's containers in
:mod:`repro.core.step` — the fleet path is literally ``jax.vmap`` over the
same pytrees, so the classes are shared (aliased, not copied):

* :class:`FleetConfig` (= :class:`repro.core.step.StepParams`) — immutable
  per-device configuration with one leading ``D`` (device) axis over the
  sweep grid (policy × eta × harvester × capacitor × seed), plus the
  per-task workload tables and pre-sampled harvester event streams.
* :class:`DeviceState` (= :class:`repro.core.step.DeviceCarry`) — the
  mutable simulation state for ONE device (``jax.vmap`` adds the device
  axis): capacitor energy, the fixed-size job queue as parallel arrays, and
  the metric accumulators.  This is the *segment carry*:
  :func:`repro.fleet.simulator.run_segments` returns/accepts it between
  horizon chunks, and :func:`repro.launch.sharding.shard_fleet_carry`
  shards it exactly like a FleetConfig.
* :class:`FleetResult` (= :class:`repro.core.step.StepResult`) — stacked
  per-device results: ``(D,)`` aggregates plus ``(D, K)`` per-task
  breakdowns, with ``.device(i)`` / ``.as_dict()`` dict exports mirroring
  ``SimResult.as_dict``.

Shapes use ``D`` devices, ``K`` tasks per device (the task-set axis: each
device runs ``K`` periodic DNN task streams contending for one harvested
energy budget, paper §3/§5's multi-app deployments), ``Q`` queue slots,
``U`` units per job, ``J`` jobs per task, ``S`` harvester slots.  Task sets
of heterogeneous depth/length are padded to common ``U``/``J`` by the grid
builder; per-task ``n_units``/``n_releases`` bound the live region.  Static
(python) dimensions and step sizes live in the hashable
:class:`FleetStatics` (= :class:`repro.core.step.StepStatics`), a
``jax.jit`` static argument.
"""
from __future__ import annotations

from ..core.step import (
    DeviceCarry,
    StepParams,
    StepResult,
    StepStatics,
    init_carry,
)

FleetStatics = StepStatics
FleetConfig = StepParams
DeviceState = DeviceCarry
FleetResult = StepResult
init_state = init_carry

__all__ = [
    "DeviceState",
    "FleetConfig",
    "FleetResult",
    "FleetStatics",
    "init_state",
]
