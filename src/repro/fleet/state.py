"""Pytree containers for the vectorized fleet simulator.

Everything the fixed-timestep simulator touches lives in two NamedTuple
pytrees of arrays:

* :class:`FleetConfig` — immutable per-device configuration: one leading
  ``D`` (device) axis over the sweep grid (policy × eta × harvester ×
  capacitor × seed), plus the per-task workload tables and pre-sampled
  harvester event streams.
* :class:`DeviceState` — the mutable simulation state for ONE device
  (``jax.vmap`` adds the device axis): capacitor energy, the fixed-size job
  queue as parallel arrays, and the metric accumulators.

Shapes use ``D`` devices, ``K`` tasks per device (the task-set axis: each
device runs ``K`` periodic DNN task streams contending for one harvested
energy budget, paper §3/§5's multi-app deployments), ``Q`` queue slots,
``U`` units per job, ``J`` jobs per task, ``S`` harvester slots.  Task sets
of heterogeneous depth/length are padded to common ``U``/``J`` by the grid
builder; per-task ``n_units``/``n_releases`` bound the live region.  Static
(python) dimensions and step sizes live in the hashable
:class:`FleetStatics`, which is a ``jax.jit`` static argument.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FleetStatics:
    """Hashable static configuration (jit static argument)."""

    queue_size: int = 3
    dt: float = 0.025            # fixed timestep (s); keep <= min unit_time
    horizon: float = 600.0
    slot_s: float = 1.0          # harvester slot length (s)

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon / self.dt))


class FleetConfig(NamedTuple):
    """Per-device configuration arrays (leading axis: D devices)."""

    # scheduler / energy scalars, (D,)
    policy: jax.Array        # int32, repro.core.policy.POLICY_IDS
    imprecise: jax.Array     # bool: early exit enabled (zygarde, edf-m)
    is_edfm: jax.Array       # bool: EDF-M never runs optional units
    eta: jax.Array           # f32
    alpha: jax.Array         # f32, 1 / max relative deadline over the task set
    beta: jax.Array          # f32
    persistent: jax.Array    # bool: use zeta (Eq. 6) instead of zeta_I (Eq. 7)
    capacity: jax.Array      # f32, usable capacitor energy (J)
    start_energy: jax.Array  # f32; negative = cold-boot dead-zone debt
    e_man: jax.Array         # f32, minimum energy to run a fragment
    e_opt: jax.Array         # f32, Eq. 7 optional-unit energy threshold
    power_on: jax.Array      # f32, harvester power in the ON state (W)
    # timekeeping: deterministic linear clock drift (fleet-path CHRT model;
    # the scalar CHRTClock's random per-read offset has no batched
    # equivalent, so the fleet models the *accumulated* error as a rate:
    # t_read = t * (1 + clock_drift))
    clock_drift: jax.Array   # f32, (D,); 0 = exact RTC
    # tunable per-unit utility-test thresholds (repro.adapt): when
    # use_exit_thr is set the utility test compares the live margin against
    # exit_thr instead of the precomputed `passes` table
    use_exit_thr: jax.Array  # bool, (D,)
    exit_thr: jax.Array      # (D, K, U) f32
    # task-set table, (D, K): K periodic task streams per device
    period: jax.Array        # f32
    rel_deadline: jax.Array  # f32, relative deadline
    fragments: jax.Array     # f32, fragments per unit
    n_units: jax.Array       # int32, <= U (live units of each task)
    n_releases: jax.Array    # int32, jobs released within the horizon (<= J)
    # per-task workload tables
    unit_time: jax.Array     # (D, K, U) f32, seconds per unit
    unit_energy: jax.Array   # (D, K, U) f32, joules per unit
    margins: jax.Array       # (D, K, J, U) f32, utility-test margins
    passes: jax.Array        # (D, K, J, U) bool, utility test passes after unit
    correct: jax.Array       # (D, K, J, U) bool, unit prediction correct
    # harvester event stream, (D, S) f32 in {0, 1}
    events: jax.Array

    @property
    def n_devices(self) -> int:
        return self.policy.shape[0]

    @property
    def n_tasks(self) -> int:
        return self.period.shape[-1]


class DeviceState(NamedTuple):
    """Mutable per-device simulation state (no device axis; vmap adds it)."""

    energy: jax.Array        # f32 scalar; < 0 while paying cold-boot debt
    was_off: jax.Array       # bool scalar: last activity was a power-down
    next_rel: jax.Array      # int32 (K,): next job index to release, per task
    # round-robin task cursor: the task id the rr policy serves next (the
    # scalar simulator's rr_cursor); unused by the other policies
    rr_cursor: jax.Array     # int32 scalar
    # limited preemption (paper §4.1): once a unit starts, it runs to its
    # boundary — the scheduler only re-picks between units.  lock_job guards
    # against the slot being recycled for a new job while locked.
    lock_slot: jax.Array     # int32 scalar: queue slot mid-unit, -1 if none
    lock_job: jax.Array      # int32 scalar: job id the lock belongs to
    # fixed-size job queue, (Q,) each
    q_active: jax.Array      # bool
    q_release: jax.Array     # f32
    q_deadline: jax.Array    # f32 (absolute)
    q_task: jax.Array        # int32, index into the (K, ...) task tables
    q_job: jax.Array         # int32, index into the (K, J, U) profile tables
    q_unit: jax.Array        # int32, next unit to execute
    q_time_left: jax.Array   # f32, seconds left in the current unit
    q_exited: jax.Array      # int32, unit where the utility test passed (-1)
    q_last_pred: jax.Array   # int32, deepest executed unit (-1)
    q_mand_time: jax.Array   # f32, mandatory-completion time (-1)
    # metric accumulators, (K,) per task (mirror scheduler.SimResult.task_*)
    m_scheduled: jax.Array   # int32
    m_correct: jax.Array     # int32
    m_misses: jax.Array      # int32
    m_units: jax.Array       # int32
    m_optional: jax.Array    # int32
    # device-level energy/time accumulators (scalars)
    m_reboots: jax.Array     # int32
    m_busy: jax.Array        # f32
    m_idle: jax.Array        # f32
    m_wasted: jax.Array      # f32


class FleetResult(NamedTuple):
    """Stacked per-device results — SimResult over the fleet.

    Aggregate fields are ``(D,)`` (summed over the task set, matching the
    scalar ``SimResult`` totals); the ``task_*`` fields break the job
    counters down per task as ``(D, K)`` arrays (matching
    ``SimResult.task_*``).
    """

    released: jax.Array
    scheduled: jax.Array
    correct: jax.Array
    deadline_misses: jax.Array
    units_executed: jax.Array
    optional_units: jax.Array
    busy_time: jax.Array
    idle_no_energy: jax.Array
    reboots: jax.Array
    wasted_reexec: jax.Array
    sim_time: jax.Array
    # per-task breakdowns, (D, K)
    task_released: jax.Array
    task_scheduled: jax.Array
    task_correct: jax.Array
    task_misses: jax.Array
    task_units: jax.Array
    task_optional: jax.Array

    def device(self, i: int) -> dict:
        """Metrics of device ``i`` as a python dict (SimResult field names);
        scalar metrics become python numbers, per-task rows become lists."""
        out = {}
        for k, v in self._asdict().items():
            row = v[i]
            out[k] = row.item() if row.ndim == 0 else row.tolist()
        return out

    def as_dict(self) -> dict:
        return {k: jnp.asarray(v) for k, v in self._asdict().items()}


def init_state(cfg: FleetConfig, statics: FleetStatics) -> DeviceState:
    """Initial state for one device (call under vmap over cfg)."""
    q = statics.queue_size
    k = cfg.period.shape[0]      # per-device view: task axis is leading
    f32 = jnp.float32
    i32 = jnp.int32
    zero_i = jnp.zeros((), i32)
    zeros_k = jnp.zeros((k,), i32)
    return DeviceState(
        energy=cfg.start_energy.astype(f32),
        was_off=jnp.zeros((), bool),
        next_rel=zeros_k,
        rr_cursor=zero_i,
        lock_slot=jnp.full((), -1, i32),
        lock_job=jnp.full((), -1, i32),
        q_active=jnp.zeros((q,), bool),
        q_release=jnp.zeros((q,), f32),
        q_deadline=jnp.zeros((q,), f32),
        q_task=jnp.zeros((q,), i32),
        q_job=jnp.zeros((q,), i32),
        q_unit=jnp.zeros((q,), i32),
        q_time_left=jnp.zeros((q,), f32),
        q_exited=jnp.full((q,), -1, i32),
        q_last_pred=jnp.full((q,), -1, i32),
        q_mand_time=jnp.full((q,), -1.0, f32),
        m_scheduled=zeros_k,
        m_correct=zeros_k,
        m_misses=zeros_k,
        m_units=zeros_k,
        m_optional=zeros_k,
        m_reboots=zero_i,
        m_busy=jnp.zeros((), f32),
        m_idle=jnp.zeros((), f32),
        m_wasted=jnp.zeros((), f32),
    )
