"""Fixed-timestep, JAX-native fleet frontend over the unified step core.

Where :func:`repro.core.scheduler.simulate` is a scalar python event loop
(one device / seed / config per call), this simulator steps the *entire*
fleet state — capacitor energies, fixed-size job queues, harvester event
streams — with one ``jax.lax.scan`` over time, ``jax.vmap``-ing the
per-device transition across the device axis.  One jitted call therefore
evaluates a whole policy × eta × harvester × capacitor × seed grid.

The per-device transition itself — release/admit, drop-expired, priority
pick via :mod:`repro.core.policy`, fragment apply, capacitor
charge/discharge, metric accumulation — lives in :mod:`repro.core.step` as
pure ``(StepParams, DeviceCarry, t) -> DeviceCarry`` functions with no
device axis; this module only adds the batching (``vmap``), the time scan,
and the optional Pallas pick (:mod:`repro.kernels.fleet_priority`, whose
in-tile semantics are the same :func:`repro.core.step.select_and_charge`).
Because batching elementwise transitions is exact, the fleet path is
*bit-exact* against the scalar-stepped frontend
:func:`repro.core.scheduler.simulate_stepped` on the shared clock — the
parity harness in ``tests/test_parity.py`` asserts equality, not calibrated
tolerances.

Two execution shapes:

* :func:`simulate_fleet` — one monolithic scan over the whole horizon.
* :func:`run_segments` — the same horizon in ``n_segments`` chunks,
  returning/accepting the full carry pytree (:class:`DeviceState`) between
  chunks and calling a host ``hook`` at each boundary.  The hook may
  rewrite the *tunable* FleetConfig fields (eta, e_opt, exit thresholds)
  mid-trajectory — the substrate of the paper's online adaptation loop
  (:mod:`repro.adapt.online`).  With no hook the chunked scan is
  bit-identical to the monolithic one for any ``n_segments``.

Fidelity notes vs the event-driven scalar simulator: execution is quantized
to ``dt`` (keep ``dt`` at or below one fragment time), fragment energy is
drained continuously rather than per-fragment, and job admission/expiry are
checked every ``dt`` rather than only at unit boundaries — so counts agree
within a small tolerance rather than bit-exactly; the parity tests in
``tests/test_fleet.py`` and the task-set harness in ``tests/test_parity.py``
pin the agreement down.  Limited preemption itself is preserved: a started
unit holds a lock (``lock_slot``/``lock_job``) and runs to its boundary
before the scheduler re-picks, exactly as in paper §4.1.  Round-robin
rotates a per-device task cursor (``rr_cursor``) at unit boundaries, the
array analogue of the scalar simulator's rotation at each pick.
"""
from __future__ import annotations

import functools
import inspect
import warnings
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core import step as S
from ..telemetry import export as T_export
from ..telemetry import state as T
from ..telemetry import trace as T_trace
from .state import DeviceState, FleetConfig, FleetResult, FleetStatics, \
    init_state

_F32 = jnp.float32

#: the FleetConfig fields adaptation hooks may rewrite mid-trajectory —
#: run_segments diffs them after each hook to stamp knob-update telemetry
TUNABLE_FIELDS = ("eta", "e_opt", "exit_thr", "use_exit_thr", "persistent")

#: execution modes of the time loop:
#: - "vmap": lax.scan over vmap(device_step) — the XLA-fused reference
#: - "pallas": same scan, but the pick stage runs in the fleet_priority
#:   kernel (one pallas_call per *step*; kept as the per-stage kernel demo)
#: - "fused": the whole segment's time loop runs inside ONE pallas_call
#:   (repro.kernels.fleet_step) with the carry tile VMEM-resident
FLEET_MODES = ("vmap", "pallas", "fused")


def _resolve_mode(mode: Optional[str],
                  use_pallas: Optional[bool] = None) -> str:
    """Fold the legacy ``use_pallas`` flag and the ``mode`` kwarg into one
    mode string.  ``use_pallas`` is DEPRECATED: passing it (either value)
    warns; the mode strings (:data:`FLEET_MODES`) are the API.  An explicit
    ``mode`` wins when both are given."""
    if use_pallas is not None:
        warnings.warn(
            "use_pallas= is deprecated; pass mode='pallas' (or 'vmap' / "
            "'fused') instead", DeprecationWarning, stacklevel=3)
        if mode is None:
            return "pallas" if use_pallas else "vmap"
    if mode is None:
        return "vmap"
    if mode not in FLEET_MODES:
        raise ValueError(f"mode must be one of {FLEET_MODES}, got {mode!r}")
    return mode


def _pick_pallas(cfg: FleetConfig, states: DeviceState, t,
                 statics: FleetStatics):
    """Batched pick via the Pallas fleet_priority kernel (whole-fleet call).

    The kernel tile gains the task dimension: the raw per-slot task ids and
    the per-device rr cursors ride into VMEM and the rotation rank is
    computed inside the kernel, next to the priority-argmax."""
    from ..kernels import ops  # local import: kernels pull in pallas

    (laxity, utility, mandatory, gate_e, drain, charge, forced,
     _task_rank) = jax.vmap(
        lambda c, s: S.pick_inputs(c, s, t, statics))(cfg, states)
    return ops.fleet_priority(
        cfg.policy, states.q_active, laxity, states.q_release, utility,
        mandatory, cfg.alpha, cfg.beta, cfg.eta, cfg.persistent,
        states.energy, cfg.e_opt, charge, cfg.capacity, gate_e, drain,
        forced, states.q_task, states.rr_cursor,
        n_tasks=cfg.period.shape[-1])


def _fleet_step(cfg: FleetConfig, states: DeviceState, i,
                statics: FleetStatics, use_pallas: bool) -> DeviceState:
    """One fleet timestep: vmap of the step core's device transition (or
    the split admit/expire/Pallas-pick/apply pipeline when the pick runs in
    the kernel, which needs the whole device batch at once)."""
    t = i.astype(_F32) * statics.dt
    t_end = (i + 1).astype(_F32) * statics.dt
    if not use_pallas:
        return jax.vmap(
            lambda c, s: S.device_step(c, s, t, statics, t_end=t_end)
        )(cfg, states)
    states = jax.vmap(lambda c, s: S.admit(c, s, t, statics))(cfg, states)
    states = jax.vmap(lambda c, s: S.drop_expired(c, s, t))(cfg, states)
    sel, picked, run, e_new = _pick_pallas(cfg, states, t, statics)
    return jax.vmap(
        lambda c, s, a, p, r, e: S.apply_step(c, s, t, a, p, r, e, statics,
                                              t_end=t_end)
    )(cfg, states, sel, picked, run, e_new)


@functools.partial(jax.jit, static_argnames=("statics",))
def init_fleet(cfg: FleetConfig, statics: FleetStatics) -> DeviceState:
    """The t=0 carry pytree for every device in ``cfg`` (the value
    :func:`run_segments` accepts/returns between horizon chunks)."""
    return jax.vmap(lambda c: init_state(c, statics))(cfg)


@functools.partial(jax.jit,
                   static_argnames=("statics", "n_steps", "use_pallas"))
def _scan_steps(cfg: FleetConfig, states: DeviceState, i0,
                statics: FleetStatics, n_steps: int,
                use_pallas: bool) -> DeviceState:
    """Scan ``n_steps`` timesteps starting at step index ``i0`` (traced, so
    all equal-length segments share one compilation)."""
    def step(states, i):
        return _fleet_step(cfg, states, i, statics, use_pallas), None

    states, _ = lax.scan(step, states, i0 + jnp.arange(n_steps))
    return states


def _scan_steps_fused(cfg: FleetConfig, states: DeviceState, i0,
                      statics: FleetStatics, n_steps: int) -> DeviceState:
    """Fused twin of :func:`_scan_steps`: the entire ``n_steps`` time loop
    runs inside ONE ``pallas_call`` (:mod:`repro.kernels.fleet_step`) with a
    ``block_d``-row carry tile VMEM-resident — no per-step dispatch, no HBM
    carry round-trips inside the segment.  Bit-exact against the scan (the
    kernel body is the same :func:`repro.core.step.device_step`)."""
    from ..kernels import ops  # local import: kernels pull in pallas

    return ops.fleet_fused_steps(cfg, states, i0, statics=statics,
                                 n_steps=n_steps)


def _fleet_step_trace(cfg: FleetConfig, states: DeviceState, i,
                      statics: FleetStatics, use_pallas: bool):
    """Descriptor-emitting twin of :func:`_fleet_step`: the same stages in
    the same order, additionally returning the step's packed
    :class:`repro.core.step.StepTrace` event words (a few bytes/device)."""
    t = i.astype(_F32) * statics.dt
    t_end = (i + 1).astype(_F32) * statics.dt
    if not use_pallas:
        return jax.vmap(
            lambda c, s: S.device_step(c, s, t, statics, trace=True,
                                       t_end=t_end)
        )(cfg, states)
    act0 = states.q_active
    states, (tr_adm, tr_ev, tr_ev_dl) = jax.vmap(
        lambda c, s: S.admit(c, s, t, statics, trace=True))(cfg, states)
    states, (tr_exp, tr_exp_dl) = jax.vmap(
        lambda c, s, a0: S.drop_expired(c, s, t, trace=True,
                                        q_active_pre=a0)
    )(cfg, states, act0)
    sel, picked, run, e_new = _pick_pallas(cfg, states, t, statics)
    states, (tr_comp, tr_comp_dl) = jax.vmap(
        lambda c, s, a, p, r, e, a0: S.apply_step(
            c, s, t, a, p, r, e, statics, trace=True, q_active_pre=a0,
            t_end=t_end)
    )(cfg, states, sel, picked, run, e_new, act0)
    return states, S.StepTrace(adm=tr_adm, evict=tr_ev, evict_dl=tr_ev_dl,
                               expire=tr_exp, expire_dl=tr_exp_dl,
                               complete=tr_comp, complete_dl=tr_comp_dl)


def _pack_spec(cfg: FleetConfig, statics: FleetStatics,
               tel: T.Telemetry) -> T_trace.PackSpec:
    return T_trace.make_pack_spec(int(cfg.period.shape[1]),
                                  statics.queue_size,
                                  int(tel.exit_hist.shape[1]))


@functools.partial(
    jax.jit, static_argnames=("statics", "n_steps", "use_pallas", "level"))
def _scan_steps_trace(cfg: FleetConfig, states: DeviceState,
                      tel: T.Telemetry, i0, statics: FleetStatics,
                      n_steps: int, use_pallas: bool, level: str):
    """Like :func:`_scan_steps`, but emitting the telemetry columns of the
    requested collection tier and reducing them into ``tel`` once per
    segment, after the scan but inside the same jit.

    ``"counters"`` reuses the plain step body and emits three registers it
    already computed; ``"full"`` runs the descriptor-emitting step twin and
    emits the bit-packed event columns (:class:`repro.telemetry.trace
    .PackSpec`), which are also returned for the sparse host-side
    ring/histogram fold (``None`` at the counters tier)."""
    st0 = states
    if level == "counters":
        def step(states, i):
            new = _fleet_step(cfg, states, i, statics, use_pallas)
            return new, T_trace.emit_counters(new)

        states, ys = lax.scan(step, states, i0 + jnp.arange(n_steps))
        return states, T_trace.reduce_counters(tel, st0, states, ys,
                                               n_steps), None

    spec = _pack_spec(cfg, statics, tel)

    def step(states, i):
        new, tr = _fleet_step_trace(cfg, states, i, statics, use_pallas)
        return new, T_trace.emit_full(spec, tr, states, new)

    states, ys = lax.scan(step, states, i0 + jnp.arange(n_steps))
    tel, ring = T_trace.reduce_full(spec, tel, st0, states, ys, i0,
                                    n_steps, statics.dt)
    return states, tel, ring


def _scan_steps_tel(cfg: FleetConfig, states: DeviceState, tel: T.Telemetry,
                    i0, statics: FleetStatics, n_steps: int,
                    use_pallas: bool,
                    tcfg: T.TelemetryConfig):
    """Telemetry-carrying twin of :func:`_scan_steps` (host wrapper).

    The jitted scan emits the tier's telemetry columns and reduces the
    dense statistics per segment; at the ``"full"`` tier the rare
    ring/histogram events are then folded host-side from the packed
    columns (:func:`repro.telemetry.trace.fold_events_host`, O(events)).
    The simulation carry is asserted bit-exact against the uninstrumented
    scan in ``tests/test_telemetry.py``, and the default-tier overhead is
    gated < 5% in ``benchmarks/check_regression.py``."""
    states, tel, ring = _scan_steps_trace(cfg, states, tel, i0, statics,
                                          n_steps, use_pallas, tcfg.level)
    if ring is not None:
        tel = T_trace.fold_events_host(
            _pack_spec(cfg, statics, tel), tel,
            tuple(np.asarray(col) for col in ring), int(i0), statics.dt)
    return states, tel


@functools.partial(
    jax.jit,
    static_argnames=("statics", "n_steps", "use_pallas", "tcfg"))
def _scan_steps_tel_reference(cfg: FleetConfig, states: DeviceState,
                              tel: T.Telemetry, i0, statics: FleetStatics,
                              n_steps: int, use_pallas: bool,
                              tcfg: T.TelemetryConfig):
    """The slow reference: fold :func:`repro.telemetry.state.record_step`
    from the before/after carry pair at every step, inside the scan.  Kept
    as the semantic spec the trace pipeline is tested against (and as the
    simplest possible implementation to read)."""
    def step(carry, i):
        states, tel = carry
        t = i.astype(_F32) * statics.dt
        new = _fleet_step(cfg, states, i, statics, use_pallas)
        ev = jax.vmap(
            lambda s0, s1: S.step_events(s0, s1, t, statics))(states, new)
        tel = jax.vmap(lambda tl, e: T.record_step(tl, e, t))(tel, ev)
        return (new, tel), None

    (states, tel), _ = lax.scan(step, (states, tel),
                                i0 + jnp.arange(n_steps))
    return states, tel


@functools.partial(jax.jit, static_argnames=("statics", "live"))
def finalize_fleet(cfg: FleetConfig, states: DeviceState,
                   statics: FleetStatics, live: bool = False) -> FleetResult:
    """Flush the carry into a :class:`FleetResult` (vmap of the step core's
    finalize).  ``live`` counts correctness from the live registers
    (:mod:`repro.serve.fleet_engine`) instead of the replay tables."""
    return jax.vmap(lambda c, s: S.finalize(c, s, statics, live))(cfg, states)


@functools.partial(jax.jit, static_argnames=("statics", "use_pallas"))
def _simulate_fleet_plain(cfg: FleetConfig, statics: FleetStatics,
                          use_pallas: bool = False) -> FleetResult:
    states0 = jax.vmap(lambda c: init_state(c, statics))(cfg)

    def step(states, i):
        return _fleet_step(cfg, states, i, statics, use_pallas), None

    states, _ = lax.scan(step, states0, jnp.arange(statics.n_steps))
    return jax.vmap(lambda c, s: S.finalize(c, s, statics))(cfg, states)


def _simulate_fleet_fused(cfg: FleetConfig,
                          statics: FleetStatics) -> FleetResult:
    """Monolithic fused run: init, ONE whole-horizon ``pallas_call``, and
    finalize — the fused analogue of :func:`_simulate_fleet_plain`."""
    states = _scan_steps_fused(cfg, init_fleet(cfg, statics), jnp.int32(0),
                               statics, statics.n_steps)
    return finalize_fleet(cfg, states, statics)


def simulate_fleet(cfg: FleetConfig, statics: FleetStatics,
                   use_pallas: Optional[bool] = None,
                   telemetry: Optional[T.TelemetryConfig] = None,
                   mode: Optional[str] = None):
    """Simulate every device in ``cfg`` in one jitted scan.

    Returns a :class:`FleetResult` of ``(D,)`` metric arrays — plus
    ``(D, K)`` per-task breakdowns — aligned with the device axis of ``cfg``
    (see :func:`repro.fleet.grid.sweep` for the grid bookkeeping).

    ``mode`` selects the time-loop execution shape (:data:`FLEET_MODES`):
    ``"vmap"`` (default), ``"pallas"`` (per-step pick kernel; the legacy
    ``use_pallas=True``), or ``"fused"`` — the whole horizon in ONE
    ``pallas_call`` with the carry VMEM-resident
    (:mod:`repro.kernels.fleet_step`).  All three are bit-exact against
    each other.  ``mode="fused"`` does not support ``telemetry`` (the
    per-step trace columns would defeat the in-kernel loop; use the vmap
    path to instrument).

    ``telemetry`` (a :class:`repro.telemetry.TelemetryConfig`)
    additionally instruments the scan and returns
    ``(FleetResult, Telemetry)``: the scan emits a few telemetry columns
    per step and the statistics reduce once per segment
    (:mod:`repro.telemetry.trace`) — at the default ``"counters"`` tier
    that is near-free; the ``"full"`` tier adds per-step event
    descriptors, with the rare ring/histogram events folded host-side.
    With the default ``None`` the instrumentation is compiled out
    entirely — the emitted program is the pre-telemetry one, and the
    FleetResult is bit-exact either way.
    """
    mode = _resolve_mode(mode, use_pallas)
    if mode == "fused":
        if telemetry is not None:
            raise ValueError(
                "mode='fused' does not support telemetry; use mode='vmap'")
        return _simulate_fleet_fused(cfg, statics)
    use_pallas = mode == "pallas"
    if telemetry is None:
        return _simulate_fleet_plain(cfg, statics, use_pallas)
    res, tel, ring = _simulate_fleet_tel(cfg, statics, use_pallas, telemetry)
    if ring is not None:
        tel = T_trace.fold_events_host(
            _pack_spec(cfg, statics, tel), tel,
            tuple(np.asarray(col) for col in ring), 0, statics.dt)
    return res, tel


@functools.partial(jax.jit,
                   static_argnames=("statics", "use_pallas", "telemetry"))
def _simulate_fleet_tel(cfg: FleetConfig, statics: FleetStatics,
                        use_pallas: bool, telemetry: T.TelemetryConfig):
    """One fused program for the instrumented monolithic run — init, scan,
    telemetry reduction, and finalize dispatch together, exactly like
    :func:`_simulate_fleet_plain` (four separate dispatches would charge
    the telemetry path for unfused init/finalize kernels the plain path
    fuses away, polluting the measured overhead)."""
    states0 = jax.vmap(lambda c: init_state(c, statics))(cfg)
    tel0 = T.init_fleet_telemetry(telemetry, cfg)
    states, tel, ring = _scan_steps_trace(
        cfg, states0, tel0, jnp.int32(0), statics, statics.n_steps,
        use_pallas, telemetry.level)
    res = jax.vmap(lambda c, s: S.finalize(c, s, statics))(cfg, states)
    return res, tel, ring


# hook signature: (segment_index, t_end, cfg, carry) -> new cfg or None
# (hooks that also declare a ``telemetry`` keyword additionally receive the
# cumulative TelemetrySummary when telemetry is enabled)
SegmentHook = Callable[[int, float, FleetConfig, DeviceState],
                       Optional[FleetConfig]]


def _hook_takes_telemetry(hook) -> bool:
    """Does ``hook`` accept a ``telemetry=`` keyword?  Bare 4-arg hooks stay
    supported unchanged; hooks opt into summaries by naming the kwarg (or
    taking **kwargs)."""
    try:
        sig = inspect.signature(hook)
    except (TypeError, ValueError):
        return False
    params = sig.parameters.values()
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return True
    return "telemetry" in sig.parameters


def _knob_change_mask(old_cfg: FleetConfig, new_cfg: FleetConfig):
    """(D,) bool: which devices had any TUNABLE_FIELDS leaf rewritten by a
    hook (host-side numpy compare; runs once per segment boundary)."""
    changed = None
    for f in TUNABLE_FIELDS:
        a = np.asarray(getattr(old_cfg, f))
        b = np.asarray(getattr(new_cfg, f))
        diff = a != b
        while diff.ndim > 1:          # per-task knobs: any task changed
            diff = diff.any(axis=-1)
        changed = diff if changed is None else (changed | diff)
    return changed


def run_segments(cfg: FleetConfig, statics: FleetStatics,
                 n_segments: int = 1, *,
                 hook: Optional[SegmentHook] = None,
                 carry: Optional[DeviceState] = None,
                 start_step: int = 0,
                 use_pallas: Optional[bool] = None,
                 mode: Optional[str] = None,
                 mesh=None,
                 telemetry: Optional[T.TelemetryConfig] = None,
                 telemetry_carry: Optional[T.Telemetry] = None):
    """Segment-at-a-time fleet simulation over the checkpointable carry.

    Splits the scan over steps ``[start_step, statics.n_steps)`` into
    ``n_segments`` contiguous chunks (lengths differ by at most one step,
    so at most two distinct compilations) and materialises the full carry
    pytree (:class:`DeviceState`) at every boundary.  After each segment
    the host ``hook(seg, t_end, cfg, carry)`` runs and may return a
    modified FleetConfig — rewriting *tunable* fields (``eta``, ``e_opt``,
    ``exit_thr``/``use_exit_thr``, ``persistent``) mid-trajectory is how
    :mod:`repro.adapt.online` implements the paper's runtime eta
    re-estimation loop.  Returning ``None`` keeps the current config.

    ``carry`` + ``start_step`` resume a previous run: pass the returned
    carry together with the number of steps it has already lived through
    (the simulation clock is ``t = step * dt``, and the carry holds
    absolute release/deadline times, so resuming must NOT restart the
    clock at zero).  ``carry=None`` starts from :func:`init_fleet` at step
    ``start_step`` (normally 0).  ``mesh`` partitions the device axis
    exactly like :func:`simulate_fleet_sharded` — the carry shards
    alongside the config (:func:`repro.launch.sharding.shard_fleet_carry`),
    the hook then observes the padded device axis (hook-returned configs
    are re-placed on the mesh so config and carry stay aligned
    shard-for-shard), and the returned result/carry are sliced back to the
    real devices.

    With ``hook=None`` the chunked scan is bit-identical to
    :func:`simulate_fleet` for any ``n_segments``: the same step indices
    run through the same jitted step body, only the carry round-trips
    through host memory between chunks.

    ``telemetry`` (a static :class:`repro.telemetry.TelemetryConfig`)
    threads a ``(D, ...)`` :class:`repro.telemetry.Telemetry` pytree
    alongside the carry and changes the return to
    ``(FleetResult, DeviceState, Telemetry)``.  Hooks that declare a
    ``telemetry`` keyword then receive the cumulative
    :class:`repro.telemetry.TelemetrySummary` at each boundary, and config
    rewrites by hooks are stamped into the telemetry as ``knob_update``
    events.  ``telemetry_carry`` resumes a prior telemetry pytree the same
    way ``carry`` resumes the simulation.  The simulation numerics are
    identical either way — only the return arity changes.

    ``mode`` selects the time-loop execution shape exactly as in
    :func:`simulate_fleet`; ``mode="fused"`` runs each segment as ONE
    ``pallas_call`` (the carry still round-trips at every boundary, so
    hooks and checkpoint resume work unchanged and stay bit-exact against
    the vmap path).  Fused excludes ``telemetry`` and ``mesh``.

    Returns ``(FleetResult, DeviceState)`` — the finalized metrics and the
    end-of-horizon carry — plus the ``Telemetry`` when enabled.
    """
    mode = _resolve_mode(mode, use_pallas)
    use_pallas = mode == "pallas"
    if mode == "fused":
        if telemetry is not None:
            raise ValueError(
                "mode='fused' does not support telemetry; use mode='vmap'")
        if mesh is not None:
            raise ValueError(
                "mode='fused' does not support mesh sharding yet")
    remaining = statics.n_steps - int(start_step)
    if not 0 <= int(start_step) <= statics.n_steps:
        raise ValueError(
            f"start_step must be in [0, {statics.n_steps}], got {start_step}")
    if not 1 <= n_segments <= max(remaining, 1):
        raise ValueError(
            f"n_segments must be in [1, {max(remaining, 1)}], "
            f"got {n_segments}")
    if telemetry is None and telemetry_carry is not None:
        raise ValueError("telemetry_carry requires telemetry=TelemetryConfig")
    n_real = cfg.n_devices
    if mesh is not None:
        from ..launch.sharding import shard_fleet_carry, shard_fleet_config

        cfg = shard_fleet_config(mesh, cfg)
        if carry is not None:
            carry = shard_fleet_carry(mesh, carry)
        if telemetry_carry is not None:
            telemetry_carry = shard_fleet_carry(mesh, telemetry_carry)
    if carry is None:
        carry = init_fleet(cfg, statics)
    tel = None
    if telemetry is not None:
        tel = telemetry_carry
        if tel is None:
            tel = T.init_fleet_telemetry(telemetry, cfg)
            if mesh is not None:
                from ..launch.sharding import shard_fleet_carry

                tel = shard_fleet_carry(mesh, tel)
    hook_wants_tel = hook is not None and telemetry is not None \
        and _hook_takes_telemetry(hook)

    sizes = [len(c) for c in np.array_split(np.arange(remaining),
                                            n_segments)]
    i0 = int(start_step)
    for seg, n in enumerate(sizes):
        if n:
            if mode == "fused":
                carry = _scan_steps_fused(cfg, carry, jnp.int32(i0),
                                          statics, n)
            elif telemetry is None:
                carry = _scan_steps(cfg, carry, jnp.int32(i0), statics, n,
                                    use_pallas)
            else:
                carry, tel = _scan_steps_tel(
                    cfg, carry, tel, jnp.int32(i0), statics, n, use_pallas,
                    telemetry)
            i0 += n
        if hook is not None:
            t_end = i0 * statics.dt
            if hook_wants_tel:
                new_cfg = hook(seg, t_end, cfg, carry,
                               telemetry=T_export.summarize(tel, t_end))
            else:
                new_cfg = hook(seg, t_end, cfg, carry)
            if new_cfg is not None:
                if telemetry is not None:
                    changed = _knob_change_mask(cfg, new_cfg)
                    if changed is not None and changed.any():
                        tel = T.record_knob_updates(tel, changed, t_end)
                cfg = new_cfg
                if mesh is not None:
                    # keep hook-returned leaves placed like the carry (the
                    # hook typically swaps in fresh host arrays)
                    cfg = shard_fleet_config(mesh, cfg)
    res = finalize_fleet(cfg, carry, statics)
    if mesh is not None and jax.tree.leaves(res)[0].shape[0] != n_real:
        res = jax.tree.map(lambda x: x[:n_real], res)
        carry = jax.tree.map(lambda x: x[:n_real], carry)
        if tel is not None:
            tel = jax.tree.map(lambda x: x[:n_real], tel)
    if telemetry is None:
        return res, carry
    return res, carry, tel


def simulate_fleet_sharded(cfg: FleetConfig, statics: FleetStatics,
                           mesh=None, use_pallas: Optional[bool] = None,
                           mode: Optional[str] = None) -> FleetResult:
    """:func:`simulate_fleet` with the device axis partitioned over ``mesh``.

    The fleet axis is embarrassingly parallel (no cross-device collectives in
    the scan body), so placing each ``FleetConfig`` leaf with a
    ``NamedSharding`` over its leading axis lets GSPMD split the whole
    simulation across the mesh devices with zero communication.  The device
    count is padded up to a mesh-size multiple (wrapping around the existing
    configs) and the padding is stripped from the result, so the output is
    bit-identical to the unsharded call for every real device.

    ``mesh=None`` falls back to the plain single-backend path.
    """
    mode = _resolve_mode(mode, use_pallas)
    if mesh is None:
        return simulate_fleet(cfg, statics, mode=mode)
    # local import: repro.launch is a heavier dependency tree than the fleet
    from ..launch.sharding import shard_fleet_config

    n_real = cfg.n_devices
    cfg = shard_fleet_config(mesh, cfg)
    res = simulate_fleet(cfg, statics, mode=mode)
    return jax.tree.map(lambda x: x[:n_real], res)
