"""Fixed-timestep, JAX-native reimplementation of the intermittent scheduler
simulation, batched over thousands of devices.

Where :func:`repro.core.scheduler.simulate` is a scalar python event loop
(one device / seed / config per call), this simulator steps the *entire*
fleet state — capacitor energies, fixed-size job queues, harvester event
streams — with one ``jax.lax.scan`` over time, ``jax.vmap``-ing the
per-device step across the device axis.  One jitted call therefore evaluates
a whole policy × eta × harvester × capacitor × seed grid.

Each device runs a *task set*: ``K`` periodic DNN task streams (the paper's
multi-app audio+camera deployments) share one capacitor and one scheduler.
Queue slots carry a ``task_id`` and every helper below gathers the right
task row — period, deadline, unit times/energies, profile tables — before
applying the exact same per-slot logic the single-task path used.  With
``K = 1`` the task axis is a size-1 gather and the simulation is
bit-identical to the pre-task-set fleet path.

Per step (dt), each device: admits at most one released job per task
(evicting an optional-only job on overflow, paper §5.2), expires
past-deadline jobs, picks a queue slot with the shared priority functions
from :mod:`repro.core.policy` (or the Pallas kernel
:mod:`repro.kernels.fleet_priority` when ``use_pallas=True``), and then
either executes ``dt`` seconds of the selected unit (draining the capacitor
at the unit's power) or idles/charges.  Unit boundaries run the utility
test against the precomputed job profiles, exactly like the scalar path.

Fidelity notes vs the event-driven scalar simulator: execution is quantized
to ``dt`` (keep ``dt`` at or below one fragment time), fragment energy is
drained continuously rather than per-fragment, and job admission/expiry are
checked every ``dt`` rather than only at unit boundaries — so counts agree
within a small tolerance rather than bit-exactly; the parity tests in
``tests/test_fleet.py`` and the task-set harness in ``tests/test_parity.py``
pin the agreement down.  Limited preemption itself is preserved: a started
unit holds a lock (``lock_slot``/``lock_job``) and runs to its boundary
before the scheduler re-picks, exactly as in paper §4.1.  Round-robin
rotates a per-device task cursor (``rr_cursor``) at unit boundaries, the
array analogue of the scalar simulator's rotation at each pick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..core import policy as P
from .state import DeviceState, FleetConfig, FleetResult, FleetStatics, init_state

_F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Per-device helpers (scalar state; jax.vmap supplies the device axis).
# --------------------------------------------------------------------------- #


def _finish_counts(cfg: FleetConfig, st: DeviceState, mask: jax.Array):
    """Tally (scheduled, correct, missed) for the queue slots in ``mask``,
    broken down per task — ``(K,)`` int arrays each."""
    n_tasks = cfg.period.shape[0]
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    sched = mask & (st.q_mand_time >= 0.0) & (st.q_mand_time <= st.q_deadline)
    job = jnp.clip(st.q_job, 0, cfg.margins.shape[1] - 1)
    lp = jnp.clip(st.q_last_pred, 0, cfg.margins.shape[2] - 1)
    corr = sched & (st.q_last_pred >= 0) & cfg.correct[tk, job, lp]
    miss = mask & ~sched
    onehot = tk[:, None] == jnp.arange(n_tasks)[None, :]   # (Q, K)

    def per_task(m):
        return jnp.sum(m[:, None] & onehot, axis=0)

    return per_task(sched), per_task(corr), per_task(miss)


def _admit(cfg: FleetConfig, st: DeviceState, t, statics: FleetStatics):
    """Admit at most one released job per task (the builder asserts
    dt < period).  The static python loop over the task axis admits in task
    order — the same order the scalar path's stable release sort yields for
    simultaneous releases."""
    q = statics.queue_size
    n_tasks = cfg.period.shape[0]
    for k in range(n_tasks):
        rel_time = st.next_rel[k].astype(_F32) * cfg.period[k]
        releasing = (st.next_rel[k] < cfg.n_releases[k]) & (rel_time <= t)

        free = ~st.q_active
        has_free = jnp.any(free)
        # overflow: evict the earliest-deadline job whose mandatory part is
        # done (optional-only work yields to the new arrival — mandatory
        # first, §5.2)
        evictable = st.q_active & (st.q_exited >= 0)
        has_evict = jnp.any(evictable)
        victim = jnp.argmin(jnp.where(evictable, st.q_deadline, jnp.inf))
        evict = releasing & ~has_free & has_evict
        vmask = evict & (jnp.arange(q) == victim)
        d_sched, d_corr, d_miss = _finish_counts(cfg, st, vmask)

        insert = releasing & (has_free | has_evict)
        slot = jnp.where(has_free, jnp.argmax(free), victim)
        ins = insert & (jnp.arange(q) == slot)
        dropped = releasing & ~insert   # queue overflow, nothing evictable
        k_hot = jnp.arange(n_tasks) == k

        st = st._replace(
            next_rel=st.next_rel.at[k].add(releasing),
            q_active=(st.q_active & ~vmask) | ins,
            q_release=jnp.where(ins, rel_time, st.q_release),
            q_deadline=jnp.where(ins, rel_time + cfg.rel_deadline[k],
                                 st.q_deadline),
            q_task=jnp.where(ins, k, st.q_task),
            q_job=jnp.where(ins, st.next_rel[k], st.q_job),
            q_unit=jnp.where(ins, 0, st.q_unit),
            q_time_left=jnp.where(ins, cfg.unit_time[k, 0], st.q_time_left),
            q_exited=jnp.where(ins, -1, st.q_exited),
            q_last_pred=jnp.where(ins, -1, st.q_last_pred),
            q_mand_time=jnp.where(ins, -1.0, st.q_mand_time),
            m_scheduled=st.m_scheduled + d_sched,
            m_correct=st.m_correct + d_corr,
            m_misses=st.m_misses + d_miss + (dropped & k_hot),
        )
    return st


def _drop_expired(cfg: FleetConfig, st: DeviceState, t):
    # the device expires jobs against its *drifting* clock (fleet CHRT
    # model): a fast clock (drift > 0) drops jobs before their true deadline
    t_read = t * (1.0 + cfg.clock_drift)
    expired = st.q_active & (t_read >= st.q_deadline)
    d_sched, d_corr, d_miss = _finish_counts(cfg, st, expired)
    return st._replace(
        q_active=st.q_active & ~expired,
        m_scheduled=st.m_scheduled + d_sched,
        m_correct=st.m_correct + d_corr,
        m_misses=st.m_misses + d_miss,
    )


def _pick_inputs(cfg: FleetConfig, st: DeviceState, t, statics: FleetStatics):
    """Per-slot priority/energy ingredients shared by the jnp pick and the
    Pallas kernel: each slot gathers its own task's row of the (K, U) /
    (K, J, U) tables before the shared priority math runs."""
    n_tasks = cfg.period.shape[0]
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    u = jnp.clip(st.q_unit, 0, cfg.unit_time.shape[1] - 1)
    unit_t = cfg.unit_time[tk, u]
    unit_e = cfg.unit_energy[tk, u]
    gate_e = jnp.maximum(unit_e / cfg.fragments[tk], cfg.e_man)
    drain = unit_e * (statics.dt / unit_t)
    job = jnp.clip(st.q_job, 0, cfg.margins.shape[1] - 1)
    lp = jnp.clip(st.q_last_pred, 0, cfg.margins.shape[2] - 1)
    utility = jnp.where(st.q_last_pred >= 0, cfg.margins[tk, job, lp], 0.0)
    mandatory = st.q_exited < 0
    laxity = st.q_deadline - t
    n_slots = cfg.events.shape[0]
    slot = jnp.minimum((t / statics.slot_s).astype(jnp.int32), n_slots - 1)
    charge = cfg.events[slot] * cfg.power_on * statics.dt
    # limited preemption: a slot mid-unit is forced until the unit boundary
    # (unless it expired or its slot was recycled for a newer job)
    ls = jnp.clip(st.lock_slot, 0, st.q_active.shape[0] - 1)
    locked = ((st.lock_slot >= 0) & st.q_active[ls]
              & (st.q_job[ls] == st.lock_job))
    forced = jnp.where(locked, ls, -1).astype(jnp.int32)
    # rr task rotation: distance of each slot's task from the rr cursor
    # (identically 0 when K == 1, keeping the FIFO key bit-identical)
    task_rank = jnp.mod(tk - st.rr_cursor, n_tasks).astype(_F32)
    return (laxity, utility, mandatory, gate_e, drain, charge, forced,
            task_rank)


def _pick(cfg: FleetConfig, st: DeviceState, t, statics: FleetStatics):
    """Priority-argmax + fused capacitor charge/discharge (pure-jnp path)."""
    (laxity, utility, mandatory, gate_e, drain, charge, forced,
     task_rank) = _pick_inputs(cfg, st, t, statics)
    scores, thr = P.policy_scores(
        cfg.policy, st.q_active, laxity, st.q_release, utility, mandatory,
        cfg.alpha, cfg.beta, cfg.eta, st.energy, cfg.e_opt, cfg.persistent,
        task_rank)
    sel = jnp.where(forced >= 0, forced,
                    jnp.argmax(scores)).astype(jnp.int32)
    picked = (forced >= 0) | (jnp.max(scores) > thr)
    run = picked & (st.energy >= gate_e[sel])
    e_new = jnp.minimum(st.energy + charge, cfg.capacity) - run * drain[sel]
    return sel, picked, run, e_new


def _pick_pallas(cfg: FleetConfig, states: DeviceState, t,
                 statics: FleetStatics):
    """Batched pick via the Pallas fleet_priority kernel (whole-fleet call).

    The kernel tile gains the task dimension: the raw per-slot task ids and
    the per-device rr cursors ride into VMEM and the rotation rank is
    computed inside the kernel, next to the priority-argmax."""
    from ..kernels import ops  # local import: kernels pull in pallas

    (laxity, utility, mandatory, gate_e, drain, charge, forced,
     _task_rank) = jax.vmap(
        lambda c, s: _pick_inputs(c, s, t, statics))(cfg, states)
    return ops.fleet_priority(
        cfg.policy, states.q_active, laxity, states.q_release, utility,
        mandatory, cfg.alpha, cfg.beta, cfg.eta, cfg.persistent,
        states.energy, cfg.e_opt, charge, cfg.capacity, gate_e, drain,
        forced, states.q_task, states.rr_cursor,
        n_tasks=cfg.period.shape[-1])


def _apply(cfg: FleetConfig, st: DeviceState, t, sel, picked, run, e_new,
           statics: FleetStatics):
    """Advance the selected job by dt; handle unit/job completion."""
    q = statics.queue_size
    n_tasks = cfg.period.shape[0]
    u_max = cfg.unit_time.shape[1] - 1
    oh = jnp.arange(q) == sel
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    tk_sel = tk[sel]

    u_sel = jnp.clip(st.q_unit[sel], 0, u_max)
    frag_t = cfg.unit_time[tk_sel, u_sel] / cfg.fragments[tk_sel]

    # power-down / reboot bookkeeping (the initial cold boot counts wasted
    # half-fragment re-execution but not a reboot — matches the scalar path)
    reboot = run & st.was_off
    was_off = jnp.where(run, False, jnp.where(picked, True, st.was_off))
    idle_inc = jnp.where(picked & ~run, statics.dt, 0.0)

    # execute dt of the selected unit
    time_left = st.q_time_left - jnp.where(run & oh, statics.dt, 0.0)
    complete = run & oh & (time_left <= statics.dt * 1e-3)

    u = jnp.clip(st.q_unit, 0, u_max)
    job = jnp.clip(st.q_job, 0, cfg.passes.shape[1] - 1)
    n_units = cfg.n_units[tk]                      # (Q,) per-slot task depth
    next_u = jnp.clip(st.q_unit + 1, 0, u_max)
    done_any = jnp.any(complete)
    mandatory = st.q_exited < 0

    last_pred = jnp.where(complete, u, st.q_last_pred)
    unit = jnp.where(complete, st.q_unit + 1, st.q_unit)
    time_left = jnp.where(complete, cfg.unit_time[tk, next_u], time_left)

    # utility test at the unit boundary (imprecise policies only); tuned
    # per-unit thresholds (repro.adapt) re-evaluate the test against the
    # live margin, otherwise the precomputed passes table applies
    passed = jnp.where(cfg.use_exit_thr,
                       P.exit_test(cfg.margins[tk, job, u],
                                   cfg.exit_thr[tk, u]),
                       cfg.passes[tk, job, u])
    exit_now = complete & cfg.imprecise & (st.q_exited < 0) & passed
    exited = jnp.where(exit_now, u, st.q_exited)
    # never-confident full execution => the whole DNN was mandatory
    full_mand = complete & (exited < 0) & (st.q_unit + 1 >= n_units)
    exited = jnp.where(full_mand, n_units - 1, exited)
    t_end = t + statics.dt
    mand_time = jnp.where(exit_now | full_mand, t_end, st.q_mand_time)

    job_done = complete & (
        (st.q_unit + 1 >= n_units) | (cfg.is_edfm & (exited >= 0))
    )
    st_done = st._replace(q_last_pred=last_pred, q_mand_time=mand_time)
    d_sched, d_corr, d_miss = _finish_counts(cfg, st_done, job_done)

    # hold the lock while the unit is in progress (including power-gated
    # waits, like the scalar fragment loop); release at the unit boundary
    lock_on = picked & ~done_any
    # rr task rotation advances past the task whose unit just completed —
    # the unit-boundary analogue of the scalar rotation at each pick
    is_rr = cfg.policy == P.POLICY_IDS["rr"]
    rr_cursor = jnp.where(is_rr & done_any, jnp.mod(tk_sel + 1, n_tasks),
                          st.rr_cursor).astype(jnp.int32)
    sel_hot = jnp.arange(n_tasks) == tk_sel
    return st._replace(
        energy=e_new,
        was_off=was_off,
        rr_cursor=rr_cursor,
        lock_slot=jnp.where(lock_on, sel, -1).astype(jnp.int32),
        lock_job=jnp.where(lock_on, st.q_job[sel], -1).astype(jnp.int32),
        q_active=st.q_active & ~job_done,
        q_unit=unit,
        q_time_left=time_left,
        q_exited=exited,
        q_last_pred=last_pred,
        q_mand_time=mand_time,
        m_scheduled=st.m_scheduled + d_sched,
        m_correct=st.m_correct + d_corr,
        m_misses=st.m_misses + d_miss,
        m_units=st.m_units + (done_any & sel_hot),
        m_optional=st.m_optional + (done_any & ~mandatory[sel] & sel_hot),
        m_reboots=st.m_reboots + (reboot & (st.m_busy > 0)),
        m_busy=st.m_busy + jnp.where(run, statics.dt, 0.0),
        m_idle=st.m_idle + idle_inc,
        m_wasted=st.m_wasted + jnp.where(reboot, 0.5 * frag_t, 0.0),
    )


def _finalize(cfg: FleetConfig, st: DeviceState,
              statics: FleetStatics) -> FleetResult:
    """Flush live jobs and count never-admitted releases as misses; emit
    both the per-task (K,) counters and their aggregates."""
    d_sched, d_corr, d_miss = _finish_counts(cfg, st, st.q_active)
    unreleased = cfg.n_releases - st.next_rel       # (K,)
    t_sched = st.m_scheduled + d_sched
    t_corr = st.m_correct + d_corr
    t_miss = st.m_misses + d_miss + unreleased
    return FleetResult(
        released=jnp.sum(cfg.n_releases),
        scheduled=jnp.sum(t_sched),
        correct=jnp.sum(t_corr),
        deadline_misses=jnp.sum(t_miss),
        units_executed=jnp.sum(st.m_units),
        optional_units=jnp.sum(st.m_optional),
        busy_time=st.m_busy,
        idle_no_energy=st.m_idle,
        reboots=st.m_reboots,
        wasted_reexec=st.m_wasted,
        sim_time=jnp.full((), statics.horizon, _F32),
        task_released=cfg.n_releases,
        task_scheduled=t_sched,
        task_correct=t_corr,
        task_misses=t_miss,
        task_units=st.m_units,
        task_optional=st.m_optional,
    )


# --------------------------------------------------------------------------- #
# Fleet entry point: scan over time, vmap over devices, one jit.
# --------------------------------------------------------------------------- #


@functools.partial(jax.jit, static_argnames=("statics", "use_pallas"))
def simulate_fleet(cfg: FleetConfig, statics: FleetStatics,
                   use_pallas: bool = False) -> FleetResult:
    """Simulate every device in ``cfg`` in one jitted scan.

    Returns a :class:`FleetResult` of ``(D,)`` metric arrays — plus
    ``(D, K)`` per-task breakdowns — aligned with the device axis of ``cfg``
    (see :func:`repro.fleet.grid.sweep` for the grid bookkeeping).
    """
    states0 = jax.vmap(lambda c: init_state(c, statics))(cfg)

    def step(states, i):
        t = i.astype(_F32) * statics.dt
        states = jax.vmap(lambda c, s: _admit(c, s, t, statics))(cfg, states)
        states = jax.vmap(lambda c, s: _drop_expired(c, s, t))(cfg, states)
        if use_pallas:
            sel, picked, run, e_new = _pick_pallas(cfg, states, t, statics)
        else:
            sel, picked, run, e_new = jax.vmap(
                lambda c, s: _pick(c, s, t, statics))(cfg, states)
        states = jax.vmap(
            lambda c, s, a, p, r, e: _apply(c, s, t, a, p, r, e, statics)
        )(cfg, states, sel, picked, run, e_new)
        return states, None

    states, _ = lax.scan(step, states0, jnp.arange(statics.n_steps))
    return jax.vmap(lambda c, s: _finalize(c, s, statics))(cfg, states)


def simulate_fleet_sharded(cfg: FleetConfig, statics: FleetStatics,
                           mesh=None, use_pallas: bool = False) -> FleetResult:
    """:func:`simulate_fleet` with the device axis partitioned over ``mesh``.

    The fleet axis is embarrassingly parallel (no cross-device collectives in
    the scan body), so placing each ``FleetConfig`` leaf with a
    ``NamedSharding`` over its leading axis lets GSPMD split the whole
    simulation across the mesh devices with zero communication.  The device
    count is padded up to a mesh-size multiple (wrapping around the existing
    configs) and the padding is stripped from the result, so the output is
    bit-identical to the unsharded call for every real device.

    ``mesh=None`` falls back to the plain single-backend path.
    """
    if mesh is None:
        return simulate_fleet(cfg, statics, use_pallas=use_pallas)
    # local import: repro.launch is a heavier dependency tree than the fleet
    from ..launch.sharding import shard_fleet_config

    n_real = cfg.n_devices
    cfg = shard_fleet_config(mesh, cfg)
    res = simulate_fleet(cfg, statics, use_pallas=use_pallas)
    return jax.tree.map(lambda x: x[:n_real], res)
