"""Grid construction for fleet sweeps.

Host-side (numpy) builders that translate the scalar simulator's objects —
:class:`repro.core.scheduler.TaskSpec`, :class:`repro.core.energy.Harvester`,
:class:`repro.core.energy.Capacitor`, :class:`repro.core.scheduler.SimConfig`
— into the stacked :class:`repro.fleet.state.FleetConfig` arrays consumed by
:func:`repro.fleet.simulator.simulate_fleet`.

The cartesian sweep mirrors the paper's benchmark grids (Figs. 17-21, 24-25):
policy × eta × harvester pattern × capacitor size × seed, one device per
grid point, all simulated by a single jitted call.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

import jax.numpy as jnp

from ..core import policy as P
from ..core.energy import PERSISTENT, Capacitor, Harvester
from ..core.scheduler import Clock, SimConfig, TaskSpec
from .state import FleetConfig, FleetStatics

_F32 = np.float32


def _n_releases(task: TaskSpec, horizon: float) -> int:
    # matches the scalar release loop: while t < horizon and j < len(profiles)
    within = int(math.ceil(horizon / task.period - 1e-12))
    return min(len(task.profiles), max(within, 0))


def _check_dt(dt: float, task: TaskSpec) -> float:
    """The fixed timestep must stay within one fragment time (else a step's
    continuous drain exceeds the energy gate and the capacitor goes
    negative) and below the period (admission is one job per step)."""
    frag_t = float(np.min(np.asarray(task.unit_time)) / task.fragments_per_unit)
    if dt > frag_t * (1 + 1e-9):
        raise ValueError(
            f"dt={dt} exceeds one fragment time ({frag_t}); the energy gate "
            "only covers one fragment of drain per step")
    if dt >= task.period:
        raise ValueError("dt must be smaller than the task period")
    return dt


def device_config(
    task: TaskSpec,
    harvester: Harvester,
    eta: float,
    cap: Capacitor,
    *,
    policy: str,
    horizon: float,
    events: np.ndarray,
    e_opt_fraction: float = 0.7,
    e_man: Optional[float] = None,
    start_charged: bool = False,
    clock_drift: float = 0.0,
    exit_thresholds: Optional[np.ndarray] = None,
) -> dict:
    """One device's configuration as a dict of (unbatched) numpy arrays.

    ``clock_drift`` is the fleet CHRT model's linear drift rate (0 = exact
    RTC).  ``exit_thresholds`` (shape ``(U,)``) switches the utility test
    from the precomputed ``passes`` table to a live margin-vs-threshold
    comparison — the knob :mod:`repro.adapt` tunes.
    """
    if task.release_jitter:
        raise ValueError("fleet simulator requires release_jitter == 0")
    unit_time = np.asarray(task.unit_time, _F32)
    unit_energy = np.asarray(task.unit_energy, _F32)
    margins = np.stack([np.asarray(p.margins, _F32) for p in task.profiles])
    passes = np.stack([np.asarray(p.passes, bool) for p in task.profiles])
    correct = np.stack([np.asarray(p.correct, bool) for p in task.profiles])

    max_frag_e = float(unit_energy.max()) / task.fragments_per_unit
    debt = 0.5 * cap.capacitance_f * cap.v_min ** 2
    return dict(
        policy=np.int32(P.POLICY_IDS[policy]),
        imprecise=np.bool_(policy in P.IMPRECISE_POLICIES),
        is_edfm=np.bool_(policy == "edf-m"),
        eta=_F32(eta),
        alpha=_F32(1.0 / task.deadline),
        beta=_F32(1.0),
        persistent=np.bool_(eta >= 1.0 and harvester.p_stay_on >= 1.0),
        capacity=_F32(cap.capacity_j),
        start_energy=_F32(cap.capacity_j if start_charged else -debt),
        e_man=_F32(max_frag_e if e_man is None else e_man),
        e_opt=_F32(e_opt_fraction * cap.capacity_j),
        clock_drift=_F32(clock_drift),
        use_exit_thr=np.bool_(exit_thresholds is not None),
        exit_thr=np.zeros(len(unit_time), _F32) if exit_thresholds is None
        else np.asarray(exit_thresholds, _F32),
        power_on=_F32(harvester.power_on),
        period=_F32(task.period),
        rel_deadline=_F32(task.deadline),
        fragments=_F32(task.fragments_per_unit),
        n_units=np.int32(len(unit_time)),
        n_releases=np.int32(_n_releases(task, horizon)),
        unit_time=unit_time,
        unit_energy=unit_energy,
        margins=margins,
        passes=passes,
        correct=correct,
        events=np.asarray(events, _F32),
    )


def sample_events(harvester: Harvester, horizon: float, seed: int) -> np.ndarray:
    """Harvester ON/OFF slots exactly as the scalar ``simulate()`` draws them
    (fresh ``default_rng(seed)``, ``init=1``) — seed-matched parity hinges on
    reproducing this stream bit-for-bit."""
    n_slots = int(horizon / harvester.slot_s) + 2
    rng = np.random.default_rng(seed)
    return harvester.sample_events(rng, n_slots, init=1).astype(_F32)


def stack_configs(devices: Sequence[dict]) -> FleetConfig:
    """Stack per-device dicts into a FleetConfig of (D, ...) jnp arrays."""
    fields = FleetConfig._fields
    return FleetConfig(**{
        f: jnp.asarray(np.stack([d[f] for d in devices])) for f in fields
    })


def from_sim_config(
    task: TaskSpec,
    harvester: Harvester,
    eta: float,
    cap: Optional[Capacitor] = None,
    sim: Optional[SimConfig] = None,
    dt: Optional[float] = None,
) -> tuple[FleetConfig, FleetStatics]:
    """Single-device FleetConfig mirroring ``simulate(task, ...)``'s setup —
    the parity-test bridge between the scalar and fleet paths."""
    sim = sim or SimConfig()
    cap = cap or Capacitor()
    clock_drift = 0.0
    if type(sim.clock) is not Clock:
        if hasattr(sim.clock, "equivalent_drift"):
            # fleet CHRT model: the scalar clock's random per-read error maps
            # onto a deterministic per-device drift rate
            clock_drift = sim.clock.equivalent_drift(sim.horizon)
        else:
            raise NotImplementedError(
                f"fleet path has no model for clock {type(sim.clock)}")
    # default dt = one fragment time: the scalar path's execution quantum
    dt = _check_dt(float(
        np.min(np.asarray(task.unit_time)) / task.fragments_per_unit
        if dt is None else dt), task)
    statics = FleetStatics(queue_size=sim.queue_size, dt=dt,
                           horizon=sim.horizon, slot_s=harvester.slot_s)
    dev = device_config(
        task, harvester, eta, cap,
        policy=sim.policy, horizon=sim.horizon,
        events=sample_events(harvester, sim.horizon, sim.seed),
        e_opt_fraction=sim.e_opt_fraction, e_man=sim.e_man,
        start_charged=sim.start_charged, clock_drift=clock_drift,
    )
    return stack_configs([dev]), statics


# --------------------------------------------------------------------------- #
# Sweep API.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """Cartesian benchmark grid: one device per (policy, eta, harvester,
    capacitor, seed) tuple, sharing a single task workload."""

    task: TaskSpec
    policies: Sequence[str] = ("zygarde",)
    etas: Sequence[float] = (1.0,)
    harvesters: Sequence[Harvester] = ()
    capacitors: Sequence[Capacitor] = ()
    seeds: Sequence[int] = (0,)
    clock_drifts: Sequence[float] = (0.0,)   # fleet CHRT drift-rate axis
    horizon: float = 600.0
    dt: Optional[float] = None      # default: one fragment time
    queue_size: int = 3
    e_opt_fraction: float = 0.7
    e_man: Optional[float] = None
    start_charged: bool = False

    def points(self):
        harvesters = self.harvesters or (PERSISTENT,)
        capacitors = self.capacitors or (Capacitor(),)
        for pol in self.policies:
            for eta in self.etas:
                for hi, h in enumerate(harvesters):
                    for cap in capacitors:
                        for seed in self.seeds:
                            for drift in self.clock_drifts:
                                yield dict(policy=pol, eta=eta, harvester=h,
                                           harvester_idx=hi, capacitor=cap,
                                           seed=seed, clock_drift=drift)


def build(grid: SweepGrid) -> tuple[FleetConfig, FleetStatics, list[dict]]:
    """Materialise the grid as a FleetConfig + per-device metadata rows."""
    points = list(grid.points())
    if not points:
        raise ValueError("empty sweep grid")
    slot_lens = {pt["harvester"].slot_s for pt in points}
    if len(slot_lens) != 1:
        raise ValueError("all harvesters in one sweep must share slot_s")
    dt = grid.dt
    if dt is None:
        dt = float(np.min(np.asarray(grid.task.unit_time))
                   / grid.task.fragments_per_unit)
    dt = _check_dt(dt, grid.task)
    statics = FleetStatics(queue_size=grid.queue_size, dt=dt,
                           horizon=grid.horizon, slot_s=slot_lens.pop())

    events_cache: dict[tuple[int, int], np.ndarray] = {}
    devices, meta = [], []
    for pt in points:
        key = (pt["harvester_idx"], pt["seed"])
        if key not in events_cache:
            events_cache[key] = sample_events(
                pt["harvester"], grid.horizon, pt["seed"])
        devices.append(device_config(
            grid.task, pt["harvester"], pt["eta"], pt["capacitor"],
            policy=pt["policy"], horizon=grid.horizon,
            events=events_cache[key],
            e_opt_fraction=grid.e_opt_fraction, e_man=grid.e_man,
            start_charged=grid.start_charged,
            clock_drift=pt["clock_drift"],
        ))
        meta.append(dict(
            policy=pt["policy"], eta=pt["eta"],
            harvester=pt["harvester"].name, seed=pt["seed"],
            capacitance_f=pt["capacitor"].capacitance_f,
            clock_drift=pt["clock_drift"],
        ))
    return stack_configs(devices), statics, meta


def sweep(grid: SweepGrid, use_pallas: bool = False, mesh=None):
    """Simulate the whole grid in one jitted call.

    Returns ``(FleetResult, meta)``: stacked (D,) metric arrays plus the
    per-device metadata rows identifying each grid point.  ``mesh`` (e.g.
    :func:`repro.launch.mesh.make_fleet_mesh`) partitions the device axis
    across backends — results are bit-identical to the unsharded call.
    """
    from .simulator import simulate_fleet_sharded

    cfg, statics, meta = build(grid)
    res = simulate_fleet_sharded(cfg, statics, mesh=mesh,
                                 use_pallas=use_pallas)
    return res, meta
