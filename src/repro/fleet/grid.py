"""Grid construction for fleet sweeps.

Host-side (numpy) builders that translate the scalar simulator's objects —
:class:`repro.core.scheduler.TaskSpec`, :class:`repro.core.energy.Harvester`,
:class:`repro.core.energy.Capacitor`, :class:`repro.core.scheduler.SimConfig`
— into the stacked :class:`repro.fleet.state.FleetConfig` arrays consumed by
:func:`repro.fleet.simulator.simulate_fleet`.

Every builder accepts either one :class:`TaskSpec` or a *task set* (any
sequence of them), mirroring the scalar ``simulate(tasks, ...)`` signature:
the per-task tables are stacked on the ``K`` axis, padded to a common
``U`` (units) / ``J`` (jobs) so heterogeneous task sets share one array —
the live region is bounded by the per-task ``n_units`` / ``n_releases``.

The cartesian sweep mirrors the paper's benchmark grids (Figs. 17-21, 24-25):
policy × eta × harvester pattern × capacitor size × seed, one device per
grid point, all simulated by a single jitted call.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

import jax.numpy as jnp

from ..core import policy as P
from ..core.energy import PERSISTENT, Capacitor, Harvester
from ..core.scheduler import Clock, SimConfig, TaskSpec
from .state import FleetConfig, FleetStatics

_F32 = np.float32

TaskSet = Union[TaskSpec, Sequence[TaskSpec]]


def as_task_set(tasks: TaskSet) -> tuple[TaskSpec, ...]:
    """Normalise a single TaskSpec or a sequence of them to a tuple."""
    if isinstance(tasks, TaskSpec):
        return (tasks,)
    out = tuple(tasks)
    if not out:
        raise ValueError("empty task set")
    if len({t.task_id for t in out}) != len(out):
        raise ValueError("task_ids within one task set must be unique")
    return out


def _n_releases(task: TaskSpec, horizon: float) -> int:
    # replicates the scalar release loop bit-for-bit — including its float
    # *accumulation* of t += period, which can slip one extra release under
    # the horizon when the period is not exactly representable (e.g. 1.2 s
    # accumulated 10× is 11.999999999999998 < 12.0, where the closed-form
    # ceil(horizon / period) says 10)
    t, j = 0.0, 0
    while t < horizon and j < len(task.profiles):
        t += task.period
        j += 1
    return j


def _check_dt(dt: float, tasks: TaskSet) -> float:
    """The fixed timestep must stay within one fragment time of every task
    (else a step's continuous drain exceeds the energy gate and the
    capacitor goes negative) and below every period (admission is one job
    per task per step)."""
    tasks = as_task_set(tasks)
    frag_t = min(
        float(np.min(np.asarray(t.unit_time)) / t.fragments_per_unit)
        for t in tasks)
    if dt > frag_t * (1 + 1e-9):
        raise ValueError(
            f"dt={dt} exceeds one fragment time ({frag_t}); the energy gate "
            "only covers one fragment of drain per step")
    if dt >= min(t.period for t in tasks):
        raise ValueError("dt must be smaller than every task period")
    return dt


def _default_dt(tasks: TaskSet) -> float:
    """One fragment time of the finest-grained task — the scalar path's
    execution quantum."""
    return min(
        float(np.min(np.asarray(t.unit_time)) / t.fragments_per_unit)
        for t in as_task_set(tasks))


def _pad_trailing(a: np.ndarray, shape: tuple, edge_axes: tuple) -> np.ndarray:
    """Zero/edge-pad ``a`` up to ``shape``; axes in ``edge_axes`` replicate
    the last valid entry (keeps padded unit times nonzero so the drain
    division in the simulator stays finite — the padding is never read by an
    active queue slot)."""
    widths = [(0, s - d) for s, d in zip(shape, a.shape)]
    if not any(w for _, w in widths):
        return a
    if edge_axes:
        a = np.pad(a, [w if i in edge_axes else (0, 0)
                       for i, w in enumerate(widths)], mode="edge")
        widths = [(0, s - d) for s, d in zip(shape, a.shape)]
    return np.pad(a, widths, mode="constant")


def device_config(
    tasks: TaskSet,
    harvester: Harvester,
    eta: float,
    cap: Capacitor,
    *,
    policy: str,
    horizon: float,
    events: np.ndarray,
    e_opt_fraction: float = 0.7,
    e_man: Optional[float] = None,
    start_charged: bool = False,
    clock_drift: float = 0.0,
    exit_thresholds: Optional[np.ndarray] = None,
) -> dict:
    """One device's configuration as a dict of (unbatched) numpy arrays.

    ``tasks`` is the device's task set (one TaskSpec or a sequence); the
    per-task tables land on a leading ``K`` axis.  ``clock_drift`` is the
    fleet CHRT model's linear drift rate (0 = exact RTC).
    ``exit_thresholds`` (shape ``(U,)`` shared by every task, or ``(K, U)``
    per task) switches the utility test from the precomputed ``passes``
    table to a live margin-vs-threshold comparison — the knob
    :mod:`repro.adapt` tunes.
    """
    tasks = as_task_set(tasks)
    if any(t.release_jitter for t in tasks):
        raise ValueError("fleet simulator requires release_jitter == 0")
    if policy == "rr" and len(tasks) > 1 and horizon >= P.RR_TASK_W:
        # the rr task-rotation rank outweighs releases only below this
        # horizon (repro.core.policy.RR_TASK_W); beyond it the rotation
        # would silently lose to release order
        raise ValueError(
            f"rr task rotation requires horizon < {P.RR_TASK_W:g} s "
            f"(got {horizon}); releases must stay below the rotation weight")
    n_units = np.array([len(t.unit_time) for t in tasks], np.int32)
    u_max = int(n_units.max())
    j_max = max(len(t.profiles) for t in tasks)

    unit_time = np.stack([
        _pad_trailing(np.asarray(t.unit_time, _F32), (u_max,), (0,))
        for t in tasks])
    unit_energy = np.stack([
        _pad_trailing(np.asarray(t.unit_energy, _F32), (u_max,), (0,))
        for t in tasks])

    def profile_table(t: TaskSpec, field: str, dtype) -> np.ndarray:
        tab = np.stack([np.asarray(getattr(p, field), dtype)
                        for p in t.profiles])
        return _pad_trailing(tab, (j_max, u_max), (1,))

    margins = np.stack([profile_table(t, "margins", _F32) for t in tasks])
    passes = np.stack([profile_table(t, "passes", bool) for t in tasks])
    correct = np.stack([profile_table(t, "correct", bool) for t in tasks])

    if exit_thresholds is None:
        exit_thr = np.zeros((len(tasks), u_max), _F32)
    else:
        exit_thr = np.asarray(exit_thresholds, _F32)
        if exit_thr.ndim == 1:
            exit_thr = np.broadcast_to(
                _pad_trailing(exit_thr, (u_max,), (0,)),
                (len(tasks), u_max)).copy()
        else:
            exit_thr = _pad_trailing(exit_thr, (len(tasks), u_max), (1,))

    # scalar-path normalisation: alpha from the *longest* relative deadline
    # in the set, the fragment-energy floor from the most expensive fragment
    max_frag_e = max(float(np.max(np.asarray(t.unit_energy)))
                     / t.fragments_per_unit for t in tasks)
    debt = 0.5 * cap.capacitance_f * cap.v_min ** 2
    return dict(
        policy=np.int32(P.POLICY_IDS[policy]),
        imprecise=np.bool_(policy in P.IMPRECISE_POLICIES),
        is_edfm=np.bool_(policy == "edf-m"),
        eta=_F32(eta),
        alpha=_F32(1.0 / max(t.deadline for t in tasks)),
        beta=_F32(1.0),
        persistent=np.bool_(eta >= 1.0 and harvester.p_stay_on >= 1.0),
        capacity=_F32(cap.capacity_j),
        start_energy=_F32(cap.capacity_j if start_charged else -debt),
        e_man=_F32(max_frag_e if e_man is None else e_man),
        e_opt=_F32(e_opt_fraction * cap.capacity_j),
        clock_drift=_F32(clock_drift),
        use_exit_thr=np.bool_(exit_thresholds is not None),
        exit_thr=exit_thr,
        power_on=_F32(harvester.power_on),
        period=np.array([t.period for t in tasks], _F32),
        rel_deadline=np.array([t.deadline for t in tasks], _F32),
        fragments=np.array([t.fragments_per_unit for t in tasks], _F32),
        n_units=n_units,
        n_releases=np.array([_n_releases(t, horizon) for t in tasks],
                            np.int32),
        unit_time=unit_time,
        unit_energy=unit_energy,
        margins=margins,
        passes=passes,
        correct=correct,
        events=np.asarray(events, _F32),
    )


def sample_events(harvester: Harvester, horizon: float, seed: int) -> np.ndarray:
    """Harvester ON/OFF slots exactly as the scalar ``simulate()`` draws them
    (fresh ``default_rng(seed)``, ``init=1``) — seed-matched parity hinges on
    reproducing this stream bit-for-bit."""
    n_slots = int(horizon / harvester.slot_s) + 2
    rng = np.random.default_rng(seed)
    return harvester.sample_events(rng, n_slots, init=1).astype(_F32)


def stack_configs(devices: Sequence[dict]) -> FleetConfig:
    """Stack per-device dicts into a FleetConfig of (D, ...) jnp arrays."""
    fields = FleetConfig._fields
    return FleetConfig(**{
        f: jnp.asarray(np.stack([d[f] for d in devices])) for f in fields
    })


def from_sim_config(
    tasks: TaskSet,
    harvester: Harvester,
    eta: float,
    cap: Optional[Capacitor] = None,
    sim: Optional[SimConfig] = None,
    dt: Optional[float] = None,
) -> tuple[FleetConfig, FleetStatics]:
    """Single-device FleetConfig mirroring ``simulate(tasks, ...)``'s setup —
    the parity-test bridge between the scalar and fleet paths.  ``tasks``
    may be one TaskSpec or a whole task set, exactly like the scalar call."""
    tasks = as_task_set(tasks)
    sim = sim or SimConfig()
    cap = cap or Capacitor()
    clock_drift = 0.0
    if type(sim.clock) is not Clock:
        if hasattr(sim.clock, "equivalent_drift"):
            # fleet CHRT model: the scalar clock's random per-read error maps
            # onto a deterministic per-device drift rate
            clock_drift = sim.clock.equivalent_drift(sim.horizon)
        else:
            raise NotImplementedError(
                f"fleet path has no model for clock {type(sim.clock)}")
    # default dt = one fragment time: the scalar path's execution quantum
    dt = _check_dt(_default_dt(tasks) if dt is None else float(dt), tasks)
    statics = FleetStatics(queue_size=sim.queue_size, dt=dt,
                           horizon=sim.horizon, slot_s=harvester.slot_s)
    dev = device_config(
        tasks, harvester, eta, cap,
        policy=sim.policy, horizon=sim.horizon,
        events=sample_events(harvester, sim.horizon, sim.seed),
        e_opt_fraction=sim.e_opt_fraction, e_man=sim.e_man,
        start_charged=sim.start_charged, clock_drift=clock_drift,
    )
    return stack_configs([dev]), statics


# --------------------------------------------------------------------------- #
# Sweep API.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """Cartesian benchmark grid: one device per (policy, eta, harvester,
    capacitor, seed) tuple, sharing a single task-set workload (``task``
    accepts one TaskSpec or a sequence — every device then runs the whole
    set)."""

    task: TaskSet
    policies: Sequence[str] = ("zygarde",)
    etas: Sequence[float] = (1.0,)
    harvesters: Sequence[Harvester] = ()
    capacitors: Sequence[Capacitor] = ()
    seeds: Sequence[int] = (0,)
    clock_drifts: Sequence[float] = (0.0,)   # fleet CHRT drift-rate axis
    horizon: float = 600.0
    dt: Optional[float] = None      # default: one fragment time
    queue_size: int = 3
    e_opt_fraction: float = 0.7
    e_man: Optional[float] = None
    start_charged: bool = False

    @property
    def tasks(self) -> tuple[TaskSpec, ...]:
        return as_task_set(self.task)

    def points(self):
        harvesters = self.harvesters or (PERSISTENT,)
        capacitors = self.capacitors or (Capacitor(),)
        for pol in self.policies:
            for eta in self.etas:
                for hi, h in enumerate(harvesters):
                    for cap in capacitors:
                        for seed in self.seeds:
                            for drift in self.clock_drifts:
                                yield dict(policy=pol, eta=eta, harvester=h,
                                           harvester_idx=hi, capacitor=cap,
                                           seed=seed, clock_drift=drift)


def build(grid: SweepGrid) -> tuple[FleetConfig, FleetStatics, list[dict]]:
    """Materialise the grid as a FleetConfig + per-device metadata rows."""
    points = list(grid.points())
    if not points:
        raise ValueError("empty sweep grid")
    tasks = grid.tasks
    slot_lens = {pt["harvester"].slot_s for pt in points}
    if len(slot_lens) != 1:
        raise ValueError("all harvesters in one sweep must share slot_s")
    dt = _check_dt(_default_dt(tasks) if grid.dt is None else grid.dt, tasks)
    statics = FleetStatics(queue_size=grid.queue_size, dt=dt,
                           horizon=grid.horizon, slot_s=slot_lens.pop())

    events_cache: dict[tuple[int, int], np.ndarray] = {}
    devices, meta = [], []
    for pt in points:
        key = (pt["harvester_idx"], pt["seed"])
        if key not in events_cache:
            events_cache[key] = sample_events(
                pt["harvester"], grid.horizon, pt["seed"])
        devices.append(device_config(
            tasks, pt["harvester"], pt["eta"], pt["capacitor"],
            policy=pt["policy"], horizon=grid.horizon,
            events=events_cache[key],
            e_opt_fraction=grid.e_opt_fraction, e_man=grid.e_man,
            start_charged=grid.start_charged,
            clock_drift=pt["clock_drift"],
        ))
        meta.append(dict(
            policy=pt["policy"], eta=pt["eta"],
            harvester=pt["harvester"].name, seed=pt["seed"],
            capacitance_f=pt["capacitor"].capacitance_f,
            clock_drift=pt["clock_drift"],
            n_tasks=len(tasks),
        ))
    return stack_configs(devices), statics, meta


def sweep(grid: SweepGrid, use_pallas=None, mesh=None, mode=None):
    """Simulate the whole grid in one jitted call.

    Returns ``(FleetResult, meta)``: stacked (D,) metric arrays (plus the
    ``(D, K)`` per-task breakdowns) and the per-device metadata rows
    identifying each grid point.  ``mesh`` (e.g.
    :func:`repro.launch.mesh.make_fleet_mesh`) partitions the device axis
    across backends — results are bit-identical to the unsharded call.
    """
    from .simulator import simulate_fleet_sharded

    cfg, statics, meta = build(grid)
    res = simulate_fleet_sharded(cfg, statics, mesh=mesh,
                                 use_pallas=use_pallas, mode=mode)
    return res, meta
