"""GQA attention: block-sparse chunked prefill + single-token decode.

The prefill path enumerates the (query-chunk, kv-chunk) block pairs that are
actually inside the causal / sliding-window footprint *statically* and scans
over that pair list with an online-softmax accumulator.  This keeps HBM
footprint at O(S * chunk) and — importantly for the roofline analysis — makes
``compiled.cost_analysis()`` count only the useful lower-triangle (or window
band) FLOPs instead of the dense S^2 rectangle.
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _use_flash_kernel() -> bool:
    return (
        os.environ.get("REPRO_FLASH_ATTENTION", "0") == "1"
        and jax.default_backend() == "tpu"
    )


def _block_pairs(n_chunks: int, chunk: int, window: int) -> np.ndarray:
    """Static (i, j) list of blocks inside the causal/window footprint."""
    pairs = []
    for i in range(n_chunks):
        if window:
            # query positions in chunk i attend back at most `window` tokens
            j_lo = max(0, (i * chunk + chunk - 1 - window) // chunk)
        else:
            j_lo = 0
        for j in range(j_lo, i + 1):
            pairs.append((i, j))
    return np.asarray(pairs, dtype=np.int32)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """q: (B, S, H, hd), k/v: (B, Skv, KV, hd) -> (B, S, H, hd).

    ``q_offset`` shifts query positions (cross-attention uses causal=False).

    On a TPU backend with REPRO_FLASH_ATTENTION=1 this dispatches to the
    fused Pallas flash kernel (``kernels/flash_attn.py``) — the §Perf P1
    answer to the O(S^2) f32 softmax HBM traffic of the XLA path.  The
    dry-run keeps the XLA path (Pallas cannot lower on the CPU host).
    """
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if _use_flash_kernel():
        from repro.kernels import ops as kops

        o = kops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
        return o.astype(q.dtype)
    if not causal and not window:
        # encoder / cross-attention: dense (Skv is small for our shapes)
        return _dense_attention(q, k, v)

    chunk = min(chunk, S, Skv)
    while S % chunk or Skv % chunk:
        chunk //= 2
    nq, nkv = S // chunk, Skv // chunk
    assert nq == nkv, "causal chunked attention expects S == Skv"
    G = H // KV
    scale = hd ** -0.5

    pairs = jnp.asarray(_block_pairs(nq, chunk, window))

    qb = q.reshape(B, nq, chunk, KV, G, hd)
    kb = k.reshape(B, nkv, chunk, KV, hd)
    vb = v.reshape(B, nkv, chunk, KV, hd)

    o0 = jnp.zeros((B, nq, chunk, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, nq, chunk, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, chunk, KV, G), jnp.float32)

    pos_in_chunk = jnp.arange(chunk)

    def step(carry, pair):
        o, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        # scores: (B, chunk_q, KV, G, chunk_k)
        s = jnp.einsum(
            "bqkgh,bckh->bqkgc",
            qi.astype(jnp.float32),
            kj.astype(jnp.float32),
        ) * scale
        qpos = i * chunk + pos_in_chunk + q_offset
        kpos = j * chunk + pos_in_chunk
        mask = qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] <= window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, i, 1, keepdims=False)

        m_new = jnp.maximum(mi, s.max(axis=-1))
        alpha = jnp.exp(mi - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = li * alpha + p.sum(axis=-1)
        o_new = oi * alpha[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p, vj.astype(jnp.float32)
        )
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), pairs)
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _dense_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum(
        "bqkgh,bckh->bqkgc", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    slot_pos: jax.Array,
    my_pos: jax.Array,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (ring-buffer) KV cache.

    q: (B, H, hd); k_cache/v_cache: (B, C, KV, hd);
    slot_pos: (B, C) absolute position stored in each slot (-1 = empty);
    my_pos: (B,) the query token's position.
    """
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    # bf16 operands with f32 accumulation: avoids materialising an f32 copy
    # (and its layout transpose) of the whole KV cache each step (§Perf
    # P3-H2); scores/softmax stay f32.
    s = jnp.einsum(
        "bkgh,bckh->bkgc", qg, k_cache,
        preferred_element_type=jnp.float32,
    ) * scale
    valid = (slot_pos >= 0) & (slot_pos <= my_pos[:, None])
    if window:
        valid &= my_pos[:, None] - slot_pos <= window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgc,bckh->bkgh", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, H, hd).astype(q.dtype)
