"""Shared building blocks: sharding hooks, norms, RoPE, initializers."""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------- #
# Logical-axis sharding.
#
# Model code annotates intermediates with *logical* axis names; the launcher
# installs a rule-set mapping logical names to physical mesh axes.  On CPU
# (tests, smoke runs) no rules are installed and ``shard`` is a no-op, so the
# same model code runs everywhere.
# --------------------------------------------------------------------------- #

_RULES: contextvars.ContextVar[Optional[tuple[Mesh, Mapping[str, Any]]]] = (
    contextvars.ContextVar("logical_axis_rules", default=None)
)

# Default logical->physical mapping for the production meshes.  ``batch`` maps
# to every data-like axis (("pod","data") on the multi-pod mesh); ``embed`` is
# the FSDP dimension; ``model``-group names map to the tensor axis.
DEFAULT_RULES = {
    "batch": ("data",),
    "embed": ("data",),  # FSDP: weight d_model dim sharded over data
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "seq": None,
    "qseq": None,
}


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: Mapping[str, Any]):
    token = _RULES.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _RULES.reset(token)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding via logical axis names (no-op w/o rules)."""
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"shard(): rank {x.ndim} array annotated with {logical_axes}"
        )
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    phys = []
    for dim, name in zip(x.shape, logical_axes):
        axes = rules.get(name) if name else None
        phys.append(sanitize_dim(axes, dim, axis_sizes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*phys)))


def sanitize_dim(axes, dim: int, axis_sizes: Mapping[str, int]):
    """Drop mesh axes a dim is not divisible by (e.g. 2 KV heads on a
    16-way model axis fall back to replication)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    total, kept = 1, []
    for a in axes:
        sz = axis_sizes.get(a, 1)
        if dim % (total * sz) == 0:
            kept.append(a)
            total *= sz
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(*logical_axes: Optional[str], rules: Mapping[str, Any]) -> P:
    return P(*[rules.get(a) if a else None for a in logical_axes])


# --------------------------------------------------------------------------- #
# Initializers (all take an explicit key; params stored in cfg dtype).
# --------------------------------------------------------------------------- #


def dense_init(key, in_dim: int, out_shape: Sequence[int], dtype) -> jax.Array:
    scale = in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, *out_shape)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


def split_like(key, tree_keys: Sequence[str]) -> dict:
    keys = jax.random.split(key, len(tree_keys))
    return dict(zip(tree_keys, keys))


# --------------------------------------------------------------------------- #
# Norms and activations.
# --------------------------------------------------------------------------- #


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale + bias


def norm_init(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": ones((d,), dtype)}
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def apply_norm(kind: str, p: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def activate(kind: str, x: jax.Array) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    if kind == "silu":
        return jax.nn.silu(x)
    raise ValueError(kind)


# --------------------------------------------------------------------------- #
# Rotary position embeddings.
# --------------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [
            x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
            x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# Gradient dtype guard (§Perf P2-H4).
#
# The f32 loss/softmax region promotes residual-stream cotangents to f32,
# which doubles the bytes of every per-layer tensor-parallel backward
# all-reduce.  Applied at block boundaries, this guard casts the incoming
# cotangent back to the activation dtype (identity in the forward pass).
# --------------------------------------------------------------------------- #


@jax.custom_vjp
def grad_dtype_guard(x: jax.Array) -> jax.Array:
    return x


def _gdg_fwd(x):
    # residuals must be jax types: carry a zero-size array for the dtype
    return x, jnp.zeros((0,), x.dtype)


def _gdg_bwd(res, g):
    return (g.astype(res.dtype),)


grad_dtype_guard.defvjp(_gdg_fwd, _gdg_bwd)
