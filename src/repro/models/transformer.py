"""Model assembly for all six architecture families.

Layer stacks are *scanned* (``jax.lax.scan`` over pattern periods with
stacked per-position parameters) so the lowered HLO is independent of depth —
94-layer qwen3-moe compiles as fast as a 2-layer smoke model.  Heterogeneous
block patterns (Griffin's rec/rec/attn, xLSTM's mlstm/slstm) unroll one
pattern period inside each scan step; layers left over when ``n_layers`` is
not a multiple of the period become individually-parameterised remainder
blocks.

Public entry points:
    init_params / forward / prefill / decode_step / init_decode_state
    unit_forward (Zygarde agile execution: one unit = ``exit_every`` blocks)
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from . import rglru as rg
from . import xlstm as xl
from .attention import chunked_attention, decode_attention
from .common import (
    apply_norm,
    apply_rope,
    activate,
    dense_init,
    dtype_of,
    embed_init,
    grad_dtype_guard,
    norm_init,
    shard,
    zeros,
)

# --------------------------------------------------------------------------- #
# Block parameter initialisation.
# --------------------------------------------------------------------------- #


def _init_attn(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (H, hd), dtype),
        "wk": dense_init(ks[1], d, (KV, hd), dtype),
        "wv": dense_init(ks[2], d, (KV, hd), dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * (H * hd) ** -0.5).astype(
            dtype
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H, hd), dtype)
        p["bk"] = zeros((KV, hd), dtype)
        p["bv"] = zeros((KV, hd), dtype)
    return p


def _init_ffn(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_init(ks[0], d, (f,), dtype),
        "w2": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.act == "swiglu":
        p["w3"] = dense_init(ks[2], d, (f,), dtype)
    return p


def init_block(key, cfg, kind: str, *, cross: bool = False) -> dict:
    dtype = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: dict = {"norm1": norm_init(cfg.norm, d, dtype)}
    if kind == "attn":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        if cfg.n_experts:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["ffn"] = _init_ffn(ks[1], cfg, dtype)
        if cross:
            p["norm_x"] = norm_init(cfg.norm, d, dtype)
            p["xattn"] = _init_attn(ks[2], cfg, dtype)
    elif kind == "rec":
        w = cfg.resolved_rglru_width
        p["gate_proj"] = dense_init(ks[0], d, (w,), dtype)
        p["rec_proj"] = dense_init(ks[1], d, (w,), dtype)
        p["conv"] = rg.init_conv1d(ks[2], w, cfg.conv1d_width, dtype)
        p["rglru"] = rg.init_rglru(ks[3], w, dtype, n_blocks=cfg.n_heads)
        p["out_proj"] = dense_init(ks[4], w, (d,), dtype)
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        if cfg.d_ff:
            p["ffn"] = _init_ffn(ks[5], cfg, dtype)
    elif kind == "mlstm":
        p["cell"] = xl.init_mlstm(ks[0], d, cfg.n_heads, dtype)
    elif kind == "slstm":
        p["cell"] = xl.init_slstm(ks[0], d, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    return p


# --------------------------------------------------------------------------- #
# Block application — full-sequence (train / prefill).
# --------------------------------------------------------------------------- #


def _qkv(p: dict, cfg, h: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dnh->bsnh", h, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", h, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", h, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def block_seq(
    p: dict,
    cfg,
    kind: str,
    x: jax.Array,
    *,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    collect_cache: bool = False,
):
    """x: (B, S, D) -> (x, aux_loss, cache_kv or None)."""
    aux = jnp.float32(0.0)
    cache = None
    B, S, D = x.shape
    window = cfg.window if window is None else window
    if kind == "attn":
        h = apply_norm(cfg.norm, p["norm1"], x)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k, v = _qkv(p["attn"], cfg, h, positions)
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        attn_fn = functools.partial(
            chunked_attention, causal=causal, window=window,
            chunk=cfg.attn_chunk,
        )
        if cfg.remat_attention:
            attn_fn = jax.checkpoint(attn_fn, prevent_cse=False)
        o = attn_fn(q, k, v)
        x = x + jnp.einsum("bsnh,nhd->bsd", o, p["attn"]["wo"])
        if collect_cache:
            cache = (k, v)
        if "xattn" in p:
            assert enc_out is not None
            hx = apply_norm(cfg.norm, p["norm_x"], x)
            qx = jnp.einsum("bsd,dnh->bsnh", hx, p["xattn"]["wq"])
            kx = jnp.einsum("bsd,dnh->bsnh", enc_out, p["xattn"]["wk"])
            vx = jnp.einsum("bsd,dnh->bsnh", enc_out, p["xattn"]["wv"])
            ox = chunked_attention(qx, kx, vx, causal=False, window=0)
            x = x + jnp.einsum("bsnh,nhd->bsd", ox, p["xattn"]["wo"])
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if "moe" in p:
            y, aux = moe_mod.apply_moe(p["moe"], cfg, h2)
        elif "ffn" in p:
            y = _apply_ffn(p["ffn"], cfg, h2)
        else:
            y = jnp.zeros_like(x)
        x = x + y
    elif kind == "rec":
        h = apply_norm(cfg.norm, p["norm1"], x)
        gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["gate_proj"]))
        r = jnp.einsum("bsd,dw->bsw", h, p["rec_proj"])
        r = rg.conv1d_seq(p["conv"], r)
        r, _ = rg.rglru_seq(p["rglru"], r)
        x = x + jnp.einsum("bsw,wd->bsd", gate * r, p["out_proj"])
        if cfg.d_ff:
            h2 = apply_norm(cfg.norm, p["norm2"], x)
            x = x + _apply_ffn(p["ffn"], cfg, h2)
    elif kind == "mlstm":
        h = apply_norm(cfg.norm, p["norm1"], x)
        y, _ = xl.mlstm_seq(p["cell"], h, cfg.n_heads)
        x = x + y
    elif kind == "slstm":
        h = apply_norm(cfg.norm, p["norm1"], x)
        y, _ = xl.slstm_seq(p["cell"], h, cfg.n_heads)
        x = x + y
    else:
        raise ValueError(kind)
    x = shard(x, "batch", "seq", None)
    # NOTE (§Perf P2-H4, refuted): wrapping x in common.grad_dtype_guard
    # forces bf16 residual cotangents at block boundaries, but measured
    # zero collective-byte change — the f32 all-reduces originate INSIDE
    # the block backward (f32-internal gate/softmax ops feeding the dots).
    return x, aux, cache


def _apply_ffn(p: dict, cfg, h: jax.Array) -> jax.Array:
    u = jnp.einsum("bsd,df->bsf", h, p["w1"])
    u = shard(u, "batch", None, "ff")
    if cfg.act == "swiglu":
        u = jax.nn.silu(u) * jnp.einsum("bsd,df->bsf", h, p["w3"])
    else:
        u = activate(cfg.act, u)
    return jnp.einsum("bsf,fd->bsd", u, p["w2"])


# --------------------------------------------------------------------------- #
# Block application — single-token decode.
# --------------------------------------------------------------------------- #


def _slot_positions(pos: jax.Array, capacity: int) -> jax.Array:
    """Absolute position stored in each ring-buffer slot (-1 = empty).

    pos: (B,) number of tokens already written.  Slot s holds the largest
    p < pos with p % capacity == s.
    """
    s = jnp.arange(capacity)
    last = pos[:, None] - 1
    cand = last - jnp.mod(last - s[None, :], capacity)
    return jnp.where((cand >= 0) & (pos[:, None] > 0), cand, -1)


def block_step(
    p: dict,
    cfg,
    kind: str,
    x: jax.Array,
    state: dict,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
):
    """x: (B, D); state: per-block decode state; pos: (B,) current position."""
    B, D = x.shape
    window = cfg.window if window is None else window
    new_state = dict(state)
    if kind == "attn":
        h = apply_norm(cfg.norm, p["norm1"], x)[:, None]  # (B, 1, D)
        q, k, v = _qkv(p["attn"], cfg, h, pos[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        C = state["k"].shape[1]
        slot = jnp.mod(pos, C)
        k_cache = _write_slot(state["k"], k, slot)
        v_cache = _write_slot(state["v"], v, slot)
        slot_pos = _slot_positions(pos + 1, C)
        o = decode_attention(q, k_cache, v_cache, slot_pos, pos, window)
        x = x + jnp.einsum("bnh,nhd->bd", o, p["attn"]["wo"])
        new_state["k"], new_state["v"] = k_cache, v_cache
        if "xattn" in p:
            hx = apply_norm(cfg.norm, p["norm_x"], x)
            qx = jnp.einsum("bd,dnh->bnh", hx, p["xattn"]["wq"])
            xk, xv = state["xk"], state["xv"]
            nenc = xk.shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(nenc), (B, nenc))
            ox = decode_attention(
                qx, xk, xv, enc_pos, jnp.full((B,), nenc, jnp.int32), 0
            )
            x = x + jnp.einsum("bnh,nhd->bd", ox, p["xattn"]["wo"])
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if "moe" in p:
            y, _ = moe_mod.apply_moe(p["moe"], cfg, h2[:, None])
            y = y[:, 0]
        elif "ffn" in p:
            y = _apply_ffn(p["ffn"], cfg, h2[:, None])[:, 0]
        else:
            y = jnp.zeros_like(x)
        x = x + y
    elif kind == "rec":
        h = apply_norm(cfg.norm, p["norm1"], x)
        gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", h, p["gate_proj"]))
        r = jnp.einsum("bd,dw->bw", h, p["rec_proj"])
        r, new_state["buf"] = rg.conv1d_step(p["conv"], r, state["buf"])
        r, new_state["h"] = rg.rglru_step(p["rglru"], r, state["h"])
        x = x + jnp.einsum("bw,wd->bd", gate * r, p["out_proj"])
        if cfg.d_ff:
            h2 = apply_norm(cfg.norm, p["norm2"], x)
            x = x + _apply_ffn(p["ffn"], cfg, h2[:, None])[:, 0]
    elif kind in ("mlstm", "slstm"):
        h = apply_norm(cfg.norm, p["norm1"], x)
        step_fn = xl.mlstm_step if kind == "mlstm" else xl.slstm_step
        y, cell = step_fn(p["cell"], h, cfg.n_heads, state["cell"])
        new_state["cell"] = cell
        x = x + y
    else:
        raise ValueError(kind)
    return x, new_state


def _write_slot(cache: jax.Array, val: jax.Array, slot: jax.Array) -> jax.Array:
    """cache: (B, C, ...), val: (B, ...), slot: (B,) per-batch write index.

    Batched scatter (``.at[].set``): touches only the B written slots.  The
    earlier one-hot blend formulation read+wrote the ENTIRE cache every
    decode step — 118 of 160 GB/step on dbrx decode_32k (§Perf P3-H1).
    """
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slot].set(val.astype(cache.dtype))


# --------------------------------------------------------------------------- #
# Whole-model parameters.
# --------------------------------------------------------------------------- #


def _layer_plan(cfg) -> Tuple[int, int, list]:
    period = cfg.pattern_period
    n_scan = cfg.n_layers // period
    rem_kinds = [cfg.layer_kind(n_scan * period + i)
                 for i in range(cfg.n_layers - n_scan * period)]
    return period, n_scan, rem_kinds


def init_params(cfg, key) -> dict:
    dtype = dtype_of(cfg)
    period, n_scan, rem_kinds = _layer_plan(cfg)
    cross = cfg.is_encoder_decoder
    keys = jax.random.split(key, 8)

    def stacked(key_q, kind):
        ks = jax.random.split(key_q, n_scan)
        blocks = [init_block(k, cfg, kind, cross=cross) for k in ks]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    qkeys = jax.random.split(keys[0], period)
    stack = tuple(
        stacked(qkeys[q], cfg.layer_kind(q)) for q in range(period)
    )
    rkeys = jax.random.split(keys[1], max(1, len(rem_kinds)))
    rem = tuple(
        init_block(rkeys[i], cfg, kind, cross=cross)
        for i, kind in enumerate(rem_kinds)
    )

    params = {
        "embed": embed_init(keys[2], cfg.padded_vocab, cfg.d_model, dtype),
        "layers": {"stack": stack, "rem": rem},
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[3], cfg.d_model, (cfg.padded_vocab,), dtype
        )
    if cfg.n_frontend_tokens or cfg.is_encoder_decoder:
        params["frontend_proj"] = dense_init(
            keys[4], cfg.d_model, (cfg.d_model,), dtype
        )
    if cfg.is_encoder_decoder:
        eks = jax.random.split(keys[5], cfg.n_enc_layers)
        enc_blocks = [init_block(k, cfg, "attn") for k in eks]
        params["enc"] = {
            "stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dtype_of(cfg)),
        }
    return params


# --------------------------------------------------------------------------- #
# Full-sequence forward (training / prefill).
# --------------------------------------------------------------------------- #


def _embed(cfg, params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    x = shard(x, "batch", "seq", None)
    return x


def _encode(cfg, params, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over stubbed frontend embeddings."""
    x = jnp.einsum("bsd,de->bse", frames.astype(dtype_of(cfg)),
                   params["frontend_proj"])

    def body(x, bp):
        x, _, _ = block_seq(bp, cfg, "attn", x, causal=False, window=0)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"]["stack"])
    return apply_norm(cfg.norm, params["enc"]["final_norm"], x)


def _run_stack(
    cfg,
    params,
    x: jax.Array,
    *,
    enc_out=None,
    window: Optional[int] = None,
    remat: bool = True,
):
    """Scan the layer stack in groups of ``cfg.remat_every`` period-groups,
    checkpointing once per group: the backward pass re-runs a group's
    forward instead of carrying one save per layer (§Perf P1-H2)."""
    period, n_scan, rem_kinds = _layer_plan(cfg)
    stack = params["layers"]["stack"]

    def apply_periods(x, aux, bps):
        """bps: tuple over q of trees with leading (k, ...) group dim."""
        k = jax.tree.leaves(bps[0])[0].shape[0] if period else 0
        for j in range(k):
            for q in range(period):
                bp = jax.tree.map(lambda a, j=j: a[j], bps[q])
                x, a, _ = block_seq(
                    bp, cfg, cfg.layer_kind(q), x,
                    enc_out=enc_out, window=window,
                )
                aux = aux + a
        return x, aux

    group_fn = apply_periods
    if remat:
        group_fn = jax.checkpoint(apply_periods, prevent_cse=False)

    k = max(1, cfg.remat_every) if remat else 1
    n_groups, leftover = divmod(n_scan, k)
    aux = jnp.float32(0.0)

    if n_groups:
        grouped = tuple(
            jax.tree.map(
                lambda a: a[: n_groups * k].reshape(
                    n_groups, k, *a.shape[1:]
                ),
                stack[q],
            )
            for q in range(period)
        )

        def body(carry, xs):
            x, aux = carry
            x, aux = group_fn(x, aux, xs)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), grouped)

    if leftover:
        tail = tuple(
            jax.tree.map(lambda a: a[n_groups * k:], stack[q])
            for q in range(period)
        )
        x, aux = group_fn(x, aux, tail)

    for bp, kind in zip(params["layers"]["rem"], rem_kinds):
        x, a, _ = block_seq(bp, cfg, kind, x, enc_out=enc_out, window=window)
        aux = aux + a
    return x, aux


def _readout(cfg, params, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", None, "vocab")


def forward(
    cfg,
    params,
    batch: dict,
    *,
    window: Optional[int] = None,
    remat: bool = True,
):
    """batch: {"tokens": (B,S) int32, optional "frontend": (B,F,D)}.

    Returns (logits (B, S_total, V) f32, aux_loss scalar).
    """
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frontend"])
    elif cfg.n_frontend_tokens and "frontend" in batch:
        fx = jnp.einsum(
            "bsd,de->bse", batch["frontend"].astype(x.dtype),
            params["frontend_proj"],
        )
        x = jnp.concatenate([fx, x], axis=1)
    x, aux = _run_stack(cfg, params, x, enc_out=enc_out, window=window,
                        remat=remat)
    return _readout(cfg, params, x), aux


# --------------------------------------------------------------------------- #
# Decode state and serving steps.
# --------------------------------------------------------------------------- #


def _block_state(cfg, kind: str, batch: int, cache_len: int, *, cross: bool):
    dtype = dtype_of(cfg)
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    if kind == "attn":
        st = {
            "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
            "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
        }
        if cross:
            st["xk"] = jnp.zeros((batch, cfg.n_enc_tokens, KV, hd), dtype)
            st["xv"] = jnp.zeros((batch, cfg.n_enc_tokens, KV, hd), dtype)
        return st
    if kind == "rec":
        w = cfg.resolved_rglru_width
        return {
            "h": jnp.zeros((batch, w), jnp.float32),
            "buf": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
        }
    if kind == "mlstm":
        return {"cell": xl.mlstm_init_state(batch, cfg.d_model, cfg.n_heads)}
    if kind == "slstm":
        return {"cell": xl.slstm_init_state(batch, cfg.d_model, cfg.n_heads)}
    raise ValueError(kind)


def cache_capacity(cfg, seq_len: int, window: Optional[int] = None) -> int:
    """Ring-buffer KV capacity: window+1 slots (rounded up to a 128 multiple
    so the cache-length dim stays MXU-aligned and mesh-shardable), capped at
    the sequence length.  Extra slots simply hold older positions that the
    window mask excludes, so any capacity >= window+1 is correct."""
    w = cfg.window if window is None else window
    if not w:
        return seq_len
    cap = -(-(w + 1) // 128) * 128
    return min(seq_len, cap)


def init_decode_state(
    cfg, batch: int, seq_len: int, *, window: Optional[int] = None,
    cache_len: Optional[int] = None, stacked: bool = True,
) -> dict:
    """Decode state.  ``stacked=True`` carries per-period (n_scan, ...)
    arrays through a ``lax.scan`` over layers (small HLO, depth-independent
    compile time).  ``stacked=False`` keeps one buffer per layer for the
    *unrolled* decode path: caches then update fully in place (a scan carry
    forces a slice read+write per layer per step — §Perf P3-H3)."""
    period, n_scan, rem_kinds = _layer_plan(cfg)
    cache_len = cache_len or cache_capacity(cfg, seq_len, window)
    cross = cfg.is_encoder_decoder

    def stacked_state(kind):
        one = _block_state(cfg, kind, batch, cache_len, cross=cross)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_scan, *a.shape)), one
        )

    def unstacked_state(kind):
        return tuple(
            _block_state(cfg, kind, batch, cache_len, cross=cross)
            for _ in range(n_scan)
        )

    make = stacked_state if stacked else unstacked_state
    state = {
        "pos": jnp.zeros((batch,), jnp.int32),
        "stack": tuple(make(cfg.layer_kind(q)) for q in range(period)),
        "rem": tuple(
            _block_state(cfg, kind, batch, cache_len, cross=cross)
            for kind in rem_kinds
        ),
    }
    if cfg.is_encoder_decoder:
        state["enc_out"] = jnp.zeros(
            (batch, cfg.n_enc_tokens, cfg.d_model), dtype_of(cfg)
        )
    return state


def decode_step(
    cfg,
    params,
    state: dict,
    token: jax.Array,
    *,
    window: Optional[int] = None,
    unroll: bool = False,
):
    """One serving step: token (B,) int32 -> (logits (B,V), new state).

    ``unroll=True`` (with a ``stacked=False`` state) emits straight-line
    per-layer code whose cache scatters are fully in place — the production
    serving configuration."""
    period, n_scan, rem_kinds = _layer_plan(cfg)
    x = params["embed"][token]
    pos = state["pos"]

    if unroll:
        # layer order matches the scan: r-th period group, q within group
        new_per_q = [[None] * n_scan for _ in range(period)]
        for r in range(n_scan):
            for q in range(period):
                bp = jax.tree.map(lambda a, r=r: a[r],
                                  params["layers"]["stack"][q])
                x, ns = block_step(
                    bp, cfg, cfg.layer_kind(q), x, state["stack"][q][r],
                    pos, window=window,
                )
                new_per_q[q][r] = ns
        new_stack = tuple(tuple(states) for states in new_per_q)
    else:
        def period_body(x, xs):
            bp_tuple, st_tuple = xs
            new_states = []
            for q in range(period):
                x, ns = block_step(
                    bp_tuple[q], cfg, cfg.layer_kind(q), x, st_tuple[q], pos,
                    window=window,
                )
                new_states.append(ns)
            return x, tuple(new_states)

        x, new_stack = jax.lax.scan(
            period_body, x, (params["layers"]["stack"], state["stack"])
        )
    new_rem = []
    for bp, st, kind in zip(params["layers"]["rem"], state["rem"], rem_kinds):
        x, ns = block_step(bp, cfg, kind, x, st, pos, window=window)
        new_rem.append(ns)

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bd,dv->bv", x, head).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")

    new_state = dict(state)
    new_state["pos"] = pos + 1
    new_state["stack"] = new_stack
    new_state["rem"] = tuple(new_rem)
    return logits, new_state


def prefill(
    cfg,
    params,
    batch: dict,
    *,
    window: Optional[int] = None,
    cache_len: Optional[int] = None,
):
    """Run the full prompt, returning last-position logits + decode state.

    Recurrent/xLSTM states are re-derived; attention KV caches are filled
    from the sequence path (last ``cache_len`` positions).  For
    full-attention serving pass ``cache_len >= prompt + max_new_tokens`` —
    the default sizes the ring buffer to the prompt, so each decoded token
    would evict the oldest cache entry.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    period, n_scan, rem_kinds = _layer_plan(cfg)
    cache_len = cache_len or cache_capacity(cfg, S, window)
    x = _embed(cfg, params, tokens)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frontend"])
    elif cfg.n_frontend_tokens and "frontend" in batch:
        fx = jnp.einsum(
            "bsd,de->bse", batch["frontend"].astype(x.dtype),
            params["frontend_proj"],
        )
        x = jnp.concatenate([fx, x], axis=1)

    state = init_decode_state(cfg, B, S, window=window, cache_len=cache_len)
    state["pos"] = jnp.full((B,), x.shape[1], jnp.int32)
    if enc_out is not None:
        state["enc_out"] = enc_out

    def fill_block(bp, kind, x, st):
        if kind == "attn":
            x, _, cache = block_seq(
                bp, cfg, kind, x, enc_out=enc_out, window=window,
                collect_cache=True,
            )
            k, v = cache
            st = dict(st)
            st["k"] = _ring_fill(k, cache_len)
            st["v"] = _ring_fill(v, cache_len)
            if "xattn" in bp:
                st["xk"] = jnp.einsum(
                    "bsd,dnh->bsnh", enc_out, bp["xattn"]["wk"]
                )
                st["xv"] = jnp.einsum(
                    "bsd,dnh->bsnh", enc_out, bp["xattn"]["wv"]
                )
            return x, st
        if kind == "rec":
            h = apply_norm(cfg.norm, bp["norm1"], x)
            gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, bp["gate_proj"]))
            r = jnp.einsum("bsd,dw->bsw", h, bp["rec_proj"])
            rc = rg.conv1d_seq(bp["conv"], r)
            ry, hlast = rg.rglru_seq(bp["rglru"], rc)
            x = x + jnp.einsum("bsw,wd->bsd", gate * ry, bp["out_proj"])
            if cfg.d_ff:
                h2 = apply_norm(cfg.norm, bp["norm2"], x)
                x = x + _apply_ffn(bp["ffn"], cfg, h2)
            st = dict(st)
            st["h"] = hlast
            kw = bp["conv"]["w"].shape[0]
            st["buf"] = r[:, -(kw - 1):] if kw > 1 else st["buf"]
            return x, st
        # xLSTM kinds
        h = apply_norm(cfg.norm, bp["norm1"], x)
        seq_fn = xl.mlstm_seq if kind == "mlstm" else xl.slstm_seq
        y, cell = seq_fn(bp["cell"], h, cfg.n_heads)
        st = dict(st)
        st["cell"] = cell
        return x + y, st

    def period_body(x, xs):
        bp_tuple, st_tuple = xs
        new_states = []
        for q in range(period):
            x, ns = fill_block(bp_tuple[q], cfg.layer_kind(q), x, st_tuple[q])
            new_states.append(ns)
        return x, tuple(new_states)

    x, new_stack = jax.lax.scan(
        period_body, x, (params["layers"]["stack"], state["stack"])
    )
    new_rem = []
    for bp, st, kind in zip(params["layers"]["rem"], state["rem"], rem_kinds):
        x, ns = fill_block(bp, kind, x, st)
        new_rem.append(ns)
    state["stack"] = new_stack
    state["rem"] = tuple(new_rem)

    logits = _readout(cfg, params, x[:, -1:])[:, 0]
    return logits, state


def _ring_fill(kv: jax.Array, cache_len: int) -> jax.Array:
    """Place the last ``cache_len`` sequence positions into ring order."""
    B, S = kv.shape[:2]
    tail = kv[:, -cache_len:]
    if S <= cache_len:
        pad = jnp.zeros((B, cache_len - S, *kv.shape[2:]), kv.dtype)
        return jnp.concatenate([tail, pad], axis=1)
    # absolute positions S-cache_len .. S-1 go to slot p % cache_len
    start = S - cache_len
    slots = jnp.mod(start + jnp.arange(cache_len), cache_len)
    return jnp.zeros_like(tail).at[:, slots].set(tail)


# --------------------------------------------------------------------------- #
# Zygarde agile (unit-wise) execution.
# --------------------------------------------------------------------------- #


def get_block(cfg, params, i: int):
    """Return (kind, block-params) for absolute layer index ``i``."""
    period, n_scan, rem_kinds = _layer_plan(cfg)
    if i < n_scan * period:
        q, r = i % period, i // period
        bp = jax.tree.map(lambda a: a[r], params["layers"]["stack"][q])
        return cfg.layer_kind(q), bp
    return rem_kinds[i - n_scan * period], params["layers"]["rem"][i - n_scan * period]


def unit_layers(cfg, unit: int) -> range:
    lo = unit * cfg.exit_every
    hi = min(cfg.n_layers, lo + cfg.exit_every)
    return range(lo, hi)


def unit_forward(
    cfg,
    params,
    x: jax.Array,
    unit: int,
    *,
    enc_out=None,
    window: Optional[int] = None,
):
    """Run one Zygarde unit over hidden states x: (B, S, D).

    Returns (x, pooled_features (B, D) f32) — the features feed the
    per-unit k-means classifier + utility test.
    """
    for i in unit_layers(cfg, unit):
        kind, bp = get_block(cfg, params, i)
        x, _, _ = block_seq(bp, cfg, kind, x, enc_out=enc_out, window=window)
    pooled = jnp.mean(x.astype(jnp.float32), axis=1)
    return x, pooled


def embed_inputs(cfg, params, batch: dict) -> Tuple[jax.Array, Any]:
    """Embedding (+ frontend) shared by agile execution paths."""
    x = _embed(cfg, params, batch["tokens"])
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frontend"])
    elif cfg.n_frontend_tokens and "frontend" in batch:
        fx = jnp.einsum(
            "bsd,de->bse", batch["frontend"].astype(x.dtype),
            params["frontend_proj"],
        )
        x = jnp.concatenate([fx, x], axis=1)
    return x, enc_out


def readout(cfg, params, x: jax.Array) -> jax.Array:
    return _readout(cfg, params, x)
