"""Mixture-of-Experts FFN with grouped, capacity-based token dispatch.

Tokens are processed in groups of ``cfg.moe_group_size``; within each group a
top-k router assigns tokens to experts with a fixed per-expert capacity
(``capacity_factor``).  Dispatch/combine are expressed as einsums so GSPMD
lowers them to all-to-alls when experts are sharded over the ``model`` mesh
axis (the dominant collective for dbrx / qwen3-moe — see EXPERIMENTS.md).

Load-balance auxiliary loss follows Switch Transformer (mean gate prob x
mean dispatch fraction per expert).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import activate, dense_init, shard


def init_moe(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, (e,), jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(ks[2], (e, f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.act == "swiglu":
        p["w3"] = (jax.random.normal(ks[3], (e, d, f)) * d ** -0.5).astype(dtype)
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(group * top_k * factor / n_experts)
    return max(4, c)


def apply_moe(p: dict, cfg, x: jax.Array):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = min(cfg.moe_group_size, B * S)
    while (B * S) % G:
        G //= 2
    n_groups = (B * S) // G
    C = _capacity(G, K, E, cfg.capacity_factor)

    xg = x.reshape(n_groups, G, D)
    xg = shard(xg, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (g, t, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # one-hot expert assignment per routing slot: (g, t, K, E)
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    # position of each (token, slot) within its expert's capacity buffer
    pos = jnp.cumsum(assign.reshape(n_groups, G * K, E), axis=1).reshape(
        n_groups, G, K, E
    ) - assign
    keep = (pos < C) * assign  # drop overflow tokens
    pos = jnp.clip(pos, 0, C - 1).astype(jnp.int32)
    # dispatch/combine tensors: (g, t, E, C).  Routing positions are exact
    # in f32 above; the (0/1-and-gate-valued) dispatch tensors themselves
    # are cast to the activation dtype — they are matmul operands sized
    # tokens x E x C and dominate MoE activation traffic (§Perf P1-H4).
    slot_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32)
    disp = jnp.einsum("gtke,gtkec->gtec", keep, slot_onehot).astype(x.dtype)
    combine = jnp.einsum(
        "gtk,gtke,gtkec->gtec", gate_vals, keep, slot_onehot
    ).astype(x.dtype)

    # ---- dispatch (induces all-to-all under expert sharding) -------------- #
    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xg)
    xe = shard(xe, "batch", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    else:
        h = activate(cfg.act, h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    ye = shard(ye, "batch", "experts", None, None)

    # ---- combine ----------------------------------------------------------- #
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = shard(out, "batch", None, None)

    # Switch-style load-balance loss
    frac_tokens = jnp.mean(assign.sum(2), axis=1)  # (g, E) fraction routed
    frac_probs = jnp.mean(probs, axis=1)  # (g, E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    return out.reshape(B, S, D), aux.astype(jnp.float32)
