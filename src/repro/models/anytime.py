"""Anytime (imprecise) execution of the big-model configs.

Zygarde schedules DNNs as *imprecise computations*: a mandatory prefix of
the network must run for a job to count at all, and optional suffix units
refine the answer when time and energy allow (paper §3; *Scheduling
Real-time Deep Learning Services as Imprecise Computations* applies the
same framing to server-side DL).  This module gives every registered
``ModelConfig`` family (dense / MoE / RG-LRU hybrid / xLSTM) that
structure without retraining the backbone:

* the layer stack is grouped into ``cfg.n_units`` schedulable units of
  ``cfg.exit_every`` layers each, the first
  ``cfg.resolved_mandatory_units`` of them mandatory;
* each non-final unit gets a *lightweight early-exit head*: the model's
  own ``final_norm`` + (tied) LM head, modulated by a per-unit diagonal
  gain vector (:func:`init_heads`).  Gains initialise to ones, so an
  untrained head is exactly "read the LM head early" (CALM-style), adds
  ~``U x d_model`` parameters, and — crucially — the **final** unit
  bypasses the gain entirely and uses the stock readout, which makes
  full-depth anytime output bit-exact vs :func:`repro.models.forward` /
  :func:`repro.models.decode_step` under ``jit`` (asserted per-config in
  ``tests/test_anytime.py``);
* the exit decision is the classifier-margin utility test shared with
  the agile-CNN path (:func:`repro.core.policy.exit_test`): exit at the
  first unit whose top1-top2 logit margin clears its threshold
  (:func:`select_depth`), thresholds calibrated offline against a
  target agreement with the full-depth prediction
  (:func:`calibrate_thresholds`) or tuned online by ``repro.adapt``.

The serving engine (:mod:`repro.serve.anytime`) drives
:func:`unit_decode_step` inside a jitted continuous-batching scan and
turns the per-unit margins into deadline/energy-aware depth control.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import policy
from . import transformer as T
from .common import apply_norm, dtype_of, shard

__all__ = [
    "init_heads", "exit_readout", "anytime_forward", "unit_decode_step",
    "margins", "select_depth", "take_at_depth", "calibrate_thresholds",
    "unit_boundaries",
]


def unit_boundaries(cfg) -> Tuple[int, ...]:
    """Absolute layer count after which each unit ends (last entry =
    ``cfg.n_layers``)."""
    return tuple(min(cfg.n_layers, (u + 1) * cfg.exit_every)
                 for u in range(cfg.n_units))


def init_heads(cfg, key=None) -> dict:
    """Per-unit exit-head parameters: a diagonal gain on the normed hidden
    state, sharing the model's own final norm + LM head.

    Ones-init means a fresh head is the identity modulation — exits read
    the stock LM head early, and the head adds only ``U * d_model``
    parameters.  ``key`` is accepted for API symmetry with
    :func:`repro.models.init_params` (ones-init ignores it).  The final
    unit never applies a gain (see :func:`exit_readout`), so training the
    gains cannot perturb full-depth output.
    """
    del key
    return {"gain": jnp.ones((cfg.n_units, cfg.d_model), dtype_of(cfg))}


def _head_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def exit_readout(cfg, params, heads, x: jax.Array, unit: int) -> jax.Array:
    """Exit-head logits for ``unit`` from hidden state ``x``.

    ``x`` is ``(B, D)`` (decode) or ``(B, S, D)`` (sequence); returns f32
    logits with a trailing vocab axis.  For the final unit this is
    literally the stock readout chain (bit-exact with
    ``decode_step`` / ``forward``); earlier units modulate the normed
    hidden state by their gain vector first.
    """
    h = apply_norm(cfg.norm, params["final_norm"], x)
    if unit < cfg.n_units - 1:
        h = h * heads["gain"][unit].astype(h.dtype)
    head = _head_matrix(cfg, params)
    if x.ndim == 2:
        logits = jnp.einsum("bd,dv->bv", h, head).astype(jnp.float32)
        return shard(logits, "batch", "vocab")
    logits = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
    return shard(logits, "batch", None, "vocab")


def anytime_forward(cfg, params, heads, batch: dict, *,
                    window: Optional[int] = None) -> jax.Array:
    """Sequence-path anytime forward: ``(U, B, S, V)`` per-unit logits.

    Runs the stack unit by unit (:func:`repro.models.transformer
    .unit_forward`) and reads an exit head after each unit.  Under
    ``jit``, row ``U-1`` is bit-exact vs ``forward(...)[0]``.
    """
    x, enc_out = T.embed_inputs(cfg, params, batch)
    outs = []
    for u in range(cfg.n_units):
        x, _ = T.unit_forward(cfg, params, x, u, enc_out=enc_out,
                              window=window)
        outs.append(exit_readout(cfg, params, heads, x, u))
    return jnp.stack(outs)


def unit_decode_step(cfg, params, heads, state: dict, token: jax.Array, *,
                     window: Optional[int] = None):
    """One anytime serving step: ``token (B,) int32 -> ((U, B, V) f32
    per-unit logits, new state)``.

    Mirrors ``decode_step(..., unroll=True)`` layer for layer (requires a
    ``stacked=False`` decode state), reading an exit head at every unit
    boundary.  The final unit's row is bit-exact vs ``decode_step`` under
    ``jit``.  The full stack always executes — depth control happens in
    the *scheduler* (:mod:`repro.serve.anytime`), which accounts
    time/energy only for the depth it selects; physically skipping
    optional layers per slot would force data-dependent control flow into
    the batched step.
    """
    period, n_scan, rem_kinds = T._layer_plan(cfg)
    bounds = unit_boundaries(cfg)
    x = params["embed"][token]
    pos = state["pos"]

    new_per_q = [[None] * n_scan for _ in range(period)]
    new_rem = [None] * len(rem_kinds)
    unit_logits = []
    unit = 0
    for i in range(cfg.n_layers):
        kind, bp = T.get_block(cfg, params, i)
        if i < n_scan * period:
            q, r = i % period, i // period
            st = state["stack"][q][r]
        else:
            st = state["rem"][i - n_scan * period]
        x, ns = T.block_step(bp, cfg, kind, x, st, pos, window=window)
        if i < n_scan * period:
            new_per_q[q][r] = ns
        else:
            new_rem[i - n_scan * period] = ns
        if i + 1 == bounds[unit]:
            unit_logits.append(exit_readout(cfg, params, heads, x, unit))
            unit += 1

    new_state = dict(state)
    new_state["pos"] = pos + 1
    new_state["stack"] = tuple(tuple(states) for states in new_per_q)
    new_state["rem"] = tuple(new_rem)
    return jnp.stack(unit_logits), new_state


def margins(unit_logits: jax.Array) -> jax.Array:
    """Top1 - top2 logit margin per unit: ``(U, ..., V) -> (U, ...)``.

    The LLM analogue of the agile path's classifier L1 margin — the
    confidence signal the utility test thresholds."""
    top2, _ = jax.lax.top_k(unit_logits, 2)
    return top2[..., 0] - top2[..., 1]


def select_depth(margin: jax.Array, exit_thr: jax.Array,
                 use_exit_thr: jax.Array, mandatory=1):
    """Depth selected by the utility test.

    margin       : (U, ...) per-unit margins
    exit_thr     : (U,) per-unit thresholds
    use_exit_thr : (U,) bool/0-1 per-unit enables
    mandatory    : scalar; units before this index may not exit

    Returns ``(depth, exit_unit)`` — ``depth`` in ``[1, U]`` (units to
    run: the first enabled unit ``u >= mandatory - 1`` whose margin
    clears its threshold, else full depth), and ``exit_unit`` in
    ``[0, U]`` (the histogram bin: U = never exited), both i32 with the
    trailing shape of ``margin``.
    """
    U = margin.shape[0]
    extra = (1,) * (margin.ndim - 1)
    u = jnp.arange(U).reshape((U,) + extra)
    can = (u >= jnp.asarray(mandatory) - 1) & (u < U - 1)
    enabled = jnp.asarray(use_exit_thr).astype(bool).reshape((U,) + extra)
    thr = jnp.asarray(exit_thr, jnp.float32).reshape((U,) + extra)
    fire = can & enabled & policy.exit_test(margin, thr)
    first = jnp.argmax(fire, axis=0).astype(jnp.int32)
    any_fire = jnp.any(fire, axis=0)
    depth = jnp.where(any_fire, first + 1, U).astype(jnp.int32)
    exit_unit = jnp.where(any_fire, first, U).astype(jnp.int32)
    return depth, exit_unit


def take_at_depth(values: jax.Array, depth: jax.Array) -> jax.Array:
    """Select the per-unit value at each element's depth.

    values: (U, ...) stacked per-unit outputs (optionally with extra
    trailing axes, e.g. a vocab axis); depth: (...) in [1, U] matching
    the leading batch shape.  Returns values[depth - 1] elementwise.
    """
    idx = depth.astype(jnp.int32) - 1
    while idx.ndim < values.ndim - 1:
        idx = idx[..., None]
    return jnp.take_along_axis(values, idx[None], axis=0)[0]


def calibrate_thresholds(unit_logits, *, target_agreement: float = 0.98):
    """Host-side threshold calibration against full-depth agreement.

    For each non-final unit, finds the smallest margin threshold such
    that among calibration tokens with ``margin > threshold`` the exit
    prediction agrees with the full-depth prediction at rate >=
    ``target_agreement``; units that cannot reach the target at any
    threshold stay disabled.  Returns ``(exit_thr (U,) f32,
    use_exit_thr (U,) bool)`` as jnp arrays, ready for
    :func:`select_depth` or as ``repro.adapt`` search seeds.
    """
    ul = np.asarray(jax.device_get(unit_logits), np.float32)
    U, V = ul.shape[0], ul.shape[-1]
    flat = ul.reshape(U, -1, V)
    preds = flat.argmax(-1)
    part = np.partition(flat, V - 2, axis=-1)
    marg = part[..., -1] - part[..., -2]
    final = preds[-1]
    thr = np.full((U,), np.inf, np.float32)
    use = np.zeros((U,), bool)
    for u in range(U - 1):
        agree = (preds[u] == final).astype(np.float64)
        order = np.argsort(-marg[u], kind="stable")
        cum = np.cumsum(agree[order]) / np.arange(1, order.size + 1)
        ok = np.nonzero(cum >= target_agreement)[0]
        if not ok.size:
            continue
        k = int(ok.max())         # largest high-margin prefix meeting target
        m_in = marg[u][order[k]]  # smallest included margin
        if k + 1 < order.size:
            thr[u] = 0.5 * (m_in + marg[u][order[k + 1]])
        else:
            thr[u] = m_in - 1.0   # everything qualifies
        if thr[u] >= m_in:        # ties: keep the strict > test inclusive
            thr[u] = np.nextafter(m_in, -np.inf)
        use[u] = True
    return jnp.asarray(thr), jnp.asarray(use)
