"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory with recurrent mixing, sequential scan).

mLSTM recurrence (per head, exponential gating with log-space stabiliser):

    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = f'_t C_{t-1} + i'_t v_t k_t^T        f' = exp(log f + m_{t-1} - m_t)
    n_t = f'_t n_{t-1} + i'_t k_t              i' = exp(log i - m_t)
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Training/prefill uses the chunkwise-parallel form (state carried across
chunks, quadratic attention-like computation within a chunk) so the matrix
memory is never materialised per time step.  Decode is the plain one-step
recurrence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, zeros

CHUNK = 128


# --------------------------------------------------------------------------- #
# Parameter init.  Both cells operate on an inner width w = 2 * d_model with
# H heads; the block does d->w up-projection and w->d down-projection.
# --------------------------------------------------------------------------- #


def init_mlstm(key, d_model: int, n_heads: int, dtype) -> dict:
    w = 2 * d_model
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d_model, (w,), dtype),
        "wq": dense_init(ks[1], w, (w,), dtype),
        "wk": dense_init(ks[2], w, (w,), dtype),
        "wv": dense_init(ks[3], w, (w,), dtype),
        "wi": dense_init(ks[4], w, (n_heads,), jnp.float32),
        "wf": dense_init(ks[5], w, (n_heads,), jnp.float32),
        "bi": zeros((n_heads,), jnp.float32),
        "bf": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gate
        "down": dense_init(ks[6], w, (d_model,), dtype),
    }


def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    w = 2 * d_model
    dh = w // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], d_model, (w,), dtype),
        "wz": dense_init(ks[1], w, (w,), jnp.float32),
        "wi": dense_init(ks[2], w, (w,), jnp.float32),
        "wf": dense_init(ks[3], w, (w,), jnp.float32),
        "wo": dense_init(ks[4], w, (w,), jnp.float32),
        # recurrent block-diagonal mixing, per head: (H, dh, dh)
        "r": (jax.random.normal(ks[5], (n_heads, dh, dh)) * dh ** -0.5).astype(
            jnp.float32
        ),
        "bf": jnp.full((w,), 3.0, jnp.float32),
        "bi": zeros((w,), jnp.float32),
        "down": dense_init(ks[6], w, (d_model,), dtype),
    }


# --------------------------------------------------------------------------- #
# mLSTM — chunkwise-parallel sequence form.
# --------------------------------------------------------------------------- #


def _mlstm_qkvg(p: dict, x: jax.Array, n_heads: int):
    u = jnp.einsum("...d,dw->...w", x, p["up"])
    u = jax.nn.silu(u)
    w = u.shape[-1]
    dh = w // n_heads

    def heads(t):
        return t.reshape(*t.shape[:-1], n_heads, dh)

    q = heads(jnp.einsum("...w,wv->...v", u, p["wq"])) * dh ** -0.5
    k = heads(jnp.einsum("...w,wv->...v", u, p["wk"])) * dh ** -0.5
    v = heads(jnp.einsum("...w,wv->...v", u, p["wv"]))
    uf = u.astype(jnp.float32)
    log_i = jnp.einsum("...w,wh->...h", uf, p["wi"]) + p["bi"]
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("...w,wh->...h", uf, p["wf"]) + p["bf"]
    )
    return q, k, v, log_i, log_f


def mlstm_seq(p: dict, x: jax.Array, n_heads: int, state=None):
    """x: (B, S, D) -> (y (B, S, D), state)."""
    B, S, D = x.shape
    q, k, v, log_i, log_f = _mlstm_qkvg(p, x, n_heads)
    w = q.shape[-2] * q.shape[-1]
    dh = q.shape[-1]

    chunk = min(CHUNK, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    def to_chunks(t):
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    if state is None:
        C0 = jnp.zeros((B, n_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, n_heads, dh), jnp.float32)
        m0 = jnp.full((B, n_heads), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, xs):
        C, n, m = carry
        q_, k_, v_, li, lf = xs  # (B, chunk, H, ...) / (B, chunk, H)
        q_ = q_.astype(jnp.float32)
        k_ = k_.astype(jnp.float32)
        v_ = v_.astype(jnp.float32)
        # cumulative log decay within chunk (inclusive of step t's forget)
        F = jnp.cumsum(lf, axis=1)  # (B, chunk, H)
        F_total = F[:, -1]
        # stabiliser: per-chunk running max of (m + F) and (li + F offsets)
        m_intra = jnp.max(li - lf + F, axis=1)  # bound on log i_s/f_s terms
        m_new = jnp.maximum(m + F_total, m_intra)
        # inter-chunk contribution: h_inter_t = q_t . C * exp(m + F_t - m_t*)
        dec_q = jnp.exp(m[:, None] + F - m_new[:, None])  # (B, chunk, H)
        h_inter = jnp.einsum("bthd,bhde->bthe", q_, C) * dec_q[..., None]
        n_inter = n[:, None] * dec_q[..., None]  # (B, chunk, H, dh)
        # intra-chunk: s<=t, weight exp(li_s + F_t - F_s - m_t*)
        wmat = (
            li[:, None, :] - F[:, None, :] + F[:, :, None] - m_new[:, None, None]
        )  # (B, t, s, H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        wmat = jnp.where(mask[None, :, :, None], jnp.exp(wmat), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", q_, k_) * wmat
        h_intra = jnp.einsum("btsh,bshd->bthd", scores, v_)
        n_intra = jnp.einsum("btsh,bshd->bthd", scores, jnp.ones_like(k_) * 0 + k_)
        h_num = h_inter + h_intra
        n_vec = n_inter + n_intra
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", q_, n_vec)),
            jnp.exp(-m_new)[:, None],
        )
        h = h_num / denom[..., None]
        # state update to chunk end
        dec_C = jnp.exp(m + F_total - m_new)  # (B, H)
        dec_k = jnp.exp(li + F_total[:, None] - F - m_new[:, None])  # (B,chunk,H)
        C_new = C * dec_C[..., None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", k_, dec_k, v_
        )
        n_new = n * dec_C[..., None] + jnp.einsum("bshd,bsh->bhd", k_, dec_k)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc)
    )
    h = hs.swapaxes(0, 1).reshape(B, S, w)
    y = jnp.einsum("...w,wd->...d", h.astype(x.dtype), p["down"])
    return y, (C, n, m)


def mlstm_step(p: dict, x: jax.Array, n_heads: int, state):
    """x: (B, D) -> (y (B, D), state)."""
    q, k, v, log_i, log_f = _mlstm_qkvg(p, x[:, None], n_heads)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    log_i, log_f = log_i[:, 0], log_f[:, 0]
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    f_ = jnp.exp(log_f + m - m_new)[..., None]
    i_ = jnp.exp(log_i - m_new)[..., None]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    C_new = C * f_[..., None] + i_[..., None] * kf[..., :, None] * vf[..., None, :]
    n_new = n * f_ + i_ * kf
    num = jnp.einsum("bhde,bhd->bhe", C_new, qf)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qf)), jnp.exp(-m_new)
    )
    h = (num / denom[..., None]).reshape(x.shape[0], -1)
    y = jnp.einsum("bw,wd->bd", h.astype(x.dtype), p["down"])
    return y, (C_new, n_new, m_new)


def mlstm_init_state(batch: int, d_model: int, n_heads: int):
    w = 2 * d_model
    dh = w // n_heads
    return (
        jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        jnp.zeros((batch, n_heads, dh), jnp.float32),
        jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


# --------------------------------------------------------------------------- #
# sLSTM — sequential scan (the recurrence mixes h_{t-1} through R).
# --------------------------------------------------------------------------- #


def _slstm_cell(p: dict, n_heads: int, u_t, carry):
    """u_t: (B, w) pre-activations input; carry: (c, n, m, h)."""
    c, n, m, h = carry
    B, w = u_t.shape
    dh = w // n_heads
    hh = h.reshape(B, n_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r"]).reshape(B, w)
    z = jnp.tanh(jnp.einsum("bw,wv->bv", u_t, p["wz"]) + rec)
    o = jax.nn.sigmoid(jnp.einsum("bw,wv->bv", u_t, p["wo"]) + rec)
    log_i = jnp.einsum("bw,wv->bv", u_t, p["wi"]) + p["bi"] + rec
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bw,wv->bv", u_t, p["wf"]) + p["bf"] + rec
    )
    m_new = jnp.maximum(log_f + m, log_i)
    f_ = jnp.exp(log_f + m - m_new)
    i_ = jnp.exp(log_i - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_seq(p: dict, x: jax.Array, n_heads: int, state=None):
    """x: (B, S, D) -> (y, state)."""
    B, S, D = x.shape
    u = jax.nn.silu(jnp.einsum("bsd,dw->bsw", x, p["up"])).astype(jnp.float32)
    w = u.shape[-1]
    if state is None:
        state = slstm_init_state(B, D, n_heads)

    def step(carry, u_t):
        return _slstm_cell(p, n_heads, u_t, carry)

    state, hs = jax.lax.scan(step, state, u.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)
    y = jnp.einsum("bsw,wd->bsd", h.astype(x.dtype), p["down"])
    return y, state


def slstm_step(p: dict, x: jax.Array, n_heads: int, state):
    u = jax.nn.silu(jnp.einsum("bd,dw->bw", x, p["up"])).astype(jnp.float32)
    state, h = _slstm_cell(p, n_heads, u, state)
    y = jnp.einsum("bw,wd->bd", h.astype(x.dtype), p["down"])
    return y, state


def slstm_init_state(batch: int, d_model: int, n_heads: int):
    w = 2 * d_model
    z = jnp.zeros((batch, w), jnp.float32)
    return (z, z, jnp.full((batch, w), -1e30, jnp.float32), z)
