"""Griffin-style recurrent block: causal conv1d + RG-LRU gated recurrence.

RG-LRU (Real-Gated Linear Recurrent Unit, arXiv:2402.19427):

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence is evaluated with ``jax.lax.associative_scan``
for full sequences (train/prefill) and as a one-step update for decode.  A
Pallas kernel (`repro.kernels.rglru_scan`) provides the TPU-tiled blocked
variant; this module is the pure-JAX reference path used by the models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, zeros

C_FACTOR = 8.0


def init_rglru(key, width: int, dtype, n_blocks: int = 1) -> dict:
    """Gate matrices are block-diagonal with ``n_blocks`` (w/H, w/H) blocks
    (Griffin appendix A) — which also makes them tensor-parallel-local when
    blocks shard over the model mesh axis (§Perf P2-H3)."""
    ks = jax.random.split(key, 3)
    dh = width // n_blocks
    # Lambda initialised so that a_t in (0.9, 0.999) (Griffin appendix)
    u = jax.random.uniform(ks[0], (width,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1((-jnp.log(u)) / C_FACTOR))  # softplus^-1
    return {
        "wa": (jax.random.normal(ks[1], (n_blocks, dh, dh)) * dh ** -0.5
               ).astype(dtype),
        "ba": zeros((width,), jnp.float32),
        "wx": (jax.random.normal(ks[2], (n_blocks, dh, dh)) * dh ** -0.5
               ).astype(dtype),
        "bx": zeros((width,), jnp.float32),
        "lam": lam.astype(jnp.float32),
    }


def _block_mm(w: jax.Array, x: jax.Array) -> jax.Array:
    """x: (..., W) through block-diagonal w: (H, dh, dh) -> (..., W)."""
    H, dh, _ = w.shape
    xb = x.reshape(*x.shape[:-1], H, dh)
    yb = jnp.einsum("...hd,hde->...he", xb, w)
    return yb.reshape(*x.shape)


def _gates(p: dict, x: jax.Array):
    r = jax.nn.sigmoid(_block_mm(p["wa"], x).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(_block_mm(p["wx"], x).astype(jnp.float32) + p["bx"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    return a, gated_x


def rglru_seq(p: dict, x: jax.Array, h0: jax.Array | None = None):
    """Full-sequence RG-LRU.  x: (B, S, W) -> (y (B, S, W), h_last (B, W))."""
    a, b = _gates(p, x)
    if h0 is not None:
        # fold the incoming state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x: jax.Array, h: jax.Array):
    """One decode step.  x: (B, W), h: (B, W) -> (y, h_new)."""
    a, b = _gates(p, x)
    h_new = a * h.astype(jnp.float32) + b
    return h_new.astype(x.dtype), h_new


# --------------------------------------------------------------------------- #
# Causal depthwise conv1d (temporal mixing before the recurrence).
# --------------------------------------------------------------------------- #


def init_conv1d(key, width: int, kernel: int, dtype) -> dict:
    w = (jax.random.normal(key, (kernel, width)) * kernel ** -0.5).astype(dtype)
    return {"w": w, "b": zeros((width,), dtype)}


def conv1d_seq(p: dict, x: jax.Array) -> jax.Array:
    """Causal depthwise conv.  x: (B, S, W)."""
    k = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * p["w"][i] for i in range(k)
    )
    return out + p["b"]


def conv1d_step(p: dict, x: jax.Array, buf: jax.Array):
    """One decode step with rolling buffer.  x: (B, W), buf: (B, k-1, W)."""
    k = p["w"].shape[0]
    window = jnp.concatenate([buf, x[:, None]], axis=1)  # (B, k, W)
    out = jnp.einsum("bkw,kw->bw", window, p["w"]) + p["b"]
    return out, window[:, 1:] if k > 1 else buf
