"""The paper's four agile CNNs (Table 3), one per dataset.

Each network is a feature extractor: every layer is one Zygarde *unit*, and
the flattened activation after each unit feeds the per-unit semi-supervised
k-means classifier (after SelectKBest-style feature selection — see
``repro.core.kmeans``).  There is no softmax head: classification is
cluster-based, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_shape: Tuple[int, int, int]  # (H, W, C)
    convs: Tuple[Tuple[int, int, bool], ...]  # (out_ch, kernel, maxpool?)
    fcs: Tuple[int, ...]
    n_classes: int

    @property
    def n_units(self) -> int:
        return len(self.convs) + len(self.fcs)


# Table 3 of the paper (conv dims are out x in x k x k; FC dims out x in).
PAPER_CNNS = {
    "mnist": CNNConfig(
        "mnist", (28, 28, 1), ((20, 5, True), (100, 5, True)), (200, 500), 10
    ),
    "esc10": CNNConfig(
        "esc10", (32, 32, 1),
        ((16, 5, True), (32, 5, True), (64, 5, True)), (95,), 10,
    ),
    "cifar100": CNNConfig(
        "cifar100", (32, 32, 3), ((32, 5, True), (64, 5, True)), (384, 192), 5
    ),
    "vww": CNNConfig(
        "vww", (32, 32, 3),
        ((16, 5, True), (32, 5, True), (64, 5, True), (64, 5, True)), (192,), 2,
    ),
}


def _feature_sizes(cfg: CNNConfig) -> List[int]:
    """Flattened feature size after each unit."""
    h, w, c = cfg.input_shape
    sizes = []
    for out_ch, k, pool in cfg.convs:
        if pool:
            h, w = h // 2, w // 2
        c = out_ch
        sizes.append(h * w * c)
    flat = sizes[-1]
    for out in cfg.fcs:
        sizes.append(out)
        flat = out
    return sizes


def init_cnn_params(cfg: CNNConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_units)
    params = {"convs": [], "fcs": []}
    c_in = cfg.input_shape[2]
    for i, (out_ch, k, _) in enumerate(cfg.convs):
        fan = c_in * k * k
        params["convs"].append(
            {
                "w": jax.random.normal(keys[i], (k, k, c_in, out_ch))
                * (2.0 / fan) ** 0.5,
                "b": jnp.zeros((out_ch,)),
            }
        )
        c_in = out_ch
    in_dim = _feature_sizes(cfg)[len(cfg.convs) - 1]
    for j, out in enumerate(cfg.fcs):
        kidx = len(cfg.convs) + j
        params["fcs"].append(
            {
                "w": jax.random.normal(keys[kidx], (in_dim, out))
                * (2.0 / in_dim) ** 0.5,
                "b": jnp.zeros((out,)),
            }
        )
        in_dim = out
    return params


def _conv_unit(p: dict, x: jax.Array, pool: bool) -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + p["b"]
    y = jax.nn.relu(y)
    if pool:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    return y


def cnn_unit_forward(cfg: CNNConfig, params: dict, x: jax.Array, unit: int):
    """Run one unit.  Conv units take/return NHWC; FC units take/return (B, d).

    Returns (activation, flattened feature (B, feat) f32).
    """
    n_conv = len(cfg.convs)
    if unit < n_conv:
        out_ch, k, pool = cfg.convs[unit]
        y = _conv_unit(params["convs"][unit], x, pool)
        feat = y.reshape(y.shape[0], -1)
        if unit == n_conv - 1:
            y = feat  # next unit is FC
        return y, feat.astype(jnp.float32)
    j = unit - n_conv
    p = params["fcs"][j]
    y = jax.nn.relu(x @ p["w"] + p["b"])
    return y, y.astype(jnp.float32)


def cnn_forward_all(cfg: CNNConfig, params: dict, x: jax.Array):
    """Run every unit; returns list of per-unit flattened features."""
    feats = []
    h = x
    for u in range(cfg.n_units):
        h, f = cnn_unit_forward(cfg, params, h, u)
        feats.append(f)
    return feats
