"""Composable model zoo (pure JAX pytrees, no flax).

Six architecture families — dense GQA, MoE, hybrid RG-LRU (Griffin),
xLSTM, VLM (stubbed frontend), audio enc-dec (stubbed codec) — plus the
paper's four small CNNs.  All models expose:

    init_params(cfg, key)                  -> params pytree
    forward(cfg, params, batch)            -> logits (+ per-unit features)
    prefill(cfg, params, tokens)           -> logits, DecodeState
    decode_step(cfg, params, state, token) -> logits, DecodeState

Early-exit ("agile") execution additionally uses
:func:`repro.models.transformer.unit_forward` to run one Zygarde unit
(a group of ``cfg.exit_every`` blocks) at a time; :mod:`repro.models
.anytime` builds the full imprecise-computation view on top of it —
per-unit exit heads, margins, and depth selection for the anytime
serving engine (:mod:`repro.serve.anytime`).
"""
from . import anytime, common, transformer, cnn  # noqa: F401
from .transformer import (  # noqa: F401
    init_params,
    forward,
    prefill,
    decode_step,
    init_decode_state,
)
