"""Loop-aware HLO cost model (FLOPs / HBM bytes / collective bytes).

XLA's ``compiled.cost_analysis()`` counts each called computation ONCE —
``while`` bodies (every ``lax.scan``: the layer stack, the chunked-attention
block loop) are NOT multiplied by their trip counts, so a scanned 40-layer
model reports ~1-layer FLOPs.  This module re-derives the costs from the
post-optimization HLO text with proper loop accounting:

  * ``while`` body costs are multiplied by ``backend_config.known_trip_count``
    (emitted by XLA for counted loops; default 1 when absent);
  * ``fusion`` bodies are recursed for FLOPs (dots inside fusions count) but
    contribute only call-site operand/output bytes (fusion-internal traffic
    never reaches HBM);
  * dots count 2·|result|·K FLOPs (K = product of lhs contracting dims);
    elementwise / reduce ops count ~1 FLOP per element processed;
  * bytes = operands + output per instruction (post-fusion, a reasonable
    HBM-traffic model and the same convention XLA's own analysis uses);
  * collectives are tallied with ring-transfer factors (see
    :mod:`repro.launch.hlo_stats`) and loop multipliers applied.

Used by the dry-run / roofline analysis; validated against closed-form
matmul counts in ``tests/test_hlo_cost.py``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# bookkeeping ops: no FLOPs, no HBM traffic of their own
_FREE_OPS = frozenset({
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "opt-barrier",
})

# pure layout / dtype ops: on the TPU target these fuse into their consumers
# and never round-trip HBM; the CPU backend leaves many of them standalone,
# which would inflate the memory roofline term ~5-10x if counted.
_LAYOUT_OPS = frozenset({
    "copy", "convert", "broadcast", "transpose", "reshape",
    "bitcast-convert", "copy-start", "copy-done",
})

_COLLECTIVES = frozenset({
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "reduce-scatter-start",
})


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _balanced(text: str, start: int) -> int:
    """Index just past the parenthesis group opening at ``start``."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


@dataclass
class Instruction:
    name: str
    op: str
    type_str: str
    operands: list[str]
    attrs: str

    @property
    def out_elems(self) -> int:
        return _shape_elems_bytes(self.type_str)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.type_str)[1]


def _parse_instruction(line: str) -> Optional[Instruction]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%") or " = " not in line:
        return None
    name, rest = line.split(" = ", 1)
    if rest.startswith("("):
        end = _balanced(rest, 0)
        type_str, rest = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        type_str, rest = rest[:sp], rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    opend = _balanced(rest, par)
    operand_str = rest[par + 1: opend - 1]
    attrs = rest[opend:]
    operands = re.findall(r"%[\w.\-]+", operand_str)
    return Instruction(name.strip(), op, type_str, operands, attrs)


@dataclass
class Cost:
    flops: float = 0.0           # total (dot + elementwise)
    dot_flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0       # ring-model ICI traffic
    coll_counts: dict = field(default_factory=dict)
    coll_bytes: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "bytes": self.bytes,
            "ici_bytes": self.ici_bytes,
            "coll_counts": self.coll_counts,
            "coll_bytes": self.coll_bytes,
        }


class HloCostModel:
    def __init__(self, hlo_text: str, n_devices: int = 1):
        self.n_devices = n_devices
        self.computations: dict[str, list[Instruction]] = {}
        self.roots: dict[str, str] = {}  # computation -> root op kind
        self.entry: Optional[str] = None
        self.symbols: dict[str, str] = {}  # %name -> type_str
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}
        self._fusion_io_memo: dict[str, float] = {}

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        header_re = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{")
        for raw in text.splitlines():
            line = raw.rstrip()
            if current is None:
                m = header_re.match(line.strip())
                if m:
                    current = m.group(2)
                    self.computations[current] = []
                    if m.group(1):
                        self.entry = current
                continue
            if line.strip() == "}":
                current = None
                continue
            inst = _parse_instruction(line)
            if inst is not None:
                self.computations[current].append(inst)
                self.symbols[inst.name] = inst.type_str
                if line.strip().startswith("ROOT "):
                    self.roots[current] = inst.op
        if self.entry is None and self.computations:
            self.entry = list(self.computations)[-1]

    # ------------------------------------------------------------------ #
    def _operand_bytes(self, inst: Instruction) -> int:
        total = 0
        for op in inst.operands:
            t = self.symbols.get(op)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def _dot_flops(self, inst: Instruction) -> float:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
        k = 1
        if m and inst.operands:
            lhs_t = self.symbols.get(inst.operands[0], "")
            sm = _SHAPE_RE.search(lhs_t)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * inst.out_elems * k

    def _conv_flops(self, inst: Instruction) -> float:
        # rhs (kernel) elems / output-feature dim ~ per-output MACs
        if len(inst.operands) < 2:
            return float(inst.out_elems)
        rhs_t = self.symbols.get(inst.operands[1], "")
        k_elems = _shape_elems_bytes(rhs_t)[0]
        m = re.search(r"dim_labels=\S*_\S*o(\d*)", inst.attrs)
        out_feat = 1
        sm = _SHAPE_RE.search(inst.type_str)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            out_feat = dims[-1] if dims else 1
        per_out = max(k_elems // max(out_feat, 1), 1)
        return 2.0 * inst.out_elems * per_out

    def _collective(self, inst: Instruction, cost: Cost) -> None:
        kind = inst.op.replace("-start", "")
        size = inst.out_bytes
        m = _GROUPS_IOTA_RE.search(inst.attrs)
        if m:
            G = int(m.group(2))
        else:
            m = _GROUPS_BRACE_RE.search(inst.attrs)
            G = (m.group(1).count(",") + 1) if m else self.n_devices
        G = max(G, 1)
        if kind == "all-gather":
            moved = size * (G - 1) / G
        elif kind == "reduce-scatter":
            moved = size * (G - 1)
        elif kind == "all-reduce":
            moved = 2.0 * size * (G - 1) / G
        elif kind == "all-to-all":
            moved = size * (G - 1) / G
        else:
            moved = float(size)
        cost.ici_bytes += moved
        cost.coll_counts[kind] = cost.coll_counts.get(kind, 0) + 1
        cost.coll_bytes[kind] = cost.coll_bytes.get(kind, 0.0) + moved

    # ------------------------------------------------------------------ #
    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        cost = Cost()
        self._memo[name] = cost  # break cycles defensively
        for inst in self.computations.get(name, []):
            op = inst.op
            if op in _FREE_OPS or op in _LAYOUT_OPS:
                continue
            if op in _COLLECTIVES:
                self._collective(inst, cost)
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(inst.attrs)
                if m:
                    trip = int(m.group(1))
                bm = re.search(r"body=(%[\w.\-]+)", inst.attrs)
                if bm:
                    cost.add(self.computation_cost(bm.group(1)), trip)
                continue
            if op == "fusion":
                cm = re.search(r"calls=(%[\w.\-]+)", inst.attrs)
                if cm:
                    inner = self.computation_cost(cm.group(1))
                    cost.flops += inner.flops
                    cost.dot_flops += inner.dot_flops
                    cost.ici_bytes += inner.ici_bytes
                    cost.bytes += self._fusion_io_bytes(cm.group(1), inst)
                else:
                    cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                cost.flops += inst.out_elems
                cost.bytes += 2.0 * inst.out_bytes  # read slice, write result
                continue
            if op == "dynamic-update-slice":
                # in place: read the update (+ indices), write the slice
                upd = 0
                if len(inst.operands) > 1:
                    upd = _shape_elems_bytes(
                        self.symbols.get(inst.operands[1], "")
                    )[1]
                cost.bytes += 2.0 * upd
                continue
            if op in ("call", "async-start", "custom-call"):
                cm = re.search(r"(?:to_apply|calls|called_computation)="
                               r"(%[\w.\-]+)", inst.attrs)
                if cm:
                    cost.add(self.computation_cost(cm.group(1)), 1.0)
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op == "conditional":
                # branches are rare in our models; count the call site only
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op == "dot":
                f = self._dot_flops(inst)
                cost.flops += f
                cost.dot_flops += f
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op == "convolution":
                f = self._conv_flops(inst)
                cost.flops += f
                cost.dot_flops += f
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            if op in ("reduce", "reduce-window"):
                cost.flops += self._operand_elems(inst)
                cost.bytes += inst.out_bytes + self._operand_bytes(inst)
                continue
            # generic elementwise / data movement
            cost.flops += inst.out_elems
            cost.bytes += inst.out_bytes + self._operand_bytes(inst)
        return cost

    def _fusion_io_bytes(self, comp: str, call: Instruction) -> float:
        """Exact HBM traffic of one fusion execution.

        A fused computation's HBM footprint is what crosses its boundary:
        * a parameter consumed *only by* ``dynamic-slice`` ops contributes
          the slice bytes, not the (possibly GB-sized while-carried) buffer;
        * a parameter consumed only as the in-place target of a
          ``dynamic-update-slice`` contributes nothing (aliased);
        * a ``dynamic-update-slice`` inside the fusion writes update-sized
          bytes; a fusion without DUS writes its full output.
        Everything else contributes its full size.  Memoised per computation
        (slice sizes are static), so loop trip multipliers stay cheap.
        """
        if comp in self._fusion_io_memo:
            return self._fusion_io_memo[comp]
        insts = self.computations.get(comp, [])
        params = {i.name for i in insts if i.op == "parameter"}
        # a fusion computing ONLY layout/dtype changes never exists on the
        # TPU target (it fuses into its consumer's MXU/VPU feed): 0 bytes
        if all(i.op == "parameter" or i.op in _LAYOUT_OPS or i.op in _FREE_OPS
               for i in insts):
            self._fusion_io_memo[comp] = 0.0
            return 0.0
        # Single-operand layout ops (convert/bitcast/...) are transparent:
        # the CPU backend legalises bf16 dots/scatters by upconverting whole
        # buffers to f32, which the TPU MXU does for free in-flight — a
        # param read "through" a convert into a dynamic-slice is still a
        # slice-sized read.
        alias: dict[str, str] = {}
        for i in insts:
            if i.op in _LAYOUT_OPS and len(i.operands) == 1:
                src = i.operands[0]
                root = alias.get(src, src)
                if root in params:
                    alias[i.name] = root

        def root_param(o: str):
            r = alias.get(o, o)
            return r if r in params else None

        consumers: dict[str, set] = {p: set() for p in params}
        slice_reads: dict[str, float] = {p: 0.0 for p in params}
        inplace_update_bytes = 0.0
        has_inplace = False
        for i in insts:
            if i.op in ("dynamic-update-slice", "scatter"):
                # in place on operand 0: only update-sized traffic
                has_inplace = True
                if len(i.operands) > 1:
                    upd = i.operands[-1]  # DUS: update; scatter: updates
                    inplace_update_bytes += _shape_elems_bytes(
                        self.symbols.get(upd, "")
                    )[1]
            for pos, o in enumerate(i.operands):
                p = root_param(o)
                if p is not None and i.op not in _LAYOUT_OPS:
                    role = i.op
                    if i.op in ("dynamic-update-slice", "scatter") and pos != 0:
                        role = "update-operand"  # small operand, read fully
                    consumers[p].add(role)
                    if i.op in ("dynamic-slice", "slice", "gather") \
                            and pos == 0:
                        slice_reads[p] += i.out_bytes
        in_bytes = 0.0
        for i in insts:
            if i.op != "parameter":
                continue
            roles = consumers.get(i.name, set())
            if not roles:
                continue  # dead parameter
            if roles <= {"dynamic-slice", "slice", "gather"}:
                in_bytes += slice_reads[i.name]
            elif roles <= {"dynamic-update-slice", "scatter"}:
                # in-place target: touched rows re-read, update-sized
                in_bytes += inplace_update_bytes
            else:
                in_bytes += i.out_bytes
        out_bytes = inplace_update_bytes if has_inplace else call.out_bytes
        total = in_bytes + out_bytes
        self._fusion_io_memo[comp] = total
        return total

    def _operand_elems(self, inst: Instruction) -> int:
        total = 0
        for op in inst.operands:
            t = self.symbols.get(op)
            if t:
                total += _shape_elems_bytes(t)[0]
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def analyze_hlo(hlo_text: str, n_devices: int = 1) -> dict:
    model = HloCostModel(hlo_text, n_devices)
    return model.entry_cost().as_dict()


def top_cost_items(model: HloCostModel, n: int = 25,
                   by: str = "bytes") -> list[dict]:
    """Per-instruction cost list (loop multipliers applied) — the dry-run
    'profile' used by the §Perf hillclimb."""
    items: list[dict] = []

    def walk(name: str, mult: float) -> None:
        for inst in model.computations.get(name, []):
            op = inst.op
            if op in _FREE_OPS or op in _LAYOUT_OPS:
                continue
            if op == "while":
                trip = 1
                m = _TRIP_RE.search(inst.attrs)
                if m:
                    trip = int(m.group(1))
                bm = re.search(r"body=(%[\w.\-]+)", inst.attrs)
                if bm:
                    walk(bm.group(1), mult * trip)
                continue
            if op == "fusion":
                cm = re.search(r"calls=(%[\w.\-]+)", inst.attrs)
                inner = (model.computation_cost(cm.group(1))
                         if cm else Cost())
                b = model._fusion_io_bytes(cm.group(1), inst) if cm else 0.0
                items.append({
                    "name": inst.name, "op": op, "mult": mult,
                    "bytes": b * mult, "flops": inner.flops * mult,
                    "type": inst.type_str[:48],
                })
                continue
            if op == "dot":
                f = model._dot_flops(inst)
                b = inst.out_bytes + model._operand_bytes(inst)
                items.append({
                    "name": inst.name, "op": op, "mult": mult,
                    "bytes": b * mult, "flops": f * mult,
                    "type": inst.type_str[:48],
                })
                continue
            if op == "dynamic-slice":
                b = 2.0 * inst.out_bytes
            elif op == "dynamic-update-slice":
                upd = (_shape_elems_bytes(
                    model.symbols.get(inst.operands[1], ""))[1]
                    if len(inst.operands) > 1 else 0)
                b = 2.0 * upd
            else:
                b = inst.out_bytes + model._operand_bytes(inst)
            items.append({
                "name": inst.name, "op": op, "mult": mult,
                "bytes": b * mult, "flops": inst.out_elems * mult,
                "type": inst.type_str[:48],
            })

    walk(model.entry, 1.0)
    items.sort(key=lambda r: -r[by])
    return items[:n]
