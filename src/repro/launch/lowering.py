"""AOT lowering of train / prefill / decode steps onto a mesh.

Shared by the multi-pod dry-run (``repro.launch.dryrun``), the roofline
benchmark, and the mesh-lowering tests (which use tiny meshes on CPU).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.configs import INPUT_SHAPES, ModelConfig
from repro.models import transformer as tfm
from repro.models.common import logical_axis_rules
from repro.train import trainer
from repro.train.optimizer import adamw_init

from . import sharding as shd
from .hlo_cost import HloCostModel
from .hlo_stats import (
    cost_analysis_dict,
    memory_analysis_dict,
    model_flops,
    roofline_terms,
)
from .inputs import LoweringSpec, input_specs
from .mesh import logical_rules


@dataclass
class LoweringResult:
    lowered: Any
    compiled: Any
    spec: LoweringSpec
    mesh: Mesh


def _params_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(tfm.init_params, cfg), jax.random.key(0)
    )


def lower_step(
    cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    compile: bool = True,
    donate: bool = True,
) -> LoweringResult:
    """Lower (and optionally compile) the step the input shape dictates."""
    spec = input_specs(cfg, INPUT_SHAPES[shape_name])
    rules = logical_rules(mesh)
    named = functools.partial(shd.named, mesh)

    with mesh, logical_axis_rules(mesh, rules):
        params_s = _params_shapes(cfg)
        psp = shd.param_specs(mesh, params_s)

        if spec.step_kind == "train":
            (batch_s,) = spec.args
            opt_s = jax.eval_shape(adamw_init, params_s)
            osp = shd.param_specs(mesh, opt_s)
            bsp = shd.batch_specs(mesh, batch_s)
            step = trainer.make_train_step(cfg, window=spec.window)
            jitted = jax.jit(
                step,
                in_shardings=(named(psp), named(osp), named(bsp)),
                out_shardings=(named(psp), named(osp), None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_s, opt_s, batch_s)

        elif spec.step_kind == "prefill":
            (batch_s,) = spec.args
            bsp = shd.batch_specs(mesh, batch_s)

            def pf(params, batch):
                return tfm.prefill(cfg, params, batch, window=spec.window)

            logits_s, state_s = jax.eval_shape(pf, params_s, batch_s)
            lsp = shd.logits_spec(mesh, *logits_s.shape, ndim=2)
            ssp = shd.state_specs(mesh, state_s)
            jitted = jax.jit(
                pf,
                in_shardings=(named(psp), named(bsp)),
                out_shardings=(named(lsp), named(ssp)),
            )
            lowered = jitted.lower(params_s, batch_s)

        else:  # decode
            state_s, token_s = spec.args
            ssp = shd.state_specs(mesh, state_s)
            tsp = shd.batch_specs(mesh, token_s)

            def ds(params, state, token):
                return tfm.decode_step(
                    cfg, params, state, token, window=spec.window,
                    unroll=True,
                )

            logits_s, _ = jax.eval_shape(ds, params_s, state_s, token_s)
            lsp = shd.logits_spec(mesh, *logits_s.shape, ndim=2)
            jitted = jax.jit(
                ds,
                in_shardings=(named(psp), named(ssp), named(tsp)),
                out_shardings=(named(lsp), named(ssp)),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_s, state_s, token_s)

        compiled = lowered.compile() if compile else None
    return LoweringResult(lowered, compiled, spec, mesh)


def analyze(result: LoweringResult) -> dict:
    """Dry-run record: memory/cost analysis + collective + roofline terms.

    FLOPs / bytes / collective traffic come from the loop-aware HLO cost
    model (:mod:`repro.launch.hlo_cost`) — XLA's ``cost_analysis()`` counts
    scan bodies once and is reported alongside for reference only.
    """
    compiled = result.compiled
    spec = result.spec
    n_dev = result.mesh.size
    mem = memory_analysis_dict(compiled)
    xla_cost = cost_analysis_dict(compiled)
    cost = HloCostModel(compiled.as_text(), n_dev).entry_cost()
    terms = roofline_terms(
        flops=cost.flops, bytes_accessed=cost.bytes, ici_bytes=cost.ici_bytes
    )
    mflops = model_flops(
        spec.cfg, spec.step_kind, spec.shape.global_batch, spec.shape.seq_len
    )
    mflops_dev = mflops / n_dev
    return {
        "arch": spec.cfg.name,
        "shape": spec.shape.name,
        "step_kind": spec.step_kind,
        "window": spec.window,
        "mesh": list(result.mesh.devices.shape),
        "mesh_axes": list(result.mesh.axis_names),
        "n_devices": n_dev,
        "memory": mem,
        "hlo_flops_per_device": cost.flops,
        "hlo_dot_flops_per_device": cost.dot_flops,
        "hlo_bytes_per_device": cost.bytes,
        "collectives": {
            "ici_bytes": cost.ici_bytes,
            "counts": cost.coll_counts,
            "by_kind_bytes": cost.coll_bytes,
        },
        "xla_cost_analysis": {
            k: xla_cost[k] for k in ("flops", "bytes accessed")
            if k in xla_cost
        },
        "roofline": terms,
        "model_flops_total": mflops,
        "model_flops_per_device": mflops_dev,
        "useful_flops_ratio": (
            (mflops_dev / cost.flops) if cost.flops else 0.0
        ),
        "params_total": spec.cfg.param_count(),
        "params_active": spec.cfg.active_param_count(),
    }
