"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input.

For a training / prefill step this is the token batch (plus the stubbed
modality-frontend embeddings for the VLM / audio architectures, per the
assignment carve-out).  For a decode step it is the single-token batch plus
the full decode state (KV caches / recurrent states) sized for the shape's
``seq_len``.  Nothing here allocates device memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import transformer as tfm


class ShapeSkip(Exception):
    """Raised for documented (arch, shape) skips (see DESIGN.md §4)."""


@dataclass(frozen=True)
class LoweringSpec:
    """Everything the dry-run needs for one (arch, shape) combination."""

    cfg: ModelConfig
    shape: InputShape
    step_kind: str                 # "train" | "prefill" | "decode"
    window: Optional[int]          # attention-window override (long_500k)
    args: tuple                    # ShapeDtypeStruct pytrees for the step


def resolve_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    """long_500k needs sub-quadratic attention: native configs run as-is,
    dense archs take the sanctioned sliding-window override, ``skip``
    raises."""
    if shape.name != "long_500k":
        return None
    if cfg.long_context == "native":
        return None
    if cfg.long_context == "window":
        return cfg.long_window
    raise ShapeSkip(
        f"{cfg.name} skips long_500k ({cfg.long_context}; see DESIGN.md §4)"
    )


def batch_structs(cfg: ModelConfig, global_batch: int, seq_len: int) -> dict:
    """Token (+ frontend) ShapeDtypeStructs for a full-sequence pass."""
    batch = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    }
    if cfg.is_encoder_decoder:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_enc_tokens, cfg.d_model), jnp.float32
        )
    elif cfg.n_frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


def input_specs(cfg: ModelConfig, shape: InputShape | str) -> LoweringSpec:
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    window = resolve_window(cfg, shape)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind in ("train", "prefill"):
        # VLM: frontend patches prepend to the sequence — keep total length
        # at the assigned seq_len so the workload matches the assignment.
        S_tok = S - cfg.n_frontend_tokens if cfg.n_frontend_tokens else S
        batch = batch_structs(cfg, B, S_tok)
        kind = "train" if shape.kind == "train" else "prefill"
        return LoweringSpec(cfg, shape, kind, window, (batch,))

    # decode: ONE new token against a seq_len-sized cache.  The serving
    # configuration unrolls layers (stacked=False) so cache scatters update
    # in place instead of round-tripping a scan-carry slice (§Perf P3-H3).
    state = jax.eval_shape(
        lambda: tfm.init_decode_state(cfg, B, S, window=window,
                                      stacked=False)
    )
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    return LoweringSpec(cfg, shape, "decode", window, (state, token))
