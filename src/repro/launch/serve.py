"""Serving driver: Zygarde scheduling over live models, small and large.

Two engines behind one CLI:

* ``--engine scalar`` (default) — the reference event-driven loop
  (:class:`repro.serve.ServeEngine`): one or more classification tasks
  (agile CNN or reduced transformer), a calibrated energy harvester, and
  live unit-wise execution with early exit, centroid adaptation, and the
  zeta_I scheduler.  For the *vectorized* descendants of this path —
  thousands of devices per jitted call (``FleetServeEngine``), the fused
  Pallas segment kernel (``run(..., mode="fused")``), and million-job
  streaming (``run_stream``) — see ``examples/intermittent_serving.py``
  and ``docs/serving.md``; they share this engine's semantics and are
  tested bit-exact against it.
* ``--engine anytime`` — deadline-aware anytime serving of a registered
  big-model config (:class:`repro.serve.anytime.AnytimeServeEngine`):
  continuous batching over a jitted decode loop, per-request deadlines,
  early-exit depth control from the exit-head margins, and the Eq. 7
  energy gate (``docs/anytime_serving.md``).

Examples::

    PYTHONPATH=src python -m repro.launch.serve --tasks mnist esc10 \
        --policy zygarde --eta 0.71 --source solar --requests 40

    PYTHONPATH=src python -m repro.launch.serve --engine anytime \
        --arch xlstm-125m --policy zygarde --requests 24 --deadline 2.5
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import energy
from repro.core.agile import AgileCNN
from repro.data import make_dataset
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import train_agile_cnn


def build_task(name: str, seed: int):
    ds = make_dataset(name, n_train=384, n_test=256, seed=seed)
    trained = train_agile_cnn(ds, epochs=3, n_pairs=768, seed=seed)
    model = AgileCNN(trained.cfg, trained.params, trained.bank)
    return ds, model


def build_harvester(args):
    if args.source == "battery":
        return energy.Harvester("battery", 1.0, 0.0, 1.0), 1.0
    harv = energy.calibrate_harvester(args.eta, args.power,
                                      name=args.source)
    return harv, args.eta


def run_scalar(args) -> None:
    harv, eta = build_harvester(args)
    models, request_streams = [], []
    for i, name in enumerate(args.tasks):
        print(f"training agile model for task {name!r} ...")
        ds, model = build_task(name, args.seed + i)
        models.append(model)
        request_streams.append([
            Request(ds.x_test[j], int(ds.y_test[j]),
                    release=j * args.period)
            for j in range(min(args.requests, len(ds.x_test)))
        ])

    n_units = max(m.n_units for m in models)
    engine = ServeEngine(
        models, harv, eta,
        config=ServeConfig(
            policy=args.policy, period=args.period,
            deadline=args.deadline,
            horizon=args.requests * args.period + 5.0,
            adapt=not args.no_adapt, seed=args.seed,
            unit_time=np.full(n_units, 0.25),
            unit_energy=np.full(n_units, 6e-3),
        ),
    )
    print(f"serving {sum(len(r) for r in request_streams)} requests "
          f"({len(models)} tasks) under {args.policy} on {args.source} "
          f"(eta={eta:.2f}) ...")
    res = engine.run(request_streams)
    print(json.dumps(res.as_dict(), indent=2))
    sched_pct = 100 * res.scheduled / max(res.released, 1)
    corr_pct = 100 * res.correct / max(res.scheduled, 1)
    print(f"scheduled {res.scheduled}/{res.released} ({sched_pct:.0f}%), "
          f"{corr_pct:.0f}% of scheduled classified correctly")


def run_anytime(args) -> None:
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import (AnytimeConfig, AnytimeRequest,
                             AnytimeServeEngine)

    # CPU-runnable variant of the registered config, deep enough to have
    # optional units worth skipping
    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=max(cfg.n_layers, 4), vocab=min(cfg.vocab, 64),
        d_model=min(cfg.d_model, 128), exit_every=1)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    policy = {"zygarde": "anytime", "edf": "edf", "edf-m": "edf-m",
              "rr": "anytime"}[args.policy]
    # enough steps for the full release span: idle steps cost t_base
    span = args.requests * args.period + args.deadline + 1.0
    serve_cfg = AnytimeConfig(
        policy=policy, batch_slots=4,
        max_steps=int(span / 0.02) + 64, prompt_len=2,
        max_new_tokens=8)
    harv = None if args.source == "battery" else build_harvester(args)[0]
    engine = AnytimeServeEngine(cfg, params, serve_cfg=serve_cfg,
                                supply=harv, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [
        AnytimeRequest(
            prompt=[int(rng.integers(0, cfg.vocab))], n_tokens=6,
            release=i * args.period,
            deadline=i * args.period + args.deadline)
        for i in range(args.requests)
    ]
    print(f"anytime-serving {len(reqs)} requests on {args.arch} "
          f"({cfg.n_units} units, policy {policy!r}, "
          f"source {args.source}) ...")
    res = engine.run(reqs)
    print(json.dumps(res.as_dict(), indent=2))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Zygarde serving driver (scalar agile engine or "
                    "anytime big-model engine)")
    ap.add_argument("--engine", default="scalar",
                    choices=["scalar", "anytime"])
    ap.add_argument("--tasks", nargs="+", default=["mnist"],
                    choices=["mnist", "esc10", "cifar100", "vww"])
    ap.add_argument("--arch", default="xlstm-125m",
                    help="registered model config for --engine anytime")
    ap.add_argument("--policy", default="zygarde",
                    choices=["zygarde", "edf", "edf-m", "rr"])
    ap.add_argument("--eta", type=float, default=0.71)
    ap.add_argument("--source", default="solar",
                    choices=["battery", "solar", "rf"])
    ap.add_argument("--power", type=float, default=0.3)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--period", type=float, default=1.0)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--no-adapt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.engine == "anytime":
        run_anytime(args)
    else:
        run_scalar(args)


if __name__ == "__main__":
    main()
