"""Production serving driver: Zygarde intermittent serving of agile models.

Builds one or more classification tasks (agile CNN or reduced transformer),
a calibrated energy harvester, and runs the ServeEngine — live unit-wise
execution with early exit, centroid adaptation, and the zeta_I scheduler.

    PYTHONPATH=src python -m repro.launch.serve --tasks mnist esc10 \
        --policy zygarde --eta 0.71 --source solar --requests 40
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import energy
from repro.core.agile import AgileCNN
from repro.data import make_dataset
from repro.serve import Request, ServeConfig, ServeEngine
from repro.train import train_agile_cnn


def build_task(name: str, seed: int):
    ds = make_dataset(name, n_train=384, n_test=256, seed=seed)
    trained = train_agile_cnn(ds, epochs=3, n_pairs=768, seed=seed)
    model = AgileCNN(trained.cfg, trained.params, trained.bank)
    return ds, model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", nargs="+", default=["mnist"],
                    choices=["mnist", "esc10", "cifar100", "vww"])
    ap.add_argument("--policy", default="zygarde",
                    choices=["zygarde", "edf", "edf-m", "rr"])
    ap.add_argument("--eta", type=float, default=0.71)
    ap.add_argument("--source", default="solar",
                    choices=["battery", "solar", "rf"])
    ap.add_argument("--power", type=float, default=0.3)
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--period", type=float, default=1.0)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--no-adapt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.source == "battery":
        harv, eta = energy.Harvester("battery", 1.0, 0.0, 1.0), 1.0
    else:
        harv = energy.calibrate_harvester(args.eta, args.power,
                                          name=args.source)
        eta = args.eta

    models, request_streams = [], []
    for i, name in enumerate(args.tasks):
        print(f"training agile model for task {name!r} ...")
        ds, model = build_task(name, args.seed + i)
        models.append(model)
        request_streams.append([
            Request(ds.x_test[j], int(ds.y_test[j]), release=j * args.period)
            for j in range(min(args.requests, len(ds.x_test)))
        ])

    n_units = max(m.n_units for m in models)
    engine = ServeEngine(
        models, harv, eta,
        config=ServeConfig(
            policy=args.policy, period=args.period, deadline=args.deadline,
            horizon=args.requests * args.period + 5.0,
            adapt=not args.no_adapt, seed=args.seed,
            unit_time=np.full(n_units, 0.25),
            unit_energy=np.full(n_units, 6e-3),
        ),
    )
    print(f"serving {sum(len(r) for r in request_streams)} requests "
          f"({len(models)} tasks) under {args.policy} on {args.source} "
          f"(eta={eta:.2f}) ...")
    res = engine.run(request_streams)
    print(json.dumps(res.as_dict(), indent=2))
    sched_pct = 100 * res.scheduled / max(res.released, 1)
    corr_pct = 100 * res.correct / max(res.scheduled, 1)
    print(f"scheduled {res.scheduled}/{res.released} ({sched_pct:.0f}%), "
          f"{corr_pct:.0f}% of scheduled classified correctly")


if __name__ == "__main__":
    main()
