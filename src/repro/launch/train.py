"""Production training driver.

Runs the LM ``train_step`` for an assigned architecture on whatever devices
exist: the production meshes on TPU pods, the 1-device host mesh on CPU
(``--reduced`` for the smoke-scale variant).  Parameters are initialised
*sharded* (jit with out_shardings so no host copy of a 100B+ model is ever
materialised), data comes from the deterministic synthetic LM stream, and
checkpoints are written every ``--ckpt-every`` steps.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import make_lm_tokens
from repro.models import transformer as tfm
from repro.models.common import logical_axis_rules
from repro.train import make_train_step, save_checkpoint
from repro.train.optimizer import adamw_init

from . import sharding as shd
from .mesh import logical_rules, make_host_mesh, make_production_mesh


def build_mesh(kind: str):
    if kind == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=(kind == "multi-pod"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=("host", "single-pod", "multi-pod"),
                    default="host")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-path", default="experiments/ckpt/train")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = build_mesh(args.mesh)
    rules = logical_rules(mesh)
    named = functools.partial(shd.named, mesh)

    with mesh, logical_axis_rules(mesh, rules):
        params_shapes = jax.eval_shape(
            functools.partial(tfm.init_params, cfg), jax.random.key(args.seed)
        )
        psp = shd.param_specs(mesh, params_shapes)
        osp = shd.param_specs(
            mesh, jax.eval_shape(adamw_init, params_shapes)
        )
        init = jax.jit(
            functools.partial(tfm.init_params, cfg),
            out_shardings=named(psp),
        )
        params = init(jax.random.key(args.seed))
        opt = jax.jit(adamw_init, out_shardings=named(osp))(params)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
              f"mesh={dict(mesh.shape)}")

        step_fn = jax.jit(
            make_train_step(cfg, lr=args.lr),
            in_shardings=(named(psp), named(osp), None),
            out_shardings=(named(psp), named(osp), None),
            donate_argnums=(0, 1),
        )

        tokens = make_lm_tokens(
            cfg.vocab, args.seq, args.batch * args.steps, seed=args.seed
        )
        frontend = None
        if cfg.is_encoder_decoder or cfg.n_frontend_tokens:
            nf = (cfg.n_enc_tokens if cfg.is_encoder_decoder
                  else cfg.n_frontend_tokens)
            frontend = np.random.default_rng(args.seed).normal(
                size=(args.batch, nf, cfg.d_model)
            ).astype(np.float32)

        t0 = time.time()
        for step in range(args.steps):
            lo = step * args.batch
            batch = {"tokens": jnp.asarray(tokens[lo:lo + args.batch])}
            if frontend is not None:
                batch["frontend"] = jnp.asarray(frontend)
            params, opt, metrics = step_fn(params, opt, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                tok_s = args.batch * args.seq * (step + 1) / max(dt, 1e-9)
                print(f"step {step:5d}  loss {loss:7.4f}  "
                      f"aux {float(metrics['aux']):.4f}  "
                      f"tokens/s {tok_s:,.0f}")
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(f"{args.ckpt_path}_{step + 1}.npz", params)
                print(f"checkpoint -> {args.ckpt_path}_{step + 1}.npz")
        print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
