"""Parameter / optimizer / decode-state / batch sharding specs.

Specs are inferred *by leaf name* (the last dict key on the pytree path) so
one rule table covers all six architecture families, the stacked-scan layer
layout (leading ``n_scan`` dim), and the mirrored AdamW ``mu``/``nu`` trees.
Logical axis names resolve through :func:`repro.launch.mesh.logical_rules`
and are dropped per-dim when the dimension is not divisible by the mesh axis
(e.g. 8 KV heads on a 16-way model axis fall back to replication) via
:func:`repro.models.common.sanitize_dim`.

Layout summary (single pod, ("data", "model")):
  * weights: FSDP — the d_model ("embed") dim shards over ``data``; the
    heads / d_ff / vocab / experts dim shards over ``model``.
  * activations: batch over ``data`` (and ``pod``), vocab/heads/ff over
    ``model`` (annotated inside the model code via ``common.shard``).
  * KV caches: kv-heads over ``model`` when divisible, otherwise the cache
    *length* shards over ``model`` (GQA with few KV heads — glm4's kv=2 —
    would otherwise replicate a multi-GB cache per device).
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.models.common import sanitize_dim

from .mesh import logical_rules

# --------------------------------------------------------------------------- #
# Leaf-name -> logical axes of the *trailing* dims.  Leading dims (layer
# stacking) are padded with None.  Names not listed replicate.
# --------------------------------------------------------------------------- #

PARAM_SPECS: Mapping[str, tuple] = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "frontend_proj": ("embed", None),
    # attention
    "wq": ("embed", "heads", None),
    "wk": ("embed", "kv_heads", None),
    "wv": ("embed", "kv_heads", None),
    "wo": ("heads", None, "embed"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    # dense FFN
    "w1": ("embed", "ff"),
    "w3": ("embed", "ff"),
    "w2": ("ff", "embed"),
    # recurrent (Griffin) block
    "gate_proj": ("embed", "ff"),
    "rec_proj": ("embed", "ff"),
    "out_proj": ("ff", "embed"),
    # RG-LRU gate weights are block-diagonal (Griffin appendix A): one
    # (w/H, w/H) block per head, blocks sharded over `model` so the gate
    # matmuls are TP-local — removing the dominant per-layer all-reduce
    # for recurrentgemma (§Perf P2-H3).
    "wa": ("heads", None, None),
    "wx": ("heads", None, None),
    "ba": ("ff",),
    "bx": ("ff",),
    "lam": ("ff",),
    # xLSTM cell
    "up": ("embed", "ff"),
    "wz": ("embed", "ff"),
    "wi": ("embed", "ff"),
    "wf": ("embed", "ff"),
    "down": ("ff", "embed"),
}

# leaves under a "moe" subtree (expert-stacked weights)
MOE_SPECS: Mapping[str, tuple] = {
    "router": ("embed", None),
    "w1": ("experts", "embed", None),
    "w3": ("experts", "embed", None),
    "w2": ("experts", None, "embed"),
}


def _path_names(path) -> list[str]:
    return [k.key for k in path if isinstance(k, DictKey)]


def _leaf_spec(path, leaf, rules, axis_sizes) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    table = MOE_SPECS if "moe" in names else PARAM_SPECS
    base = table.get(name)
    if base is None or leaf.ndim < len(base):
        return P()
    pad = leaf.ndim - len(base)
    phys = [None] * pad
    for dim, logical in zip(leaf.shape[pad:], base):
        axes = rules.get(logical) if logical else None
        phys.append(sanitize_dim(axes, dim, axis_sizes))
    return P(*phys)


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh alike


def param_specs(mesh: Mesh, params: Any) -> Any:
    """PartitionSpec tree for a params (or AdamW state) shape-tree."""
    rules = logical_rules(mesh)
    sizes = _axis_sizes(mesh)
    return tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, rules, sizes), params
    )


# --------------------------------------------------------------------------- #
# Decode-state specs.
# --------------------------------------------------------------------------- #

_STATE_4D = ("k", "v", "xk", "xv")  # (..., B, C, KV, hd)


def _state_leaf_spec(path, leaf, rules, sizes, model_axis: str) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    batch_axes = rules.get("batch")
    model_size = sizes.get(model_axis, 1)

    if name in _STATE_4D:
        pad = leaf.ndim - 4
        B, C, KV, hd = leaf.shape[pad:]
        batch = sanitize_dim(batch_axes, B, sizes)
        if KV % model_size == 0:
            return P(*([None] * pad), batch, None, model_axis, None)
        if C % model_size == 0:
            # few KV heads: shard the cache length instead (see module doc)
            return P(*([None] * pad), batch, model_axis, None, None)
        return P(*([None] * pad), batch, None, None, None)
    if name == "h":  # RG-LRU hidden state (..., B, W)
        pad = leaf.ndim - 2
        B, W = leaf.shape[pad:]
        batch = sanitize_dim(batch_axes, B, sizes)
        width = model_axis if W % model_size == 0 else None
        return P(*([None] * pad), batch, width)
    if name == "buf":  # conv ring buffer (..., B, k-1, W)
        pad = leaf.ndim - 3
        B, _, W = leaf.shape[pad:]
        batch = sanitize_dim(batch_axes, B, sizes)
        width = model_axis if W % model_size == 0 else None
        return P(*([None] * pad), batch, None, width)
    if name == "pos":
        return P(sanitize_dim(batch_axes, leaf.shape[0], sizes))
    if name == "enc_out":
        batch = sanitize_dim(batch_axes, leaf.shape[0], sizes)
        return P(batch, None, None)
    # xLSTM cell tuples and anything unnamed: batch is the dim right after
    # any stacking dims; find the first dim divisible by the batch axes.
    for i, dim in enumerate(leaf.shape):
        batch = sanitize_dim(batch_axes, dim, sizes)
        if batch is not None:
            return P(*([None] * i), batch, *([None] * (leaf.ndim - i - 1)))
    return P()


def state_specs(mesh: Mesh, state: Any) -> Any:
    rules = logical_rules(mesh)
    sizes = _axis_sizes(mesh)
    model_axis = "model" if "model" in mesh.axis_names else None
    return tree_map_with_path(
        lambda path, leaf: _state_leaf_spec(
            path, leaf, rules, sizes, model_axis
        ),
        state,
    )


# --------------------------------------------------------------------------- #
# Batch / token / logits specs.
# --------------------------------------------------------------------------- #


def batch_specs(mesh: Mesh, batch: Any) -> Any:
    """Input batch: leading dim is the global batch -> data axes."""
    rules = logical_rules(mesh)
    sizes = _axis_sizes(mesh)

    def spec(leaf):
        b = sanitize_dim(rules.get("batch"), leaf.shape[0], sizes)
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def logits_spec(mesh: Mesh, batch_dim: int, vocab_dim: int, ndim: int) -> P:
    rules = logical_rules(mesh)
    sizes = _axis_sizes(mesh)
    b = sanitize_dim(rules.get("batch"), batch_dim, sizes)
    v = sanitize_dim(rules.get("vocab"), vocab_dim, sizes)
    return P(b, *([None] * (ndim - 2)), v)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# --------------------------------------------------------------------------- #
# Fleet device-axis sharding (repro.fleet / repro.adapt).
# --------------------------------------------------------------------------- #


def fleet_specs(mesh: Mesh, cfg: Any) -> Any:
    """PartitionSpecs for a :class:`repro.fleet.state.FleetConfig` (or any
    pytree of ``(D, ...)`` leaves — the segment carry
    :class:`repro.fleet.state.DeviceState` included): the leading device
    axis shards over the whole mesh; every trailing dim replicates —
    including the task-set axis ``K`` and the per-task workload tables
    ``(D, K, U)`` / ``(D, K, J, U)``, which stay whole per shard because
    each device steps its entire task set locally (the fleet axis is the
    only data-parallel dimension).
    """
    axes = tuple(mesh.axis_names)
    return jax.tree.map(lambda l: P(axes, *([None] * (l.ndim - 1))), cfg)


def shard_fleet_config(mesh: Mesh, cfg: Any) -> Any:
    """Place a FleetConfig with its device axis partitioned over ``mesh``.

    The fleet axis is data-parallel with no collectives, so this is the only
    placement the simulator needs.  ``D`` is padded up to a mesh-size
    multiple by wrapping around the existing devices (every shard then holds
    valid configs); callers slice results back to the real device count.
    """
    d = jax.tree.leaves(cfg)[0].shape[0]
    n = mesh.size
    pad = (-d) % n
    if pad:
        idx = jax.numpy.arange(d + pad) % d
        cfg = jax.tree.map(lambda l: l[idx], cfg)
    return jax.tree.map(
        lambda l, s: jax.device_put(l, NamedSharding(mesh, s)),
        cfg, fleet_specs(mesh, cfg),
    )


def shard_fleet_carry(mesh: Mesh, carry: Any) -> Any:
    """Place a segment carry (:class:`repro.fleet.state.DeviceState`) with
    its device axis partitioned over ``mesh``.

    The carry is a pytree of ``(D, ...)`` leaves just like a FleetConfig,
    and :func:`repro.fleet.simulator.run_segments` must keep the two
    aligned shard-for-shard between horizon chunks — same wrap-around
    padding to a mesh-size multiple, same leading-axis ``NamedSharding``.
    It is therefore the same placement rule; the separate name documents
    (and pins, via tests) the contract that carries shard like configs.
    """
    return shard_fleet_config(mesh, carry)


def shard_serve_carry(mesh: Mesh, carry: Any, *,
                      shared_bank: bool = False) -> Any:
    """Place a live-serving carry (:class:`repro.fleet.state.ServeCarry`).

    The scheduling state (``dev``) and per-job log (``log``) are plain
    ``(D, ...)`` pytrees and shard exactly like a fleet carry.  The
    centroid bank depends on the engine's bank mode: per-device banks carry
    a leading ``D`` axis and shard alongside, while a ``shared`` bank has
    no device axis and must replicate (every shard's collaborative
    ``online_update`` needs the whole table).  The serving engine requires
    ``D`` to be a mesh-size multiple, so no wrap-around padding happens
    here — config, carry and tables stay aligned shard-for-shard.
    """
    bank = carry.bank
    if shared_bank:
        bank = jax.tree.map(
            lambda l: jax.device_put(l, NamedSharding(mesh, P())), bank)
    else:
        bank = shard_fleet_config(mesh, bank)
    return carry._replace(dev=shard_fleet_config(mesh, carry.dev),
                          bank=bank,
                          log=shard_fleet_config(mesh, carry.log))


def serve_table_shardings(mesh: Mesh, tables: Any,
                          per_device: bool = False) -> Any:
    """Per-leaf :class:`NamedSharding` pytree for a
    :class:`repro.serve.fleet_engine.ServeTables` — the placement *rule*
    without the placement.

    The classifier metadata (``clabels``/``fidx``/``thr``) never has a
    device axis and replicates.  The feature/label tables gain a leading
    ``D`` axis only when every device serves its *own* request stream
    (``per_device=True``) — then they shard over the fleet axis; a shared
    stream replicates (each shard classifies against the same table).
    Exposed separately so streaming callers can hand the shardings to
    ``jax.device_put`` on freshly staged chunk windows (same shapes every
    chunk, so the rule is computed once) — :func:`shard_serve_tables` is
    this rule applied.
    """
    batched = {"sel_feats", "full_feats", "labels"} if per_device else set()
    axes = tuple(mesh.axis_names)
    out = {}
    for name, leaf in tables._asdict().items():
        spec = (P(axes, *([None] * (leaf.ndim - 1))) if name in batched
                else P())
        out[name] = NamedSharding(mesh, spec)
    return type(tables)(**out)


def shard_serve_tables(mesh: Mesh, tables: Any,
                       per_device: bool = False) -> Any:
    """Place a :class:`repro.serve.fleet_engine.ServeTables` according to
    :func:`serve_table_shardings`."""
    return jax.tree.map(jax.device_put, tables,
                        serve_table_shardings(mesh, tables, per_device))
