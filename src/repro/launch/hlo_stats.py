"""Roofline-term extraction from a compiled (dry-run) executable.

``compiled.cost_analysis()`` supplies per-device HLO FLOPs and bytes
accessed; collective traffic is NOT in cost_analysis, so we parse the
post-SPMD HLO text and sum the bytes every collective moves over ICI,
using ring-algorithm transfer factors per op kind:

    all-gather          out_bytes * (G-1)/G     (out = gathered result)
    reduce-scatter      out_bytes * (G-1)       (= operand * (G-1)/G)
    all-reduce          2 * bytes * (G-1)/G     (reduce-scatter + all-gather)
    all-to-all          bytes * (G-1)/G
    collective-permute  bytes

where G is the replica-group size parsed from the op's ``replica_groups``.
The raw sum of result bytes is reported too (``collective_raw_bytes``).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (single-link serialization — conservative).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = <result-type> <op>(` where op may have a -start suffix (async).
_OP_RE = re.compile(
    r"=\s*(\(?[^()]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    return default


@dataclass
class CollectiveStats:
    ici_bytes: float = 0.0         # ring-model bytes over ICI, per device
    raw_bytes: float = 0.0         # sum of collective result bytes
    counts: dict = field(default_factory=dict)
    by_kind_bytes: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "ici_bytes": self.ici_bytes,
            "raw_bytes": self.raw_bytes,
            "counts": self.counts,
            "by_kind_bytes": self.by_kind_bytes,
        }


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_type, kind, suffix = m.group(1), m.group(2), m.group(3)
        size = _shape_bytes(result_type)
        G = max(_group_size(line, n_devices), 1)
        if kind == "all-gather":
            moved = size * (G - 1) / G
        elif kind == "reduce-scatter":
            moved = size * (G - 1)
        elif kind == "all-reduce":
            moved = 2.0 * size * (G - 1) / G
        elif kind == "all-to-all":
            moved = size * (G - 1) / G
        else:  # collective-permute
            moved = float(size)
        st.ici_bytes += moved
        st.raw_bytes += size
        st.counts[kind] = st.counts.get(kind, 0) + 1
        st.by_kind_bytes[kind] = st.by_kind_bytes.get(kind, 0.0) + moved
    return st


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for name in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, name, None)
        if v is not None:
            out[name] = int(v)
    return out


def cost_analysis_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def roofline_terms(
    *, flops: float, bytes_accessed: float, ici_bytes: float,
) -> dict:
    """Three per-device roofline terms (seconds) + the dominant one.

    ``flops``/``bytes_accessed`` come from the per-device (post-SPMD)
    module's cost_analysis; ``ici_bytes`` from :func:`collective_stats`.
    """
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = ici_bytes / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant.replace("_s", "")
    total = max(compute_s, memory_s, collective_s)
    terms["bound_s"] = total
    terms["compute_fraction_of_bound"] = compute_s / total if total else 0.0
    return terms


def model_flops(cfg, step_kind: str, global_batch: int, seq_len: int) -> float:
    """Useful-work estimate: 6·N_active·D (train) / 2·N_active·D (inference);
    D = tokens processed (decode: one token per sequence)."""
    n = cfg.active_param_count()
    mult = 6.0 if step_kind == "train" else 2.0
    tokens = global_batch * (seq_len if step_kind != "decode" else 1)
    return mult * n * tokens
