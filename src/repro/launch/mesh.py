"""Production meshes and logical-axis rules.

Target hardware: TPU v5e.  Single pod = 16x16 = 256 chips with axes
("data", "model"); multi-pod = 2 pods = 512 chips with ("pod", "data",
"model") — the pod axis is pure data parallelism (gradient all-reduce over
DCN in production; here it lowers like a third mesh axis, which is what the
multi-pod dry-run must prove shards correctly).

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see the 1-device CPU backend).
"""
from __future__ import annotations

from typing import Mapping

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax (e.g. 0.4.x containers)
    AxisType = None


def make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the jax version has them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_abstract_mesh(shape, axes):
    """Device-less mesh for spec inference, across jax versions."""
    from jax.sharding import AbstractMesh

    if AxisType is None:
        return AbstractMesh(tuple(zip(axes, shape)))
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke runs of the distributed code path."""
    return make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ("dev",) mesh for the fleet simulator's embarrassingly-parallel
    device axis (`repro.fleet` / `repro.adapt`): every backend simulates an
    independent slice of the candidate × harvester × seed population.
    Defaults to all visible devices."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh((n,), ("dev",))


def logical_rules(mesh: Mesh) -> Mapping[str, object]:
    """Logical-axis -> mesh-axis mapping used by ``models.common.shard``."""
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return {
        "batch": batch,
        # FSDP dim for weights/optimizer state; on the multi-pod mesh the
        # shard extends across pods (ZeRO over DCN) — this is what brings
        # the 132B/235B optimizer state under 16 GiB/chip (see §Roofline)
        "embed": (("pod", "data") if has_pod else ("data",)),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        # NOTE (§Perf P2-H2, refuted): mapping "seq" -> ("model",) enables
        # Megatron-SP-style residual sharding; measured on this GSPMD
        # version it cut the memory term 2.6x but grew the collective bound
        # (involuntary resharding around attention / the recurrent scan),
        # so the default keeps the sequence replicated.
        "seq": None,
        "qseq": None,
    }
