"""Profiling harness: compile/steady split, trace capture, roofline join.

The benchmark lane previously timed jitted entry points with one warm call
and a wall clock — conflating compilation, dispatch, and device time, and
leaving nothing to attribute a regression to.  This module is the shared
measurement core used by :mod:`benchmarks.common` and the ``--profile``
flag on ``benchmarks/run.py``:

* :func:`measure` — AOT-lowers the function (``jit -> lower -> compile``)
  so compile time is measured *separately* from steady-state, then times
  repeated executions with ``jax.block_until_ready`` around every call
  (async dispatch otherwise lets device work leak between timestamps).
* :func:`trace` — a ``jax.profiler`` trace context writing a TensorBoard-
  loadable trace directory; degrades to a no-op (with a notice) when the
  profiler cannot start, so ``--profile`` never breaks a bench lane.
* :func:`roofline_join` — joins a measured steady-state time against the
  loop-aware HLO cost model (:mod:`repro.launch.hlo_cost`) and the device
  roofline (:func:`repro.launch.hlo_stats.roofline_terms`): modeled FLOPs /
  bytes, the bound term, and measured-vs-bound ratio — the attribution
  record behind the vmap-vs-Pallas device-step gap on the ROADMAP.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax

from .hlo_cost import HloCostModel
from .hlo_stats import collective_stats, roofline_terms


@dataclass
class Measurement:
    """One profiled entry point: compile vs steady-state, plus the optional
    roofline join (``roofline`` stays None unless requested)."""

    label: str
    compile_s: float             # lower+compile wall time (one-off)
    steady_s: float              # median per-call, fully blocked
    steady_min_s: float
    steady_max_s: float
    repeats: int
    roofline: Optional[dict] = None
    extra: dict = field(default_factory=dict)

    def as_row(self) -> dict:
        """Flat JSON/CSV-friendly view for ``benchmarks.common.emit``."""
        row = dict(
            label=self.label,
            compile_s=round(self.compile_s, 4),
            steady_s=round(self.steady_s, 6),
            steady_min_s=round(self.steady_min_s, 6),
            steady_max_s=round(self.steady_max_s, 6),
            repeats=self.repeats,
        )
        if self.roofline is not None:
            row.update({f"roofline_{k}": v for k, v in self.roofline.items()})
        row.update(self.extra)
        return row


def _block(x):
    jax.block_until_ready(x)
    return x


def measure(fn, *args, label: str = "fn", repeats: int = 10,
            warmup: int = 2, static_argnames=(), **kwargs) -> Measurement:
    """Profile one jittable callable: AOT compile split from steady-state.

    ``fn`` is wrapped in ``jax.jit`` (pass ``static_argnames`` for hashable
    statics) and lowered/compiled once under a timer; the compiled
    executable is then run ``warmup`` throwaway + ``repeats`` timed calls,
    each wrapped in ``block_until_ready`` so async dispatch cannot smear
    device work across timestamps.  Keyword args are forwarded to the
    traced call (static ones participate in lowering).
    """
    jitted = jax.jit(fn, static_argnames=tuple(static_argnames))
    t0 = time.perf_counter()
    compiled = jitted.lower(*args, **kwargs).compile()
    compile_s = time.perf_counter() - t0

    dyn_kwargs = {k: v for k, v in kwargs.items()
                  if k not in set(static_argnames)}
    for _ in range(warmup):
        _block(compiled(*args, **dyn_kwargs))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(compiled(*args, **dyn_kwargs))
        times.append(time.perf_counter() - t0)
    meas = Measurement(
        label=label,
        compile_s=compile_s,
        steady_s=float(np.median(times)),
        steady_min_s=float(np.min(times)),
        steady_max_s=float(np.max(times)),
        repeats=repeats,
    )
    meas.extra["_compiled"] = compiled     # for roofline_join; stripped below
    return meas


def roofline_join(meas: Measurement, n_devices: int = 1) -> Measurement:
    """Attach the HLO-cost roofline attribution to a :func:`measure` result.

    Re-derives loop-aware FLOPs/bytes from the compiled module's
    post-optimization HLO (XLA's own ``cost_analysis`` counts scan bodies
    once — useless for a 400-step ``lax.scan``), computes the roofline
    bound, and records ``measured / bound`` — how far the measured
    steady-state sits above the model's best case.
    """
    compiled = meas.extra.pop("_compiled", None)
    if compiled is None:
        return meas
    try:
        hlo = compiled.as_text()
    except Exception:                      # backend without HLO text access
        return meas
    cost = HloCostModel(hlo, n_devices).entry_cost()
    ici = collective_stats(hlo, n_devices).ici_bytes
    terms = roofline_terms(flops=cost.flops, bytes_accessed=cost.bytes,
                           ici_bytes=ici)
    bound = terms["bound_s"]
    meas.roofline = dict(
        flops=cost.flops,
        bytes=cost.bytes,
        ici_bytes=ici,
        bound_s=round(bound, 9),
        dominant=terms["dominant"],
        measured_over_bound=(round(meas.steady_s / bound, 2)
                             if bound > 0 else None),
    )
    return meas


def profile_call(fn, *args, label: str = "fn", repeats: int = 10,
                 warmup: int = 2, static_argnames=(), n_devices: int = 1,
                 **kwargs) -> Measurement:
    """:func:`measure` + :func:`roofline_join` in one call (the shape the
    bench modules use under ``--profile``)."""
    meas = measure(fn, *args, label=label, repeats=repeats, warmup=warmup,
                   static_argnames=static_argnames, **kwargs)
    meas = roofline_join(meas, n_devices=n_devices)
    meas.extra.pop("_compiled", None)
    return meas


@contextlib.contextmanager
def trace(log_dir, enabled: bool = True):
    """``jax.profiler`` trace context (TensorBoard / Perfetto loadable).

    ``enabled=False`` makes it a clean no-op so call sites can thread a
    ``--profile`` flag straight through; a profiler that fails to start
    (already active, unsupported backend) degrades to a warning instead of
    failing the bench lane.
    """
    if not enabled:
        yield None
        return
    started = False
    try:
        jax.profiler.start_trace(str(log_dir))
        started = True
    except Exception as e:                 # pragma: no cover - backend-dep
        print(f"# profiling: trace disabled ({e})")
    try:
        yield str(log_dir) if started else None
    finally:
        if started:
            jax.profiler.stop_trace()
