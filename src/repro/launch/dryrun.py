import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles the step every (architecture x input-shape) pair dictates
— ``train_step`` for train_4k, ``prefill`` for prefill_32k, ``serve_step``
(one token against a seq_len KV cache) for decode_32k / long_500k — on the
production meshes:

    single-pod : 16 x 16           ("data", "model")        = 256 chips
    multi-pod  : 2 x 16 x 16       ("pod", "data", "model") = 512 chips

and records memory_analysis / cost_analysis / collective schedule and the
three roofline terms into a JSON record per combination (EXPERIMENTS.md
§Dry-run and §Roofline read these).

The two lines above MUST stay first: they install 512 placeholder host
devices before jax locks the device count.  Do not set XLA_FLAGS globally —
smoke tests and benchmarks must see the single real CPU device.

Usage:
    python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
    python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all --out-dir experiments/dryrun
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def run_one(arch: str, shape: str, multi_pod: bool) -> dict:
    # imports deferred so --all subprocesses re-init jax themselves
    from repro.configs import get_config
    from repro.launch.inputs import ShapeSkip
    from repro.launch.lowering import analyze, lower_step
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        result = lower_step(cfg, shape, mesh)
    except ShapeSkip as e:
        return {
            "arch": arch, "shape": shape,
            "mesh": list(mesh.devices.shape), "status": "skip",
            "reason": str(e),
        }
    record = analyze(result)
    record["status"] = "ok"
    record["compile_s"] = round(time.time() - t0, 1)
    return record


def combo_list():
    from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

    return [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]


def sweep(out_dir: Path, multi_pod: bool, jobs: int, archs=None,
          shapes=None) -> int:
    """Run every combination in subprocesses (isolation + parallelism)."""
    out_dir.mkdir(parents=True, exist_ok=True)
    combos = [
        (a, s) for a, s in combo_list()
        if (archs is None or a in archs) and (shapes is None or s in shapes)
    ]
    pending = list(combos)
    running: list[tuple] = []
    failures = 0
    while pending or running:
        while pending and len(running) < jobs:
            arch, shape = pending.pop(0)
            tag = f"{arch}__{shape}" + ("__multipod" if multi_pod else "")
            out = out_dir / f"{tag}.json"
            if out.exists():
                print(f"[skip-existing] {tag}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--out", str(out),
            ]
            if multi_pod:
                cmd.append("--multi-pod")
            proc = subprocess.Popen(
                cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True,
            )
            running.append((proc, tag, out, time.time()))
        done = [r for r in running if r[0].poll() is not None]
        for proc, tag, out, t0 in done:
            running.remove((proc, tag, out, t0))
            dt = time.time() - t0
            if proc.returncode == 0 and out.exists():
                rec = json.loads(out.read_text())
                r = rec.get("roofline", {})
                print(
                    f"[{rec['status']:>4}] {tag} ({dt:.0f}s) "
                    f"dom={r.get('dominant', '-')}"
                )
            else:
                failures += 1
                log = proc.stdout.read() if proc.stdout else ""
                (out_dir / f"{tag}.err").write_text(log)
                print(f"[FAIL] {tag} ({dt:.0f}s) -> {out_dir / (tag + '.err')}")
        time.sleep(1.0)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", nargs="*", help="subset filter for --all")
    ap.add_argument("--shapes", nargs="*", help="subset filter for --all")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", help="JSON output path (single combo)")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        n_fail = sweep(
            Path(args.out_dir), args.multi_pod, args.jobs,
            archs=args.archs, shapes=args.shapes,
        )
        sys.exit(1 if n_fail else 0)

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        record = run_one(args.arch, args.shape, args.multi_pod)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    text = json.dumps(record, indent=2)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(text)
    print(text)


if __name__ == "__main__":
    main()
