"""Intermittent execution substrate (paper §2, §7 — SONIC/Alpaca-style).

A *job* is an ordered list of atomic, idempotent *fragments* (pure functions
of a pytree state).  After each fragment commits, the state is snapshotted to
"FRAM" (a host-side store).  On power failure the MCU reboots and resumes
from the last committed snapshot; because fragments are pure JAX functions of
explicit state, re-execution is idempotent by construction — the invariant
``run with failures == run without failures`` is tested bit-exactly in
``tests/test_intermittent.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

import jax

from .energy import Capacitor, Harvester


@dataclass(frozen=True)
class Fragment:
    """An atomic execution quantum."""

    fn: Callable[[Any], Any]      # pure: state -> state
    time_s: float
    energy_j: float
    name: str = ""


@dataclass
class FRAMStore:
    """Non-volatile snapshot store (double-buffered commit)."""

    _slots: dict = field(default_factory=dict)
    commits: int = 0

    def commit(self, key: str, state: Any) -> None:
        # copy leaves so later in-place host mutation can't corrupt the
        # committed snapshot (FRAM write semantics)
        self._slots[key] = jax.tree.map(lambda a: a, state)
        self.commits += 1

    def restore(self, key: str) -> Any:
        return self._slots[key]

    def __contains__(self, key: str) -> bool:
        return key in self._slots


@dataclass
class RunStats:
    wall_time: float = 0.0
    busy_time: float = 0.0
    off_time: float = 0.0
    reboots: int = 0
    fragments_run: int = 0
    fragments_reexecuted: int = 0
    energy_used: float = 0.0


def run_intermittent(
    fragments: Sequence[Fragment],
    state: Any,
    harvester: Harvester,
    cap: Capacitor | None = None,
    *,
    fram: FRAMStore | None = None,
    job_key: str = "job",
    dt: float = 0.01,
    seed: int = 0,
    max_wall: float = 1e6,
) -> tuple[Any, RunStats]:
    """Execute ``fragments`` over ``state`` under intermittent power.

    A fragment executes only if the capacitor holds its energy cost; if power
    runs out mid-fragment the partial work is discarded (time wasted) and the
    fragment re-executes after recharge, resuming from the last committed
    FRAM snapshot.
    """
    cap = dataclasses.replace(cap) if cap is not None else Capacitor()
    if cap.energy_j == 0.0:
        cap.energy_j = cap.capacity_j
    fram = fram if fram is not None else FRAMStore()
    rng = np.random.default_rng(seed)
    stats = RunStats()

    n_slots = int(max_wall / harvester.slot_s) + 2
    events = harvester.sample_events(rng, min(n_slots, 10_000_000), init=1)

    def power_at(t: float) -> float:
        slot = min(int(t / harvester.slot_s), len(events) - 1)
        return float(events[slot]) * harvester.power_on

    fram.commit(job_key, state)  # initial checkpoint
    t = 0.0
    i = 0
    attempted = set()
    while i < len(fragments):
        frag = fragments[i]
        if cap.energy_j < frag.energy_j:
            # power failure: lose volatile progress, wait for recharge
            if (job_key, i) in attempted:
                stats.fragments_reexecuted += 1
            was_running = stats.busy_time > 0 or i > 0
            off_start = t
            while cap.energy_j < frag.energy_j and t < max_wall:
                cap.charge(power_at(t) * dt)
                t += dt
            stats.off_time += t - off_start
            if t >= max_wall:
                break
            if was_running:
                stats.reboots += 1
            state = fram.restore(job_key)  # resume from committed snapshot
            continue
        attempted.add((job_key, i))
        cap.charge(power_at(t) * frag.time_s)
        cap.discharge(frag.energy_j)
        state = frag.fn(state)
        t += frag.time_s
        stats.busy_time += frag.time_s
        stats.energy_used += frag.energy_j
        stats.fragments_run += 1
        fram.commit(job_key, state)
        i += 1

    stats.wall_time = t
    return state, stats


def fragment_unit(
    unit_fn: Callable[[Any], Any],
    n_fragments: int,
    time_s: float,
    energy_j: float,
    name: str = "unit",
) -> list[Fragment]:
    """Split one DNN unit into n atomic fragments.

    The first n-1 fragments are bookkeeping-sized slices of the unit's cost
    (in a real SONIC deployment these are loop tiles with idempotent
    loop-continuation); the final fragment applies the actual (pure) unit
    function.  Costs are spread evenly, matching the paper's EnergyTrace++
    per-fragment accounting.
    """
    frags = [
        Fragment(lambda s: s, time_s / n_fragments, energy_j / n_fragments,
                 f"{name}/f{i}")
        for i in range(n_fragments - 1)
    ]
    frags.append(
        Fragment(unit_fn, time_s / n_fragments, energy_j / n_fragments,
                 f"{name}/commit")
    )
    return frags
