"""The unified per-device step core: ONE implementation of the scheduler
transition shared by every simulation frontend.

Everything that happens to a single intermittently-powered device in one
fixed timestep — release/admit, expiry, priority pick via
:mod:`repro.core.policy`, fragment execution, capacitor charge/discharge,
metric accumulation — lives here as pure functions over two pytrees:

* :class:`StepParams` — immutable per-device configuration (task tables,
  harvester event stream, scheduler scalars).  No device axis; batching is
  the caller's job.
* :class:`DeviceCarry` — the mutable simulation state threaded through
  ``(params, carry, t) -> carry`` transitions: capacitor energy, the
  fixed-size job queue as parallel arrays, metric accumulators.

Three frontends consume the same functions:

* :func:`repro.core.scheduler.simulate_stepped` — the scalar discretized
  frontend: one device, one ``lax.scan``, no ``vmap``.
* :mod:`repro.fleet.simulator` — ``jax.vmap`` adds the device axis and
  ``lax.scan`` the time axis (optionally chunked into segments with a host
  hook between chunks, the substrate for in-trajectory online adaptation).
* :mod:`repro.kernels.fleet_priority` — the Pallas kernel evaluates the
  pick stage on VMEM tiles; its post-score selection semantics are
  :func:`select_and_charge`, imported from here so the in-tile math can
  never drift from the reference.

Because the fleet path is literally ``vmap`` of these functions, the
scalar-stepped and fleet paths are *bit-exact* on the shared clock — the
parity harness in ``tests/test_parity.py`` asserts exact equality, not
calibrated tolerances.

Shapes use ``K`` tasks per device, ``Q`` queue slots, ``U`` units per job,
``J`` jobs per task, ``S`` harvester slots.  Static (python) dimensions and
step sizes live in the hashable :class:`StepStatics` (a ``jax.jit`` static
argument).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from . import policy as P

_F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Batched indexing helpers with a frontend-selected lowering.
#
# The stages below run in three very different execution contexts: scalar
# (one device, no batch axes), under ``vmap`` (fleet axis stripped), and
# *inside a Pallas kernel tile* with a ``(block_d,)`` device axis attached to
# every leaf (repro.kernels.fleet_step).  Mosaic supports neither gathers nor
# 1-D iota, so inside the kernel every table lookup is phrased as a one-hot
# iota contraction over the trailing axis; on the XLA frontends the same
# lookup lowers to ``take_along_axis`` (a cheap batched gather — the one-hot
# form is ~10x slower there: three passes over Q*N per lookup vs Q reads).
# The two lowerings are bit-exact against each other: exactly one lane is
# hot and ``x + 0.0 == x`` / ``x | False == x``, and every call site with a
# possibly-invalid index (-1 sentinels) masks the looked-up value
# downstream.  The transition logic itself is written ONCE; only this
# helper switches, under :func:`onehot_lowering` (entered by the fused
# kernel body around its time loop).  All reductions and index reads use
# trailing-axis (-1/-2) conventions so arbitrary leading batch axes ride
# along untouched.
# --------------------------------------------------------------------------- #

_ONEHOT_ONLY = False


@contextlib.contextmanager
def onehot_lowering():
    """Trace-time switch: lower table lookups as one-hot iota contractions
    (Mosaic kernels — no gather support) instead of ``take_along_axis``."""
    global _ONEHOT_ONLY
    prev = _ONEHOT_ONLY
    _ONEHOT_ONLY = True
    try:
        yield
    finally:
        _ONEHOT_ONLY = prev


def _oh_eq(idx, n: int):
    """One-hot of ``idx`` over a new trailing axis of size ``n`` (bool)."""
    iota = lax.broadcasted_iota(jnp.int32, idx.shape + (n,), idx.ndim)
    return iota == idx[..., None]


def _take(table, idx):
    """``table[..., idx]`` over the trailing axis.

    ``table``: ``(..., N)``; ``idx``: int ``(..., Q)`` with the same leading
    axes -> ``(..., Q)`` in ``table.dtype``.  Exact: one hot lane (one-hot
    lowering) / clamped gather (XLA lowering); call sites mask any slot
    whose index can be out of range.
    """
    if not _ONEHOT_ONLY:
        lead = jnp.broadcast_shapes(table.shape[:-1], idx.shape[:-1])
        return jnp.take_along_axis(
            jnp.broadcast_to(table, lead + table.shape[-1:]),
            jnp.broadcast_to(idx, lead + idx.shape[-1:]),
            axis=-1, mode="clip")
    oh = _oh_eq(idx, table.shape[-1])          # (..., Q, N)
    t = table[..., None, :]                    # (..., 1, N)
    if table.dtype == jnp.bool_:
        return jnp.any(oh & t, axis=-1)
    return jnp.sum(jnp.where(oh, t, jnp.zeros((), table.dtype)), axis=-1)


def _take1(table, idx):
    """``table[..., idx]`` for a single per-device index."""
    return _take(table, idx[..., None])[..., 0]


def take_rows(table, idx):
    """``table[..., idx, :]`` — one row of the second-to-last axis per index.

    ``table``: ``(..., N, M)``; ``idx``: int ``(...,)`` with leading axes
    broadcastable against the table's -> ``(..., M)`` in ``table.dtype``.
    The live-serving transition uses this to pull one device's feature /
    centroid row out of the flattened ``(K*J*U, ...)`` tables.  Same
    lowering contract as :func:`_take`: ``take_along_axis`` (clamped) on
    the XLA frontends, a one-hot iota contraction over the row axis inside
    Mosaic kernels — bit-exact against each other (one hot lane,
    ``x + 0 == x``).  A 2-D table with batched indices lowers as a plain
    ``jnp.take`` so the operand is gathered directly instead of being
    broadcast across the batch.
    """
    if not _ONEHOT_ONLY:
        n = table.shape[-2]
        if table.ndim == 2:
            return jnp.take(table, jnp.clip(idx, 0, n - 1), axis=0)
        lead = jnp.broadcast_shapes(table.shape[:-2], idx.shape)
        t = jnp.broadcast_to(table, lead + table.shape[-2:])
        ix = jnp.broadcast_to(idx[..., None, None],
                              lead + (1,) + table.shape[-1:])
        return jnp.take_along_axis(t, ix, axis=-2, mode="clip")[..., 0, :]
    oh = _oh_eq(idx, table.shape[-2])[..., None]       # (..., N, 1)
    if table.dtype == jnp.bool_:
        return jnp.any(oh & table, axis=-2)
    return jnp.sum(jnp.where(oh, table, jnp.zeros((), table.dtype)),
                   axis=-2)


def _flat2(t):
    """Collapse the two trailing axes (e.g. (..., K, U) -> (..., K*U))."""
    return t.reshape(t.shape[:-2] + (t.shape[-2] * t.shape[-1],))


def _flat3(t):
    """Collapse the three trailing axes ((..., K, J, U) -> (..., K*J*U))."""
    return t.reshape(
        t.shape[:-3] + (t.shape[-3] * t.shape[-2] * t.shape[-1],))


@dataclasses.dataclass(frozen=True)
class StepStatics:
    """Hashable static configuration (jit static argument)."""

    queue_size: int = 3
    dt: float = 0.025            # fixed timestep (s); keep <= min unit_time
    horizon: float = 600.0
    slot_s: float = 1.0          # harvester slot length (s)

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon / self.dt))


class StepParams(NamedTuple):
    """Immutable per-device configuration arrays.

    The shapes below are the *per-device* view consumed by the step
    functions; the fleet path stacks a leading ``D`` (device) axis on every
    leaf (see :class:`repro.fleet.state.FleetConfig`, an alias of this
    class) and ``vmap`` strips it back off.
    """

    # scheduler / energy scalars
    policy: jax.Array        # int32, repro.core.policy.POLICY_IDS
    imprecise: jax.Array     # bool: early exit enabled (zygarde, edf-m)
    is_edfm: jax.Array       # bool: EDF-M never runs optional units
    eta: jax.Array           # f32
    alpha: jax.Array         # f32, 1 / max relative deadline over the task set
    beta: jax.Array          # f32
    persistent: jax.Array    # bool: use zeta (Eq. 6) instead of zeta_I (Eq. 7)
    capacity: jax.Array      # f32, usable capacitor energy (J)
    start_energy: jax.Array  # f32; negative = cold-boot dead-zone debt
    e_man: jax.Array         # f32, minimum energy to run a fragment
    e_opt: jax.Array         # f32, Eq. 7 optional-unit energy threshold
    power_on: jax.Array      # f32, harvester power in the ON state (W)
    # timekeeping: deterministic linear clock drift (fleet-path CHRT model;
    # the scalar CHRTClock's random per-read offset has no batched
    # equivalent, so the step core models the *accumulated* error as a rate:
    # t_read = t * (1 + clock_drift))
    clock_drift: jax.Array   # f32; 0 = exact RTC
    # tunable per-unit utility-test thresholds (repro.adapt): when
    # use_exit_thr is set the utility test compares the live margin against
    # exit_thr instead of the precomputed `passes` table.  These are the
    # fields in-trajectory online adaptation rewrites between segments.
    use_exit_thr: jax.Array  # bool
    exit_thr: jax.Array      # (K, U) f32
    # task-set table, (K,): K periodic task streams per device
    period: jax.Array        # f32
    rel_deadline: jax.Array  # f32, relative deadline
    fragments: jax.Array     # f32, fragments per unit
    n_units: jax.Array       # int32, <= U (live units of each task)
    n_releases: jax.Array    # int32, jobs released within the horizon (<= J)
    # per-task workload tables
    unit_time: jax.Array     # (K, U) f32, seconds per unit
    unit_energy: jax.Array   # (K, U) f32, joules per unit
    margins: jax.Array       # (K, J, U) f32, utility-test margins
    passes: jax.Array        # (K, J, U) bool, utility test passes after unit
    correct: jax.Array       # (K, J, U) bool, unit prediction correct
    # harvester event stream, (S,) f32 — 0/1 flags or fractional amplitudes
    events: jax.Array

    @property
    def n_devices(self) -> int:
        """Fleet-level accessor (leading device axis stacked on every leaf)."""
        return self.policy.shape[0]

    @property
    def n_tasks(self) -> int:
        return self.period.shape[-1]


class DeviceCarry(NamedTuple):
    """Mutable per-device simulation state (no device axis; vmap adds it)."""

    energy: jax.Array        # f32 scalar; < 0 while paying cold-boot debt
    was_off: jax.Array       # bool scalar: last activity was a power-down
    next_rel: jax.Array      # int32 (K,): next job index to release, per task
    # round-robin task cursor: the task id the rr policy serves next (the
    # scalar simulator's rr_cursor); unused by the other policies
    rr_cursor: jax.Array     # int32 scalar
    # limited preemption (paper §4.1): once a unit starts, it runs to its
    # boundary — the scheduler only re-picks between units.  lock_job guards
    # against the slot being recycled for a new job while locked.
    lock_slot: jax.Array     # int32 scalar: queue slot mid-unit, -1 if none
    lock_job: jax.Array      # int32 scalar: job id the lock belongs to
    # fixed-size job queue, (Q,) each
    q_active: jax.Array      # bool
    q_release: jax.Array     # f32
    q_deadline: jax.Array    # f32 (absolute)
    q_task: jax.Array        # int32, index into the (K, ...) task tables
    q_job: jax.Array         # int32, index into the (K, J, U) profile tables
    q_unit: jax.Array        # int32, next unit to execute
    q_time_left: jax.Array   # f32, seconds left in the current unit
    q_exited: jax.Array      # int32, unit where the utility test passed (-1)
    q_last_pred: jax.Array   # int32, deepest executed unit (-1)
    q_mand_time: jax.Array   # f32, mandatory-completion time (-1)
    # live-profile registers (repro.serve.fleet_engine): when the step runs
    # in ``live`` mode the margins/passes/correct *tables* are never read —
    # the serving engine classifies the just-executed unit against its
    # evolving centroid bank and injects the outcome here instead.  Replay
    # mode neither reads nor writes them.
    q_margin: jax.Array      # f32, live margin after the last executed unit
    q_correct: jax.Array     # bool, live prediction correct at last unit
    q_apass: jax.Array       # bool, utility test has passed at some unit
    # metric accumulators, (K,) per task (mirror scheduler.SimResult.task_*)
    m_scheduled: jax.Array   # int32
    m_correct: jax.Array     # int32
    m_misses: jax.Array      # int32
    m_units: jax.Array       # int32
    m_optional: jax.Array    # int32
    # device-level energy/time accumulators (scalars)
    m_reboots: jax.Array     # int32
    m_busy: jax.Array        # f32
    m_idle: jax.Array        # f32
    m_wasted: jax.Array      # f32


class StepResult(NamedTuple):
    """Finalized metrics — SimResult-shaped, per device.

    With the fleet's stacked device axis, aggregate fields are ``(D,)``
    (summed over the task set, matching the scalar ``SimResult`` totals) and
    the ``task_*`` fields break the job counters down per task as ``(D, K)``
    arrays (see :class:`repro.fleet.state.FleetResult`, an alias).
    """

    released: jax.Array
    scheduled: jax.Array
    correct: jax.Array
    deadline_misses: jax.Array
    units_executed: jax.Array
    optional_units: jax.Array
    busy_time: jax.Array
    idle_no_energy: jax.Array
    reboots: jax.Array
    wasted_reexec: jax.Array
    sim_time: jax.Array
    # per-task breakdowns, (K,) / fleet (D, K)
    task_released: jax.Array
    task_scheduled: jax.Array
    task_correct: jax.Array
    task_misses: jax.Array
    task_units: jax.Array
    task_optional: jax.Array

    def device(self, i: int) -> dict:
        """Metrics of device ``i`` as a python dict (SimResult field names);
        scalar metrics become python numbers, per-task rows become lists."""
        out = {}
        for k, v in self._asdict().items():
            row = v[i]
            out[k] = row.item() if row.ndim == 0 else row.tolist()
        return out

    def as_dict(self) -> dict:
        """JSON-serializable dict mirroring ``SimResult.as_dict``: scalar
        leaves become python numbers, array leaves (the ``(D,)`` metric
        columns and ``(D, K)`` ``task_*`` breakdowns) become nested lists —
        what ``benchmarks/run.py`` writes into ``BENCH_<name>.json``."""
        out = {}
        for k, v in self._asdict().items():
            a = np.asarray(v)
            out[k] = a.item() if a.ndim == 0 else a.tolist()
        return out


def init_carry(params: StepParams, statics: StepStatics) -> DeviceCarry:
    """Initial carry for one device (call under vmap for a fleet)."""
    q = statics.queue_size
    k = params.period.shape[0]   # per-device view: task axis is leading
    f32 = jnp.float32
    i32 = jnp.int32
    zero_i = jnp.zeros((), i32)
    zeros_k = jnp.zeros((k,), i32)
    return DeviceCarry(
        energy=params.start_energy.astype(f32),
        was_off=jnp.zeros((), bool),
        next_rel=zeros_k,
        rr_cursor=zero_i,
        lock_slot=jnp.full((), -1, i32),
        lock_job=jnp.full((), -1, i32),
        q_active=jnp.zeros((q,), bool),
        q_release=jnp.zeros((q,), f32),
        q_deadline=jnp.zeros((q,), f32),
        q_task=jnp.zeros((q,), i32),
        q_job=jnp.zeros((q,), i32),
        q_unit=jnp.zeros((q,), i32),
        q_time_left=jnp.zeros((q,), f32),
        q_exited=jnp.full((q,), -1, i32),
        q_last_pred=jnp.full((q,), -1, i32),
        q_mand_time=jnp.full((q,), -1.0, f32),
        q_margin=jnp.zeros((q,), f32),
        q_correct=jnp.zeros((q,), bool),
        q_apass=jnp.zeros((q,), bool),
        m_scheduled=zeros_k,
        m_correct=zeros_k,
        m_misses=zeros_k,
        m_units=zeros_k,
        m_optional=zeros_k,
        m_reboots=zero_i,
        m_busy=jnp.zeros((), f32),
        m_idle=jnp.zeros((), f32),
        m_wasted=jnp.zeros((), f32),
    )


# --------------------------------------------------------------------------- #
# Transition stages.
# --------------------------------------------------------------------------- #


def finish_counts(params: StepParams, st: DeviceCarry, mask: jax.Array,
                  live: bool = False):
    """Tally (scheduled, correct, missed) for the queue slots in ``mask``,
    broken down per task — ``(..., K)`` int arrays each.  ``live`` reads the
    slot's live correctness register instead of the replay table.

    Batch-polymorphic and gather-free: leading axes on every leaf (vmap's
    device axis, or a Pallas tile's block axis) ride along untouched."""
    n_tasks = params.period.shape[-1]
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    sched = mask & (st.q_mand_time >= 0.0) & (st.q_mand_time <= st.q_deadline)
    if live:
        corr = sched & (st.q_last_pred >= 0) & st.q_correct
    else:
        n_jobs = params.margins.shape[-2]
        n_u = params.margins.shape[-1]
        job = jnp.clip(st.q_job, 0, n_jobs - 1)
        lp = jnp.clip(st.q_last_pred, 0, n_u - 1)
        corr = sched & (st.q_last_pred >= 0) & _take(
            _flat3(params.correct), (tk * n_jobs + job) * n_u + lp)
    miss = mask & ~sched
    onehot = _oh_eq(tk, n_tasks)                           # (..., Q, K)

    def per_task(m):
        return jnp.sum(m[..., None] & onehot, axis=-2)

    return per_task(sched), per_task(corr), per_task(miss)


class StepTrace(NamedTuple):
    """Per-step event descriptors of ONE device transition (telemetry's
    in-scan emission; see :mod:`repro.telemetry.trace` for the decoding).

    Every retirement a step can produce flows through exactly one of three
    channels, each bounded to at most one event per task (admission order
    admits one release per task per step; same-task deadlines are spaced a
    full period apart, far more than ``dt`` at realistic ppm-scale clock
    drift) — so fixed-size ``(K,)`` words capture a step losslessly and the
    telemetry reduction never needs the ``(Q,)`` queue axis after the scan.

    Word packing (0 = no event): ``exited + 2`` in bits 0-5, the task id in
    bits 6-10 where present, ``job + 1`` in the bits above.  The ``*_dl``
    floats carry the retiring slot's deadline *register* (so slack needs no
    reconstruction); garbage where the matching word is 0.
    """

    adm: jax.Array       # (K,) i32: insert | dropped << 1 | evict << 2
    evict: jax.Array     # (K,) i32: victim (job+1)<<11 | task<<6 | exited+2
    evict_dl: jax.Array  # (K,) f32: victim q_deadline
    expire: jax.Array    # (K,) i32: expired (job+1)<<6 | exited+2
    expire_dl: jax.Array  # (K,) f32: expired-slot q_deadline
    complete: jax.Array  # i32: retiring job_done (job+1)<<11|task<<6|exited+2
    complete_dl: jax.Array  # f32: completed slot q_deadline


def admit(params: StepParams, st: DeviceCarry, t, statics: StepStatics,
          live: bool = False, trace: bool = False):
    """Admit at most one released job per task (the builder asserts
    dt < period).  The static python loop over the task axis admits in task
    order — the same order the scalar path's stable release sort yields for
    simultaneous releases.

    ``trace`` (python-level, so the plain path's program is untouched)
    additionally returns the admission/eviction descriptor words of
    :class:`StepTrace` — read from registers the stage already computed.
    """
    q = statics.queue_size
    n_tasks = params.period.shape[-1]
    k_iota = lax.broadcasted_iota(jnp.int32, st.next_rel.shape,
                                  st.next_rel.ndim - 1)       # (..., K)
    tr_adm, tr_evict, tr_evict_dl = [], [], []
    for k in range(n_tasks):
        nr_k = st.next_rel[..., k]
        rel_time = nr_k.astype(_F32) * params.period[..., k]
        releasing = (nr_k < params.n_releases[..., k]) & (rel_time <= t)

        free = ~st.q_active
        has_free = jnp.any(free, axis=-1)
        # overflow: evict the earliest-deadline job whose mandatory part is
        # done (optional-only work yields to the new arrival — mandatory
        # first, §5.2)
        evictable = st.q_active & (st.q_exited >= 0)
        has_evict = jnp.any(evictable, axis=-1)
        victim = jnp.argmin(jnp.where(evictable, st.q_deadline, jnp.inf),
                            axis=-1)
        evict = releasing & ~has_free & has_evict
        vmask = evict[..., None] & _oh_eq(victim, q)
        d_sched, d_corr, d_miss = finish_counts(params, st, vmask, live)

        insert = releasing & (has_free | has_evict)
        slot = jnp.where(has_free, jnp.argmax(free, axis=-1), victim)
        ins = insert[..., None] & _oh_eq(slot, q)
        dropped = releasing & ~insert   # queue overflow, nothing evictable
        k_hot = k_iota == k

        if trace:
            # the victim's pre-step registers (a just-admitted job has
            # q_exited == -1, so it is never evictable — victims always
            # hold jobs that were queued before this step began).  Trace
            # emission is per-device only (telemetry wraps it in vmap).
            tr_adm.append(insert.astype(jnp.int32)
                          + (dropped.astype(jnp.int32) << 1)
                          + (evict.astype(jnp.int32) << 2))
            tr_evict.append(jnp.where(
                evict,
                ((st.q_job[victim] + 1) << 11) + (st.q_task[victim] << 6)
                + (st.q_exited[victim] + 2), 0).astype(jnp.int32))
            tr_evict_dl.append(st.q_deadline[victim].astype(_F32))

        st = st._replace(
            next_rel=st.next_rel + (k_hot & releasing[..., None]),
            q_active=(st.q_active & ~vmask) | ins,
            q_release=jnp.where(ins, rel_time[..., None], st.q_release),
            q_deadline=jnp.where(
                ins, (rel_time + params.rel_deadline[..., k])[..., None],
                st.q_deadline),
            q_task=jnp.where(ins, k, st.q_task),
            q_job=jnp.where(ins, nr_k[..., None], st.q_job),
            q_unit=jnp.where(ins, 0, st.q_unit),
            q_time_left=jnp.where(ins, params.unit_time[..., k, 0][..., None],
                                  st.q_time_left),
            q_exited=jnp.where(ins, -1, st.q_exited),
            q_last_pred=jnp.where(ins, -1, st.q_last_pred),
            q_mand_time=jnp.where(ins, -1.0, st.q_mand_time),
            q_margin=jnp.where(ins, 0.0, st.q_margin),
            q_correct=jnp.where(ins, False, st.q_correct),
            q_apass=jnp.where(ins, False, st.q_apass),
            m_scheduled=st.m_scheduled + d_sched,
            m_correct=st.m_correct + d_corr,
            m_misses=st.m_misses + d_miss + (dropped[..., None] & k_hot),
        )
    if trace:
        return st, (jnp.stack(tr_adm), jnp.stack(tr_evict),
                    jnp.stack(tr_evict_dl))
    return st


def drop_expired(params: StepParams, st: DeviceCarry, t,
                 live: bool = False, trace: bool = False,
                 q_active_pre=None):
    # the device expires jobs against its *drifting* clock (fleet CHRT
    # model): a fast clock (drift > 0) drops jobs before their true deadline
    t_read = t * (1.0 + params.clock_drift)
    expired = st.q_active & (t_read[..., None] >= st.q_deadline)
    d_sched, d_corr, d_miss = finish_counts(params, st, expired, live)
    new = st._replace(
        q_active=st.q_active & ~expired,
        m_scheduled=st.m_scheduled + d_sched,
        m_correct=st.m_correct + d_corr,
        m_misses=st.m_misses + d_miss,
    )
    if trace:
        # at most one same-task deadline crosses per dt (deadlines are a
        # period apart), so a single packed word per task is lossless; the
        # q_active_pre guard drops jobs admitted this very step, which the
        # delta-view reference (step_events) never counts as retirements
        # (per-device only, like every trace branch)
        n_tasks = params.period.shape[-1]
        exp = expired if q_active_pre is None else expired & q_active_pre
        word = ((st.q_job + 1) << 6) + (st.q_exited + 2)
        onehot = exp[:, None] & (st.q_task[:, None]
                                 == jnp.arange(n_tasks)[None, :])
        tr_exp = jnp.sum(
            jnp.where(onehot, word[:, None], 0), axis=0).astype(jnp.int32)
        tr_exp_dl = jnp.sum(
            jnp.where(onehot, st.q_deadline[:, None], 0.0),
            axis=0).astype(_F32)
        return new, (tr_exp, tr_exp_dl)
    return new


def pick_inputs(params: StepParams, st: DeviceCarry, t,
                statics: StepStatics, live: bool = False):
    """Per-slot priority/energy ingredients shared by the jnp pick and the
    Pallas kernel: each slot gathers its own task's row of the (K, U) /
    (K, J, U) tables before the shared priority math runs.  ``live`` swaps
    the replayed utility margin for the slot's live margin register."""
    n_tasks = params.period.shape[-1]
    n_u = params.unit_time.shape[-1]
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    u = jnp.clip(st.q_unit, 0, n_u - 1)
    unit_t = _take(_flat2(params.unit_time), tk * n_u + u)
    unit_e = _take(_flat2(params.unit_energy), tk * n_u + u)
    gate_e = jnp.maximum(unit_e / _take(params.fragments, tk),
                         params.e_man[..., None])
    drain = unit_e * (statics.dt / unit_t)
    if live:
        margin = st.q_margin
    else:
        n_jobs = params.margins.shape[-2]
        job = jnp.clip(st.q_job, 0, n_jobs - 1)
        lp = jnp.clip(st.q_last_pred, 0, params.margins.shape[-1] - 1)
        margin = _take(_flat3(params.margins),
                       (tk * n_jobs + job) * params.margins.shape[-1] + lp)
    utility = jnp.where(st.q_last_pred >= 0, margin, 0.0)
    mandatory = st.q_exited < 0
    laxity = st.q_deadline - t
    n_slots = params.events.shape[-1]
    slot = jnp.minimum((t / statics.slot_s).astype(jnp.int32), n_slots - 1)
    amp = _take1(params.events, slot)
    charge = amp * params.power_on * statics.dt
    # limited preemption: a slot mid-unit is forced until the unit boundary
    # (unless it expired or its slot was recycled for a newer job)
    ls = jnp.clip(st.lock_slot, 0, st.q_active.shape[-1] - 1)
    locked = ((st.lock_slot >= 0) & _take1(st.q_active, ls)
              & (_take1(st.q_job, ls) == st.lock_job))
    forced = jnp.where(locked, ls, -1).astype(jnp.int32)
    # rr task rotation: distance of each slot's task from the rr cursor
    # (identically 0 when K == 1, keeping the FIFO key bit-identical)
    task_rank = jnp.mod(tk - st.rr_cursor[..., None], n_tasks).astype(_F32)
    return (laxity, utility, mandatory, gate_e, drain, charge, forced,
            task_rank)


def select_and_charge(scores, threshold, forced, energy, charge, capacity,
                      gate_e, drain):
    """Post-score selection + fused capacitor update — the shared reference
    semantics of the pick stage.

    Reduces over the trailing (queue) axis; leading axes batch.  The jnp
    pick calls this with ``(Q,)`` scores and scalar per-device operands, the
    Pallas ``fleet_priority`` kernel with ``(block_d, Q)`` VMEM tiles — both
    therefore apply the exact same argmax / threshold / energy-gate math.
    Uses only iota/arithmetic (no gathers) so the body is Mosaic-safe.
    """
    sel = jnp.where(forced >= 0, forced,
                    jnp.argmax(scores, axis=-1)).astype(jnp.int32)
    picked = (forced >= 0) | (jnp.max(scores, axis=-1) > threshold)
    # lane-select the chosen slot's energy gate / drain (iota keeps the
    # expression gather-free inside Pallas tiles)
    onehot = (lax.broadcasted_iota(jnp.int32, scores.shape, scores.ndim - 1)
              == sel[..., None])
    gate_sel = jnp.sum(jnp.where(onehot, gate_e, 0.0), axis=-1)
    drain_sel = jnp.sum(jnp.where(onehot, drain, 0.0), axis=-1)
    run = picked & (energy >= gate_sel)
    e_new = jnp.minimum(energy + charge, capacity) - run * drain_sel
    return sel, picked, run, e_new


def pick(params: StepParams, st: DeviceCarry, t, statics: StepStatics,
         live: bool = False):
    """Priority-argmax + fused capacitor charge/discharge (pure-jnp path).

    Per-device operands enter ``policy_scores`` as ``x[..., None]`` so they
    broadcast against the ``(..., Q)`` queue-shaped operands regardless of
    leading batch axes; the threshold comes back ``(..., 1)`` and is
    squeezed for :func:`select_and_charge`'s trailing-axis reduction."""
    (laxity, utility, mandatory, gate_e, drain, charge, forced,
     task_rank) = pick_inputs(params, st, t, statics, live)
    scores, thr = P.policy_scores(
        params.policy[..., None], st.q_active, laxity, st.q_release,
        utility, mandatory, params.alpha[..., None], params.beta[..., None],
        params.eta[..., None], st.energy[..., None], params.e_opt[..., None],
        params.persistent[..., None], task_rank)
    return select_and_charge(scores, thr[..., 0], forced, st.energy, charge,
                             params.capacity, gate_e, drain)


def apply_step(params: StepParams, st: DeviceCarry, t, sel, picked, run,
               e_new, statics: StepStatics, live: bool = False,
               outcomes=None, trace: bool = False, q_active_pre=None,
               t_end=None):
    """Advance the selected job by dt; handle unit/job completion.

    ``t_end`` is the step's end-of-interval clock.  Callers that know the
    integer step index should pass ``(i + 1) * dt`` — a single correctly-
    rounded multiply, bit-identical in every execution context.  The
    ``t + dt`` fallback is a mul feeding an add, which compilers may
    contract into a single-rounding FMA *differently per program* (the
    fused Pallas kernel vs the vmap scan), drifting ``q_mand_time`` by
    1 ulp and breaking carry bit-parity.

    ``live``/``outcomes`` form the live-profile hook
    (:mod:`repro.serve.fleet_engine`): ``outcomes`` is a
    ``(margin, passed, correct)`` scalar triple for the *selected* slot's
    just-completing unit, computed by classifying the real model features
    against the engine's evolving centroid bank.  At most one slot
    completes per step (the ``oh`` mask), so scalars suffice; the values
    land in the ``q_margin``/``q_correct`` registers and replace every
    read of the ``margins``/``passes``/``correct`` replay tables.  With
    ``live=False`` (and ``outcomes=None``) the replay path is untouched
    and bit-identical to before the hook existed.
    """
    q = statics.queue_size
    n_tasks = params.period.shape[-1]
    n_u = params.unit_time.shape[-1]
    u_max = n_u - 1
    oh = _oh_eq(sel, q)
    tk = jnp.clip(st.q_task, 0, n_tasks - 1)
    tk_sel = _take1(tk, sel)

    u_sel = jnp.clip(_take1(st.q_unit, sel), 0, u_max)
    frag_t = (_take1(_flat2(params.unit_time), tk_sel * n_u + u_sel)
              / _take1(params.fragments, tk_sel))

    # power-down / reboot bookkeeping (the initial cold boot counts wasted
    # half-fragment re-execution but not a reboot — matches the scalar path)
    reboot = run & st.was_off
    was_off = jnp.where(run, False, jnp.where(picked, True, st.was_off))
    idle_inc = jnp.where(picked & ~run, statics.dt, 0.0)

    # execute dt of the selected unit
    time_left = st.q_time_left - jnp.where(run[..., None] & oh,
                                           statics.dt, 0.0)
    complete = run[..., None] & oh & (time_left <= statics.dt * 1e-3)

    u = jnp.clip(st.q_unit, 0, u_max)
    job = jnp.clip(st.q_job, 0, params.passes.shape[-2] - 1)
    n_units = _take(params.n_units, tk)        # (..., Q) per-slot task depth
    next_u = jnp.clip(st.q_unit + 1, 0, u_max)
    done_any = jnp.any(complete, axis=-1)
    mandatory = st.q_exited < 0

    last_pred = jnp.where(complete, u, st.q_last_pred)
    unit = jnp.where(complete, st.q_unit + 1, st.q_unit)
    time_left = jnp.where(
        complete, _take(_flat2(params.unit_time), tk * n_u + next_u),
        time_left)

    # utility test at the unit boundary (imprecise policies only); tuned
    # per-unit thresholds (repro.adapt) re-evaluate the test against the
    # live margin, otherwise the precomputed passes table applies
    if live:
        margin_sel, passed_sel, correct_sel = outcomes
        if jnp.ndim(passed_sel) == complete.ndim - 1:
            # batch-polymorphic: outcomes carry the leading device/tile
            # axes but not the queue axis — expand so the broadcasts below
            # align the right way up (value-identical on the vmap path,
            # where the outcomes are rank-0 scalars)
            margin_sel = margin_sel[..., None]
            passed_sel = passed_sel[..., None]
            correct_sel = correct_sel[..., None]
        passed = jnp.broadcast_to(passed_sel, complete.shape)
        q_margin = jnp.where(complete, margin_sel, st.q_margin)
        q_correct = jnp.where(complete, correct_sel, st.q_correct)
        st = st._replace(q_margin=q_margin, q_correct=q_correct)
    else:
        n_jobs = params.margins.shape[-2]
        kju = (tk * n_jobs + job) * n_u + u
        passed = jnp.where(
            params.use_exit_thr[..., None],
            P.exit_test(_take(_flat3(params.margins), kju),
                        _take(_flat2(params.exit_thr), tk * n_u + u)),
            _take(_flat3(params.passes), kju))
    exit_now = (complete & params.imprecise[..., None]
                & (st.q_exited < 0) & passed)
    exited = jnp.where(exit_now, u, st.q_exited)
    # never-confident full execution => the whole DNN was mandatory
    full_mand = complete & (exited < 0) & (st.q_unit + 1 >= n_units)
    exited = jnp.where(full_mand, n_units - 1, exited)
    if t_end is None:
        t_end = t + statics.dt
    mand_time = jnp.where(exit_now | full_mand, t_end, st.q_mand_time)

    job_done = complete & (
        (st.q_unit + 1 >= n_units)
        | (params.is_edfm[..., None] & (exited >= 0))
    )
    st_done = st._replace(q_last_pred=last_pred, q_mand_time=mand_time)
    d_sched, d_corr, d_miss = finish_counts(params, st_done, job_done, live)

    # hold the lock while the unit is in progress (including power-gated
    # waits, like the scalar fragment loop); release at the unit boundary
    lock_on = picked & ~done_any
    # rr task rotation advances past the task whose unit just completed —
    # the unit-boundary analogue of the scalar rotation at each pick
    is_rr = params.policy == P.POLICY_IDS["rr"]
    rr_cursor = jnp.where(is_rr & done_any, jnp.mod(tk_sel + 1, n_tasks),
                          st.rr_cursor).astype(jnp.int32)
    sel_hot = _oh_eq(tk_sel, n_tasks)
    if trace:
        # only the selected slot can complete, so one scalar word per step
        # covers the job_done channel; the q_active_pre guard excludes a
        # job admitted and finished within the same step (no q_active flag
        # change, so the delta-view reference never sees it retire).
        # exited >= 0 always holds at job_done (full_mand backfills it).
        jd_sel = job_done[sel]
        if q_active_pre is not None:
            jd_sel = jd_sel & q_active_pre[sel]
        tr_comp = jnp.where(
            jd_sel,
            ((st.q_job[sel] + 1) << 11) + (tk_sel << 6) + (exited[sel] + 2),
            0).astype(jnp.int32)
        tr_comp_dl = st.q_deadline[sel].astype(_F32)
    out = st._replace(
        energy=e_new,
        was_off=was_off,
        rr_cursor=rr_cursor,
        lock_slot=jnp.where(lock_on, sel, -1).astype(jnp.int32),
        lock_job=jnp.where(lock_on, _take1(st.q_job, sel),
                           -1).astype(jnp.int32),
        q_active=st.q_active & ~job_done,
        q_unit=unit,
        q_time_left=time_left,
        q_exited=exited,
        q_last_pred=last_pred,
        q_mand_time=mand_time,
        m_scheduled=st.m_scheduled + d_sched,
        m_correct=st.m_correct + d_corr,
        m_misses=st.m_misses + d_miss,
        m_units=st.m_units + (done_any[..., None] & sel_hot),
        m_optional=st.m_optional + (
            (done_any & ~_take1(mandatory, sel))[..., None] & sel_hot),
        m_reboots=st.m_reboots + (reboot & (st.m_busy > 0)),
        m_busy=st.m_busy + jnp.where(run, statics.dt, 0.0),
        m_idle=st.m_idle + idle_inc,
        m_wasted=st.m_wasted + jnp.where(reboot, 0.5 * frag_t, 0.0),
    )
    if trace:
        return out, (tr_comp, tr_comp_dl)
    return out


def device_step(params: StepParams, st: DeviceCarry, t,
                statics: StepStatics, trace: bool = False, t_end=None):
    """One full per-device transition: admit -> expire -> pick -> apply.

    ``trace=True`` (a python flag: the plain program is byte-identical)
    additionally returns the step's :class:`StepTrace` descriptor words —
    the in-scan fold :mod:`repro.telemetry.trace` consumes them.
    ``t_end`` forwards to :func:`apply_step` (see there for why callers
    with the integer step index should pass ``(i + 1) * dt``).
    """
    if trace:
        act0 = st.q_active
        st, (tr_adm, tr_ev, tr_ev_dl) = admit(params, st, t, statics,
                                              trace=True)
        st, (tr_exp, tr_exp_dl) = drop_expired(params, st, t, trace=True,
                                               q_active_pre=act0)
        sel, picked, run, e_new = pick(params, st, t, statics)
        st, (tr_comp, tr_comp_dl) = apply_step(
            params, st, t, sel, picked, run, e_new, statics, trace=True,
            q_active_pre=act0, t_end=t_end)
        return st, StepTrace(adm=tr_adm, evict=tr_ev, evict_dl=tr_ev_dl,
                             expire=tr_exp, expire_dl=tr_exp_dl,
                             complete=tr_comp, complete_dl=tr_comp_dl)
    st = admit(params, st, t, statics)
    st = drop_expired(params, st, t)
    sel, picked, run, e_new = pick(params, st, t, statics)
    return apply_step(params, st, t, sel, picked, run, e_new, statics,
                      t_end=t_end)


class StepEvents(NamedTuple):
    """Observable events of ONE device transition, derived purely from the
    ``(before, after)`` carry pair — the single source of truth consumed by
    :mod:`repro.telemetry`.

    Deriving events from carry *deltas* (rather than instrumenting the
    transition stages) keeps the step math byte-for-byte identical whether
    or not anyone is watching: the counters below are differences of the
    same ``m_*`` accumulators the metrics already use, so telemetry totals
    reconcile exactly against :class:`StepResult`, and the per-slot fields
    are best-effort reads of the queue registers at retirement (a slot
    recycled by an admit-evict in the same step reports its *pre-step*
    registers).
    """

    releases: jax.Array      # i32: jobs released this step (sum over tasks)
    misses: jax.Array        # i32: deadline misses this step
    scheduled: jax.Array     # i32: on-time completions this step
    retired: jax.Array       # (Q,) bool: slots that left the queue
    slack: jax.Array         # (Q,) f32: deadline - t_end for retired slots
    exit_depth: jax.Array    # (Q,) i32: q_exited at retirement (-1 = never)
    power_fail: jax.Array    # bool: the device powered down this step
    reboots: jax.Array       # i32: reboot-count delta
    queue_occ: jax.Array     # i32: active queue slots after the step
    energy: jax.Array        # f32: capacitor energy after the step


def step_events(st0: DeviceCarry, st1: DeviceCarry, t,
                statics: StepStatics) -> StepEvents:
    """Derive :class:`StepEvents` from one transition's before/after carries.

    Pure, per-device (vmap adds the fleet axis), and read-only — calling it
    cannot perturb the simulation.  ``retired`` covers both cleared slots
    and slots recycled for a new job by an overflow-evict in the same step;
    for the latter the pre-step registers are reported.
    """
    recycled = st0.q_active & st1.q_active & (
        (st1.q_job != st0.q_job) | (st1.q_task != st0.q_task))
    retired = (st0.q_active & ~st1.q_active) | recycled
    t_end = t + statics.dt
    return StepEvents(
        releases=jnp.sum(st1.next_rel - st0.next_rel).astype(jnp.int32),
        misses=jnp.sum(st1.m_misses - st0.m_misses).astype(jnp.int32),
        scheduled=jnp.sum(
            st1.m_scheduled - st0.m_scheduled).astype(jnp.int32),
        retired=retired,
        slack=(st0.q_deadline - t_end).astype(_F32),
        exit_depth=jnp.where(recycled, st0.q_exited, st1.q_exited),
        power_fail=st1.was_off & ~st0.was_off,
        reboots=(st1.m_reboots - st0.m_reboots).astype(jnp.int32),
        queue_occ=jnp.sum(st1.q_active).astype(jnp.int32),
        energy=st1.energy.astype(_F32),
    )


def finalize(params: StepParams, st: DeviceCarry,
             statics: StepStatics, live: bool = False) -> StepResult:
    """Flush live jobs and count never-admitted releases as misses; emit
    both the per-task (K,) counters and their aggregates."""
    d_sched, d_corr, d_miss = finish_counts(params, st, st.q_active, live)
    unreleased = params.n_releases - st.next_rel    # (K,)
    t_sched = st.m_scheduled + d_sched
    t_corr = st.m_correct + d_corr
    t_miss = st.m_misses + d_miss + unreleased
    return StepResult(
        released=jnp.sum(params.n_releases),
        scheduled=jnp.sum(t_sched),
        correct=jnp.sum(t_corr),
        deadline_misses=jnp.sum(t_miss),
        units_executed=jnp.sum(st.m_units),
        optional_units=jnp.sum(st.m_optional),
        busy_time=st.m_busy,
        idle_no_energy=st.m_idle,
        reboots=st.m_reboots,
        wasted_reexec=st.m_wasted,
        sim_time=jnp.full((), statics.horizon, _F32),
        task_released=params.n_releases,
        task_scheduled=t_sched,
        task_correct=t_corr,
        task_misses=t_miss,
        task_units=st.m_units,
        task_optional=st.m_optional,
    )


@functools.partial(jax.jit, static_argnames=("statics",))
def simulate_device(params: StepParams, statics: StepStatics) -> StepResult:
    """Simulate ONE device: a scalar ``lax.scan`` over the step core with no
    ``vmap`` anywhere — the reference the fleet path is bit-exact against
    (see :func:`repro.core.scheduler.simulate_stepped`)."""
    carry0 = init_carry(params, statics)

    def step(st, i):
        return device_step(params, st, i.astype(_F32) * statics.dt,
                           statics,
                           t_end=(i + 1).astype(_F32) * statics.dt), None

    carry, _ = lax.scan(step, carry0, jnp.arange(statics.n_steps))
    return finalize(params, carry, statics)
