"""Semi-supervised k-means classifier bank (paper §4.3).

One classifier per Zygarde unit.  Offline construction: per-unit features
from the trained agile DNN -> SelectKBest-style feature selection -> k-means
seeded at class means -> cluster labels by majority vote.  Online: L1
classify (Pallas `l1_topk2` kernel), weighted-average centroid adaptation,
and centroid *propagation* to deeper layers after early exit
(c^{i+1} = (1/r) sigma(W^{i+1} r c^i)).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops


class UnitClassifier(NamedTuple):
    """Pytree classifier state for one unit."""

    centroids: jax.Array      # (k, d_full) f32 — full-dim (for propagation)
    labels: jax.Array         # (k,) int32 — class label per cluster
    feature_idx: jax.Array    # (n_sel,) int32 — SelectKBest dims
    counts: jax.Array         # (k,) f32 — cluster sizes (the paper's r)
    threshold: jax.Array      # () f32 — utility threshold


# --------------------------------------------------------------------------- #
# Offline construction (network-trainer side; numpy).
# --------------------------------------------------------------------------- #


def select_k_best(
    feats: np.ndarray, labels: np.ndarray, n_sel: int
) -> np.ndarray:
    """ANOVA-F-style scoring (stand-in for the paper's chi^2 SelectKBest,
    which requires non-negative counts): between-class variance over
    within-class variance, top n_sel dims."""
    feats = np.asarray(feats, np.float64)
    classes = np.unique(labels)
    overall = feats.mean(0)
    between = np.zeros(feats.shape[1])
    within = np.zeros(feats.shape[1])
    for c in classes:
        sub = feats[labels == c]
        between += len(sub) * (sub.mean(0) - overall) ** 2
        within += ((sub - sub.mean(0)) ** 2).sum(0)
    score = between / (within + 1e-9)
    n_sel = min(n_sel, feats.shape[1])
    return np.sort(np.argsort(-score)[:n_sel]).astype(np.int32)


def fit_unit_classifier(
    feats: np.ndarray,
    labels: np.ndarray,
    *,
    n_clusters: int | None = None,
    n_sel: int = 150,
    n_iter: int = 10,
    threshold: float = 0.1,
    seed: int = 0,
) -> UnitClassifier:
    """Semi-supervised fit: seed centroids at class means, Lloyd-iterate with
    L1 assignment, label clusters by member majority."""
    feats = np.asarray(feats, np.float32)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    k = n_clusters or len(classes)
    per = max(1, k // len(classes))
    rng = np.random.default_rng(seed)

    idx = select_k_best(feats, labels, n_sel)
    fsel = feats[:, idx]

    cents = []
    for c in classes:
        sub = fsel[labels == c]
        cents.append(sub.mean(0))
        for _ in range(per - 1):  # extra seeds: jittered class means
            cents.append(sub[rng.integers(len(sub))])
    cents = np.stack(cents)[:k] if len(cents) >= k else np.stack(
        cents + [fsel[rng.integers(len(fsel))] for _ in range(k - len(cents))]
    )
    k = len(cents)

    for _ in range(n_iter):
        d = np.abs(fsel[:, None, :] - cents[None]).sum(-1)
        assign = d.argmin(1)
        for j in range(k):
            members = fsel[assign == j]
            if len(members):
                cents[j] = members.mean(0)

    d = np.abs(fsel[:, None, :] - cents[None]).sum(-1)
    assign = d.argmin(1)
    clabels = np.zeros(k, np.int32)
    counts = np.zeros(k, np.float32)
    for j in range(k):
        member_labels = labels[assign == j]
        counts[j] = max(1.0, len(member_labels))
        clabels[j] = (
            np.bincount(member_labels).argmax() if len(member_labels)
            else classes[j % len(classes)]
        )

    # store FULL-dim centroids (mean of members in full space) for propagation
    cents_full = np.zeros((k, feats.shape[1]), np.float32)
    for j in range(k):
        members = feats[assign == j]
        cents_full[j] = members.mean(0) if len(members) else feats.mean(0)
    cents_full[:, idx] = cents  # selected dims exactly as fitted

    return UnitClassifier(
        centroids=jnp.asarray(cents_full),
        labels=jnp.asarray(clabels),
        feature_idx=jnp.asarray(idx),
        counts=jnp.asarray(counts),
        threshold=jnp.float32(threshold),
    )


# --------------------------------------------------------------------------- #
# Online operations (device side).
# --------------------------------------------------------------------------- #


def classify(uc: UnitClassifier, feats: jax.Array):
    """feats: (B, d_full) -> (pred (B,), d1, d2, cluster_idx, margin)."""
    fsel = feats[:, uc.feature_idx].astype(jnp.float32)
    csel = uc.centroids[:, uc.feature_idx]
    d1, d2, idx = ops.l1_topk2(fsel, csel)
    pred = uc.labels[idx]
    margin = (d2 - d1) / jnp.maximum(d1 + d2, 1e-9)  # scale-free margin
    return pred, d1, d2, idx, margin


def utility_test(uc: UnitClassifier, margin: jax.Array) -> jax.Array:
    """True = confident enough to exit (|Delta2 - Delta1| above threshold)."""
    return margin > uc.threshold


def adapt(
    uc: UnitClassifier, feats: jax.Array, cluster_idx: jax.Array,
    weight: float = 32.0,
) -> UnitClassifier:
    """Weighted-average centroid update (runs when the utility test passes).

    ``weight`` is the mass assigned to the current centroid — large values
    make adaptation gradual and outlier-robust (paper §11.3).
    """
    new_c = ops.centroid_update(
        uc.centroids, feats.astype(jnp.float32), cluster_idx, weight
    )
    new_counts = uc.counts + jnp.bincount(
        cluster_idx, length=uc.counts.shape[0]
    ).astype(jnp.float32)
    return uc._replace(centroids=new_c, counts=new_counts)


def propagate(
    uc_from: UnitClassifier,
    uc_to: UnitClassifier,
    unit_apply: Callable[[jax.Array], jax.Array],
    cluster_idx: jax.Array,
) -> UnitClassifier:
    """Paper §4.3 "updating centroids beyond mandatory layers":

        c^{i+1} = (1/r) * sigma(W^{i+1} (r * c^i))

    ``unit_apply`` maps full-dim unit-i features through layer i+1 (weights
    and bias included); sigma is ReLU ((x+|x|)/2).  Only the clusters that
    actually absorbed new examples (``cluster_idx``) are refreshed.
    """
    r = uc_from.counts[:, None]
    img = jax.nn.relu(unit_apply(r * uc_from.centroids)) / r
    mask = jnp.zeros(uc_from.counts.shape[0], bool).at[cluster_idx].set(True)
    new_c = jnp.where(mask[:, None], img, uc_to.centroids)
    return uc_to._replace(centroids=new_c)


# --------------------------------------------------------------------------- #
# Raw-table online operations (fleet-batched, jit-safe).
#
# The :class:`UnitClassifier` API above wraps one classifier per DNN unit;
# the harvest-pattern forecaster (:mod:`repro.adapt.forecast`) instead
# clusters *feature windows* — ``(D, W, F)`` fleet batches with no labels,
# no feature selection and no propagation.  These entry points expose the
# same L1-classify / weighted-centroid-adapt machinery over raw centroid
# tables, dispatching to the fleet-shaped Pallas wrappers in
# :mod:`repro.kernels.ops` (``fleet_l1_topk2`` / ``fleet_centroid_update``).
# Both are pure jnp-in/jnp-out and safe to call under ``jax.jit``.
# --------------------------------------------------------------------------- #


def classify_batch(centroids: jax.Array, x: jax.Array):
    """L1-classify a fleet batch of feature windows against a raw table.

    ``centroids``: ``(k, F)``; ``x``: ``(..., F)`` with any leading batch
    shape (``(D, W, F)`` for W trailing windows of D devices).  Returns
    ``(idx, d1, d2, margin)`` shaped like the batch — ``margin`` is the
    same scale-free top-2 separation statistic as :func:`classify`.
    """
    d1, d2, idx = ops.fleet_l1_topk2(x, centroids)
    margin = (d2 - d1) / jnp.maximum(d1 + d2, 1e-9)
    return idx, d1, d2, margin


def online_update(
    centroids: jax.Array,
    counts: jax.Array,
    x: jax.Array,
    idx: jax.Array,
    weight: float = 32.0,
):
    """Weighted-average centroid adaptation over a fleet window batch.

    The raw-table counterpart of :func:`adapt`: every window in ``x``
    (``(..., F)``) moves its assigned centroid toward the batch mean with
    inertia ``weight`` (rows with ``idx < 0`` are ignored).  Returns the
    new ``(k, F)`` table and the updated ``(k,)`` member counts.
    """
    k = centroids.shape[0]
    new_c = ops.fleet_centroid_update(centroids, x, idx, weight)
    flat = jnp.asarray(idx, jnp.int32).reshape((-1,))
    new_counts = counts + jnp.bincount(
        jnp.where(flat >= 0, flat, k), length=k + 1
    )[:k].astype(jnp.float32)
    return new_c, new_counts


# --------------------------------------------------------------------------- #
# Bank helpers.
# --------------------------------------------------------------------------- #


def fit_bank(
    per_unit_feats: Sequence[np.ndarray],
    labels: np.ndarray,
    *,
    n_clusters: int | None = None,
    n_sel: int = 150,
    thresholds: Sequence[float] | None = None,
    seed: int = 0,
) -> list[UnitClassifier]:
    bank = []
    for u, feats in enumerate(per_unit_feats):
        thr = thresholds[u] if thresholds is not None else 0.1
        bank.append(
            fit_unit_classifier(
                feats, labels, n_clusters=n_clusters, n_sel=n_sel,
                threshold=thr, seed=seed + u,
            )
        )
    return bank


def bank_accuracy(
    bank: Sequence[UnitClassifier],
    per_unit_feats: Sequence[np.ndarray],
    labels: np.ndarray,
) -> list[float]:
    accs = []
    for uc, feats in zip(bank, per_unit_feats):
        pred, *_ = classify(uc, jnp.asarray(feats))
        accs.append(float((np.asarray(pred) == labels).mean()))
    return accs
