"""Zygarde core: the paper's contributions C1-C6.

energy       — eta-factor, harvester/capacitor models, schedulability (C1, C5)
losses       — layer-aware contrastive loss + baselines (C2)
kmeans       — semi-supervised k-means classifier bank (C3)
utility      — utility test + threshold calibration (C3)
policy       — priority/policy math as pure array functions (C4, shared
               with the vectorized fleet simulator in repro.fleet)
scheduler    — imprecise real-time scheduler + event simulator (C4)
intermittent — atomic-fragment execution substrate (C6)
agile        — unit-wise early-exit execution engine (C2+C3 glue)
"""
from . import (  # noqa: F401
    energy, losses, kmeans, utility, policy, scheduler, intermittent, agile,
)
