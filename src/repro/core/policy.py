"""Scheduler policy/priority logic as pure array functions (paper Eqs. 6-7).

Single source of truth for the priority math, shared by three call sites:

* the scalar discrete-event simulator (:mod:`repro.core.scheduler`), which
  calls these with python floats / bools;
* the vectorized fleet simulator (:mod:`repro.fleet`), which calls them with
  ``(devices, queue)``-shaped ``jnp`` arrays under ``vmap``/``scan``;
* the Pallas priority kernel (:mod:`repro.kernels.fleet_priority`), whose
  kernel body evaluates the same expressions on VMEM-resident tiles.

To stay polymorphic over float / numpy / jnp / Pallas tracer inputs, the
priority functions use only arithmetic and comparisons (booleans are blended
by multiplication instead of ``where``).  Larger score = higher priority
everywhere; EDF-style "earliest wins" keys are therefore negated deadlines.
"""
from __future__ import annotations

import jax.numpy as jnp

# Policy identifiers shared by the scalar and fleet paths.
POLICY_IDS = {"zygarde": 0, "edf": 1, "edf-m": 2, "rr": 3}
IMPRECISE_POLICIES = ("zygarde", "edf-m")   # early exit enabled

# Sentinel for "never schedulable" (python scalar so Pallas treats it as a
# compile-time constant, not a captured array).
NEG = -1e30

# Deadline ties are broken by release order (scalar path: lexicographic
# ``(deadline, release)``); in the array path the release enters the score at
# a scale far below any deadline difference.
_TIE = 1e-9

# Round-robin task rotation: the rotation distance of a slot's task from the
# per-device cursor dominates the within-task FIFO release key.  Requires
# releases (bounded by the horizon) to stay below this weight — true for any
# horizon under ~10^4 s (the fleet grids run minutes, not hours).
RR_TASK_W = 1e4


def exit_test(margin, threshold):
    """The utility test (paper §4.1): exit when the classifier margin clears
    the per-unit threshold.  Strict ``>`` matches the host-side calibration
    in :func:`repro.core.utility.calibrate_threshold` (and the precomputed
    ``JobProfile.passes`` tables).  Polymorphic over floats and arrays so
    the fleet simulator can evaluate it against *tuned* per-device
    ``(D, U)`` threshold arrays instead of baked-in booleans.
    """
    return margin > threshold


def zeta_priority(laxity, utility, mandatory, alpha, beta):
    """Eq. 6 (continuous power): dynamic priority zeta.

    laxity    : deadline - t_now
    utility   : psi, classifier confidence after the last executed unit
    mandatory : gamma, 1/True if the *next* unit is mandatory
    """
    gamma = 1.0 * mandatory
    return (1.0 - alpha * laxity) + (1.0 - beta * utility) + gamma


def zeta_intermittent_priority(laxity, utility, mandatory, alpha, beta,
                               eta, energy, e_opt):
    """Eq. 7 (intermittent power): the eta-weighted energy gate zeroes the
    priority of optional units while the store is below E_opt."""
    base = (1.0 - alpha * laxity) + (1.0 - beta * utility)
    gamma = 1.0 * mandatory
    gate = 1.0 * (eta * energy >= e_opt)
    return gate * (base + gamma) + (1.0 - gate) * gamma * base


def edf_key(deadline, release):
    """Earliest-deadline-first as a max-score key.

    ``deadline`` may be absolute or a laxity (deadline - t): subtracting a
    common t leaves the per-device ordering unchanged.  Deadline ties break
    by release order through a float perturbation — equivalent to the scalar
    simulator's exact lexicographic ``(deadline, release)`` whenever genuine
    deadline gaps exceed ``_TIE * release`` (always true for the fleet path's
    single periodic task stream, whose deadlines are distinct by period).
    """
    return -(deadline + _TIE * release)


def edfm_key(deadline, release, mandatory):
    """EDF over mandatory units only: optional work is never schedulable."""
    m = 1.0 * mandatory
    return m * edf_key(deadline, release) + (1.0 - m) * NEG


def rr_key(release, task_rank=0.0):
    """Round-robin at unit granularity: rotate across tasks, FIFO-by-release
    within a task.  ``task_rank`` is the rotation distance of the slot's task
    from the device's round-robin cursor (``(task - cursor) mod K``); with a
    single task stream it is identically 0 and the key degenerates to the
    pure FIFO ``-release`` (bit-identical to the pre-task-set fleet path).
    The scalar simulator implements the same rotation imperatively."""
    return -(task_rank * RR_TASK_W + release)


def policy_scores(policy_id, active, laxity, release, utility, mandatory,
                  alpha, beta, eta, energy, e_opt, persistent,
                  task_rank=0.0):
    """Batched score matrix + validity threshold for every policy.

    Queue-shaped args (``active`` .. ``mandatory``, ``task_rank``) carry a
    trailing queue axis; per-device args (``policy_id`` .. ``persistent``)
    must broadcast against them (callers pass ``x[..., None]`` shapes).
    ``task_rank`` (the round-robin rotation distance of each slot's task,
    0 for single-task devices) only enters the ``rr`` key.  Returns
    ``(scores, threshold)``: pick ``argmax(scores)`` and treat the device as
    idle when ``max(scores) <= threshold``.
    """
    zyg = jnp.where(
        persistent.astype(bool),
        zeta_priority(laxity, utility, mandatory, alpha, beta),
        zeta_intermittent_priority(laxity, utility, mandatory, alpha, beta,
                                   eta, energy, e_opt),
    )
    edf = edf_key(laxity, release)
    edfm = edfm_key(laxity, release, mandatory)
    rr = rr_key(release, task_rank)

    scores = jnp.select(
        [policy_id == 0, policy_id == 1, policy_id == 2],
        [zyg, edf, edfm],
        rr,
    )
    scores = jnp.where(active.astype(bool), scores, NEG)
    # zygarde idles when even the best score is <= 0 (energy-gated optional
    # work); the deadline-keyed policies only idle on an empty queue.
    threshold = jnp.where(policy_id == 0, 0.0, 0.5 * NEG)
    return scores, threshold
