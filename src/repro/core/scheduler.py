"""Imprecise real-time scheduler (paper §5) + discrete-event simulator.

Policies:
  * ``zygarde`` — dynamic-priority zeta (Eq. 6) / zeta_I (Eq. 7): considers
    remaining deadline, utility (classifier confidence), mandatory/optional
    status, and — on intermittent power — the eta-gated energy state.
  * ``edf``    — earliest deadline first, full execution (no early exit).
  * ``edf-m``  — EDF over mandatory units only (early exit enabled).
  * ``rr``     — round-robin across tasks at unit granularity.

The simulator executes *jobs* made of *units* (one DNN layer-group + k-means
classify + utility test each), themselves split into atomic *fragments*
(intermittent-safe execution quantum).  Energy comes from a bursty harvester
charging a capacitor; a unit's fragments only run while the stored energy is
above the fragment cost, otherwise the CPU is off and time passes (a
"reboot" when it comes back).  Limited preemption: the scheduler runs at
unit boundaries (paper §4.1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from . import policy
from .energy import Capacitor, Harvester

# --------------------------------------------------------------------------- #
# Workload description.
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class JobProfile:
    """Pre-computed per-sample execution profile (from the agile DNN).

    margins[u]  : utility-test margin after unit u
    passes[u]   : margin > threshold_u (would exit after unit u)
    correct[u]  : unit-u k-means prediction correct?
    """

    margins: np.ndarray
    passes: np.ndarray
    correct: np.ndarray

    @property
    def n_units(self) -> int:
        return len(self.margins)

    def mandatory_units(self) -> int:
        """Dynamic M: first unit whose utility test passes (1-based count)."""
        idx = np.flatnonzero(self.passes)
        return int(idx[0]) + 1 if len(idx) else self.n_units


@dataclass(frozen=True)
class TaskSpec:
    task_id: int
    period: float
    deadline: float               # relative deadline
    unit_time: np.ndarray         # (n_units,) seconds per unit
    unit_energy: np.ndarray       # (n_units,) joules per unit
    profiles: Sequence[JobProfile]
    fragments_per_unit: int = 4
    release_jitter: float = 0.0


@dataclass
class Job:
    task: TaskSpec
    job_id: int
    release: float
    deadline: float
    profile: JobProfile
    unit: int = 0                 # next unit to execute
    exited_at: int = -1           # unit index where the utility test passed
    last_pred_unit: int = -1      # deepest executed unit (prediction source)
    mandatory_done_time: float = -1.0
    finished: bool = False

    @property
    def n_units(self) -> int:
        return self.profile.n_units

    @property
    def mandatory_next(self) -> bool:
        """Is the *next* unit mandatory?  (gamma of Eq. 6/7)."""
        return self.exited_at < 0

    @property
    def utility(self) -> float:
        """Psi: confidence after the last executed unit (0 before any)."""
        if self.last_pred_unit < 0:
            return 0.0
        return float(self.profile.margins[self.last_pred_unit])

    @property
    def mandatory_met(self) -> bool:
        return self.mandatory_done_time >= 0

    @property
    def prediction_correct(self) -> Optional[bool]:
        if self.last_pred_unit < 0:
            return None
        return bool(self.profile.correct[self.last_pred_unit])


# --------------------------------------------------------------------------- #
# Clocks (RTC vs the CHRT remanence timekeeper, paper §8.7).
# --------------------------------------------------------------------------- #


class Clock:
    def read(self, t: float, rng: np.random.Generator) -> float:
        return t


class CHRTClock(Clock):
    """Tier-3 CHRT error model: 80% exact, ~17% +1s, rare +2s/-1s/-2s."""

    def __init__(self, p_exact=0.80, p_p1=0.17, p_p2=0.01, p_m1=0.015,
                 p_m2=0.005):
        self.choices = np.array([0.0, 1.0, 2.0, -1.0, -2.0])
        self.probs = np.array([p_exact, p_p1, p_p2, p_m1, p_m2])
        self.probs /= self.probs.sum()

    def read(self, t: float, rng: np.random.Generator) -> float:
        return t + rng.choice(self.choices, p=self.probs)

    def mean_error(self) -> float:
        """Expected per-read clock error (seconds); ~+0.165 s for the paper's
        Table-5 CHRT distribution (the remanence timekeeper reads fast)."""
        return float((self.choices * self.probs).sum())

    def equivalent_drift(self, horizon: float) -> float:
        """Constant drift *rate* for the fleet path's deterministic clock
        model ``t_read = t * (1 + r)``.  The scalar model redraws an iid
        offset every read, so its expected error is flat over time; matching
        the time-averaged error over ``[0, horizon]`` (``r * horizon / 2``)
        gives ``r = 2 * E[err] / horizon``."""
        return 2.0 * self.mean_error() / float(horizon)


# --------------------------------------------------------------------------- #
# Priority functions (Eqs. 6-7) — thin Job-aware views over the pure array
# functions in repro.core.policy, which the fleet simulator and the Pallas
# priority kernel share.
# --------------------------------------------------------------------------- #


def zeta(job: Job, t_now: float, alpha: float, beta: float) -> float:
    return float(policy.zeta_priority(
        job.deadline - t_now, job.utility, job.mandatory_next, alpha, beta
    ))


def zeta_intermittent(
    job: Job, t_now: float, alpha: float, beta: float,
    eta: float, e_curr: float, e_opt: float,
) -> float:
    return float(policy.zeta_intermittent_priority(
        job.deadline - t_now, job.utility, job.mandatory_next, alpha, beta,
        eta, e_curr, e_opt,
    ))


# --------------------------------------------------------------------------- #
# Simulator.
# --------------------------------------------------------------------------- #


@dataclass
class SimResult:
    released: int = 0
    scheduled: int = 0            # mandatory complete before deadline
    correct: int = 0              # scheduled AND final prediction correct
    deadline_misses: int = 0
    units_executed: int = 0
    optional_units: int = 0
    busy_time: float = 0.0
    idle_no_energy: float = 0.0
    reboots: int = 0
    wasted_reexec: float = 0.0
    sim_time: float = 0.0
    # per-task breakdowns, (K,) int arrays aligned with the ``tasks`` argument
    # of :func:`simulate` (aggregate counters above are their sums).  Mirrors
    # the fleet path's ``FleetResult.task_*`` fields so the scalar↔fleet
    # parity harness can compare per-task on-time/accuracy/drop counts.
    task_released: Optional[np.ndarray] = None
    task_scheduled: Optional[np.ndarray] = None
    task_correct: Optional[np.ndarray] = None
    task_misses: Optional[np.ndarray] = None

    def as_dict(self) -> dict:
        # per-task arrays become lists so the dict stays JSON-serializable
        # (launch/serve.py dumps it verbatim)
        return {k: v.tolist() if isinstance(v, np.ndarray) else v
                for k, v in dataclasses.asdict(self).items()}


@dataclass
class SimConfig:
    policy: str = "zygarde"       # zygarde | edf | edf-m | rr
    horizon: float = 600.0
    dt: float = 0.05              # integration step while idle/off
    e_man: Optional[float] = None # default: max fragment energy
    e_opt_fraction: float = 0.7   # E_opt as fraction of capacitor capacity
    queue_size: int = 3
    seed: int = 0
    clock: Clock = field(default_factory=Clock)
    # start with an empty capacitor (batteryless deployments boot cold; a
    # large capacitor then pays its long first charge — paper Fig. 21).
    start_charged: bool = False


def simulate_stepped(
    tasks: Sequence[TaskSpec],
    harvester: Harvester,
    eta: float,
    cap: Optional[Capacitor] = None,
    sim: Optional[SimConfig] = None,
    dt: Optional[float] = None,
) -> SimResult:
    """Discretized single-device frontend over the unified step core.

    Same signature and :class:`SimResult` contract as :func:`simulate`, but
    instead of the event-driven python loop it runs the pure
    ``(StepParams, DeviceCarry, t) -> DeviceCarry`` transition from
    :mod:`repro.core.step` with one scalar ``lax.scan`` — no ``vmap``, no
    device axis.  Because the fleet path is exactly ``vmap`` of the same
    functions, results here are *bit-exact* against the corresponding
    device of :func:`repro.fleet.simulate_fleet` on the shared fixed clock
    (asserted in ``tests/test_parity.py``), while the event-driven
    :func:`simulate` agrees only within the documented discretization
    bounds.  ``dt`` defaults to one fragment time of the finest-grained
    task — the scalar path's execution quantum.
    """
    # local imports: the grid builders live fleet-side (they translate the
    # scalar objects into step-core arrays) and pull in jax
    import jax

    from ..fleet.grid import from_sim_config
    from .step import simulate_device

    cfg, statics = from_sim_config(tasks, harvester, eta, cap=cap, sim=sim,
                                   dt=dt)
    params = jax.tree.map(lambda l: l[0], cfg)   # strip the device axis
    r = simulate_device(params, statics)
    return SimResult(
        released=int(r.released),
        scheduled=int(r.scheduled),
        correct=int(r.correct),
        deadline_misses=int(r.deadline_misses),
        units_executed=int(r.units_executed),
        optional_units=int(r.optional_units),
        busy_time=float(r.busy_time),
        idle_no_energy=float(r.idle_no_energy),
        reboots=int(r.reboots),
        wasted_reexec=float(r.wasted_reexec),
        sim_time=float(r.sim_time),
        task_released=np.asarray(r.task_released, np.int64),
        task_scheduled=np.asarray(r.task_scheduled, np.int64),
        task_correct=np.asarray(r.task_correct, np.int64),
        task_misses=np.asarray(r.task_misses, np.int64),
    )


def simulate(
    tasks: Sequence[TaskSpec],
    harvester: Harvester,
    eta: float,
    cap: Optional[Capacitor] = None,
    sim: Optional[SimConfig] = None,
) -> SimResult:
    sim = sim or SimConfig()
    cap = cap or Capacitor()
    cap = dataclasses.replace(cap) if dataclasses.is_dataclass(cap) else cap
    cap.energy_j = cap.capacity_j if sim.start_charged else 0.0
    rng = np.random.default_rng(sim.seed)
    res = SimResult(
        task_released=np.zeros(len(tasks), np.int64),
        task_scheduled=np.zeros(len(tasks), np.int64),
        task_correct=np.zeros(len(tasks), np.int64),
        task_misses=np.zeros(len(tasks), np.int64),
    )
    task_row = {t.task_id: i for i, t in enumerate(tasks)}

    max_frag_e = max(
        float(np.max(t.unit_energy)) / t.fragments_per_unit for t in tasks
    )
    e_man = sim.e_man if sim.e_man is not None else max_frag_e
    e_opt = sim.e_opt_fraction * cap.capacity_j
    max_deadline = max(t.deadline for t in tasks)
    alpha, beta = 1.0 / max_deadline, 1.0

    # --- energy slots ------------------------------------------------------ #
    n_slots = int(sim.horizon / harvester.slot_s) + 2
    events = harvester.sample_events(rng, n_slots, init=1)

    def power_at(t: float) -> float:
        slot = min(int(t / harvester.slot_s), n_slots - 1)
        return events[slot] * harvester.power_on

    # --- job releases ------------------------------------------------------ #
    releases: list[Job] = []
    for task in tasks:
        t, j = 0.0, 0
        while t < sim.horizon and j < len(task.profiles):
            rel = t + rng.uniform(0, task.release_jitter)
            releases.append(
                Job(task, j, rel, rel + task.deadline, task.profiles[j])
            )
            res.task_released[task_row[task.task_id]] += 1
            t += task.period
            j += 1
    releases.sort(key=lambda job: job.release)
    res.released = len(releases)

    queue: list[Job] = []
    rel_idx = 0
    t_now = 0.0
    was_off = False
    rr_cursor = 0

    def admit(t_now: float):
        nonlocal rel_idx
        while rel_idx < len(releases) and releases[rel_idx].release <= t_now:
            if len(queue) >= sim.queue_size:
                # a job whose mandatory part is done only holds optional
                # work — evict it in favour of the new arrival (mandatory
                # first, paper §5.2)
                evictable = [j for j in queue if j.exited_at >= 0]
                if evictable:
                    victim = min(evictable, key=lambda j: j.deadline)
                    queue.remove(victim)
                    finish_job(victim)
            if len(queue) < sim.queue_size:
                queue.append(releases[rel_idx])
            else:
                res.deadline_misses += 1  # queue overflow = dropped
                res.task_misses[task_row[releases[rel_idx].task.task_id]] += 1
            rel_idx += 1

    def drop_expired(t_now: float):
        t_read = sim.clock.read(t_now, rng)
        for job in list(queue):
            if t_read >= job.deadline:
                queue.remove(job)
                finish_job(job)

    def finish_job(job: Job):
        job.finished = True
        k = task_row[job.task.task_id]
        if job.mandatory_met and job.mandatory_done_time <= job.deadline:
            res.scheduled += 1
            res.task_scheduled[k] += 1
            if job.prediction_correct:
                res.correct += 1
                res.task_correct[k] += 1
        else:
            res.deadline_misses += 1
            res.task_misses[k] += 1

    def pick(t_now: float) -> Optional[Job]:
        nonlocal rr_cursor
        if not queue:
            return None
        cands = queue
        # EDF/EDF-M/RR keep exact lexicographic ordering here; the float-key
        # equivalents in repro.core.policy (edf_key etc.) serve the array
        # paths, where tie-breaking is approximate by a 1e-9 perturbation.
        if sim.policy == "edf":
            return min(cands, key=lambda j: (j.deadline, j.release))
        if sim.policy == "edf-m":
            mand = [j for j in cands if j.mandatory_next]
            return (
                min(mand, key=lambda j: (j.deadline, j.release)) if mand else None
            )
        if sim.policy == "rr":
            by_task = sorted({j.task.task_id for j in cands})
            for off in range(len(by_task)):
                tid = by_task[(rr_cursor + off) % len(by_task)]
                sub = [j for j in cands if j.task.task_id == tid]
                if sub:
                    rr_cursor = (rr_cursor + off + 1) % len(by_task)
                    return min(sub, key=lambda j: j.release)
            return None
        # zygarde
        if eta >= 1.0 and harvester.p_stay_on >= 1.0:
            key = lambda j: zeta(j, t_now, alpha, beta)  # noqa: E731
        else:
            key = lambda j: zeta_intermittent(  # noqa: E731
                j, t_now, alpha, beta, eta, cap.energy_j, e_opt
            )
        best = max(queue, key=key)
        if key(best) <= 0.0:
            return None  # only optional work and energy gate closed
        return best

    # --- cold boot ---------------------------------------------------------- #
    # Charging from 0 V to the MCU cutoff v_min stores 1/2 C v_min^2 of
    # unusable "dead-zone" energy first — the physical cost that makes an
    # oversized capacitor slow to boot (paper Fig. 21).
    if not sim.start_charged:
        debt = 0.5 * cap.capacitance_f * cap.v_min ** 2
        while debt > 0.0 and t_now < sim.horizon:
            debt -= power_at(t_now) * sim.dt
            t_now += sim.dt
            res.idle_no_energy += sim.dt

    # --- main loop ---------------------------------------------------------- #
    while t_now < sim.horizon:
        admit(t_now)
        drop_expired(t_now)
        job = pick(t_now)
        if job is None:
            if rel_idx >= len(releases) and not queue:
                break
            cap.charge(power_at(t_now) * sim.dt)
            t_now += sim.dt
            continue

        # execute one unit = fragments_per_unit atomic fragments
        u = job.unit
        frag_t = job.task.unit_time[u] / job.task.fragments_per_unit
        frag_e = job.task.unit_energy[u] / job.task.fragments_per_unit
        frag = 0
        aborted = False
        while frag < job.task.fragments_per_unit:
            if cap.energy_j < max(frag_e, e_man):
                # power down: wait for charge
                was_off = True
                res.idle_no_energy += sim.dt
                cap.charge(power_at(t_now) * sim.dt)
                t_now += sim.dt
                if t_now >= sim.horizon:
                    aborted = True
                    break
                if sim.clock.read(t_now, rng) >= job.deadline:
                    aborted = True
                    break
                continue
            if was_off:
                # the initial cold boot is not a reboot
                if res.busy_time > 0:
                    res.reboots += 1
                # re-execute the interrupted fragment (idempotent, but the
                # partial work was lost)
                res.wasted_reexec += frag_t * 0.5
                was_off = False
            cap.charge(power_at(t_now) * frag_t)
            cap.discharge(frag_e)
            t_now += frag_t
            res.busy_time += frag_t
            frag += 1

        if aborted:
            continue  # deadline/horizon handling at loop top

        # unit complete: classify + utility test (costs folded into unit_time)
        res.units_executed += 1
        if not job.mandatory_next:
            res.optional_units += 1
        job.last_pred_unit = u
        job.unit += 1
        imprecise = sim.policy in policy.IMPRECISE_POLICIES
        if imprecise and job.exited_at < 0 and job.profile.passes[u]:
            job.exited_at = u
            job.mandatory_done_time = t_now
        if job.exited_at < 0 and job.unit >= job.n_units:
            # imprecise: never-confident => full execution is mandatory.
            # EDF/RR (no early termination): the whole DNN is mandatory.
            job.exited_at = job.n_units - 1
            job.mandatory_done_time = t_now

        job_done = job.unit >= job.n_units
        if sim.policy in policy.IMPRECISE_POLICIES and job.exited_at >= 0:
            if sim.policy == "edf-m":
                job_done = True  # EDF-M never runs optional units
        if job_done:
            queue.remove(job)
            finish_job(job)

    # flush remaining jobs
    for job in queue:
        finish_job(job)
    while rel_idx < len(releases):
        res.deadline_misses += 1
        res.task_misses[task_row[releases[rel_idx].task.task_id]] += 1
        rel_idx += 1
    res.sim_time = t_now
    # expose the (mutated-in-place) Job records as a plain attribute — NOT a
    # dataclass field, so ``as_dict`` stays JSON-serializable.  The serving
    # parity harness reads per-job units/exits/deadline outcomes from here.
    res.jobs = releases
    return res
