"""Agile DNN execution (paper §4): unit-wise inference with cluster-based
classification, the utility test, runtime centroid adaptation, and centroid
propagation past early exits.

Two frontends share one engine:
  * :class:`AgileCNN`         — the paper's CNNs (unit = one layer)
  * :class:`AgileTransformer` — assigned architectures (unit = block group)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import cnn as cnn_mod
from repro.models import transformer as tfm

from . import kmeans as km
from .scheduler import JobProfile


@dataclass
class InferenceResult:
    prediction: int
    exit_unit: int                # unit at which the utility test passed
    units_executed: int
    margin: float
    adapted: bool


class _AgileBase:
    """Shared unit-wise inference over a classifier bank."""

    bank: list[km.UnitClassifier]

    # subclasses: _initial_state(x), _run_unit(state, u) -> (state, feats)
    # and unit_apply_flat(u, flat_feats) for centroid propagation.

    @property
    def n_units(self) -> int:
        return len(self.bank)

    def profile_batch(
        self, xs, labels: np.ndarray
    ) -> list[JobProfile]:
        """Full forward for a batch; returns per-sample JobProfiles for the
        scheduler simulator."""
        feats = self._all_features(xs)  # list of (B, d_u)
        B = len(labels)
        margins = np.zeros((B, self.n_units))
        passes = np.zeros((B, self.n_units), bool)
        correct = np.zeros((B, self.n_units), bool)
        for u, f in enumerate(feats):
            uc = self.bank[u]
            pred, d1, d2, idx, margin = km.classify(uc, f)
            margins[:, u] = np.asarray(margin)
            passes[:, u] = np.asarray(margin > uc.threshold)
            correct[:, u] = np.asarray(pred) == labels
        return [
            JobProfile(margins[i], passes[i], correct[i]) for i in range(B)
        ]

    def infer(
        self, x, *, adapt: bool = True, unit_budget: Optional[int] = None,
        adapt_weight: float = 32.0,
    ) -> InferenceResult:
        """Sequential unit-wise inference with early exit (+ adaptation and
        centroid propagation when the utility test passes)."""
        state = self._initial_state(x)
        budget = unit_budget or self.n_units
        pred, margin, exit_u = -1, 0.0, -1
        for u in range(min(budget, self.n_units)):
            state, feats = self._run_unit(state, u)
            uc = self.bank[u]
            p, d1, d2, idx, m = km.classify(uc, feats)
            pred, margin = int(p[0]), float(m[0])
            if margin > float(uc.threshold):
                exit_u = u
                if adapt:
                    self.bank[u] = km.adapt(uc, feats, idx,
                                            weight=adapt_weight)
                    self._propagate_from(u, idx)
                break
        return InferenceResult(
            prediction=pred,
            exit_unit=exit_u,
            units_executed=(exit_u + 1) if exit_u >= 0 else min(
                budget, self.n_units),
            margin=margin,
            adapted=adapt and exit_u >= 0,
        )

    def _propagate_from(self, u: int, cluster_idx) -> None:
        """Propagate adapted centroids to the skipped deeper units."""
        for v in range(u, self.n_units - 1):
            self.bank[v + 1] = km.propagate(
                self.bank[v], self.bank[v + 1],
                lambda f, v=v: self.unit_apply_flat(v + 1, f),
                cluster_idx,
            )

    def unit_features(
        self, xs, *, batch_size: Optional[int] = None
    ) -> list[np.ndarray]:
        """Per-unit features for a request batch, scan-over-units style.

        Runs ``_run_unit`` for unit 0 over the whole batch, then unit 1, ...
        — the "stacked scan over layers" shape the vectorized serving engine
        (:mod:`repro.serve.fleet_engine`) consumes: features are a pure
        function of the input (adaptation only moves *centroids*), so they
        can be computed once up front while classification happens inside
        the scheduling scan against the evolving bank.

        Returns a list of ``n_units`` arrays, entry ``u`` shaped
        ``(B, F_u)``.  ``batch_size`` chunks the batch to bound activation
        memory; ``batch_size=1`` reproduces the exact per-sample arithmetic
        of a :class:`repro.serve.engine.DynamicJobProfile` (same conv batch
        shape), which the scalar↔fleet bit-parity harness relies on.
        """
        if isinstance(xs, dict):
            n = len(next(iter(xs.values())))
            chunk = lambda a, b: {k: v[a:b] for k, v in xs.items()}  # noqa: E731
        else:
            if isinstance(xs, (list, tuple)):
                xs = np.stack([np.asarray(x) for x in xs])
            n = len(xs)
            chunk = lambda a, b: xs[a:b]  # noqa: E731
        bs = n if batch_size is None else int(batch_size)
        out: list[list[np.ndarray]] = [[] for _ in range(self.n_units)]
        for b0 in range(0, n, bs):
            state = self._initial_state(chunk(b0, min(b0 + bs, n)))
            for u in range(self.n_units):
                state, f = self._run_unit(state, u)
                out[u].append(np.asarray(f, np.float32))
        return [np.concatenate(c, axis=0) for c in out]


# --------------------------------------------------------------------------- #
# CNN frontend.
# --------------------------------------------------------------------------- #


class AgileCNN(_AgileBase):
    def __init__(self, cfg: cnn_mod.CNNConfig, params: dict,
                 bank: Sequence[km.UnitClassifier]):
        self.cfg, self.params = cfg, params
        self.bank = list(bank)
        # activation shape entering each unit (for flat->NHWC propagation)
        self._entry_shapes = self._trace_shapes()

    def _trace_shapes(self):
        x = jnp.zeros((1, *self.cfg.input_shape))
        shapes = []
        h = x
        for u in range(self.cfg.n_units):
            shapes.append(h.shape[1:])
            h, _ = cnn_mod.cnn_unit_forward(self.cfg, self.params, h, u)
        return shapes

    def _initial_state(self, x):
        if x.ndim == len(self.cfg.input_shape):
            x = x[None]
        return jnp.asarray(x)

    def _run_unit(self, state, u):
        h, feats = cnn_mod.cnn_unit_forward(self.cfg, self.params, state, u)
        return h, feats

    def _all_features(self, xs):
        return cnn_mod.cnn_forward_all(self.cfg, self.params, jnp.asarray(xs))

    def unit_apply_flat(self, u: int, flat: jax.Array) -> jax.Array:
        """Apply unit u to flattened unit-(u-1) features (for propagation)."""
        shape = self._entry_shapes[u]
        x = flat.reshape(flat.shape[0], *shape).astype(jnp.float32)
        _, feats = cnn_mod.cnn_unit_forward(self.cfg, self.params, x, u)
        return feats


# --------------------------------------------------------------------------- #
# Transformer frontend (assigned architectures).
# --------------------------------------------------------------------------- #


class AgileTransformer(_AgileBase):
    """Unit = ``cfg.exit_every`` transformer blocks; features = mean-pooled
    hidden states.  Used for sequence-classification style Zygarde tasks on
    the assigned architectures (see examples/intermittent_serving.py)."""

    def __init__(self, cfg, params, bank: Sequence[km.UnitClassifier]):
        self.cfg, self.params = cfg, params
        self.bank = list(bank)

    def _initial_state(self, batch):
        if isinstance(batch, dict):
            x, enc_out = tfm.embed_inputs(self.cfg, self.params, batch)
        else:
            x, enc_out = tfm.embed_inputs(
                self.cfg, self.params, {"tokens": jnp.asarray(batch)}
            )
        return (x, enc_out)

    def _run_unit(self, state, u):
        x, enc_out = state
        x, pooled = tfm.unit_forward(self.cfg, self.params, x, u,
                                     enc_out=enc_out)
        return (x, enc_out), pooled

    def _all_features(self, batches):
        state = self._initial_state(batches)
        feats = []
        for u in range(self.n_units):
            state, f = self._run_unit(state, u)
            feats.append(f)
        return feats

    def unit_apply_flat(self, u: int, flat: jax.Array) -> jax.Array:
        """Propagation for pooled features: treat the centroid as a length-1
        sequence hidden state and push it through unit u."""
        x = flat[:, None, :].astype(tfm.dtype_of(self.cfg))
        x, pooled = tfm.unit_forward(self.cfg, self.params, x, u)
        return pooled

    @property
    def n_units_model(self) -> int:
        return self.cfg.n_units
