"""Intermittent-energy modeling (paper §3): energy events, conditional energy
events h(N), Kantorovich-Wasserstein distance, and the eta-factor; plus the
harvester/capacitor simulation substrate and the schedulability condition
(paper §5.3).

An *energy event* H_t in {0,1} says whether the storage gained at least
Delta-K joules during slot t.  Harvesters are bursty: h(N) — the probability
of an event given N consecutive preceding events (N>0) or non-events (N<0) —
decays with |N|.  eta in [0,1] normalises the KW distance of the h(N) curve
from a persistent source against a purely random one (Eq. 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

# --------------------------------------------------------------------------- #
# Conditional energy events and the eta-factor (Eqs. 1-3).
# --------------------------------------------------------------------------- #


def conditional_energy_event(trace: np.ndarray, n: int) -> float:
    """h(N) per Eq. 1.  trace: binary array of energy events; n != 0."""
    trace = np.asarray(trace, dtype=np.int8)
    assert n != 0
    run = abs(n)
    if len(trace) <= run:
        return np.nan
    target = 1 if n > 0 else 0
    # windows of length `run` ending at t-1 that are all == target
    ok = np.ones(len(trace) - run, dtype=bool)
    for i in range(run):
        ok &= trace[i : i + len(trace) - run] == target
    follow = trace[run:]
    if ok.sum() == 0:
        return np.nan
    return float(follow[ok].mean())


def h_curve(trace: np.ndarray, n_max: int = 20) -> np.ndarray:
    """h(N) for N in [-n_max..-1, 1..n_max] (NaN where unobserved)."""
    ns = list(range(-n_max, 0)) + list(range(1, n_max + 1))
    return np.array([conditional_energy_event(trace, n) for n in ns])


def ideal_h_curve(n_max: int = 20) -> np.ndarray:
    """h(N) of a perfectly state-maintaining ("persistent-pattern") source:
    after N consecutive events the next is certain (h=1); after N consecutive
    non-events the next event never happens (h=0).  This is the ideal
    *predictability* reference of Eq. 2 — Fig. 4(a)'s persistent source is
    the N>0 half of it (the N<0 half is unobservable there)."""
    return np.concatenate([np.zeros(n_max), np.ones(n_max)])


def random_h_curve(n_max: int = 20) -> np.ndarray:
    """A patternless harvester: h(N) = 1/2 everywhere."""
    return np.full(2 * n_max, 0.5)


def kw_distance(h_a: np.ndarray, h_b: np.ndarray) -> float:
    """Kantorovich-Wasserstein distance between two h(N) curves (Eq. 2):
    the L1 distance between their (normalised) cumulative curves over N.

    Using cumulative-over-N (a discrete CDF integral) rather than pointwise
    L1 makes the metric robust to N-bins estimated from few instances — the
    limitation the paper notes before normalising into eta.
    """
    a = np.asarray(h_a, np.float64)
    b = np.asarray(h_b, np.float64)
    mask = np.isfinite(a) & np.isfinite(b)
    if not mask.any():
        return 0.0
    a, b = a[mask], b[mask]
    ca = np.cumsum(a) / len(a)
    cb = np.cumsum(b) / len(b)
    return float(np.abs(ca - cb).mean())


def eta_factor(trace: np.ndarray, n_max: int = 20) -> float:
    """Eq. 3: eta = 1 - KW(H, P) / KW(R, P), clipped to [0, 1].

    eta = 1 for a persistent source, 0 for a patternless one; for a
    symmetric bursty (Markov) harvester with stay-probability p it grows
    monotonically with p (~ 2p - 1).  Only N-bins actually observed in the
    trace participate (the paper's "not all h(N) estimated from the same
    number of instances" normalisation concern)."""
    h = h_curve(trace, n_max)
    persistent = ideal_h_curve(n_max)
    rand = random_h_curve(n_max)
    obs = np.isfinite(h)
    persistent = np.where(obs, persistent, np.nan)
    rand = np.where(obs, rand, np.nan)
    denom = kw_distance(rand, persistent)
    if denom <= 0:
        return 1.0
    eta = 1.0 - kw_distance(h, persistent) / denom
    return float(np.clip(eta, 0.0, 1.0))


# --------------------------------------------------------------------------- #
# Harvester models (simulation substrate; §7's solar / RF / piezo setups).
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class Harvester:
    """Two-state bursty (Markov) harvester.

    p_stay_on / p_stay_off: probability of keeping the current binary state
    in the next slot — burstiness, the empirical property behind eta.
    power_on: average harvesting power (W) while in the ON state.
    """

    name: str
    p_stay_on: float
    p_stay_off: float
    power_on: float
    slot_s: float = 1.0

    def sample_events(self, rng: np.random.Generator, n_slots: int,
                      init: Optional[int] = None) -> np.ndarray:
        u = rng.random(n_slots)
        out = np.empty(n_slots, dtype=np.int8)
        state = rng.integers(0, 2) if init is None else init
        for t in range(n_slots):
            stay = self.p_stay_on if state else self.p_stay_off
            if u[t] > stay:
                state = 1 - state
            out[t] = state
        return out

    def power_trace(self, rng: np.random.Generator, n_slots: int) -> np.ndarray:
        return self.sample_events(rng, n_slots).astype(np.float64) * self.power_on


PERSISTENT = Harvester("battery", 1.0, 0.0, 1.0)


def calibrate_harvester(
    target_eta: float, power_on: float, name: str = "harvester",
    n_slots: int = 20_000, seed: int = 0,
) -> Harvester:
    """Binary-search the Markov stay-probability to hit a target eta."""
    if target_eta >= 0.999:
        return Harvester(name, 1.0, 0.0, power_on)
    lo, hi = 0.5, 0.9999
    for _ in range(20):
        mid = 0.5 * (lo + hi)
        h = Harvester(name, mid, mid, power_on)
        e = float(np.mean([
            eta_factor(h.sample_events(np.random.default_rng(seed + s),
                                       n_slots))
            for s in range(3)
        ]))
        if e < target_eta:
            lo = mid
        else:
            hi = mid
    p = 0.5 * (lo + hi)
    return Harvester(name, p, p, power_on)


# --------------------------------------------------------------------------- #
# Capacitor energy storage.
# --------------------------------------------------------------------------- #


@dataclass
class Capacitor:
    """Supercapacitor: E = 1/2 C V^2 between v_min (cutoff) and v_max."""

    capacitance_f: float = 0.05  # 50 mF, the paper's default
    v_max: float = 3.3
    v_min: float = 1.8
    energy_j: float = 0.0

    @property
    def capacity_j(self) -> float:
        return 0.5 * self.capacitance_f * (self.v_max ** 2 - self.v_min ** 2)

    def charge(self, joules: float) -> float:
        """Add harvested energy; returns the amount actually stored."""
        room = self.capacity_j - self.energy_j
        add = min(max(joules, 0.0), room)
        self.energy_j += add
        return add

    def discharge(self, joules: float) -> bool:
        """Spend energy; False (and no change) if insufficient."""
        if joules > self.energy_j:
            return False
        self.energy_j -= joules
        return True

    @property
    def full(self) -> bool:
        return self.energy_j >= self.capacity_j - 1e-12


def optimal_capacitance(
    avg_power_w: float, slack_s: float, v: float = 3.3
) -> float:
    """Paper §8.6: C = sqrt(2 P deltaT / V^2) (rough estimate)."""
    return float(np.sqrt(2.0 * avg_power_w * slack_s / v ** 2))


# --------------------------------------------------------------------------- #
# Schedulability (paper §5.3).
# --------------------------------------------------------------------------- #


def expected_outage_slots(eta: float) -> float:
    """E[C_e] = eta / (1 - eta) (geometric)."""
    eta = min(eta, 1 - 1e-9)
    return eta / (1.0 - eta)


def min_energy_task_period(eta: float, utilization: float) -> float:
    """Necessary condition: T_E >= (eta/(1-eta)) / (1 - sum C_i/T_i)."""
    if utilization >= 1.0:
        return float("inf")
    return expected_outage_slots(eta) / (1.0 - utilization)


def is_schedulable(
    mandatory_utils: list[float], eta: float, energy_task_period: float
) -> bool:
    """N+1-task condition: sum C_i/T_i + C_e/T_e <= 1."""
    u = sum(mandatory_utils)
    c_e = expected_outage_slots(eta)
    return u + c_e / energy_task_period <= 1.0
