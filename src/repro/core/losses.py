"""Loss functions (paper §4.2): contrastive (Eq. 5), layer-aware (Eq. 4),
plus the cross-entropy baseline compared against in Fig. 15.

The layer-aware loss is a convex combination of per-layer contrastive losses
computed on siamese (paired) forward passes — it forces *every* hidden layer
to produce classification-ready (cluster-separable) features, which is what
makes early exit accurate.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def l1_distance(f1: jax.Array, f2: jax.Array) -> jax.Array:
    """Mean (dimension-normalised) L1 distance — matches the classifier's
    metric so the learned geometry and the k-means geometry agree."""
    return jnp.mean(jnp.abs(f1.astype(jnp.float32) - f2.astype(jnp.float32)),
                    axis=-1)


def contrastive_loss(
    f1: jax.Array, f2: jax.Array, different: jax.Array, margin: float = 1.0
) -> jax.Array:
    """Eq. 5.  different (Y): 0 = same class (pull), 1 = different (push)."""
    d = l1_distance(f1, f2)
    y = different.astype(jnp.float32)
    pull = 0.5 * (1.0 - y) * d
    push = 0.5 * y * jnp.maximum(0.0, margin - d)
    return jnp.mean(pull + push)


def layer_aware_loss(
    feats1: Sequence[jax.Array],
    feats2: Sequence[jax.Array],
    different: jax.Array,
    coeffs: Sequence[float] | None = None,
    margin: float = 1.0,
) -> jax.Array:
    """Eq. 4: LA = sum_i a_i * LC(layer i), sum a_i = 1.

    Default coefficients weight layers uniformly; the network trainer tunes
    them (exhaustive search) in `repro.train.trainer`.
    """
    L = len(feats1)
    if coeffs is None:
        coeffs = [1.0 / L] * L
    c = jnp.asarray(coeffs, jnp.float32)
    c = c / jnp.sum(c)
    losses = jnp.stack(
        [contrastive_loss(f1, f2, different, margin)
         for f1, f2 in zip(feats1, feats2)]
    )
    return jnp.sum(c * losses)


def final_layer_contrastive(
    feats1: Sequence[jax.Array],
    feats2: Sequence[jax.Array],
    different: jax.Array,
    margin: float = 1.0,
) -> jax.Array:
    """Baseline [71]: contrastive loss at the last layer only."""
    return contrastive_loss(feats1[-1], feats2[-1], different, margin)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Baseline [142] (and the LM training loss for the big archs)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Next-token LM loss: predict tokens[:, 1:] from logits[:, :-1]."""
    return cross_entropy(logits[:, :-1], tokens[:, 1:])
