"""Utility functions (paper §4.1, §11.2): the early-exit confidence test and
per-unit threshold calibration (the Fig. 8 accuracy/latency trade-off).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax.numpy as jnp

from .kmeans import UnitClassifier, classify


def margin_utility(d1: np.ndarray, d2: np.ndarray) -> np.ndarray:
    """Scale-free cluster margin |Delta2 - Delta1| / (Delta1 + Delta2)."""
    return (d2 - d1) / np.maximum(d1 + d2, 1e-9)


def entropy_utility(probs: np.ndarray) -> np.ndarray:
    """Generic utility for probabilistic classifiers (paper §11.2):
    U = -sum p log2 p; low entropy = confident."""
    p = np.clip(probs, 1e-12, 1.0)
    return -(p * np.log2(p)).sum(-1)


def scalarized_objective(correct, released, deadline_misses=None,
                         optional_units=None, units_executed=None, *,
                         miss_weight: float = 0.0,
                         optional_weight: float = 0.0):
    """Scalar fleet-tuning reward: on-time accuracy with optional penalties.

    The base term is ``correct / released`` — the fraction of released jobs
    whose mandatory part finished before the deadline *and* whose final
    prediction was right (the paper's headline "on-time accuracy" metric,
    Figs. 17-20).  ``miss_weight`` subtracts the deadline-miss rate and
    ``optional_weight`` adds the optional-unit fraction (rewarding deeper
    execution when energy allows).

    All inputs may be python scalars or ``(D,)`` arrays (the fleet device
    axis); counts are cast to f32 and denominators clamped, so the result is
    a smooth function of the count values — the property the
    antithetic-perturbation ES gradients in :mod:`repro.adapt` rely on.
    """
    rel = jnp.maximum(jnp.asarray(released, jnp.float32), 1.0)
    score = jnp.asarray(correct, jnp.float32) / rel
    if miss_weight and deadline_misses is not None:
        score = score - miss_weight * (
            jnp.asarray(deadline_misses, jnp.float32) / rel)
    if optional_weight and optional_units is not None:
        if units_executed is None:
            raise ValueError(
                "optional_weight needs both optional_units and "
                "units_executed")
        units = jnp.maximum(jnp.asarray(units_executed, jnp.float32), 1.0)
        score = score + optional_weight * (
            jnp.asarray(optional_units, jnp.float32) / units)
    return score


def calibrate_threshold(
    uc: UnitClassifier,
    feats: np.ndarray,
    labels: np.ndarray,
    *,
    min_accuracy: float = 0.85,
    grid: int = 50,
):
    """Sweep the utility threshold on held-out features; return the smallest
    threshold whose *exited* samples have accuracy >= min_accuracy (relative
    to this unit's achievable accuracy), plus the full trade-off curve.
    """
    pred, d1, d2, _, margin = classify(uc, jnp.asarray(feats))
    pred, margin = np.asarray(pred), np.asarray(margin)
    correct = pred == labels
    base_acc = max(correct.mean(), 1e-9)

    thresholds = np.quantile(margin, np.linspace(0.0, 0.98, grid))
    curve = []  # (threshold, exit_fraction, exit_accuracy)
    for t in thresholds:
        exited = margin > t
        frac = exited.mean()
        acc = correct[exited].mean() if exited.any() else 1.0
        curve.append((float(t), float(frac), float(acc)))

    chosen = curve[-1][0]
    for t, frac, acc in curve:
        if acc >= min_accuracy * base_acc:
            chosen = t
            break
    return float(chosen), curve


def calibrate_bank_thresholds(
    bank: Sequence[UnitClassifier],
    per_unit_feats: Sequence[np.ndarray],
    labels: np.ndarray,
    *,
    min_accuracy: float = 0.85,
) -> list[UnitClassifier]:
    out = []
    for uc, feats in zip(bank, per_unit_feats):
        thr, _ = calibrate_threshold(
            uc, feats, labels, min_accuracy=min_accuracy
        )
        out.append(uc._replace(threshold=jnp.float32(thr)))
    return out
