from .engine import ServeEngine, ServeConfig, DynamicJobProfile, Request  # noqa: F401
from .fleet_engine import FleetServeEngine, FleetServeResult  # noqa: F401
from .anytime import (  # noqa: F401
    AnytimeConfig,
    AnytimeKnobs,
    AnytimeRequest,
    AnytimeResult,
    AnytimeServeEngine,
    AnytimeTables,
)
