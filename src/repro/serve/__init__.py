from .engine import ServeEngine, ServeConfig, DynamicJobProfile, Request  # noqa: F401
