"""Vectorized live serving: real agile-model execution inside the fleet path.

:class:`repro.serve.engine.ServeEngine` is the *faithful* live path — an
event-driven python loop serving one job at a time, executing DNN units and
adapting k-means centroids in exactly the order the scheduler chose.  It
cannot scale past a handful of devices.  The fleet simulator scales to
thousands of devices but only *replays* precomputed ``(K, J, U)`` profile
tables.  This module closes the gap: one jitted ``lax.scan`` serves live
traffic for a whole fleet, with real unit outcomes and runtime centroid
adaptation threaded through the unified device step.

The key factorisation: per-unit *features* are a pure function of the input
— runtime adaptation moves only the k-means *centroids*, never the DNN
weights — so the engine precomputes features for every (job, unit) in one
batched scan-over-units pass (``_AgileBase.unit_features``) outside the
scheduling scan, and keeps only the state that actually evolves (the
centroid bank) inside it.  Each timestep then:

1. runs the step core's admit / drop-expired / pick stages in ``live`` mode
   (``vmap`` over devices, margins read from the live registers);
2. gathers the selected slot's (task, job, unit) identity per device;
3. classifies the completing unit's *real* features against the device's
   *current* centroid bank (same L1 top-2 arithmetic as
   :func:`repro.core.kmeans.classify`);
4. injects the ``(margin, passed, correct)`` outcome into
   :func:`repro.core.step.apply_step`;
5. adapts the bank where the utility test passed for the first time
   (weighted-average update + centroid propagation to deeper units, paper
   §4.3), exactly as ``DynamicJobProfile`` does one job at a time.

Because classification/adaptation are elementwise per device and the step
core is the same ``vmap``-ed transition the replay fleet uses, the live
fleet is *bit-exact* against a scalar :class:`ServeEngine` run on workloads
where the event-driven and fixed-step clocks coincide (persistent power,
charged start, unit times commensurate with ``dt`` — see
``tests/test_fleet_engine.py``).

Bank modes:

* ``per-device`` (default): every device owns a full centroid bank —
  ``ServeBank`` leaves carry a leading ``D`` axis and shard with the fleet
  (:func:`repro.launch.sharding.shard_serve_carry`).  This is the mode the
  scalar parity holds in.
* ``shared``: one global bank; every device's first-pass exits fold into a
  single collaborative :func:`repro.core.kmeans.online_update` per (task,
  unit) each step — the fleet-scale collaborative-adaptation substrate.

The scan carry (:class:`repro.fleet.state.ServeCarry`) is a flat pytree, so
``run(..., n_segments=N)`` checkpoints it at segment boundaries exactly like
:func:`repro.fleet.simulator.run_segments` — bit-identical to the monolithic
scan for any ``N``.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core import step as S
from ..core.energy import Capacitor, Harvester
from ..core.scheduler import JobProfile, TaskSpec
from ..fleet import grid
from ..fleet.simulator import finalize_fleet
from ..telemetry import state as T
from ..telemetry import trace as T_trace
from ..fleet.state import (
    FleetConfig,
    FleetResult,
    FleetStatics,
    ServeBank,
    ServeCarry,
    ServeLog,
    init_state,
)
from .engine import Request, ServeConfig, per_task

_F32 = jnp.float32
_I32 = jnp.int32

# padded cluster rows sit this far from everything: never in the L1 top-2
_FAR = 1e15
# the kernel's second-minimum mask value (repro.kernels.l1_topk2.POS)
_POS = 1e30


class ServeTables(NamedTuple):
    """Read-only per-request / per-classifier tables consumed by the scan.

    Shapes use ``K`` tasks, ``J`` jobs, ``U`` units, ``C`` clusters, ``S``
    selected features, ``F`` padded full-feature width (always one wider
    than the largest real feature dim: the extra column is zero everywhere
    and is where padded ``fidx`` entries point, so padding is L1-exact).
    With per-device request streams every *feature/label* leaf gains a
    leading ``D`` axis; the classifier metadata never does.
    """

    sel_feats: jax.Array     # ([D,] K, J, U, S) f32 — selected-dim features
    full_feats: jax.Array    # ([D,] K, J, U, F) f32 — full-dim (adaptation)
    labels: jax.Array        # ([D,] K, J) i32 — request ground truth
    clabels: jax.Array       # (K, U, C) i32 — cluster -> class label
    fidx: jax.Array          # (K, U, S) i32 — SelectKBest dims (pad -> F-1)
    thr: jax.Array           # (K, U) f32 — bank utility thresholds


@dataclass(frozen=True)
class BankMeta:
    """Static (python) shape metadata for the stacked bank."""

    n_units: tuple           # per task
    n_clusters: tuple        # per (task, unit)
    feat_dim: tuple          # per (task, unit) real feature width
    n_sel: tuple             # per (task, unit) real selected count


def stack_banks(models: Sequence) -> tuple[ServeBank, dict, BankMeta]:
    """Stack every model's per-unit :class:`UnitClassifier` bank into the
    padded ``(K, U, C, F)`` tables of a :class:`ServeBank` (+ the read-only
    classifier metadata for :class:`ServeTables`).

    Padding conventions (all L1- and update-exact, see module docstring):
    dummy cluster rows at ``_FAR`` with label -1 and count 1; features
    zero-padded to a common width ``F`` that always includes one guaranteed
    all-zero trailing column for padded ``fidx`` entries.
    """
    K = len(models)
    n_units = tuple(m.n_units for m in models)
    U = max(n_units)
    n_clusters = tuple(
        tuple(int(uc.centroids.shape[0]) for uc in m.bank) for m in models)
    feat_dim = tuple(
        tuple(int(uc.centroids.shape[1]) for uc in m.bank) for m in models)
    n_sel = tuple(
        tuple(int(uc.feature_idx.shape[0]) for uc in m.bank) for m in models)
    C = max(max(r) for r in n_clusters)
    S = max(max(r) for r in n_sel)
    F = max(max(r) for r in feat_dim) + 1    # +1: the all-zero pad column

    cents = np.full((K, U, C, F), _FAR, np.float32)
    counts = np.ones((K, U, C), np.float32)
    clabels = np.full((K, U, C), -1, np.int32)
    fidx = np.full((K, U, S), F - 1, np.int32)
    thr = np.zeros((K, U), np.float32)
    for k, m in enumerate(models):
        for u, uc in enumerate(m.bank):
            c = np.asarray(uc.centroids, np.float32)
            kc, fu = c.shape
            cents[k, u, :kc, :fu] = c
            cents[k, u, :kc, fu:] = 0.0
            counts[k, u, :kc] = np.asarray(uc.counts, np.float32)
            clabels[k, u, :kc] = np.asarray(uc.labels, np.int32)
            ns = n_sel[k][u]
            fidx[k, u, :ns] = np.asarray(uc.feature_idx, np.int32)
            thr[k, u] = float(uc.threshold)
    bank = ServeBank(centroids=jnp.asarray(cents), counts=jnp.asarray(counts))
    tables = dict(clabels=jnp.asarray(clabels), fidx=jnp.asarray(fidx),
                  thr=jnp.asarray(thr))
    return bank, tables, BankMeta(n_units, n_clusters, feat_dim, n_sel)


def build_feature_tables(
    models: Sequence,
    requests_per_task: Sequence[Sequence[Request]],
    meta: BankMeta,
    bank_tables: dict,
    *,
    feature_batch: Optional[int] = None,
    n_jobs: Optional[int] = None,
) -> dict:
    """Precompute the (job, unit) feature tables for one request stream.

    Features come from ``unit_features`` (scan-over-units, chunked by
    ``feature_batch``); the selected-dim gather happens host-side against
    the *initial* feature selection — valid for the whole run because
    ``feature_idx`` never adapts.  ``n_jobs`` fixes the job axis (so
    per-device streams of different lengths stack); default = longest
    stream given.
    """
    K = len(models)
    J = int(n_jobs or max(len(r) for r in requests_per_task))
    fidx = np.asarray(bank_tables["fidx"])
    U, S = fidx.shape[1], fidx.shape[2]
    F = max(max(r) for r in meta.feat_dim) + 1
    sel = np.zeros((K, J, U, S), np.float32)
    full = np.zeros((K, J, U, F), np.float32)
    labels = np.full((K, J), -1, np.int32)
    for k, (m, reqs) in enumerate(zip(models, requests_per_task)):
        if not reqs:
            continue
        feats = m.unit_features([r.x for r in reqs],
                                batch_size=feature_batch)
        for u, f in enumerate(feats):
            full[k, :len(reqs), u, :f.shape[1]] = f
            ns = meta.n_sel[k][u]
            sel[k, :len(reqs), u, :ns] = f[:, fidx[k, u, :ns]]
        labels[k, :len(reqs)] = [r.label for r in reqs]
    return dict(sel_feats=sel, full_feats=full, labels=labels)


def classify_unit(bank: ServeBank, tables: ServeTables, tk, u, job):
    """Single-row live classification for one device's completing unit.

    The pure-jnp row variant of :func:`repro.core.kmeans.classify`: same
    elementwise ``|x - c|`` innermost-axis reduction, same one-hot-masked
    second minimum (mask value :data:`_POS`), same scale-free margin — so
    the result is bit-identical to the scalar path's ``l1_topk2`` kernel
    (interpret mode) on the same operands (asserted in
    ``tests/test_fleet_engine.py``).  Returns
    ``(margin, cluster_idx, pred)``.
    """
    fsel = tables.sel_feats[tk, job, u]                       # (S,)
    idxs = tables.fidx[tk, u]                                 # (S,)
    csel = bank.centroids[tk, u][:, idxs]                     # (C, S)
    dist = jnp.sum(jnp.abs(fsel[None, :] - csel), axis=-1)    # (C,)
    d1 = jnp.min(dist)
    ci = jnp.argmin(dist).astype(_I32)
    d2 = jnp.min(jnp.where(jnp.arange(dist.shape[0]) == ci, _POS, dist))
    margin = (d2 - d1) / jnp.maximum(d1 + d2, 1e-9)
    pred = tables.clabels[tk, u, ci]
    return margin, ci, pred


def _classify_rows(bank: ServeBank, tables: ServeTables, tk, u, job):
    """Batch-polymorphic twin of :func:`classify_unit`.

    ``tk``/``u``/``job`` carry arbitrary leading axes (the scan passes
    ``(D,)``, the fused kernel a ``(bd,)`` tile); ``bank``/feature leaves
    may or may not share those leading axes (shared vs per-device modes).
    All gathers go through the dual-lowering :func:`repro.core.step.take_rows`
    / ``_take`` helpers so the same trace compiles as ``take_along_axis``
    under XLA and as one-hot iota contractions inside Mosaic — and the
    arithmetic (innermost L1 reduction, first-min tie-break, one-hot-masked
    second minimum, scale-free margin) matches :func:`classify_unit`
    bit-for-bit.
    """
    K = tables.fidx.shape[-3]
    Ub = tables.fidx.shape[-2]
    Wl = tables.labels.shape[-1]
    C = bank.centroids.shape[-2]
    F = bank.centroids.shape[-1]
    ku = tk * Ub + u
    sf = tables.sel_feats.reshape(
        tables.sel_feats.shape[:-4] + (K * Wl * Ub,
                                       tables.sel_feats.shape[-1]))
    fsel = S.take_rows(sf, (tk * Wl + job) * Ub + u)          # (..., S)
    idxs = S.take_rows(
        tables.fidx.reshape(tables.fidx.shape[:-3]
                            + (K * Ub, tables.fidx.shape[-1])), ku)
    crow = S.take_rows(
        bank.centroids.reshape(bank.centroids.shape[:-4] + (K * Ub, C * F)),
        ku)
    crow = crow.reshape(crow.shape[:-1] + (C, F))             # (..., C, F)
    csel = S._take(crow, idxs[..., None, :])                  # (..., C, S)
    dist = jnp.sum(jnp.abs(fsel[..., None, :] - csel), axis=-1)
    d1 = jnp.min(dist, axis=-1)
    ci = jnp.argmin(dist, axis=-1).astype(_I32)
    iota_c = lax.broadcasted_iota(_I32, dist.shape, dist.ndim - 1)
    d2 = jnp.min(jnp.where(iota_c == ci[..., None], _POS, dist), axis=-1)
    margin = (d2 - d1) / jnp.maximum(d1 + d2, 1e-9)
    pred = S._take1(
        tables.clabels.reshape(tables.clabels.shape[:-3] + (K * Ub * C,)),
        ku * C + ci)
    return margin, ci, pred


def serve_step(cfg: FleetConfig, tables: ServeTables, dev, bank: ServeBank,
               log: ServeLog, t, job0, *, statics: FleetStatics):
    """One live-serving timestep for every device — batch-polymorphic.

    The whole-fleet twin of :meth:`FleetServeEngine._scan_steps`'s per-step
    body, written over arbitrary leading device axes so the exact same
    trace runs as the scan body (XLA, leading ``(D,)``) *and* inside the
    fused Pallas segment kernel (a ``(bd,)`` VMEM tile under
    :func:`repro.core.step.onehot_lowering`): admit → drop-expired → pick →
    classify against the bank → inject ``(margin, passed, correct)`` into
    :func:`repro.core.step.apply_step` → latch the utility pass → write the
    per-job outcome log.

    ``job0`` (``(K,)`` i32) rebases global job ids into the streamed table
    window: row ``j`` of the ``(..., K, Wl)`` feature/label/log leaves holds
    job ``job0[k] + j``.  The monolithic path passes zeros, making the
    rebasing the identity.  Bank adaptation stays fleet-level (the
    propagation convs don't tile) — the engine applies it after this step
    from the returned ``(first_pass, tk, u, job, ci)`` aux; the ordering
    swap is exact because the log never reads the bank.

    Like :func:`repro.core.step.apply_step`'s live mode, ``t_end`` is left
    to the ``t + dt`` fallback in *both* execution contexts so the serve
    paths stay bit-identical to each other and to the scalar engine.
    """
    K = cfg.period.shape[-1]
    n_u = cfg.unit_time.shape[-1]
    Ue = cfg.exit_thr.shape[-1]
    Wl = tables.labels.shape[-1]
    Ub = tables.fidx.shape[-2]
    Q = statics.queue_size

    dev = S.admit(cfg, dev, t, statics, True)
    dev = S.drop_expired(cfg, dev, t, True)
    sel, picked, run, e_new = S.pick(cfg, dev, t, statics, True)

    # selected-slot identity, pre-apply
    tk = jnp.clip(S._take1(dev.q_task, sel), 0, K - 1)
    u = jnp.clip(S._take1(dev.q_unit, sel), 0, n_u - 1)
    job = jnp.clip(S._take1(dev.q_job, sel) - S._take1(job0, tk),
                   0, Wl - 1)
    complete = run & (S._take1(dev.q_time_left, sel) - statics.dt
                      <= statics.dt * 1e-3)
    exited_pre = S._take1(dev.q_exited, sel)
    apass_pre = S._take1(dev.q_apass, sel)
    ddl = S._take1(dev.q_deadline, sel)
    nu_sel = S._take1(cfg.n_units, tk)
    thr_cfg = S._take1(S._flat2(cfg.exit_thr), tk * Ue + u)

    margin, ci, pred = _classify_rows(bank, tables, tk, u, job)
    label = S._take1(
        tables.labels.reshape(tables.labels.shape[:-2] + (K * Wl,)),
        tk * Wl + job)
    correct = pred == label
    pass_bank = margin > S._take1(
        tables.thr.reshape(tables.thr.shape[:-2] + (K * Ub,)), tk * Ub + u)
    passed = jnp.where(cfg.use_exit_thr, margin > thr_cfg, pass_bank)

    dev = S.apply_step(cfg, dev, t, sel, picked, run, e_new, statics, True,
                       (margin, passed, correct))

    # engine-owned utility-pass latch: adaptation fires at the FIRST
    # bank-threshold pass (like DynamicJobProfile — even under EDF, where
    # the scheduler itself never exits early)
    first_pass = complete & pass_bank & ~apass_pre
    oh = S._oh_eq(sel, Q)
    dev = dev._replace(
        q_apass=dev.q_apass | (oh & (complete & pass_bank)[..., None]))

    # per-job outcome log (mirrors apply_step's completion math)
    exit_now = complete & cfg.imprecise & (exited_pre < 0) & passed
    exited_mid = jnp.where(exit_now, u, exited_pre)
    full_mand = complete & (exited_mid < 0) & (u + 1 >= nu_sel)
    mand_now = exit_now | full_mand
    sched_now = (t + statics.dt) <= ddl
    nd = complete.ndim
    kk = lax.broadcasted_iota(_I32, complete.shape + (K, Wl), nd)
    jj = lax.broadcasted_iota(_I32, complete.shape + (K, Wl), nd + 1)
    m_jd = (complete[..., None, None]
            & (kk == tk[..., None, None]) & (jj == job[..., None, None]))

    def put(old, new, mask=None):
        mm = m_jd if mask is None else m_jd & mask[..., None, None]
        return jnp.where(mm, new[..., None, None], old)

    log = ServeLog(
        units=put(log.units, u + 1),
        pred=put(log.pred, pred),
        correct=put(log.correct, correct),
        margin=put(log.margin, margin),
        exit_unit=put(log.exit_unit, u, first_pass),
        sched=put(log.sched, sched_now, mand_now),
    )
    return dev, log, (first_pass, tk, u, job, ci)


def _shift_log(log: ServeLog, shift):
    """Advance the per-task log window by ``shift`` jobs.

    Row ``j`` of the new window is row ``j + shift[k]`` of the old; rows
    shifted in from beyond the old window reset to the t=0 defaults (the
    same values :meth:`FleetServeEngine.build`'s ``log0`` uses, so a job
    that is never served reads identically in streamed and monolithic
    runs).  ``shift`` is a traced ``(K,)`` i32 — every chunk shares one
    compiled program.
    """
    Wl = log.units.shape[-1]
    K = shift.shape[-1]
    jj = lax.broadcasted_iota(_I32, (K, Wl), 1)
    src = jj + shift[..., None]
    valid = src < Wl
    srcc = jnp.clip(src, 0, Wl - 1)

    def gather(leaf, default):
        idx = jnp.broadcast_to(srcc, leaf.shape)
        moved = jnp.take_along_axis(leaf, idx, axis=-1)
        return jnp.where(valid, moved, jnp.asarray(default, leaf.dtype))

    return ServeLog(
        units=gather(log.units, 0),
        pred=gather(log.pred, -1),
        correct=gather(log.correct, False),
        margin=gather(log.margin, 0.0),
        exit_unit=gather(log.exit_unit, -1),
        sched=gather(log.sched, False),
    )


def _device_peak_bytes() -> int:
    """Peak live device bytes, or 0 where the backend keeps no memory
    statistics (plain-CPU ``memory_stats()`` returns ``None``)."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return 0
    if not stats:
        return 0
    return int(stats.get("peak_bytes_in_use", 0))


@dataclass
class FleetServeResult:
    """Outcome of one vectorized live-serving run.

    ``fleet`` holds the step core's SimResult-shaped ``(D,)`` aggregates
    (live-mode finalize: correctness from the live registers); the per-job
    arrays are the numpy view of the :class:`ServeLog` (``(D, K, J)``
    each).  ``carry`` is the end-of-horizon :class:`ServeCarry` for
    checkpoint/resume; ``wall_s``/``jobs_per_sec`` time the jitted scan
    only (feature precompute excluded — it is amortised, input-dependent
    work shared with any batched-inference baseline).
    """

    fleet: FleetResult
    units: np.ndarray
    pred: np.ndarray
    correct: np.ndarray
    margin: np.ndarray
    exit_unit: np.ndarray
    sched: np.ndarray
    carry: ServeCarry
    jobs: int
    wall_s: float
    telemetry: Optional[T.Telemetry] = None
    #: steady-state/compile split (streaming runs): ``wall_s`` above counts
    #: staging + execution only; one-time chunk-runner compiles land here
    compile_s: float = 0.0
    #: backend peak live bytes after the run (0 on stats-less backends)
    peak_bytes: int = 0
    #: device bytes of ONE staged feature-window table — the O(chunk)
    #: resident footprint that replaces the O(total jobs) tables of `run`
    chunk_table_bytes: int = 0
    n_chunks: int = 1

    @property
    def jobs_per_sec(self) -> float:
        return self.jobs / max(self.wall_s, 1e-9)


class FleetServeEngine:
    """Vectorized live serving of agile-model tasks across a device fleet.

    Same constructor shape as the scalar :class:`ServeEngine` plus the
    fleet knobs: ``bank_mode`` ("per-device" | "shared") and
    ``feature_batch`` (chunk size of the feature precompute; ``1``
    reproduces the scalar engine's per-sample arithmetic exactly).
    """

    def __init__(
        self,
        models: Sequence,
        harvester: Harvester,
        eta: float,
        cap: Optional[Capacitor] = None,
        config: Optional[ServeConfig] = None,
        *,
        bank_mode: str = "per-device",
        feature_batch: Optional[int] = None,
        adapt_weight: float = 32.0,
    ):
        if bank_mode not in ("per-device", "shared"):
            raise ValueError(f"unknown bank_mode {bank_mode!r}")
        self.models = list(models)
        self.harvester = harvester
        self.eta = eta
        self.cap = cap or Capacitor()
        self.config = config or ServeConfig()
        self.bank_mode = bank_mode
        self.feature_batch = feature_batch
        self.adapt_weight = float(adapt_weight)
        self.bank0, self._bank_tables, self.meta = stack_banks(self.models)
        self._runners: dict = {}
        # AOT-compiled streaming chunk runners, keyed by (static config,
        # arg shape/dtype signature).  jit's own dispatch cache is NOT
        # populated by ``lower().compile()``, so the executables are cached
        # and invoked directly — same-shape chunks never recompile.
        self._compiled: dict = {}

    # ------------------------------------------------------------------ #
    # Builders.
    # ------------------------------------------------------------------ #

    def _task_specs(self, n_jobs_per_task: Sequence[int]) -> list[TaskSpec]:
        """TaskSpecs with *dummy* zero profiles: live mode never reads the
        replay tables, but the grid builder still sizes ``n_releases`` and
        the clip bounds from them."""
        cfg = self.config
        periods = per_task(cfg.period, len(self.models))
        deadlines = per_task(cfg.deadline, len(self.models))
        tasks = []
        for tid, (m, n_jobs) in enumerate(zip(self.models,
                                              n_jobs_per_task)):
            nu = m.n_units
            ut = (np.asarray(cfg.unit_time, float)
                  if cfg.unit_time is not None else np.full(nu, 0.2))
            ue = (np.asarray(cfg.unit_energy, float)
                  if cfg.unit_energy is not None else np.full(nu, 5e-3))
            zeros = JobProfile(np.zeros(nu), np.zeros(nu, bool),
                               np.zeros(nu, bool))
            tasks.append(TaskSpec(
                task_id=tid, period=periods[tid], deadline=deadlines[tid],
                unit_time=ut[:nu], unit_energy=ue[:nu],
                profiles=[zeros] * n_jobs,
                fragments_per_unit=cfg.fragments_per_unit,
            ))
        return tasks

    def build(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
    ) -> tuple[FleetConfig, FleetStatics, ServeTables, ServeCarry, bool]:
        """Materialise configs, statics, feature tables and the t=0 carry.

        ``requests`` is either one stream shared by every device —
        ``requests[task][job]`` — or per-device streams
        ``requests[device][task][job]`` (detected by nesting).  Returns
        ``(cfg, statics, tables, carry0, per_dev_tables)``.
        """
        cfg = self.config
        per_dev = not isinstance(requests[0][0], Request)
        if per_dev:
            D = len(requests)
            if n_devices is not None and n_devices != D:
                raise ValueError(
                    f"n_devices={n_devices} but {D} request streams given")
            streams = requests
        else:
            D = int(n_devices or 1)
            streams = [requests] * D
        if len(streams[0]) != len(self.models):
            raise ValueError(
                f"{len(streams[0])} request streams per device for "
                f"{len(self.models)} models")

        n_jobs = [max(len(s[k]) for s in streams)
                  for k in range(len(self.models))]
        tasks = self._task_specs(n_jobs)
        dt = grid._check_dt(
            grid._default_dt(tasks) if cfg.sim_dt is None
            else float(cfg.sim_dt), tasks)
        statics = FleetStatics(queue_size=cfg.queue_size, dt=dt,
                               horizon=cfg.horizon,
                               slot_s=self.harvester.slot_s)
        seeds = (list(seeds) if seeds is not None
                 else [cfg.seed] * D)
        if len(seeds) != D:
            raise ValueError(f"{len(seeds)} seeds for {D} devices")
        events = {s: grid.sample_events(self.harvester, cfg.horizon, s)
                  for s in set(seeds)}
        devs = [grid.device_config(
            tasks, self.harvester, self.eta, self.cap,
            policy=cfg.policy, horizon=cfg.horizon, events=events[s],
            e_opt_fraction=cfg.e_opt_fraction,
            start_charged=cfg.start_charged,
        ) for s in seeds]
        fleet_cfg = grid.stack_configs(devs)

        feats = [build_feature_tables(
            self.models, s, self.meta, self._bank_tables,
            feature_batch=self.feature_batch, n_jobs=max(n_jobs))
            for s in streams]
        if per_dev:
            stacked = {k: jnp.asarray(np.stack([f[k] for f in feats]))
                       for k in feats[0]}
        else:
            stacked = {k: jnp.asarray(v) for k, v in feats[0].items()}
        tables = ServeTables(**stacked, **self._bank_tables)

        dev0 = jax.vmap(lambda c: init_state(c, statics))(fleet_cfg)
        bank0 = self.bank0
        if self.bank_mode == "per-device":
            bank0 = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (D,) + l.shape), bank0)
        K, J = len(self.models), max(n_jobs)
        log0 = ServeLog(
            units=jnp.zeros((D, K, J), _I32),
            pred=jnp.full((D, K, J), -1, _I32),
            correct=jnp.zeros((D, K, J), bool),
            margin=jnp.zeros((D, K, J), _F32),
            exit_unit=jnp.full((D, K, J), -1, _I32),
            sched=jnp.zeros((D, K, J), bool),
        )
        return (fleet_cfg, statics, tables,
                ServeCarry(dev=dev0, bank=bank0, log=log0), per_dev)

    # ------------------------------------------------------------------ #
    # The jitted scan.
    # ------------------------------------------------------------------ #

    def _adapt_per_device(self, bank: ServeBank, x_full, tk, u, ci, do):
        """One device's weighted-average bank update + centroid propagation
        (unbatched; the runner vmaps it over the fleet).

        Bit-matches ``km.adapt`` + ``_propagate_from`` on one sample: the
        assigned row becomes ``(w c + x) / (w + 1)`` (the kernel's one-hot
        matmul contributes exactly ``x``), every other row is untouched
        (the kernel computes ``(w c) / w`` — exact for ``w = 32``), and the
        propagation chain refreshes row ``ci`` of each deeper unit from the
        *progressively updated* shallower tables, exactly as the scalar
        loop does."""
        w = self.adapt_weight
        K_, U_, C_, _ = bank.centroids.shape
        m3 = (do
              & (jnp.arange(K_)[:, None, None] == tk)
              & (jnp.arange(U_)[None, :, None] == u)
              & (jnp.arange(C_)[None, None, :] == ci))
        # the barrier keeps the divisor out of constant folding: XLA would
        # otherwise rewrite /(w+1) into *(1/(w+1)) under jit, drifting one
        # ulp off the scalar path's true division
        denom = lax.optimization_barrier(jnp.float32(w + 1.0))
        cents = jnp.where(m3[..., None],
                          (w * bank.centroids + x_full) / denom,
                          bank.centroids)
        counts = bank.counts + m3
        for k, m in enumerate(self.models):
            for v in range(m.n_units - 1):
                act = do & (tk == k) & (u <= v)
                kc = self.meta.n_clusters[k][v]
                f_in = self.meta.feat_dim[k][v]
                f_out = self.meta.feat_dim[k][v + 1]
                r = counts[k, v, :kc, None]
                src = cents[k, v, :kc, :f_in]
                img = jax.nn.relu(m.unit_apply_flat(v + 1, r * src)) / r
                row = (jnp.arange(kc) == ci) & act
                new = jnp.where(row[:, None], img,
                                cents[k, v + 1, :kc, :f_out])
                cents = cents.at[k, v + 1, :kc, :f_out].set(new)
        return ServeBank(centroids=cents, counts=counts)

    def _adapt_shared(self, bank: ServeBank, x_full, tk, u, ci, do):
        """Collaborative shared-bank update: all devices exiting at (k, u)
        this step fold into ONE :func:`km.online_update` (batch-averaged —
        the documented semantic difference vs sequential per-device
        adaptation), then one propagation sweep refreshes every touched
        row of the deeper units."""
        from ..core import kmeans as km

        cents, counts = bank.centroids, bank.counts
        C_ = cents.shape[2]
        for k, m in enumerate(self.models):
            hot = jnp.zeros((C_,), bool)
            for v in range(m.n_units):
                kc = self.meta.n_clusters[k][v]
                fu = self.meta.feat_dim[k][v]
                mrow = do & (tk == k) & (u == v)
                idxk = jnp.where(mrow, ci, -1)
                new_c, new_n = km.online_update(
                    cents[k, v, :kc, :fu], counts[k, v, :kc],
                    x_full[:, :fu], idxk, weight=self.adapt_weight)
                cents = cents.at[k, v, :kc, :fu].set(new_c)
                counts = counts.at[k, v, :kc].set(new_n)
                if v == m.n_units - 1:
                    break
                hot = hot | jnp.any(
                    mrow[:, None] & (jnp.arange(C_)[None, :] == ci[:, None]),
                    axis=0)
                f_out = self.meta.feat_dim[k][v + 1]
                r = counts[k, v, :kc, None]
                src = cents[k, v, :kc, :fu]
                img = jax.nn.relu(m.unit_apply_flat(v + 1, r * src)) / r
                new = jnp.where(hot[:kc, None], img,
                                cents[k, v + 1, :kc, :f_out])
                cents = cents.at[k, v + 1, :kc, :f_out].set(new)
        return ServeBank(centroids=cents, counts=counts)

    def _scan_steps(self, cfg: FleetConfig, tables: ServeTables,
                    carry, i0, tel=None, job0=None, *,
                    statics: FleetStatics,
                    n_steps: int, adapt: bool, shared: bool,
                    per_dev_tables: bool,
                    tcfg: Optional[T.TelemetryConfig] = None):
        """Scan ``n_steps`` live timesteps from step index ``i0``.

        The per-step transition is the batch-polymorphic
        :func:`serve_step` (shared verbatim with the fused Pallas kernel),
        plus the fleet-level bank adaptation from its aux outputs.
        ``job0`` (``(K,)`` i32, default zeros) rebases global job ids into
        streamed table windows — see :meth:`run_stream`.

        With ``tcfg`` set, the scan emits the telemetry columns of the
        requested tier and reduces them into ``tel`` post-scan, returning
        ``(ServeCarry, Telemetry, ring_columns)``: at the ``"counters"``
        tier the plain step body emits three registers it already computed
        (``ring_columns`` is ``None``); at the ``"full"`` tier the stages
        run their descriptor-emitting twins
        (:class:`repro.core.step.StepTrace`), the events are bit-packed
        per step, and the caller folds the rare ring/histogram events
        host-side via :func:`repro.telemetry.trace.fold_events_host`.  The
        serve numerics cannot change: tracing only adds outputs."""
        trace = tcfg is not None and tcfg.level == "full"
        counters = tcfg is not None and not trace
        spec = (T_trace.make_pack_spec(int(cfg.period.shape[1]),
                                       statics.queue_size,
                                       int(cfg.unit_time.shape[-1]) + 1)
                if trace else None)
        K = cfg.period.shape[1]
        u_max = cfg.unit_time.shape[2] - 1
        J = tables.labels.shape[-1]
        Q = statics.queue_size
        if job0 is None:
            job0 = jnp.zeros((K,), _I32)
        tab_axes = ServeTables(
            sel_feats=0 if per_dev_tables else None,
            full_feats=0 if per_dev_tables else None,
            labels=0 if per_dev_tables else None,
            clabels=None, fidx=None, thr=None)
        bank_ax = None if shared else 0

        def gather(c, s, a, r):
            """Selected-slot identity for one device, pre-apply."""
            tk = jnp.clip(s.q_task[a], 0, K - 1)
            u = jnp.clip(s.q_unit[a], 0, u_max)
            job = jnp.clip(s.q_job[a] - job0[tk], 0, J - 1)
            complete = r & (s.q_time_left[a] - statics.dt
                            <= statics.dt * 1e-3)
            return (tk, u, job, complete, s.q_exited[a], s.q_apass[a],
                    s.q_deadline[a], c.n_units[tk], c.imprecise,
                    c.use_exit_thr, c.exit_thr[tk, u])

        def adapt_bank(bank, tk, u, job, ci, first_pass):
            Ub = tables.fidx.shape[-2]
            if per_dev_tables:
                x_full = tables.full_feats[
                    jnp.arange(tk.shape[0]), tk, job, u]
            else:
                ff = tables.full_feats.reshape(
                    (K * J * Ub, tables.full_feats.shape[-1]))
                x_full = S.take_rows(ff, (tk * J + job) * Ub + u)

            def _upd(args):
                b, xf, tkk, uu, cii, fp = args
                if shared:
                    return self._adapt_shared(b, xf, tkk, uu, cii, fp)
                return jax.vmap(self._adapt_per_device)(
                    b, xf, tkk, uu, cii, fp)

            # most steps complete nothing: skip the propagation convs
            # entirely unless some device's utility test just passed
            return lax.cond(
                jnp.any(first_pass), _upd, lambda args: args[0],
                (bank, x_full, tk, u, ci, first_pass))

        def step(carry, i):
            dev, bank, log = carry
            t = i.astype(_F32) * statics.dt
            dev, log, (first_pass, tk, u, job, ci) = serve_step(
                cfg, tables, dev, bank, log, t, job0, statics=statics)
            if adapt:
                bank = adapt_bank(bank, tk, u, job, ci, first_pass)
            new_carry = ServeCarry(dev=dev, bank=bank, log=log)
            if counters:
                return new_carry, T_trace.emit_counters(dev)
            return new_carry, None

        def step_trace(carry, i):
            dev, bank, log = carry
            dev0 = dev
            t = i.astype(_F32) * statics.dt
            act0 = dev.q_active
            dev, (tr_adm, tr_ev, tr_ev_dl) = jax.vmap(
                lambda c, s: S.admit(c, s, t, statics, True,
                                     trace=True))(cfg, dev)
            dev, (tr_exp, tr_exp_dl) = jax.vmap(
                lambda c, s, a0: S.drop_expired(c, s, t, True,
                                                trace=True,
                                                q_active_pre=a0)
            )(cfg, dev, act0)
            sel, picked, run, e_new = jax.vmap(
                lambda c, s: S.pick(c, s, t, statics, True))(cfg, dev)
            (tk, u, job, complete, exited_pre, apass_pre, ddl, nu_sel,
             imprec, use_thr, thr_cfg) = jax.vmap(gather)(cfg, dev, sel, run)

            margin, ci, pred = jax.vmap(
                classify_unit, in_axes=(bank_ax, tab_axes, 0, 0, 0))(
                bank, tables, tk, u, job)
            if per_dev_tables:
                label = tables.labels[jnp.arange(tk.shape[0]), tk, job]
            else:
                label = tables.labels[tk, job]
            correct = pred == label
            pass_bank = margin > tables.thr[tk, u]
            passed = jnp.where(use_thr, margin > thr_cfg, pass_bank)

            dev, (tr_comp, tr_comp_dl) = jax.vmap(
                lambda c, s, a, p, r, e, mg, ps, co, a0: S.apply_step(
                    c, s, t, a, p, r, e, statics, True, (mg, ps, co),
                    trace=True, q_active_pre=a0))(
                cfg, dev, sel, picked, run, e_new, margin, passed,
                correct, act0)
            tr = S.StepTrace(adm=tr_adm, evict=tr_ev,
                             evict_dl=tr_ev_dl, expire=tr_exp,
                             expire_dl=tr_exp_dl, complete=tr_comp,
                             complete_dl=tr_comp_dl)

            # engine-owned utility-pass latch: adaptation fires at the FIRST
            # bank-threshold pass (like DynamicJobProfile — even under EDF,
            # where the scheduler itself never exits early)
            first_pass = complete & pass_bank & ~apass_pre
            oh = jnp.arange(Q)[None, :] == sel[:, None]
            dev = dev._replace(
                q_apass=dev.q_apass | (oh & (complete & pass_bank)[:, None]))

            if adapt:
                bank = adapt_bank(bank, tk, u, job, ci, first_pass)

            # per-job outcome log (mirrors apply_step's completion math)
            exit_now = complete & imprec & (exited_pre < 0) & passed
            exited_mid = jnp.where(exit_now, u, exited_pre)
            full_mand = complete & (exited_mid < 0) & (u + 1 >= nu_sel)
            mand_now = exit_now | full_mand
            sched_now = (t + statics.dt) <= ddl
            m_jd = (complete[:, None, None]
                    & (jnp.arange(K)[None, :, None] == tk[:, None, None])
                    & (jnp.arange(J)[None, None, :] == job[:, None, None]))

            def put(old, new, mask=None):
                mm = m_jd if mask is None else m_jd & mask[:, None, None]
                return jnp.where(mm, new[:, None, None], old)

            log = ServeLog(
                units=put(log.units, u + 1),
                pred=put(log.pred, pred),
                correct=put(log.correct, correct),
                margin=put(log.margin, margin),
                exit_unit=put(log.exit_unit, u, first_pass),
                sched=put(log.sched, sched_now, mand_now),
            )
            new_carry = ServeCarry(dev=dev, bank=bank, log=log)
            return new_carry, T_trace.emit_full(spec, tr, dev0, dev)

        if trace:
            step = step_trace

        if tcfg is None:
            carry, _ = lax.scan(step, carry, i0 + jnp.arange(n_steps))
            return carry
        st0 = carry.dev
        carry, ys = lax.scan(step, carry, i0 + jnp.arange(n_steps))
        if counters:
            return carry, T_trace.reduce_counters(tel, st0, carry.dev, ys,
                                                  n_steps), None
        tel, ring = T_trace.reduce_full(spec, tel, st0, carry.dev, ys, i0,
                                        n_steps, statics.dt)
        return carry, tel, ring

    def _runner(self, statics: FleetStatics, n_steps: int, adapt: bool,
                shared: bool, per_dev_tables: bool, tcfg=None):
        key = (statics, n_steps, adapt, shared, per_dev_tables, tcfg)
        if key not in self._runners:
            self._runners[key] = jax.jit(functools.partial(
                self._scan_steps, statics=statics, n_steps=n_steps,
                adapt=adapt, shared=shared, per_dev_tables=per_dev_tables,
                tcfg=tcfg))
        return self._runners[key]

    # ------------------------------------------------------------------ #
    # Public entry point.
    # ------------------------------------------------------------------ #

    def run(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
        n_segments: int = 1,
        carry: Optional[ServeCarry] = None,
        mesh=None,
        telemetry: Optional[T.TelemetryConfig] = None,
        mode: str = "scan",
    ) -> FleetServeResult:
        """Serve every request stream live through one jitted fleet scan.

        ``n_segments > 1`` materialises the :class:`ServeCarry` at segment
        boundaries (checkpointable, bit-identical to ``n_segments=1``);
        ``carry`` resumes from a previous run's carry.  ``mesh`` places the
        carry/config/tables with the device axis partitioned
        (:func:`repro.launch.sharding.shard_serve_carry`; ``D`` must be a
        mesh-size multiple).  ``telemetry`` (a
        :class:`repro.telemetry.TelemetryConfig`) threads a ``(D, ...)``
        telemetry pytree through the serve scan and fills
        ``FleetServeResult.telemetry`` — the serve outcome itself is
        bit-exact either way.

        ``mode="fused"`` runs each segment as ONE ``pallas_call``
        (:func:`repro.kernels.ops.serve_fused_steps`): the classify +
        live-register update execute in-tile with the centroid bank
        VMEM-resident, bit-exact vs the scan.  Adaptation moves centroids
        through whole-model convs (``unit_apply_flat``) that don't tile,
        so the fused mode requires ``adapt=False`` — and it has no
        telemetry/mesh hooks.
        """
        if mode not in ("scan", "fused"):
            raise ValueError(f"unknown serve mode {mode!r}")
        adapt = bool(self.config.adapt)
        if mode == "fused":
            if adapt:
                raise ValueError(
                    "mode='fused' requires adapt=False: bank adaptation "
                    "propagates centroids through whole-model convs that "
                    "cannot run inside a device tile")
            if telemetry is not None or mesh is not None:
                raise ValueError(
                    "mode='fused' does not support telemetry= or mesh=")
        cfg, statics, tables, carry0, per_dev = self.build(
            requests, n_devices, seeds=seeds)
        if carry is not None:
            carry0 = carry
        shared = self.bank_mode == "shared"
        tel = (None if telemetry is None
               else T.init_fleet_telemetry(telemetry, cfg))
        if mesh is not None:
            from ..launch.sharding import (
                shard_fleet_carry,
                shard_fleet_config,
                shard_serve_carry,
                shard_serve_tables,
            )

            D = cfg.n_devices
            if D % mesh.size:
                raise ValueError(
                    f"D={D} devices must divide over mesh size {mesh.size}")
            cfg = shard_fleet_config(mesh, cfg)
            carry0 = shard_serve_carry(mesh, carry0, shared_bank=shared)
            tables = shard_serve_tables(mesh, tables, per_device=per_dev)
            if tel is not None:
                tel = shard_fleet_carry(mesh, tel)

        sizes = [len(c) for c in
                 np.array_split(np.arange(statics.n_steps), n_segments)]
        t0 = time.perf_counter()
        i0 = 0
        out = carry0
        for n in sizes:
            if not n:
                continue
            if mode == "fused":
                from ..kernels import ops

                out = ops.serve_fused_steps(
                    cfg, out, tables, jnp.int32(i0),
                    jnp.zeros((len(self.models),), _I32),
                    statics=statics, n_steps=n, shared_bank=shared,
                    per_dev_tables=per_dev)
                i0 += n
                continue
            runner = self._runner(statics, n, adapt, shared, per_dev,
                                  telemetry)
            if telemetry is None:
                out = runner(cfg, tables, out, jnp.int32(i0))
            else:
                out, tel, ring = runner(cfg, tables, out, jnp.int32(i0),
                                        tel)
                if ring is not None:
                    spec = T_trace.make_pack_spec(
                        int(cfg.period.shape[1]), statics.queue_size,
                        int(tel.exit_hist.shape[1]))
                    tel = T_trace.fold_events_host(
                        spec, tel, tuple(np.asarray(c) for c in ring),
                        i0, statics.dt)
            i0 += n
        fleet = finalize_fleet(cfg, out.dev, statics, live=True)
        jax.block_until_ready(fleet)
        wall = time.perf_counter() - t0

        log = out.log
        return FleetServeResult(
            fleet=fleet,
            units=np.asarray(log.units),
            pred=np.asarray(log.pred),
            correct=np.asarray(log.correct),
            margin=np.asarray(log.margin),
            exit_unit=np.asarray(log.exit_unit),
            sched=np.asarray(log.sched),
            carry=out,
            jobs=int(np.asarray(fleet.released).sum()),
            wall_s=wall,
            telemetry=tel,
        )

    # ------------------------------------------------------------------ #
    # Streaming entry point: O(chunk) device memory for any job total.
    # ------------------------------------------------------------------ #

    @staticmethod
    def _count_releases(period: float, horizon: float,
                        max_jobs: int) -> int:
        """Replicate ``grid._n_releases``'s float release accumulation
        (bit-for-bit, including the ``t += period`` slip) with the cap
        taken from the streamed job total instead of ``len(profiles)``."""
        t, j = 0.0, 0
        while t < horizon and j < max_jobs:
            t += period
            j += 1
        return j

    def build_stream(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
        total_jobs=None,
    ):
        """Like :meth:`build`, but O(1) in the total job count.

        The grid builder gets single-job placeholder profiles (live mode
        never reads the replay tables) with ``n_releases`` overridden to
        the streamed totals, and the feature/label tables stay host-side
        numpy — :meth:`run_stream` stages a bounded window of them per
        chunk.  ``total_jobs`` (int or per-task sequence, default = the
        base stream length) sets how many jobs each task serves; totals
        beyond the base stream cycle it (job ``j`` reuses request
        ``j % len(base)``).

        Returns ``(cfg, statics, base_tables, dev0, bank0, per_dev,
        totals, base_len)`` with ``base_tables`` a numpy dict.
        """
        cfg = self.config
        K = len(self.models)
        per_dev = not isinstance(requests[0][0], Request)
        if per_dev:
            D = len(requests)
            if n_devices is not None and n_devices != D:
                raise ValueError(
                    f"n_devices={n_devices} but {D} request streams given")
            streams = requests
        else:
            D = int(n_devices or 1)
            streams = [requests]
        if len(streams[0]) != K:
            raise ValueError(
                f"{len(streams[0])} request streams per device for "
                f"{K} models")
        base_len = [max(len(s[k]) for s in streams) for k in range(K)]
        if any(b <= 0 for b in base_len):
            raise ValueError("every task needs at least one base request")
        if total_jobs is None:
            totals = list(base_len)
        elif np.ndim(total_jobs) == 0:
            totals = [int(total_jobs)] * K
        else:
            totals = [int(x) for x in total_jobs]

        tasks = self._task_specs([1] * K)
        dt = grid._check_dt(
            grid._default_dt(tasks) if cfg.sim_dt is None
            else float(cfg.sim_dt), tasks)
        statics = FleetStatics(queue_size=cfg.queue_size, dt=dt,
                               horizon=cfg.horizon,
                               slot_s=self.harvester.slot_s)
        seeds = (list(seeds) if seeds is not None else [cfg.seed] * D)
        if len(seeds) != D:
            raise ValueError(f"{len(seeds)} seeds for {D} devices")
        events = {s: grid.sample_events(self.harvester, cfg.horizon, s)
                  for s in set(seeds)}
        devs = [grid.device_config(
            tasks, self.harvester, self.eta, self.cap,
            policy=cfg.policy, horizon=cfg.horizon, events=events[s],
            e_opt_fraction=cfg.e_opt_fraction,
            start_charged=cfg.start_charged,
        ) for s in seeds]
        fleet_cfg = grid.stack_configs(devs)
        n_rel = np.array([self._count_releases(tasks[k].period, cfg.horizon,
                                               totals[k])
                          for k in range(K)], np.int32)
        fleet_cfg = fleet_cfg._replace(
            n_releases=jnp.asarray(np.broadcast_to(n_rel, (D, K)).copy()))

        feats = [build_feature_tables(
            self.models, s, self.meta, self._bank_tables,
            feature_batch=self.feature_batch, n_jobs=max(base_len))
            for s in streams]
        if per_dev:
            base = {k: np.stack([f[k] for f in feats]) for k in feats[0]}
        else:
            base = feats[0]

        dev0 = jax.vmap(lambda c: init_state(c, statics))(fleet_cfg)
        bank0 = self.bank0
        if self.bank_mode == "per-device":
            bank0 = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (D,) + l.shape), bank0)
        return (fleet_cfg, statics, base, dev0, bank0, per_dev, totals,
                base_len)

    def _stream_step_chunk(self, cfg, tables, carry, i0, job0, shift, *,
                           statics, n_steps, adapt, shared,
                           per_dev_tables, mode):
        """One donated chunk: advance the log window, scan the chunk."""
        carry = carry._replace(log=_shift_log(carry.log, shift))
        if mode == "fused":
            from ..kernels import ops

            return ops.serve_fused_steps(
                cfg, carry, tables, i0, job0, statics=statics,
                n_steps=n_steps, shared_bank=shared,
                per_dev_tables=per_dev_tables)
        return self._scan_steps(cfg, tables, carry, i0, None, job0,
                                statics=statics, n_steps=n_steps,
                                adapt=adapt, shared=shared,
                                per_dev_tables=per_dev_tables, tcfg=None)

    def _stream_step_chunk_tel(self, cfg, tables, carry, i0, job0, shift,
                               tel, *, statics, n_steps, adapt, shared,
                               per_dev_tables, tcfg):
        carry = carry._replace(log=_shift_log(carry.log, shift))
        carry, tel, _ = self._scan_steps(cfg, tables, carry, i0, tel, job0,
                                         statics=statics, n_steps=n_steps,
                                         adapt=adapt, shared=shared,
                                         per_dev_tables=per_dev_tables,
                                         tcfg=tcfg)
        return carry, tel

    def _stream_runner(self, *, statics, n_steps, adapt, shared,
                       per_dev_tables, mode, tcfg, args):
        """AOT-compiled chunk runner with the carry (and telemetry)
        buffers DONATED — chunk N+1's carry reuses chunk N's memory, so
        peak live bytes don't grow with the chunk count.  ``lower().
        compile()`` bypasses jit's dispatch cache, so executables are
        cached here keyed by (static config, arg shapes/dtypes): every
        same-shape chunk reuses one compilation."""
        if tcfg is None:
            fn = functools.partial(
                self._stream_step_chunk, statics=statics, n_steps=n_steps,
                adapt=adapt, shared=shared, per_dev_tables=per_dev_tables,
                mode=mode)
            donate = (2,)
        else:
            fn = functools.partial(
                self._stream_step_chunk_tel, statics=statics,
                n_steps=n_steps, adapt=adapt, shared=shared,
                per_dev_tables=per_dev_tables, tcfg=tcfg)
            donate = (2, 6)
        sig = tuple((tuple(l.shape), str(l.dtype))
                    for l in jax.tree.leaves(args))
        key = (statics, n_steps, adapt, shared, per_dev_tables, mode,
               tcfg, sig)
        hit = self._compiled.get(key)
        if hit is not None:
            return hit, 0.0
        t0 = time.perf_counter()
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
        cs = time.perf_counter() - t0
        self._compiled[key] = compiled
        return compiled, cs

    def run_stream(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
        total_jobs=None,
        n_chunks: int = 1,
        mode: str = "scan",
        collect_log: bool = True,
        telemetry: Optional[T.TelemetryConfig] = None,
    ) -> FleetServeResult:
        """Serve a job stream of any length with O(chunk) device memory.

        The horizon is split into ``n_chunks`` step ranges; each chunk
        stages only the bounded window of per-job feature/label rows its
        steps can touch (computed from periods, deadlines and clock
        drift), rebases job ids with ``job0``, and runs one donated,
        AOT-cached chunk program — the :class:`ServeCarry` buffers are
        reused in place between chunks and the full per-job log is
        assembled host-side.  Bit-exact vs :meth:`run` on the same
        requests for ANY chunking.  ``total_jobs`` streams past the base
        request list by cycling it (job ``j`` serves request
        ``j % len(base)``), which is how a single call serves millions of
        jobs.  ``mode="fused"`` routes each chunk through the fused
        Pallas segment kernel.  ``telemetry`` supports the ``"counters"``
        tier (the ``"full"`` tier's ring fold is per-run host state —
        use :meth:`run`).
        """
        cfg_s = self.config
        adapt = bool(cfg_s.adapt)
        shared = self.bank_mode == "shared"
        if mode not in ("scan", "fused"):
            raise ValueError(f"unknown serve mode {mode!r}")
        if mode == "fused" and (adapt or telemetry is not None):
            raise ValueError(
                "mode='fused' requires adapt=False and no telemetry")
        if telemetry is not None and telemetry.level == "full":
            raise ValueError(
                "run_stream supports the 'counters' telemetry tier only")

        (fleet_cfg, statics, base, dev0, bank0, per_dev, totals,
         base_len) = self.build_stream(requests, n_devices, seeds=seeds,
                                       total_jobs=total_jobs)
        D = int(fleet_cfg.policy.shape[0])
        K = len(self.models)
        periods = np.array(per_task(cfg_s.period, K), float)
        deadl = np.array(per_task(cfg_s.deadline, K), float)
        drift = float(np.max(np.abs(np.asarray(fleet_cfg.clock_drift))))
        n_steps = statics.n_steps
        nc = int(max(1, min(n_chunks, max(n_steps, 1))))
        segs = [s for s in np.array_split(np.arange(n_steps), nc)
                if len(s)]
        bounds = [(int(s[0]), int(s[-1]) + 1) for s in segs]

        # per-chunk job windows: a job live during [t0, t1) must release
        # before t1 and expire after t0 (with the slow-clock drift bound
        # t_read = t * (1 + drift) stretching lifetimes by ≤ 1 + 2*drift);
        # ±2 rows absorb the f32 release-accumulation slip
        lo_list, hi_list = [], []
        for s0, s1 in bounds:
            t0s, t1s = s0 * statics.dt, s1 * statics.dt
            lo_list.append(np.floor(
                (t0s / (1.0 + 2.0 * drift) - deadl) / periods
            ).astype(np.int64) - 2)
            hi_list.append(np.floor(t1s / periods).astype(np.int64) + 2)
        Wl = int(max(int(np.max(h - l))
                     for l, h in zip(lo_list, hi_list)))
        Wl = max(Wl, 1)

        sel_b, full_b, lab_b = (base["sel_feats"], base["full_feats"],
                                base["labels"])

        def stage(w0):
            idx = w0[:, None] + np.arange(Wl)[None, :]
            ps, pf, pl = [], [], []
            for k in range(K):
                src = idx[k] % base_len[k]
                ps.append(np.take(sel_b[..., k, :, :, :], src, axis=-3))
                pf.append(np.take(full_b[..., k, :, :, :], src, axis=-3))
                pl.append(np.take(lab_b[..., k, :], src, axis=-1))
            return (np.stack(ps, axis=-4), np.stack(pf, axis=-4),
                    np.stack(pl, axis=-2))

        log0 = ServeLog(
            units=jnp.zeros((D, K, Wl), _I32),
            pred=jnp.full((D, K, Wl), -1, _I32),
            correct=jnp.zeros((D, K, Wl), bool),
            margin=jnp.zeros((D, K, Wl), _F32),
            exit_unit=jnp.full((D, K, Wl), -1, _I32),
            sched=jnp.zeros((D, K, Wl), bool),
        )
        carry = ServeCarry(dev=dev0, bank=bank0, log=log0)
        # donated chunk inputs must not alias non-donated args: init_state
        # forwards some config leaves by reference (e.g. dev.energy IS
        # cfg.start_energy when starting charged), and XLA rejects
        # `f(a, donate(a))`.  One up-front copy of the O(chunk) carry
        # breaks every such alias; later chunks reuse donated buffers.
        carry = jax.tree.map(jnp.array, carry)
        tel = (None if telemetry is None
               else T.init_fleet_telemetry(telemetry, fleet_cfg))

        full_log = None
        if collect_log:
            Jt = max(max(totals), 1)
            full_log = dict(
                units=np.zeros((D, K, Jt), np.int32),
                pred=np.full((D, K, Jt), -1, np.int32),
                correct=np.zeros((D, K, Jt), bool),
                margin=np.zeros((D, K, Jt), np.float32),
                exit_unit=np.full((D, K, Jt), -1, np.int32),
                sched=np.zeros((D, K, Jt), bool),
            )

        compile_s = 0.0
        wall = 0.0
        chunk_bytes = 0
        prev_w0 = lo_list[0]
        win_cols = np.arange(Wl)
        for (s0, s1), w0 in zip(bounds, lo_list):
            selw, fullw, labw = stage(w0)
            shift = (w0 - prev_w0).astype(np.int64)
            assert (shift >= 0).all(), "job windows must advance"
            prev_w0 = w0
            t_a = time.perf_counter()
            tabs = ServeTables(sel_feats=jnp.asarray(selw),
                               full_feats=jnp.asarray(fullw),
                               labels=jnp.asarray(labw),
                               **self._bank_tables)
            i0 = jnp.int32(s0)
            j0 = jnp.asarray(w0, _I32)
            sh = jnp.asarray(shift, _I32)
            stage_s = time.perf_counter() - t_a
            args = ((fleet_cfg, tabs, carry, i0, j0, sh) if tel is None
                    else (fleet_cfg, tabs, carry, i0, j0, sh, tel))
            runner, cs = self._stream_runner(
                statics=statics, n_steps=s1 - s0, adapt=adapt,
                shared=shared, per_dev_tables=per_dev, mode=mode,
                tcfg=telemetry, args=args)
            compile_s += cs
            t_r = time.perf_counter()
            res = runner(*args)
            jax.block_until_ready(res)
            wall += time.perf_counter() - t_r + stage_s
            if tel is None:
                carry = res
            else:
                carry, tel = res
            chunk_bytes = max(chunk_bytes, sum(
                int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(tabs)))
            if collect_log:
                win = {f: np.asarray(getattr(carry.log, f))
                       for f in ServeLog._fields}
                for k in range(K):
                    cols = w0[k] + win_cols
                    ok = (cols >= 0) & (cols < totals[k])
                    if ok.any():
                        for f in full_log:
                            full_log[f][:, k, cols[ok]] = win[f][:, k, ok]

        t_r = time.perf_counter()
        fleet = finalize_fleet(fleet_cfg, carry.dev, statics, live=True)
        jax.block_until_ready(fleet)
        wall += time.perf_counter() - t_r
        if full_log is None:
            full_log = {f: np.asarray(getattr(carry.log, f))
                        for f in ServeLog._fields}
        return FleetServeResult(
            fleet=fleet,
            units=full_log["units"],
            pred=full_log["pred"],
            correct=full_log["correct"],
            margin=full_log["margin"],
            exit_unit=full_log["exit_unit"],
            sched=full_log["sched"],
            carry=carry,
            jobs=int(np.asarray(fleet.released).sum()),
            wall_s=wall,
            telemetry=tel,
            compile_s=compile_s,
            peak_bytes=_device_peak_bytes(),
            chunk_table_bytes=chunk_bytes,
            n_chunks=len(bounds),
        )
