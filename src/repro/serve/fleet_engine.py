"""Vectorized live serving: real agile-model execution inside the fleet path.

:class:`repro.serve.engine.ServeEngine` is the *faithful* live path — an
event-driven python loop serving one job at a time, executing DNN units and
adapting k-means centroids in exactly the order the scheduler chose.  It
cannot scale past a handful of devices.  The fleet simulator scales to
thousands of devices but only *replays* precomputed ``(K, J, U)`` profile
tables.  This module closes the gap: one jitted ``lax.scan`` serves live
traffic for a whole fleet, with real unit outcomes and runtime centroid
adaptation threaded through the unified device step.

The key factorisation: per-unit *features* are a pure function of the input
— runtime adaptation moves only the k-means *centroids*, never the DNN
weights — so the engine precomputes features for every (job, unit) in one
batched scan-over-units pass (``_AgileBase.unit_features``) outside the
scheduling scan, and keeps only the state that actually evolves (the
centroid bank) inside it.  Each timestep then:

1. runs the step core's admit / drop-expired / pick stages in ``live`` mode
   (``vmap`` over devices, margins read from the live registers);
2. gathers the selected slot's (task, job, unit) identity per device;
3. classifies the completing unit's *real* features against the device's
   *current* centroid bank (same L1 top-2 arithmetic as
   :func:`repro.core.kmeans.classify`);
4. injects the ``(margin, passed, correct)`` outcome into
   :func:`repro.core.step.apply_step`;
5. adapts the bank where the utility test passed for the first time
   (weighted-average update + centroid propagation to deeper units, paper
   §4.3), exactly as ``DynamicJobProfile`` does one job at a time.

Because classification/adaptation are elementwise per device and the step
core is the same ``vmap``-ed transition the replay fleet uses, the live
fleet is *bit-exact* against a scalar :class:`ServeEngine` run on workloads
where the event-driven and fixed-step clocks coincide (persistent power,
charged start, unit times commensurate with ``dt`` — see
``tests/test_fleet_engine.py``).

Bank modes:

* ``per-device`` (default): every device owns a full centroid bank —
  ``ServeBank`` leaves carry a leading ``D`` axis and shard with the fleet
  (:func:`repro.launch.sharding.shard_serve_carry`).  This is the mode the
  scalar parity holds in.
* ``shared``: one global bank; every device's first-pass exits fold into a
  single collaborative :func:`repro.core.kmeans.online_update` per (task,
  unit) each step — the fleet-scale collaborative-adaptation substrate.

The scan carry (:class:`repro.fleet.state.ServeCarry`) is a flat pytree, so
``run(..., n_segments=N)`` checkpoints it at segment boundaries exactly like
:func:`repro.fleet.simulator.run_segments` — bit-identical to the monolithic
scan for any ``N``.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core import step as S
from ..core.energy import Capacitor, Harvester
from ..core.scheduler import JobProfile, TaskSpec
from ..fleet import grid
from ..fleet.simulator import finalize_fleet
from ..telemetry import state as T
from ..telemetry import trace as T_trace
from ..fleet.state import (
    FleetConfig,
    FleetResult,
    FleetStatics,
    ServeBank,
    ServeCarry,
    ServeLog,
    init_state,
)
from .engine import Request, ServeConfig, per_task

_F32 = jnp.float32
_I32 = jnp.int32

# padded cluster rows sit this far from everything: never in the L1 top-2
_FAR = 1e15
# the kernel's second-minimum mask value (repro.kernels.l1_topk2.POS)
_POS = 1e30


class ServeTables(NamedTuple):
    """Read-only per-request / per-classifier tables consumed by the scan.

    Shapes use ``K`` tasks, ``J`` jobs, ``U`` units, ``C`` clusters, ``S``
    selected features, ``F`` padded full-feature width (always one wider
    than the largest real feature dim: the extra column is zero everywhere
    and is where padded ``fidx`` entries point, so padding is L1-exact).
    With per-device request streams every *feature/label* leaf gains a
    leading ``D`` axis; the classifier metadata never does.
    """

    sel_feats: jax.Array     # ([D,] K, J, U, S) f32 — selected-dim features
    full_feats: jax.Array    # ([D,] K, J, U, F) f32 — full-dim (adaptation)
    labels: jax.Array        # ([D,] K, J) i32 — request ground truth
    clabels: jax.Array       # (K, U, C) i32 — cluster -> class label
    fidx: jax.Array          # (K, U, S) i32 — SelectKBest dims (pad -> F-1)
    thr: jax.Array           # (K, U) f32 — bank utility thresholds


@dataclass(frozen=True)
class BankMeta:
    """Static (python) shape metadata for the stacked bank."""

    n_units: tuple           # per task
    n_clusters: tuple        # per (task, unit)
    feat_dim: tuple          # per (task, unit) real feature width
    n_sel: tuple             # per (task, unit) real selected count


def stack_banks(models: Sequence) -> tuple[ServeBank, dict, BankMeta]:
    """Stack every model's per-unit :class:`UnitClassifier` bank into the
    padded ``(K, U, C, F)`` tables of a :class:`ServeBank` (+ the read-only
    classifier metadata for :class:`ServeTables`).

    Padding conventions (all L1- and update-exact, see module docstring):
    dummy cluster rows at ``_FAR`` with label -1 and count 1; features
    zero-padded to a common width ``F`` that always includes one guaranteed
    all-zero trailing column for padded ``fidx`` entries.
    """
    K = len(models)
    n_units = tuple(m.n_units for m in models)
    U = max(n_units)
    n_clusters = tuple(
        tuple(int(uc.centroids.shape[0]) for uc in m.bank) for m in models)
    feat_dim = tuple(
        tuple(int(uc.centroids.shape[1]) for uc in m.bank) for m in models)
    n_sel = tuple(
        tuple(int(uc.feature_idx.shape[0]) for uc in m.bank) for m in models)
    C = max(max(r) for r in n_clusters)
    S = max(max(r) for r in n_sel)
    F = max(max(r) for r in feat_dim) + 1    # +1: the all-zero pad column

    cents = np.full((K, U, C, F), _FAR, np.float32)
    counts = np.ones((K, U, C), np.float32)
    clabels = np.full((K, U, C), -1, np.int32)
    fidx = np.full((K, U, S), F - 1, np.int32)
    thr = np.zeros((K, U), np.float32)
    for k, m in enumerate(models):
        for u, uc in enumerate(m.bank):
            c = np.asarray(uc.centroids, np.float32)
            kc, fu = c.shape
            cents[k, u, :kc, :fu] = c
            cents[k, u, :kc, fu:] = 0.0
            counts[k, u, :kc] = np.asarray(uc.counts, np.float32)
            clabels[k, u, :kc] = np.asarray(uc.labels, np.int32)
            ns = n_sel[k][u]
            fidx[k, u, :ns] = np.asarray(uc.feature_idx, np.int32)
            thr[k, u] = float(uc.threshold)
    bank = ServeBank(centroids=jnp.asarray(cents), counts=jnp.asarray(counts))
    tables = dict(clabels=jnp.asarray(clabels), fidx=jnp.asarray(fidx),
                  thr=jnp.asarray(thr))
    return bank, tables, BankMeta(n_units, n_clusters, feat_dim, n_sel)


def build_feature_tables(
    models: Sequence,
    requests_per_task: Sequence[Sequence[Request]],
    meta: BankMeta,
    bank_tables: dict,
    *,
    feature_batch: Optional[int] = None,
    n_jobs: Optional[int] = None,
) -> dict:
    """Precompute the (job, unit) feature tables for one request stream.

    Features come from ``unit_features`` (scan-over-units, chunked by
    ``feature_batch``); the selected-dim gather happens host-side against
    the *initial* feature selection — valid for the whole run because
    ``feature_idx`` never adapts.  ``n_jobs`` fixes the job axis (so
    per-device streams of different lengths stack); default = longest
    stream given.
    """
    K = len(models)
    J = int(n_jobs or max(len(r) for r in requests_per_task))
    fidx = np.asarray(bank_tables["fidx"])
    U, S = fidx.shape[1], fidx.shape[2]
    F = max(max(r) for r in meta.feat_dim) + 1
    sel = np.zeros((K, J, U, S), np.float32)
    full = np.zeros((K, J, U, F), np.float32)
    labels = np.full((K, J), -1, np.int32)
    for k, (m, reqs) in enumerate(zip(models, requests_per_task)):
        if not reqs:
            continue
        feats = m.unit_features([r.x for r in reqs],
                                batch_size=feature_batch)
        for u, f in enumerate(feats):
            full[k, :len(reqs), u, :f.shape[1]] = f
            ns = meta.n_sel[k][u]
            sel[k, :len(reqs), u, :ns] = f[:, fidx[k, u, :ns]]
        labels[k, :len(reqs)] = [r.label for r in reqs]
    return dict(sel_feats=sel, full_feats=full, labels=labels)


def classify_unit(bank: ServeBank, tables: ServeTables, tk, u, job):
    """Single-row live classification for one device's completing unit.

    The pure-jnp row variant of :func:`repro.core.kmeans.classify`: same
    elementwise ``|x - c|`` innermost-axis reduction, same one-hot-masked
    second minimum (mask value :data:`_POS`), same scale-free margin — so
    the result is bit-identical to the scalar path's ``l1_topk2`` kernel
    (interpret mode) on the same operands (asserted in
    ``tests/test_fleet_engine.py``).  Returns
    ``(margin, cluster_idx, pred)``.
    """
    fsel = tables.sel_feats[tk, job, u]                       # (S,)
    idxs = tables.fidx[tk, u]                                 # (S,)
    csel = bank.centroids[tk, u][:, idxs]                     # (C, S)
    dist = jnp.sum(jnp.abs(fsel[None, :] - csel), axis=-1)    # (C,)
    d1 = jnp.min(dist)
    ci = jnp.argmin(dist).astype(_I32)
    d2 = jnp.min(jnp.where(jnp.arange(dist.shape[0]) == ci, _POS, dist))
    margin = (d2 - d1) / jnp.maximum(d1 + d2, 1e-9)
    pred = tables.clabels[tk, u, ci]
    return margin, ci, pred


@dataclass
class FleetServeResult:
    """Outcome of one vectorized live-serving run.

    ``fleet`` holds the step core's SimResult-shaped ``(D,)`` aggregates
    (live-mode finalize: correctness from the live registers); the per-job
    arrays are the numpy view of the :class:`ServeLog` (``(D, K, J)``
    each).  ``carry`` is the end-of-horizon :class:`ServeCarry` for
    checkpoint/resume; ``wall_s``/``jobs_per_sec`` time the jitted scan
    only (feature precompute excluded — it is amortised, input-dependent
    work shared with any batched-inference baseline).
    """

    fleet: FleetResult
    units: np.ndarray
    pred: np.ndarray
    correct: np.ndarray
    margin: np.ndarray
    exit_unit: np.ndarray
    sched: np.ndarray
    carry: ServeCarry
    jobs: int
    wall_s: float
    telemetry: Optional[T.Telemetry] = None

    @property
    def jobs_per_sec(self) -> float:
        return self.jobs / max(self.wall_s, 1e-9)


class FleetServeEngine:
    """Vectorized live serving of agile-model tasks across a device fleet.

    Same constructor shape as the scalar :class:`ServeEngine` plus the
    fleet knobs: ``bank_mode`` ("per-device" | "shared") and
    ``feature_batch`` (chunk size of the feature precompute; ``1``
    reproduces the scalar engine's per-sample arithmetic exactly).
    """

    def __init__(
        self,
        models: Sequence,
        harvester: Harvester,
        eta: float,
        cap: Optional[Capacitor] = None,
        config: Optional[ServeConfig] = None,
        *,
        bank_mode: str = "per-device",
        feature_batch: Optional[int] = None,
        adapt_weight: float = 32.0,
    ):
        if bank_mode not in ("per-device", "shared"):
            raise ValueError(f"unknown bank_mode {bank_mode!r}")
        self.models = list(models)
        self.harvester = harvester
        self.eta = eta
        self.cap = cap or Capacitor()
        self.config = config or ServeConfig()
        self.bank_mode = bank_mode
        self.feature_batch = feature_batch
        self.adapt_weight = float(adapt_weight)
        self.bank0, self._bank_tables, self.meta = stack_banks(self.models)
        self._runners: dict = {}

    # ------------------------------------------------------------------ #
    # Builders.
    # ------------------------------------------------------------------ #

    def _task_specs(self, n_jobs_per_task: Sequence[int]) -> list[TaskSpec]:
        """TaskSpecs with *dummy* zero profiles: live mode never reads the
        replay tables, but the grid builder still sizes ``n_releases`` and
        the clip bounds from them."""
        cfg = self.config
        periods = per_task(cfg.period, len(self.models))
        deadlines = per_task(cfg.deadline, len(self.models))
        tasks = []
        for tid, (m, n_jobs) in enumerate(zip(self.models,
                                              n_jobs_per_task)):
            nu = m.n_units
            ut = (np.asarray(cfg.unit_time, float)
                  if cfg.unit_time is not None else np.full(nu, 0.2))
            ue = (np.asarray(cfg.unit_energy, float)
                  if cfg.unit_energy is not None else np.full(nu, 5e-3))
            zeros = JobProfile(np.zeros(nu), np.zeros(nu, bool),
                               np.zeros(nu, bool))
            tasks.append(TaskSpec(
                task_id=tid, period=periods[tid], deadline=deadlines[tid],
                unit_time=ut[:nu], unit_energy=ue[:nu],
                profiles=[zeros] * n_jobs,
                fragments_per_unit=cfg.fragments_per_unit,
            ))
        return tasks

    def build(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
    ) -> tuple[FleetConfig, FleetStatics, ServeTables, ServeCarry, bool]:
        """Materialise configs, statics, feature tables and the t=0 carry.

        ``requests`` is either one stream shared by every device —
        ``requests[task][job]`` — or per-device streams
        ``requests[device][task][job]`` (detected by nesting).  Returns
        ``(cfg, statics, tables, carry0, per_dev_tables)``.
        """
        cfg = self.config
        per_dev = not isinstance(requests[0][0], Request)
        if per_dev:
            D = len(requests)
            if n_devices is not None and n_devices != D:
                raise ValueError(
                    f"n_devices={n_devices} but {D} request streams given")
            streams = requests
        else:
            D = int(n_devices or 1)
            streams = [requests] * D
        if len(streams[0]) != len(self.models):
            raise ValueError(
                f"{len(streams[0])} request streams per device for "
                f"{len(self.models)} models")

        n_jobs = [max(len(s[k]) for s in streams)
                  for k in range(len(self.models))]
        tasks = self._task_specs(n_jobs)
        dt = grid._check_dt(
            grid._default_dt(tasks) if cfg.sim_dt is None
            else float(cfg.sim_dt), tasks)
        statics = FleetStatics(queue_size=cfg.queue_size, dt=dt,
                               horizon=cfg.horizon,
                               slot_s=self.harvester.slot_s)
        seeds = (list(seeds) if seeds is not None
                 else [cfg.seed] * D)
        if len(seeds) != D:
            raise ValueError(f"{len(seeds)} seeds for {D} devices")
        events = {s: grid.sample_events(self.harvester, cfg.horizon, s)
                  for s in set(seeds)}
        devs = [grid.device_config(
            tasks, self.harvester, self.eta, self.cap,
            policy=cfg.policy, horizon=cfg.horizon, events=events[s],
            e_opt_fraction=cfg.e_opt_fraction,
            start_charged=cfg.start_charged,
        ) for s in seeds]
        fleet_cfg = grid.stack_configs(devs)

        feats = [build_feature_tables(
            self.models, s, self.meta, self._bank_tables,
            feature_batch=self.feature_batch, n_jobs=max(n_jobs))
            for s in streams]
        if per_dev:
            stacked = {k: jnp.asarray(np.stack([f[k] for f in feats]))
                       for k in feats[0]}
        else:
            stacked = {k: jnp.asarray(v) for k, v in feats[0].items()}
        tables = ServeTables(**stacked, **self._bank_tables)

        dev0 = jax.vmap(lambda c: init_state(c, statics))(fleet_cfg)
        bank0 = self.bank0
        if self.bank_mode == "per-device":
            bank0 = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (D,) + l.shape), bank0)
        K, J = len(self.models), max(n_jobs)
        log0 = ServeLog(
            units=jnp.zeros((D, K, J), _I32),
            pred=jnp.full((D, K, J), -1, _I32),
            correct=jnp.zeros((D, K, J), bool),
            margin=jnp.zeros((D, K, J), _F32),
            exit_unit=jnp.full((D, K, J), -1, _I32),
            sched=jnp.zeros((D, K, J), bool),
        )
        return (fleet_cfg, statics, tables,
                ServeCarry(dev=dev0, bank=bank0, log=log0), per_dev)

    # ------------------------------------------------------------------ #
    # The jitted scan.
    # ------------------------------------------------------------------ #

    def _adapt_per_device(self, bank: ServeBank, x_full, tk, u, ci, do):
        """One device's weighted-average bank update + centroid propagation
        (unbatched; the runner vmaps it over the fleet).

        Bit-matches ``km.adapt`` + ``_propagate_from`` on one sample: the
        assigned row becomes ``(w c + x) / (w + 1)`` (the kernel's one-hot
        matmul contributes exactly ``x``), every other row is untouched
        (the kernel computes ``(w c) / w`` — exact for ``w = 32``), and the
        propagation chain refreshes row ``ci`` of each deeper unit from the
        *progressively updated* shallower tables, exactly as the scalar
        loop does."""
        w = self.adapt_weight
        K_, U_, C_, _ = bank.centroids.shape
        m3 = (do
              & (jnp.arange(K_)[:, None, None] == tk)
              & (jnp.arange(U_)[None, :, None] == u)
              & (jnp.arange(C_)[None, None, :] == ci))
        # the barrier keeps the divisor out of constant folding: XLA would
        # otherwise rewrite /(w+1) into *(1/(w+1)) under jit, drifting one
        # ulp off the scalar path's true division
        denom = lax.optimization_barrier(jnp.float32(w + 1.0))
        cents = jnp.where(m3[..., None],
                          (w * bank.centroids + x_full) / denom,
                          bank.centroids)
        counts = bank.counts + m3
        for k, m in enumerate(self.models):
            for v in range(m.n_units - 1):
                act = do & (tk == k) & (u <= v)
                kc = self.meta.n_clusters[k][v]
                f_in = self.meta.feat_dim[k][v]
                f_out = self.meta.feat_dim[k][v + 1]
                r = counts[k, v, :kc, None]
                src = cents[k, v, :kc, :f_in]
                img = jax.nn.relu(m.unit_apply_flat(v + 1, r * src)) / r
                row = (jnp.arange(kc) == ci) & act
                new = jnp.where(row[:, None], img,
                                cents[k, v + 1, :kc, :f_out])
                cents = cents.at[k, v + 1, :kc, :f_out].set(new)
        return ServeBank(centroids=cents, counts=counts)

    def _adapt_shared(self, bank: ServeBank, x_full, tk, u, ci, do):
        """Collaborative shared-bank update: all devices exiting at (k, u)
        this step fold into ONE :func:`km.online_update` (batch-averaged —
        the documented semantic difference vs sequential per-device
        adaptation), then one propagation sweep refreshes every touched
        row of the deeper units."""
        from ..core import kmeans as km

        cents, counts = bank.centroids, bank.counts
        C_ = cents.shape[2]
        for k, m in enumerate(self.models):
            hot = jnp.zeros((C_,), bool)
            for v in range(m.n_units):
                kc = self.meta.n_clusters[k][v]
                fu = self.meta.feat_dim[k][v]
                mrow = do & (tk == k) & (u == v)
                idxk = jnp.where(mrow, ci, -1)
                new_c, new_n = km.online_update(
                    cents[k, v, :kc, :fu], counts[k, v, :kc],
                    x_full[:, :fu], idxk, weight=self.adapt_weight)
                cents = cents.at[k, v, :kc, :fu].set(new_c)
                counts = counts.at[k, v, :kc].set(new_n)
                if v == m.n_units - 1:
                    break
                hot = hot | jnp.any(
                    mrow[:, None] & (jnp.arange(C_)[None, :] == ci[:, None]),
                    axis=0)
                f_out = self.meta.feat_dim[k][v + 1]
                r = counts[k, v, :kc, None]
                src = cents[k, v, :kc, :fu]
                img = jax.nn.relu(m.unit_apply_flat(v + 1, r * src)) / r
                new = jnp.where(hot[:kc, None], img,
                                cents[k, v + 1, :kc, :f_out])
                cents = cents.at[k, v + 1, :kc, :f_out].set(new)
        return ServeBank(centroids=cents, counts=counts)

    def _scan_steps(self, cfg: FleetConfig, tables: ServeTables,
                    carry, i0, tel=None, *, statics: FleetStatics,
                    n_steps: int, adapt: bool, shared: bool,
                    per_dev_tables: bool,
                    tcfg: Optional[T.TelemetryConfig] = None):
        """Scan ``n_steps`` live timesteps from step index ``i0``.

        With ``tcfg`` set, the scan emits the telemetry columns of the
        requested tier and reduces them into ``tel`` post-scan, returning
        ``(ServeCarry, Telemetry, ring_columns)``: at the ``"counters"``
        tier the plain step body emits three registers it already computed
        (``ring_columns`` is ``None``); at the ``"full"`` tier the stages
        run their descriptor-emitting twins
        (:class:`repro.core.step.StepTrace`), the events are bit-packed
        per step, and the caller folds the rare ring/histogram events
        host-side via :func:`repro.telemetry.trace.fold_events_host`.  The
        serve numerics cannot change: tracing only adds outputs."""
        trace = tcfg is not None and tcfg.level == "full"
        counters = tcfg is not None and not trace
        spec = (T_trace.make_pack_spec(int(cfg.period.shape[1]),
                                       statics.queue_size,
                                       int(cfg.unit_time.shape[-1]) + 1)
                if trace else None)
        K = cfg.period.shape[1]
        u_max = cfg.unit_time.shape[2] - 1
        J = tables.labels.shape[-1]
        Q = statics.queue_size
        tab_axes = ServeTables(
            sel_feats=0 if per_dev_tables else None,
            full_feats=0 if per_dev_tables else None,
            labels=0 if per_dev_tables else None,
            clabels=None, fidx=None, thr=None)
        bank_ax = None if shared else 0

        def gather(c, s, a, r):
            """Selected-slot identity for one device, pre-apply."""
            tk = jnp.clip(s.q_task[a], 0, K - 1)
            u = jnp.clip(s.q_unit[a], 0, u_max)
            job = jnp.clip(s.q_job[a], 0, J - 1)
            complete = r & (s.q_time_left[a] - statics.dt
                            <= statics.dt * 1e-3)
            return (tk, u, job, complete, s.q_exited[a], s.q_apass[a],
                    s.q_deadline[a], c.n_units[tk], c.imprecise,
                    c.use_exit_thr, c.exit_thr[tk, u])

        def step(carry, i):
            dev, bank, log = carry
            dev0 = dev
            t = i.astype(_F32) * statics.dt
            act0 = dev.q_active
            if trace:
                dev, (tr_adm, tr_ev, tr_ev_dl) = jax.vmap(
                    lambda c, s: S.admit(c, s, t, statics, True,
                                         trace=True))(cfg, dev)
                dev, (tr_exp, tr_exp_dl) = jax.vmap(
                    lambda c, s, a0: S.drop_expired(c, s, t, True,
                                                    trace=True,
                                                    q_active_pre=a0)
                )(cfg, dev, act0)
            else:
                dev = jax.vmap(
                    lambda c, s: S.admit(c, s, t, statics, True))(cfg, dev)
                dev = jax.vmap(
                    lambda c, s: S.drop_expired(c, s, t, True))(cfg, dev)
            sel, picked, run, e_new = jax.vmap(
                lambda c, s: S.pick(c, s, t, statics, True))(cfg, dev)
            (tk, u, job, complete, exited_pre, apass_pre, ddl, nu_sel,
             imprec, use_thr, thr_cfg) = jax.vmap(gather)(cfg, dev, sel, run)

            margin, ci, pred = jax.vmap(
                classify_unit, in_axes=(bank_ax, tab_axes, 0, 0, 0))(
                bank, tables, tk, u, job)
            if per_dev_tables:
                label = tables.labels[jnp.arange(tk.shape[0]), tk, job]
            else:
                label = tables.labels[tk, job]
            correct = pred == label
            pass_bank = margin > tables.thr[tk, u]
            passed = jnp.where(use_thr, margin > thr_cfg, pass_bank)

            if trace:
                dev, (tr_comp, tr_comp_dl) = jax.vmap(
                    lambda c, s, a, p, r, e, mg, ps, co, a0: S.apply_step(
                        c, s, t, a, p, r, e, statics, True, (mg, ps, co),
                        trace=True, q_active_pre=a0))(
                    cfg, dev, sel, picked, run, e_new, margin, passed,
                    correct, act0)
                tr = S.StepTrace(adm=tr_adm, evict=tr_ev,
                                 evict_dl=tr_ev_dl, expire=tr_exp,
                                 expire_dl=tr_exp_dl, complete=tr_comp,
                                 complete_dl=tr_comp_dl)
            else:
                dev = jax.vmap(
                    lambda c, s, a, p, r, e, mg, ps, co: S.apply_step(
                        c, s, t, a, p, r, e, statics, True, (mg, ps, co)))(
                    cfg, dev, sel, picked, run, e_new, margin, passed,
                    correct)

            # engine-owned utility-pass latch: adaptation fires at the FIRST
            # bank-threshold pass (like DynamicJobProfile — even under EDF,
            # where the scheduler itself never exits early)
            first_pass = complete & pass_bank & ~apass_pre
            oh = jnp.arange(Q)[None, :] == sel[:, None]
            dev = dev._replace(
                q_apass=dev.q_apass | (oh & (complete & pass_bank)[:, None]))

            if adapt:
                if per_dev_tables:
                    x_full = tables.full_feats[
                        jnp.arange(tk.shape[0]), tk, job, u]
                else:
                    x_full = tables.full_feats[tk, job, u]

                def _upd(args):
                    b, xf, tkk, uu, cii, fp = args
                    if shared:
                        return self._adapt_shared(b, xf, tkk, uu, cii, fp)
                    return jax.vmap(self._adapt_per_device)(
                        b, xf, tkk, uu, cii, fp)

                # most steps complete nothing: skip the propagation convs
                # entirely unless some device's utility test just passed
                bank = lax.cond(
                    jnp.any(first_pass), _upd, lambda args: args[0],
                    (bank, x_full, tk, u, ci, first_pass))

            # per-job outcome log (mirrors apply_step's completion math)
            exit_now = complete & imprec & (exited_pre < 0) & passed
            exited_mid = jnp.where(exit_now, u, exited_pre)
            full_mand = complete & (exited_mid < 0) & (u + 1 >= nu_sel)
            mand_now = exit_now | full_mand
            sched_now = (t + statics.dt) <= ddl
            m_jd = (complete[:, None, None]
                    & (jnp.arange(K)[None, :, None] == tk[:, None, None])
                    & (jnp.arange(J)[None, None, :] == job[:, None, None]))

            def put(old, new, mask=None):
                mm = m_jd if mask is None else m_jd & mask[:, None, None]
                return jnp.where(mm, new[:, None, None], old)

            log = ServeLog(
                units=put(log.units, u + 1),
                pred=put(log.pred, pred),
                correct=put(log.correct, correct),
                margin=put(log.margin, margin),
                exit_unit=put(log.exit_unit, u, first_pass),
                sched=put(log.sched, sched_now, mand_now),
            )
            new_carry = ServeCarry(dev=dev, bank=bank, log=log)
            if trace:
                return new_carry, T_trace.emit_full(spec, tr, dev0, dev)
            if counters:
                return new_carry, T_trace.emit_counters(dev)
            return new_carry, None

        if tcfg is None:
            carry, _ = lax.scan(step, carry, i0 + jnp.arange(n_steps))
            return carry
        st0 = carry.dev
        carry, ys = lax.scan(step, carry, i0 + jnp.arange(n_steps))
        if counters:
            return carry, T_trace.reduce_counters(tel, st0, carry.dev, ys,
                                                  n_steps), None
        tel, ring = T_trace.reduce_full(spec, tel, st0, carry.dev, ys, i0,
                                        n_steps, statics.dt)
        return carry, tel, ring

    def _runner(self, statics: FleetStatics, n_steps: int, adapt: bool,
                shared: bool, per_dev_tables: bool, tcfg=None):
        key = (statics, n_steps, adapt, shared, per_dev_tables, tcfg)
        if key not in self._runners:
            self._runners[key] = jax.jit(functools.partial(
                self._scan_steps, statics=statics, n_steps=n_steps,
                adapt=adapt, shared=shared, per_dev_tables=per_dev_tables,
                tcfg=tcfg))
        return self._runners[key]

    # ------------------------------------------------------------------ #
    # Public entry point.
    # ------------------------------------------------------------------ #

    def run(
        self,
        requests,
        n_devices: Optional[int] = None,
        *,
        seeds: Optional[Sequence[int]] = None,
        n_segments: int = 1,
        carry: Optional[ServeCarry] = None,
        mesh=None,
        telemetry: Optional[T.TelemetryConfig] = None,
    ) -> FleetServeResult:
        """Serve every request stream live through one jitted fleet scan.

        ``n_segments > 1`` materialises the :class:`ServeCarry` at segment
        boundaries (checkpointable, bit-identical to ``n_segments=1``);
        ``carry`` resumes from a previous run's carry.  ``mesh`` places the
        carry/config/tables with the device axis partitioned
        (:func:`repro.launch.sharding.shard_serve_carry`; ``D`` must be a
        mesh-size multiple).  ``telemetry`` (a
        :class:`repro.telemetry.TelemetryConfig`) threads a ``(D, ...)``
        telemetry pytree through the serve scan and fills
        ``FleetServeResult.telemetry`` — the serve outcome itself is
        bit-exact either way.
        """
        cfg, statics, tables, carry0, per_dev = self.build(
            requests, n_devices, seeds=seeds)
        if carry is not None:
            carry0 = carry
        adapt = bool(self.config.adapt)
        shared = self.bank_mode == "shared"
        tel = (None if telemetry is None
               else T.init_fleet_telemetry(telemetry, cfg))
        if mesh is not None:
            from ..launch.sharding import (
                shard_fleet_carry,
                shard_fleet_config,
                shard_serve_carry,
                shard_serve_tables,
            )

            D = cfg.n_devices
            if D % mesh.size:
                raise ValueError(
                    f"D={D} devices must divide over mesh size {mesh.size}")
            cfg = shard_fleet_config(mesh, cfg)
            carry0 = shard_serve_carry(mesh, carry0, shared_bank=shared)
            tables = shard_serve_tables(mesh, tables, per_device=per_dev)
            if tel is not None:
                tel = shard_fleet_carry(mesh, tel)

        sizes = [len(c) for c in
                 np.array_split(np.arange(statics.n_steps), n_segments)]
        t0 = time.perf_counter()
        i0 = 0
        out = carry0
        for n in sizes:
            if not n:
                continue
            runner = self._runner(statics, n, adapt, shared, per_dev,
                                  telemetry)
            if telemetry is None:
                out = runner(cfg, tables, out, jnp.int32(i0))
            else:
                out, tel, ring = runner(cfg, tables, out, jnp.int32(i0),
                                        tel)
                if ring is not None:
                    spec = T_trace.make_pack_spec(
                        int(cfg.period.shape[1]), statics.queue_size,
                        int(tel.exit_hist.shape[1]))
                    tel = T_trace.fold_events_host(
                        spec, tel, tuple(np.asarray(c) for c in ring),
                        i0, statics.dt)
            i0 += n
        fleet = finalize_fleet(cfg, out.dev, statics, live=True)
        jax.block_until_ready(fleet)
        wall = time.perf_counter() - t0

        log = out.log
        return FleetServeResult(
            fleet=fleet,
            units=np.asarray(log.units),
            pred=np.asarray(log.pred),
            correct=np.asarray(log.correct),
            margin=np.asarray(log.margin),
            exit_unit=np.asarray(log.exit_unit),
            sched=np.asarray(log.sched),
            carry=out,
            jobs=int(np.asarray(fleet.released).sum()),
            wall_s=wall,
            telemetry=tel,
        )
