"""Deadline-aware anytime serving of the big-model configs.

Continuous batching + Zygarde imprecise computation in one jitted
``lax.scan``: every step the engine admits released requests into free
batch slots (priority-ordered by the paper's zeta_I — Eq. 7 — or by EDF),
runs ONE batched :func:`repro.models.anytime.unit_decode_step` over all
slots, and picks a per-request *depth* for accounting:

* ``policy="anytime"`` — the margin utility test
  (:func:`repro.models.anytime.select_depth` over the per-unit exit-head
  margins, knobs ``exit_thr``/``use_exit_thr``) proposes a depth; a
  deadline cap (greedy per-token latency budget) and the Eq. 7 energy
  gate (``eta * energy >= E_opt``) can force it down to the mandatory
  prefix; the result is clamped to ``[mandatory, U]``.
* ``policy="edf"`` — fixed full depth (the precise-computation baseline).
* ``policy="edf-m"`` — fixed mandatory depth (maximal imprecision).

Step latency is the continuous-batching cost ``t_base + unit_time *
max(depth over active slots)`` — the whole batch waits for its deepest
request, which is exactly why per-request depth control beats fixed-depth
EDF under tight deadlines (``examples/anytime_serve.py``).  Energy flows
through a capacitor fed by a :class:`repro.core.energy.Harvester` power
trace; when the store cannot cover the platform base cost the step
brownouts (no compute, time still passes) — the intermittent-power
regime the zeta_I gate exists for.

Mechanics reused from the fleet substrate: a pure pytree
:class:`AnytimeCarry` stepped by a closed-over transition (``core/step.py``
style), checkpointable segmented scans (``run(..., n_segments=, hook=)``
— bit-exact for any segmentation, hooks may retune knobs between
segments), ``mesh=`` sharding of the decode state via
:func:`repro.launch.sharding.state_specs`, and an optional
:class:`repro.telemetry.Telemetry` fold (depth histogram, deadline
slack, admission/retire counters) compiled out when disabled.

The exit decision is *propagated* (CALM-style): an early-exited token is
fed back and the KV/recurrent state is still built by the full stack, so
depth is an accounting (time/energy) construct while the physical batch
step stays shape-static.  Agreement of every emitted token with the
full-depth argmax is tracked per request — the accuracy side of the
score.  Knobs are dynamic arguments (:class:`AnytimeKnobs`), so
``repro.adapt.tune`` can vmap thousands of candidate threshold/E_opt
settings over one compiled engine (:mod:`repro.adapt.anytime`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import energy as EN
from ..core import policy as POL
from ..launch import sharding as SH
from ..models import anytime as A
from ..models import transformer as T
from ..telemetry import TelemetryConfig, init_telemetry, record_anytime_step

_F32 = jnp.float32
_I32 = jnp.int32

__all__ = [
    "AnytimeConfig", "AnytimeKnobs", "AnytimeRequest", "AnytimeTables",
    "AnytimeCarry", "AnytimeResult", "AnytimeServeEngine",
]


# --------------------------------------------------------------------------- #
# Configuration, knobs, requests.
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class AnytimeConfig:
    """Static engine configuration (hashable; baked into the jit trace).

    Latency model: a step costs ``t_base + unit_time * max(depth)``
    seconds; energy: ``e_base`` per non-idle step plus ``unit_energy``
    per unit of charged depth per slot, drawn from a capacitor of
    ``capacity`` joules refilled by the supply trace (``trace_dt``
    seconds per trace slot).  ``mandatory_units=0`` defers to the model
    config's ``resolved_mandatory_units``.
    """

    policy: str = "anytime"       # "anytime" | "edf" | "edf-m"
    batch_slots: int = 4          # continuous-batching slots (B)
    max_steps: int = 256          # scan horizon (T)
    prompt_len: int = 4           # prompt table width (P)
    max_new_tokens: int = 16      # per-request generation cap
    alpha: float = 0.1            # zeta laxity weight
    beta: float = 0.5             # zeta utility weight
    t_base: float = 0.02          # per-step fixed latency (s)
    unit_time: float = 0.05       # latency per unit of depth (s)
    e_base: float = 0.05          # energy per non-idle step (J)
    unit_energy: float = 0.1      # energy per unit of depth per slot (J)
    capacity: float = 50.0        # capacitor size (J)
    start_frac: float = 1.0       # initial charge fraction
    trace_dt: float = 1.0         # seconds per supply-trace slot
    mandatory_units: int = 0      # 0 => model config's mandatory prefix
    deadline_cap: bool = True     # anytime: laxity-budget depth cap
    window: Optional[int] = None  # attention window override

    def __post_init__(self):
        if self.policy not in ("anytime", "edf", "edf-m"):
            raise ValueError(f"unknown policy {self.policy!r}")


class AnytimeKnobs(NamedTuple):
    """Dynamic scheduler knobs (tunable without recompilation)."""

    exit_thr: jax.Array      # (U,) f32 per-unit margin thresholds
    use_exit_thr: jax.Array  # (U,) f32 0/1 per-unit enables
    eta: jax.Array           # () f32 harvest-predictability factor
    e_opt: jax.Array         # () f32 optional-work energy gate (J)


@dataclass(frozen=True)
class AnytimeRequest:
    """One serving request: prompt tokens, generation budget, timing."""

    prompt: Sequence[int]
    n_tokens: int
    release: float
    deadline: float


class AnytimeTables(NamedTuple):
    """Packed request tables (device arrays)."""

    prompt: jax.Array      # (N, P) i32
    prompt_len: jax.Array  # (N,) i32
    n_tokens: jax.Array    # (N,) i32
    release: jax.Array     # (N,) f32
    deadline: jax.Array    # (N,) f32


class AnytimeCarry(NamedTuple):
    """The scan carry: pure pytree, checkpointable at any segment
    boundary, shardable via :func:`repro.launch.sharding.state_specs`
    (the decode state's batch axis)."""

    now: jax.Array         # () f32 simulation clock
    energy: jax.Array      # () f32 capacitor charge
    state: Any             # stacked=False decode state for B slots
    slot_req: jax.Array    # (B,) i32 request index, -1 = free
    slot_next: jax.Array   # (B,) i32 next input token per slot
    req_status: jax.Array  # (N,) i32 0 wait / 1 run / 2 on-time / 3 late
    req_finish: jax.Array  # (N,) f32 completion time (0 until retired)
    req_agree: jax.Array   # (N,) i32 tokens agreeing with full depth
    req_tokens: jax.Array  # (N,) i32 tokens generated
    req_depth: jax.Array   # (N,) i32 summed depth over generated tokens
    tel: Any               # Telemetry, or None when disabled


# --------------------------------------------------------------------------- #
# Results.
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class AnytimeResult:
    """Host-side per-request outcome + summary metrics.

    ``score`` is seeded-deterministic (pure function of the request set,
    knobs, and supply trace): the fraction of *requested* tokens that
    were generated by an on-time request AND agree with the full-depth
    prediction — timeliness and accuracy in one number, the quantity the
    regression gate tracks and ``adapt.tune`` maximises.
    """

    status: np.ndarray     # (N,) final req_status
    finish: np.ndarray     # (N,) completion time (horizon if unfinished)
    tardiness: np.ndarray  # (N,) max(0, finish - deadline)
    agree: np.ndarray      # (N,) tokens agreeing with full depth
    tokens: np.ndarray     # (N,) tokens generated
    depth_sum: np.ndarray  # (N,) summed depth over generated tokens
    requested: np.ndarray  # (N,) tokens requested
    horizon: float         # simulation end time
    n_units: int
    telemetry: Any = None

    @property
    def n_requests(self) -> int:
        return int(self.status.size)

    @property
    def completed(self) -> int:
        return int((self.status >= 2).sum())

    @property
    def on_time(self) -> int:
        return int((self.status == 2).sum())

    @property
    def missed(self) -> int:
        """Late completions + requests unfinished at the horizon."""
        return self.n_requests - self.on_time

    @property
    def mean_depth(self) -> float:
        return float(self.depth_sum.sum() / max(int(self.tokens.sum()), 1))

    @property
    def agreement(self) -> float:
        return float(self.agree.sum() / max(int(self.tokens.sum()), 1))

    @property
    def mean_tardiness(self) -> float:
        return float(self.tardiness.mean()) if self.tardiness.size else 0.0

    @property
    def score(self) -> float:
        good = np.where(self.status == 2, self.agree, 0)
        return float(good.sum() / max(int(self.requested.sum()), 1))

    def as_dict(self) -> dict:
        return {
            "n_requests": self.n_requests, "completed": self.completed,
            "on_time": self.on_time, "missed": self.missed,
            "mean_depth": self.mean_depth, "agreement": self.agreement,
            "mean_tardiness": self.mean_tardiness, "score": self.score,
            "horizon": self.horizon,
        }


# --------------------------------------------------------------------------- #
# The engine.
# --------------------------------------------------------------------------- #


def _bmask(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a (B,) mask over a batch-leading leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


class AnytimeServeEngine:
    """Continuous-batching anytime engine for one registered model config.

    ``supply`` is a :class:`repro.core.energy.Harvester` (its power trace
    is sampled with ``seed``), a precomputed watts array, or ``None`` for
    an always-ample persistent source.
    """

    def __init__(self, cfg, params, heads=None, *,
                 serve_cfg: AnytimeConfig = AnytimeConfig(),
                 supply=None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.heads = heads if heads is not None else A.init_heads(cfg)
        self.scfg = serve_cfg
        self.n_units = cfg.n_units
        self.mandatory = (serve_cfg.mandatory_units
                          or cfg.resolved_mandatory_units)
        if not 1 <= self.mandatory <= self.n_units:
            raise ValueError(
                f"mandatory_units {self.mandatory} outside [1, "
                f"{self.n_units}]")
        sc = serve_cfg
        horizon = sc.max_steps * (sc.t_base + sc.unit_time * self.n_units)
        if supply is None:
            # persistent: always refill faster than the worst-case burn
            burn = (sc.e_base + sc.batch_slots * sc.unit_energy
                    * self.n_units) / max(sc.t_base, 1e-9)
            trace = np.full(1, burn, np.float64)
        elif isinstance(supply, EN.Harvester):
            n_slots = int(np.ceil(horizon / sc.trace_dt)) + 1
            trace = supply.power_trace(
                np.random.default_rng(seed), n_slots)
        else:
            trace = np.asarray(supply, np.float64)
        self.trace = jnp.asarray(trace, _F32)
        self._cache_len = sc.prompt_len + sc.max_new_tokens
        self._zero_state = T.init_decode_state(
            cfg, sc.batch_slots, self._cache_len, window=sc.window,
            cache_len=self._cache_len, stacked=False)
        self._seg_fns: dict = {}

    # ------------------------------------------------------------------ #
    def default_knobs(self, *, exit_thr=None, use_exit_thr=None,
                      eta: float = 1.0,
                      e_opt_fraction: float = 0.25) -> AnytimeKnobs:
        U = self.n_units
        if exit_thr is None:
            exit_thr = jnp.full((U,), self.cfg.utility_threshold, _F32)
        if use_exit_thr is None:
            use_exit_thr = jnp.ones((U,), _F32)
        return AnytimeKnobs(
            exit_thr=jnp.asarray(exit_thr, _F32).reshape(U),
            use_exit_thr=jnp.asarray(use_exit_thr, _F32).reshape(U),
            eta=jnp.asarray(eta, _F32),
            e_opt=jnp.asarray(e_opt_fraction * self.scfg.capacity, _F32),
        )

    def pack(self, requests: Sequence[AnytimeRequest]) -> AnytimeTables:
        """Pad/clip host requests into device tables."""
        sc = self.scfg
        N, P = len(requests), sc.prompt_len
        prompt = np.zeros((N, P), np.int32)
        plen = np.zeros((N,), np.int32)
        ntok = np.zeros((N,), np.int32)
        rel = np.zeros((N,), np.float32)
        ddl = np.zeros((N,), np.float32)
        for i, r in enumerate(requests):
            toks = np.asarray(list(r.prompt)[-P:], np.int32)
            if toks.size < 1:
                raise ValueError("empty prompt")
            prompt[i, :toks.size] = toks
            plen[i] = toks.size
            ntok[i] = min(max(int(r.n_tokens), 1), sc.max_new_tokens)
            rel[i] = r.release
            ddl[i] = r.deadline
        return AnytimeTables(
            prompt=jnp.asarray(prompt), prompt_len=jnp.asarray(plen),
            n_tokens=jnp.asarray(ntok), release=jnp.asarray(rel),
            deadline=jnp.asarray(ddl))

    def init_carry(self, tables: AnytimeTables, *,
                   telemetry: Optional[TelemetryConfig] = None
                   ) -> AnytimeCarry:
        N = tables.prompt.shape[0]
        B = self.scfg.batch_slots
        tel = (init_telemetry(telemetry, self.n_units)
               if telemetry is not None else None)
        carry = AnytimeCarry(
            now=jnp.zeros((), _F32),
            energy=jnp.asarray(
                self.scfg.start_frac * self.scfg.capacity, _F32),
            state=self._zero_state,
            slot_req=jnp.full((B,), -1, _I32),
            slot_next=jnp.zeros((B,), _I32),
            req_status=jnp.zeros((N,), _I32),
            req_finish=jnp.zeros((N,), _F32),
            req_agree=jnp.zeros((N,), _I32),
            req_tokens=jnp.zeros((N,), _I32),
            req_depth=jnp.zeros((N,), _I32),
            tel=tel,
        )
        # deep-copy every leaf: run() donates the carry into the segment
        # scan, which must neither invalidate the engine's cached zero
        # state nor see one deduplicated zeros constant at two argument
        # positions (XLA rejects donating the same buffer twice)
        return jax.tree.map(jnp.copy, carry)

    # ------------------------------------------------------------------ #
    def _step(self, tables: AnytimeTables, carry: AnytimeCarry,
              knobs: AnytimeKnobs, tel_on: bool) -> AnytimeCarry:
        cfg, sc = self.cfg, self.scfg
        B, U, m = sc.batch_slots, self.n_units, self.mandatory
        N = tables.prompt.shape[0]
        now, energy = carry.now, carry.energy
        slot_req, slot_next = carry.slot_req, carry.slot_next
        req_status = carry.req_status

        # --- admission: released, waiting requests into free slots ----- #
        laxity = tables.deadline - now
        if sc.policy == "anytime":
            scores = POL.zeta_intermittent_priority(
                laxity, 0.0, 1.0, sc.alpha, sc.beta, knobs.eta, energy,
                knobs.e_opt)
        else:
            scores = POL.edf_key(laxity, tables.release)
        waiting = (req_status == 0) & (tables.release <= now)
        scores = jnp.where(waiting, scores, POL.NEG)
        prev_slot_req = slot_req
        for b in range(B):
            best = jnp.argmax(scores).astype(_I32)
            ok = (slot_req[b] < 0) & (scores[best] > 0.5 * POL.NEG)
            slot_req = slot_req.at[b].set(
                jnp.where(ok, best, slot_req[b]))
            scores = jnp.where(ok, scores.at[best].set(POL.NEG), scores)
        admitted = slot_req != prev_slot_req                     # (B,)
        req = jnp.clip(slot_req, 0, N - 1)
        oob = jnp.where(admitted, req, N)
        req_status = req_status.at[oob].set(1, mode="drop")
        state = jax.tree.map(
            lambda a, z: jnp.where(_bmask(admitted, a), z, a),
            carry.state, self._zero_state)
        slot_next = jnp.where(admitted, tables.prompt[req, 0], slot_next)

        # --- power: brownout when the store can't cover the base cost -- #
        active = slot_req >= 0
        on = energy >= sc.e_base

        def run_model(st):
            return A.unit_decode_step(cfg, self.params, self.heads, st,
                                      slot_next, window=sc.window)

        def skip_model(st):
            return (jnp.zeros((U, B, cfg.padded_vocab), _F32), st)

        unit_logits, new_state = jax.lax.cond(
            on, run_model, skip_model, state)
        run_mask = active & on

        # --- depth control --------------------------------------------- #
        plen = tables.prompt_len[req]
        ntok = tables.n_tokens[req]
        ddl = tables.deadline[req]
        pos = state["pos"]
        gen_step = pos >= plen - 1        # this step's output is generated
        if sc.policy == "edf":
            depth = jnp.full((B,), U, _I32)
        elif sc.policy == "edf-m":
            depth = jnp.full((B,), m, _I32)
        else:
            marg = A.margins(unit_logits)                       # (U, B)
            depth, _ = A.select_depth(marg, knobs.exit_thr,
                                      knobs.use_exit_thr, m)
            if sc.deadline_cap:
                # greedy per-token latency budget for the remaining work
                rem = jnp.maximum(ntok - jnp.maximum(pos - plen + 1, 0), 1)
                budget = (ddl - now) / rem
                d_cap = jnp.floor(
                    (budget - sc.t_base) / sc.unit_time).astype(_I32)
                depth = jnp.minimum(depth, d_cap)
            gate_open = knobs.eta * energy >= knobs.e_opt
            depth = jnp.where(gate_open, depth, m)
            depth = jnp.clip(depth, m, U)
        depth = jnp.where(gen_step, depth, U)   # prompt steps: full depth
        depth = jnp.where(run_mask, depth, 0)

        # --- continuous-batching cost ---------------------------------- #
        max_depth = jnp.max(depth)
        dt = sc.t_base + sc.unit_time * max_depth.astype(_F32)
        consume = (jnp.any(run_mask).astype(_F32) * sc.e_base
                   + sc.unit_energy * jnp.sum(depth).astype(_F32))
        slot_i = jnp.clip((now / sc.trace_dt).astype(_I32), 0,
                          self.trace.shape[0] - 1)
        new_energy = jnp.clip(energy - consume + self.trace[slot_i] * dt,
                              0.0, sc.capacity)
        new_now = now + dt

        # --- emission + retirement ------------------------------------- #
        emit_full = jnp.argmax(unit_logits[-1], -1).astype(_I32)
        picked = A.take_at_depth(unit_logits, jnp.maximum(depth, 1))
        emit = jnp.argmax(picked, -1).astype(_I32)
        next_pos = pos + 1
        nxt = jnp.where(
            next_pos < plen,
            tables.prompt[req, jnp.clip(next_pos, 0, sc.prompt_len - 1)],
            emit)
        slot_next = jnp.where(run_mask, nxt, slot_next)
        gen_now = run_mask & gen_step
        emitted_after = jnp.maximum(pos - plen + 2, 0)
        agree_now = gen_now & (emit == emit_full)
        gen_req = jnp.where(gen_now, req, N)
        req_agree = carry.req_agree.at[gen_req].add(
            agree_now.astype(_I32), mode="drop")
        req_tokens = carry.req_tokens.at[gen_req].add(1, mode="drop")
        req_depth = carry.req_depth.at[gen_req].add(depth, mode="drop")

        done = gen_now & (emitted_after >= ntok)
        ontime = done & (new_now <= ddl)
        done_req = jnp.where(done, req, N)
        req_status = req_status.at[done_req].set(
            jnp.where(ontime, 2, 3), mode="drop")
        req_finish = carry.req_finish.at[done_req].set(
            new_now, mode="drop")
        slot_req = jnp.where(done, -1, slot_req)

        tel = carry.tel
        if tel_on:
            bins = jnp.where(depth < U, depth - 1, U)
            depth_hist = jnp.sum(
                gen_now[:, None]
                & (bins[:, None] == jnp.arange(U + 1)[None, :]),
                axis=0).astype(_I32)
            slack = jnp.where(done, ddl - new_now, 0.0)
            tel = record_anytime_step(
                tel,
                releases=jnp.sum(admitted).astype(_I32),
                misses=jnp.sum(done & ~ontime).astype(_I32),
                scheduled=jnp.sum(ontime).astype(_I32),
                retired=jnp.sum(done).astype(_I32),
                slack_sum=jnp.sum(slack),
                slack_min=jnp.min(
                    jnp.where(done, ddl - new_now, jnp.inf)),
                depth_hist=depth_hist,
                occupancy=jnp.sum(active).astype(_I32),
                energy=new_energy, t=new_now)

        return AnytimeCarry(
            now=new_now, energy=new_energy, state=new_state,
            slot_req=slot_req, slot_next=slot_next,
            req_status=req_status, req_finish=req_finish,
            req_agree=req_agree, req_tokens=req_tokens,
            req_depth=req_depth, tel=tel)

    # ------------------------------------------------------------------ #
    def _segment_fn(self, n_steps: int, tel_on: bool):
        key = (n_steps, tel_on)
        if key not in self._seg_fns:
            def seg(carry, tables, knobs):
                def body(c, _):
                    return self._step(tables, c, knobs, tel_on), None
                carry, _ = jax.lax.scan(
                    body, carry, None, length=n_steps)
                return carry

            self._seg_fns[key] = jax.jit(seg, donate_argnums=(0,))
        return self._seg_fns[key]

    def run(self, requests, *, knobs: Optional[AnytimeKnobs] = None,
            telemetry: Optional[TelemetryConfig] = None,
            n_segments: int = 1, hook=None, mesh=None) -> AnytimeResult:
        """Serve ``requests`` (host :class:`AnytimeRequest` list or a
        packed :class:`AnytimeTables`) over ``max_steps`` scan steps.

        ``n_segments`` splits the horizon into checkpointable chunks —
        bit-exact for any segmentation; ``hook(seg_index, carry, knobs)``
        runs between segments and may return replacement
        :class:`AnytimeKnobs` (dynamic args: no recompilation).
        ``mesh`` shards the decode state's batch axis via
        :func:`repro.launch.sharding.state_specs`.
        """
        tables = (requests if isinstance(requests, AnytimeTables)
                  else self.pack(requests))
        knobs = knobs if knobs is not None else self.default_knobs()
        carry = self.init_carry(tables, telemetry=telemetry)
        if mesh is not None:
            carry = carry._replace(state=jax.device_put(
                carry.state,
                SH.named(mesh, SH.state_specs(mesh, carry.state))))
        T_total = self.scfg.max_steps
        if not 1 <= n_segments <= T_total:
            raise ValueError(f"n_segments {n_segments} outside "
                             f"[1, {T_total}]")
        base, extra = divmod(T_total, n_segments)
        tel_on = telemetry is not None
        for seg in range(n_segments):
            n_steps = base + (1 if seg < extra else 0)
            if n_steps == 0:
                continue
            carry = self._segment_fn(n_steps, tel_on)(
                carry, tables, knobs)
            if hook is not None:
                new = hook(seg, carry, knobs)
                if new is not None:
                    knobs = new
        return self._finalize(tables, carry)

    def _finalize(self, tables: AnytimeTables,
                  carry: AnytimeCarry) -> AnytimeResult:
        status = np.asarray(jax.device_get(carry.req_status))
        finish = np.asarray(jax.device_get(carry.req_finish), np.float64)
        deadline = np.asarray(jax.device_get(tables.deadline), np.float64)
        horizon = float(jax.device_get(carry.now))
        finish = np.where(status >= 2, finish, horizon)
        tardiness = np.maximum(0.0, finish - deadline)
        return AnytimeResult(
            status=status, finish=finish, tardiness=tardiness,
            agree=np.asarray(jax.device_get(carry.req_agree)),
            tokens=np.asarray(jax.device_get(carry.req_tokens)),
            depth_sum=np.asarray(jax.device_get(carry.req_depth)),
            requested=np.asarray(jax.device_get(tables.n_tokens)),
            horizon=horizon, n_units=self.n_units,
            telemetry=carry.tel)

    # ------------------------------------------------------------------ #
    def score_fn(self, tables: AnytimeTables, *,
                 tardiness_weight: float = 0.0):
        """A pure ``knobs -> scalar score`` function of the dynamic knobs
        (jit/vmap-able — the :mod:`repro.adapt` objective surface).

        Score = on-time agreed-token fraction, minus
        ``tardiness_weight`` x mean tardiness normalised by the mean
        deadline — the latency/energy-budget objective the exit
        thresholds are tuned against.
        """
        T_total = self.scfg.max_steps
        norm = jnp.maximum(jnp.mean(tables.deadline), 1e-6)

        def score(knobs: AnytimeKnobs):
            carry = self.init_carry(tables)

            def body(c, _):
                return self._step(tables, c, knobs, False), None

            carry, _ = jax.lax.scan(body, carry, None, length=T_total)
            ontime = carry.req_status == 2
            good = jnp.sum(jnp.where(ontime, carry.req_agree, 0))
            frac = good / jnp.maximum(jnp.sum(tables.n_tokens), 1)
            finish = jnp.where(carry.req_status >= 2, carry.req_finish,
                               carry.now)
            tardy = jnp.mean(jnp.maximum(finish - tables.deadline, 0.0))
            return frac - tardiness_weight * tardy / norm

        return score
