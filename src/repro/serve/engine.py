"""Serving engine: job queue + Zygarde scheduler + agile executor + energy sim.

Unlike :func:`repro.core.scheduler.simulate` (which replays precomputed job
profiles for large-scale scheduler studies), the engine *actually executes*
the model unit-by-unit through the agile frontends, including runtime
centroid adaptation — classification outcomes therefore depend on the order
the scheduler chose, exactly as on the device.

Job profiles are *lazy*: unit u's utility-test outcome is computed the first
time the scheduler executes unit u (``DynamicJobProfile``), so the same
event-driven simulator drives both the replay and live paths.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core import kmeans as km
from repro.core.energy import Capacitor, Harvester
from repro.core.scheduler import (
    Job,
    SimConfig,
    SimResult,
    TaskSpec,
    simulate,
)


class _LazyVec:
    """Array-like view that materialises per-unit results on first access."""

    def __init__(self, profile: "DynamicJobProfile", name: str):
        self._p = profile
        self._name = name

    def __getitem__(self, u):
        self._p._ensure(int(u))
        return getattr(self._p, "_" + self._name)[int(u)]

    def __len__(self):
        return self._p.n_units


class DynamicJobProfile:
    """Duck-typed :class:`repro.core.scheduler.JobProfile` that runs the
    agile model lazily (with adaptation) as units are scheduled."""

    def __init__(self, model, x, label: int, *, adapt: bool = True,
                 adapt_weight: float = 32.0):
        self._model = model
        self._label = int(label)
        self._adapt = adapt
        self._adapt_weight = adapt_weight
        self._state = model._initial_state(x)
        self._exec_units = 0
        n = model.n_units
        self._margins = np.zeros(n)
        self._passes = np.zeros(n, bool)
        self._correct = np.zeros(n, bool)
        self._exited = False
        self.margins = _LazyVec(self, "margins")
        self.passes = _LazyVec(self, "passes")
        self.correct = _LazyVec(self, "correct")

    @property
    def n_units(self) -> int:
        return self._model.n_units

    def _ensure(self, u: int) -> None:
        while self._exec_units <= u:
            i = self._exec_units
            self._state, feats = self._model._run_unit(self._state, i)
            uc = self._model.bank[i]
            pred, d1, d2, idx, margin = km.classify(uc, feats)
            self._margins[i] = float(margin[0])
            ok = float(margin[0]) > float(uc.threshold)
            self._passes[i] = ok
            self._correct[i] = int(pred[0]) == self._label
            if ok and not self._exited:
                self._exited = True
                if self._adapt:
                    self._model.bank[i] = km.adapt(
                        uc, feats, idx, weight=self._adapt_weight
                    )
                    self._model._propagate_from(i, idx)
            self._exec_units += 1

    def mandatory_units(self) -> int:
        for u in range(self.n_units):
            self._ensure(u)
            if self._passes[u]:
                return u + 1
        return self.n_units


@dataclass(frozen=True)
class Request:
    x: object            # model input (image / token sequence / batch dict)
    label: int
    release: float


@dataclass
class ServeConfig:
    policy: str = "zygarde"
    period: float = 1.0
    deadline: float = 2.0
    unit_time: Optional[np.ndarray] = None      # seconds per unit
    unit_energy: Optional[np.ndarray] = None    # joules per unit
    fragments_per_unit: int = 4
    horizon: float = 600.0
    queue_size: int = 3
    adapt: bool = True
    seed: int = 0
    e_opt_fraction: float = 0.7


class ServeEngine:
    """End-to-end intermittent serving of one or more agile-model tasks."""

    def __init__(
        self,
        models: Sequence,                 # agile frontends (one per task)
        harvester: Harvester,
        eta: float,
        cap: Optional[Capacitor] = None,
        config: Optional[ServeConfig] = None,
    ):
        self.models = list(models)
        self.harvester = harvester
        self.eta = eta
        self.cap = cap or Capacitor()
        self.config = config or ServeConfig()

    def run(self, requests_per_task: Sequence[Sequence[Request]]) -> SimResult:
        cfg = self.config
        tasks = []
        for tid, (model, reqs) in enumerate(
            zip(self.models, requests_per_task)
        ):
            n_units = model.n_units
            ut = (
                cfg.unit_time if cfg.unit_time is not None
                else np.full(n_units, 0.2)
            )
            ue = (
                cfg.unit_energy if cfg.unit_energy is not None
                else np.full(n_units, 5e-3)
            )
            profiles = [
                DynamicJobProfile(model, r.x, r.label, adapt=cfg.adapt)
                for r in reqs
            ]
            tasks.append(
                TaskSpec(
                    task_id=tid,
                    period=cfg.period,
                    deadline=cfg.deadline,
                    unit_time=np.asarray(ut, float),
                    unit_energy=np.asarray(ue, float),
                    profiles=profiles,
                    fragments_per_unit=cfg.fragments_per_unit,
                )
            )
        sim = SimConfig(
            policy=cfg.policy,
            horizon=cfg.horizon,
            queue_size=cfg.queue_size,
            seed=cfg.seed,
            e_opt_fraction=cfg.e_opt_fraction,
        )
        return simulate(tasks, self.harvester, self.eta, self.cap, sim)
