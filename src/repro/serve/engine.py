"""Scalar serving engine: job queue + Zygarde scheduler + agile executor.

This is the *reference* single-device engine — an event-driven loop over
one agile CNN / reduced-transformer task set.  Unlike
:func:`repro.core.scheduler.simulate` (which replays precomputed job
profiles for large-scale scheduler studies), the engine *actually
executes* the model unit-by-unit through the agile frontends, including
runtime centroid adaptation — classification outcomes therefore depend on
the order the scheduler chose, exactly as on the device.

Job profiles are *lazy*: unit u's utility-test outcome is computed the
first time the scheduler executes unit u (``DynamicJobProfile``), so the
same event-driven simulator drives both the replay and live paths.

Scaled-up siblings (this module stays the semantics oracle they are
tested against):

* :class:`repro.serve.fleet_engine.FleetServeEngine` — the vectorized
  fleet path: one jitted ``lax.scan`` serves thousands of devices, with
  ``run(..., mode="fused")`` executing the whole segment loop inside one
  Pallas kernel and ``run_stream`` streaming million-job workloads
  through donated chunked scans.  Bit-exact vs this engine on
  clock-commensurate workloads (``tests/test_fleet_engine.py``).
* :class:`repro.serve.anytime.AnytimeServeEngine` — deadline-aware
  anytime serving of the big-model configs: continuous batching over a
  jitted decode loop with per-request early-exit depth control
  (``docs/anytime_serving.md``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core import kmeans as km
from repro.core.energy import Capacitor, Harvester
from repro.core.scheduler import (
    Job,
    SimConfig,
    SimResult,
    TaskSpec,
    simulate,
)


class _LazyVec:
    """Array-like view that materialises per-unit results on first access."""

    def __init__(self, profile: "DynamicJobProfile", name: str):
        self._p = profile
        self._name = name

    def __getitem__(self, u):
        self._p._ensure(int(u))
        return getattr(self._p, "_" + self._name)[int(u)]

    def __len__(self):
        return self._p.n_units


class DynamicJobProfile:
    """Duck-typed :class:`repro.core.scheduler.JobProfile` that runs the
    agile model lazily (with adaptation) as units are scheduled."""

    def __init__(self, model, x, label: int, *, adapt: bool = True,
                 adapt_weight: float = 32.0):
        self._model = model
        self._label = int(label)
        self._adapt = adapt
        self._adapt_weight = adapt_weight
        self._state = model._initial_state(x)
        self._exec_units = 0
        n = model.n_units
        self._margins = np.zeros(n)
        self._passes = np.zeros(n, bool)
        self._correct = np.zeros(n, bool)
        self._preds = np.full(n, -1, np.int64)
        self._exited = False
        self.margins = _LazyVec(self, "margins")
        self.passes = _LazyVec(self, "passes")
        self.correct = _LazyVec(self, "correct")

    @property
    def n_units(self) -> int:
        return self._model.n_units

    def _ensure(self, u: int) -> None:
        while self._exec_units <= u:
            i = self._exec_units
            self._state, feats = self._model._run_unit(self._state, i)
            uc = self._model.bank[i]
            pred, d1, d2, idx, margin = km.classify(uc, feats)
            self._margins[i] = float(margin[0])
            ok = float(margin[0]) > float(uc.threshold)
            self._passes[i] = ok
            self._preds[i] = int(pred[0])
            self._correct[i] = int(pred[0]) == self._label
            if ok and not self._exited:
                self._exited = True
                if self._adapt:
                    self._model.bank[i] = km.adapt(
                        uc, feats, idx, weight=self._adapt_weight
                    )
                    self._model._propagate_from(i, idx)
            self._exec_units += 1

    def mandatory_units(self) -> int:
        for u in range(self.n_units):
            self._ensure(u)
            if self._passes[u]:
                return u + 1
        return self.n_units


@dataclass(frozen=True)
class Request:
    x: object            # model input (image / token sequence / batch dict)
    label: int
    release: float


@dataclass
class ServeConfig:
    policy: str = "zygarde"
    # period/deadline: one float shared by every task, or a sequence with
    # one entry per task (same order as the ``models`` list)
    period: object = 1.0
    deadline: object = 2.0
    unit_time: Optional[np.ndarray] = None      # seconds per unit
    unit_energy: Optional[np.ndarray] = None    # joules per unit
    fragments_per_unit: int = 4
    horizon: float = 600.0
    queue_size: int = 3
    adapt: bool = True
    seed: int = 0
    e_opt_fraction: float = 0.7
    # cold-boot control + the event loop's idle integration step; the fleet
    # serving parity workloads pin both (charged start, dt = one fragment)
    start_charged: bool = False
    sim_dt: Optional[float] = None


def per_task(value, n_tasks: int) -> list[float]:
    """Broadcast a scalar config value to ``n_tasks`` (or validate a
    per-task sequence)."""
    if np.ndim(value) == 0:
        return [float(value)] * n_tasks
    vals = [float(v) for v in np.asarray(value).ravel()]
    if len(vals) != n_tasks:
        raise ValueError(
            f"per-task config has {len(vals)} entries for {n_tasks} tasks")
    return vals


class ServeEngine:
    """End-to-end intermittent serving of one or more agile-model tasks."""

    def __init__(
        self,
        models: Sequence,                 # agile frontends (one per task)
        harvester: Harvester,
        eta: float,
        cap: Optional[Capacitor] = None,
        config: Optional[ServeConfig] = None,
    ):
        self.models = list(models)
        self.harvester = harvester
        self.eta = eta
        self.cap = cap or Capacitor()
        self.config = config or ServeConfig()

    def run(self, requests_per_task: Sequence[Sequence[Request]]) -> SimResult:
        cfg = self.config
        periods = per_task(cfg.period, len(self.models))
        deadlines = per_task(cfg.deadline, len(self.models))
        tasks = []
        for tid, (model, reqs) in enumerate(
            zip(self.models, requests_per_task)
        ):
            n_units = model.n_units
            ut = (
                cfg.unit_time if cfg.unit_time is not None
                else np.full(n_units, 0.2)
            )
            ue = (
                cfg.unit_energy if cfg.unit_energy is not None
                else np.full(n_units, 5e-3)
            )
            profiles = [
                DynamicJobProfile(model, r.x, r.label, adapt=cfg.adapt)
                for r in reqs
            ]
            tasks.append(
                TaskSpec(
                    task_id=tid,
                    period=periods[tid],
                    deadline=deadlines[tid],
                    unit_time=np.asarray(ut, float),
                    unit_energy=np.asarray(ue, float),
                    profiles=profiles,
                    fragments_per_unit=cfg.fragments_per_unit,
                )
            )
        sim = SimConfig(
            policy=cfg.policy,
            horizon=cfg.horizon,
            queue_size=cfg.queue_size,
            seed=cfg.seed,
            e_opt_fraction=cfg.e_opt_fraction,
            start_charged=cfg.start_charged,
        )
        if cfg.sim_dt is not None:
            sim.dt = float(cfg.sim_dt)
        res = simulate(tasks, self.harvester, self.eta, self.cap, sim)
        # retained for post-run inspection: the live profiles carry the
        # per-unit margins/predictions the scheduler actually computed, and
        # the per-job records back the scalar side of the fleet live-parity
        # harness (tests/test_fleet_engine.py)
        self.tasks_ = tasks
        self.profiles_ = [t.profiles for t in tasks]
        self.jobs_ = getattr(res, "jobs", None)
        return res
