"""Segmented vs monolithic fleet execution: the cost of checkpointability.

``fleet.run_segments`` trades one long ``lax.scan`` for ``n_segments``
shorter jitted scans with the full carry pytree materialised on the host
at every boundary — the substrate online adaptation hooks into.  This
bench quantifies what that costs on a mid-size grid:

* **compile time** — first-call wall time (the segmented path compiles at
  most two scan lengths, amortised across all segments, so its compile
  time should *drop* vs the monolithic scan's single long unroll);
* **steady state** — device-steps/sec on the second call, isolating the
  per-boundary host round-trip overhead for n_segments in {1, 8, 32}.

Rows carry the usual throughput keys plus a ``result`` digest taken from
``FleetResult.as_dict()`` (the JSON export mirroring ``SimResult.as_dict``)
— also asserting segmented results stay bit-identical to the monolithic
scan while the clock runs.
"""
from __future__ import annotations

import time

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec

from .common import emit


def _task(n_jobs=25, n_units=4, exit_at=1, unit_t=0.1):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _grid(horizon):
    return fleet.SweepGrid(
        task=_task(),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.3, 0.6, 0.9),
        harvesters=(energy.Harvester("h", 0.95, 0.95, 0.08),),
        capacitors=tuple(energy.Capacitor(capacitance_f=c)
                         for c in (0.025, 0.05, 0.1)),
        seeds=(0, 1),
        horizon=horizon,
    )


def _digest(res: fleet.FleetResult) -> dict:
    """Compact summary of a FleetResult via its JSON export."""
    d = res.as_dict()
    return dict(
        devices=len(d["released"]),
        released=int(np.sum(d["released"])),
        scheduled=int(np.sum(d["scheduled"])),
        correct=int(np.sum(d["correct"])),
        deadline_misses=int(np.sum(d["deadline_misses"])),
    )


def run(quick: bool = True) -> None:
    horizon = 20.0 if quick else 120.0
    cfg, statics, _ = fleet.build(_grid(horizon))
    n_dev, n_steps = cfg.n_devices, statics.n_steps

    def dsteps(wall: float) -> float:
        return round(n_dev * n_steps / wall, 1)

    # monolithic scan: compile (first call) + steady state (second call)
    t0 = time.perf_counter()
    ref = fleet.simulate_fleet(cfg, statics)
    ref.released.block_until_ready()
    mono_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref = fleet.simulate_fleet(cfg, statics)
    ref.released.block_until_ready()
    mono_steady = time.perf_counter() - t0

    rows = [dict(mode="monolithic", n_segments=0, devices=n_dev,
                 n_steps=n_steps, compile_s=round(mono_compile, 3),
                 steady_s=round(mono_steady, 3),
                 device_steps_per_sec=dsteps(mono_steady),
                 result=_digest(ref))]

    for n_seg in (1, 8, 32):
        # fresh compile per segment count is impossible to isolate inside
        # one process (the two chunk lengths cache across counts), so the
        # first-call number for n_segments=1 carries the compile cost and
        # the later counts show the amortised boundary overhead
        t0 = time.perf_counter()
        res, _ = fleet.run_segments(cfg, statics, n_seg)
        res.released.block_until_ready()
        first = time.perf_counter() - t0
        t0 = time.perf_counter()
        res, _ = fleet.run_segments(cfg, statics, n_seg)
        res.released.block_until_ready()
        steady = time.perf_counter() - t0
        for name in ref._fields:       # segmented == monolithic, always
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, name)),
                np.asarray(getattr(res, name)), err_msg=name)
        rows.append(dict(
            mode="run_segments", n_segments=n_seg, devices=n_dev,
            n_steps=n_steps, compile_s=round(first, 3),
            steady_s=round(steady, 3),
            device_steps_per_sec=dsteps(steady),
            vs_monolithic=round(mono_steady / steady, 3),
            result=_digest(res)))

    emit("fleet_segments", rows)


if __name__ == "__main__":
    run()
