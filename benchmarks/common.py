"""Shared benchmark plumbing: cached trained models, CSV row printing,
and the compile-vs-steady timing discipline every bench lane shares."""
from __future__ import annotations

import functools
import time
from pathlib import Path

import numpy as np

import jax

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments"

# rows emitted since the last drain, keyed by bench name — run.py drains
# this after each module to write the per-bench BENCH_<name>.json artifact
PENDING_ROWS: dict[str, list[dict]] = {}

# cold-vs-steady detail per labelled timeit() call since the last drain —
# written into each BENCH_<name>.json as its "timings" section
PENDING_TIMINGS: dict[str, dict] = {}

# set by ``benchmarks/run.py --profile``: bench modules consult it to attach
# roofline attribution (repro.launch.profiling) to their measurements
PROFILE: bool = False


def drain_rows() -> dict[str, list[dict]]:
    out = dict(PENDING_ROWS)
    PENDING_ROWS.clear()
    return out


def drain_timings() -> dict[str, dict]:
    out = dict(PENDING_TIMINGS)
    PENDING_TIMINGS.clear()
    return out


@functools.lru_cache(maxsize=None)
def dataset(name: str, n_train: int = 384, n_test: int = 192,
            environment: int = 0, seed: int = 0,
            separability: float = 2.0):
    from repro.data import make_dataset

    return make_dataset(
        name, n_train=n_train, n_test=n_test, environment=environment,
        seed=seed, separability=separability,
    )


@functools.lru_cache(maxsize=None)
def trained(name: str, loss: str = "layer_aware", seed: int = 0,
            epochs: int = 3, n_pairs: int = 768,
            separability: float = 2.0):
    """Train (and cache, per process) one agile CNN.

    min_exit_accuracy=0.96 is the paper's programmer-configured Fig-8
    trade-off point: exit thresholds are calibrated so exited samples keep
    >= 96% of the achievable accuracy (the Fig 16 <= 2.5-pt regime)."""
    from repro.train import train_agile_cnn

    return train_agile_cnn(
        dataset(name, separability=separability), loss=loss, epochs=epochs,
        n_pairs=n_pairs, batch_size=32, seed=seed, min_exit_accuracy=0.96,
    )


@functools.lru_cache(maxsize=None)
def agile(name: str, loss: str = "layer_aware", seed: int = 0,
          separability: float = 2.0):
    from repro.core.agile import AgileCNN

    t = trained(name, loss, seed, separability=separability)
    return AgileCNN(t.cfg, t.params, t.bank)


@functools.lru_cache(maxsize=None)
def profiles(name: str, loss: str = "layer_aware", seed: int = 0,
             separability: float = 2.0):
    ds = dataset(name, separability=separability)
    return tuple(
        agile(name, loss, seed, separability).profile_batch(
            ds.x_test, ds.y_test
        )
    )


def timeit(fn, *args, repeats: int = 20, warmup: int = 3,
           label: str | None = None) -> float:
    """Median steady-state wall-time per call in microseconds.

    Every call — warmup and timed — is followed by
    ``jax.block_until_ready``; JAX dispatch is asynchronous, so without the
    barrier the first timed call could absorb device work still in flight
    from warmup (and each timestamp would measure dispatch, not execution).
    The first warmup call is timed separately as the *cold* call (it
    carries compilation for jitted ``fn``); pass ``label`` to record the
    cold/steady split into the bench's ``BENCH_<name>.json`` ``timings``
    section.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    cold_s = time.perf_counter() - t0
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    steady_us = float(np.median(times) * 1e6)
    if label is not None:
        PENDING_TIMINGS[label] = dict(
            cold_us=round(cold_s * 1e6, 1), steady_us=round(steady_us, 1),
            repeats=repeats)
    return steady_us


def emit(bench: str, rows: list[dict]) -> list[dict]:
    """Print rows as CSV and queue them for the per-module
    ``experiments/BENCH_<name>.json`` artifact (written by ``run.py``; the
    legacy aggregate ``bench_results.json`` is gone — nothing read it)."""
    for r in rows:
        flat = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{bench},{flat}")
    PENDING_ROWS.setdefault(bench, []).extend(rows)
    return rows
