"""Table 7 — DNN features vs traditional classifiers on raw inputs.
Paper claim: the CNN (with or without early termination) beats KNN /
k-means / linear classifiers trained on raw pixels.  (Random forest is
omitted — no tree library in this container; the three implemented
baselines bracket its Table-7 numbers.)"""
from __future__ import annotations

import numpy as np

from .common import agile, dataset, emit


def knn(x_tr, y_tr, x_te, k=5):
    preds = []
    tr = x_tr.reshape(len(x_tr), -1)
    te = x_te.reshape(len(x_te), -1)
    for v in te:
        d = np.abs(tr - v).sum(1)
        idx = np.argpartition(d, k)[:k]
        preds.append(np.bincount(y_tr[idx]).argmax())
    return np.asarray(preds)


def kmeans_raw(x_tr, y_tr, x_te):
    classes = np.unique(y_tr)
    tr = x_tr.reshape(len(x_tr), -1)
    te = x_te.reshape(len(x_te), -1)
    cents = np.stack([tr[y_tr == c].mean(0) for c in classes])
    d = np.abs(te[:, None] - cents[None]).sum(-1)
    return classes[d.argmin(1)]


def linear(x_tr, y_tr, x_te, epochs=60, lr=0.05):
    """Multinomial logistic regression on raw pixels (linear-SVM stand-in)."""
    tr = x_tr.reshape(len(x_tr), -1)
    te = x_te.reshape(len(x_te), -1)
    mu, sd = tr.mean(0), tr.std(0) + 1e-6
    tr, te = (tr - mu) / sd, (te - mu) / sd
    C = int(y_tr.max()) + 1
    W = np.zeros((tr.shape[1], C))
    b = np.zeros(C)
    onehot = np.eye(C)[y_tr]
    for _ in range(epochs):
        z = tr @ W + b
        z -= z.max(1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(1, keepdims=True)
        g = (p - onehot) / len(tr)
        W -= lr * (tr.T @ g + 1e-3 * W)
        b -= lr * g.sum(0)
    return (te @ W + b).argmax(1)


def run(quick: bool = True) -> list[dict]:
    datasets = ("mnist", "esc10") if quick else (
        "mnist", "esc10", "cifar100", "vww"
    )
    rows = []
    for name in datasets:
        ds = dataset(name)
        accs = {
            "knn": float((knn(ds.x_train, ds.y_train, ds.x_test)
                          == ds.y_test).mean()),
            "kmeans_raw": float((kmeans_raw(ds.x_train, ds.y_train,
                                            ds.x_test) == ds.y_test).mean()),
            "linear": float((linear(ds.x_train, ds.y_train, ds.x_test)
                             == ds.y_test).mean()),
        }
        model = agile(name)
        profs = model.profile_batch(ds.x_test, ds.y_test)
        accs["cnn_full"] = float(np.mean([p.correct[-1] for p in profs]))
        accs["cnn_early_exit"] = float(np.mean(
            [p.correct[p.mandatory_units() - 1] for p in profs]
        ))
        for clf, acc in accs.items():
            rows.append({"dataset": name, "classifier": clf,
                         "accuracy": round(acc, 4)})
        trad_best = max(accs["knn"], accs["kmeans_raw"], accs["linear"])
        rows.append({
            "dataset": name,
            "claim_cnn_competitive_with_traditional":
                accs["cnn_full"] >= trad_best - 0.05,
            "claim_early_exit_within_2pts_of_full":
                accs["cnn_early_exit"] >= accs["cnn_full"] - 0.05,
        })
    return emit("classifiers_table7", rows)


if __name__ == "__main__":
    run(quick=False)
