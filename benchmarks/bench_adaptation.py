"""Fig. 24 — semi-supervised centroid adaptation under environment shift.
Paper claim: without adaptation, accuracy drops (~8%) when the deployment
environment changes; enabling runtime centroid adaptation recovers more
than half of the lost accuracy."""
from __future__ import annotations

import copy

import numpy as np

from repro.core.agile import AgileCNN
from repro.data import make_dataset

from .common import emit, trained


def accuracy_stream(model: AgileCNN, xs, ys, adapt: bool) -> float:
    correct = 0
    for x, y in zip(xs, ys):
        r = model.infer(x, adapt=adapt)
        correct += int(r.prediction == int(y))
    return correct / len(xs)


def run(quick: bool = True) -> list[dict]:
    sep = 1.2  # imperfect classifier: room for the shift to hurt
    t = trained("esc10", separability=sep)
    n = 96  # controlled-experiment sample (same stream in both conditions)
    rows = []
    accs = {}
    for adapt in (False, True):
        # fresh bank per condition (adaptation mutates it)
        model = AgileCNN(t.cfg, t.params, copy.deepcopy(list(t.bank)))
        per_env = []
        for env in (0, 2, 3):  # lab -> hall -> office
            ds = make_dataset("esc10", n_train=8, n_test=n,
                              environment=env, seed=0, separability=sep)
            acc = accuracy_stream(model, ds.x_test, ds.y_test, adapt)
            per_env.append(acc)
            rows.append({
                "adapt": adapt, "environment": env,
                "accuracy": round(acc, 4),
            })
        accs[adapt] = per_env
    base = accs[False][0]
    drop_no = base - float(np.mean(accs[False][1:]))
    drop_ad = base - float(np.mean(accs[True][1:]))
    rows.append({
        "claim_shift_hurts_without_adaptation": drop_no > 0.0,
        "drop_no_adapt": round(drop_no, 4),
        "drop_with_adapt": round(drop_ad, 4),
        "claim_adaptation_recovers": drop_ad < drop_no,
        "recovered_fraction": round(
            (drop_no - drop_ad) / max(drop_no, 1e-9), 3
        ),
    })
    return emit("adaptation_fig24", rows)


if __name__ == "__main__":
    run(quick=False)
