"""Fig. 25 — eta-factor validation: the estimated eta of a harvester
converges to its next-slot energy-state prediction accuracy.
Paper example: kinetic harvester eta=0.65 <-> ~65% prediction accuracy."""
from __future__ import annotations

import numpy as np

from repro.core import energy

from .common import emit


def predict_next_accuracy(trace: np.ndarray) -> float:
    """Persistence predictor: next state == current state (what eta's
    burstiness licenses the scheduler to assume)."""
    return float((trace[1:] == trace[:-1]).mean())


def run(quick: bool = True) -> list[dict]:
    n = 30_000 if quick else 120_000
    rows = []
    for name, p_stay in (
        ("solar-like", 0.95), ("kinetic-like", 0.825), ("rf-like", 0.69),
        ("random", 0.5),
    ):
        h = energy.Harvester(name, p_stay, p_stay, 1.0)
        tr = h.sample_events(np.random.default_rng(21), n)
        eta = energy.eta_factor(tr)
        acc = predict_next_accuracy(tr)
        # chance-corrected accuracy, comparable to eta in [0,1]
        acc_corr = max(0.0, 2 * acc - 1)
        rows.append({
            "harvester": name, "p_stay": p_stay,
            "eta": round(eta, 3),
            "pred_next_acc": round(acc, 3),
            "pred_acc_chance_corrected": round(acc_corr, 3),
            "abs_gap": round(abs(eta - acc_corr), 3),
        })
    # The cumulative-KW eta estimator saturates to 0 for weakly-bursty
    # sources (paper §11.4 notes the estimator's accuracy depends on the
    # trace) — the convergence claim (Fig. 25) is for usable harvesters,
    # i.e. eta above ~0.3 (the paper's own systems span 0.38-0.71).
    gaps = [r["abs_gap"] for r in rows if r["eta"] >= 0.3]
    low = [r["abs_gap"] for r in rows if r["eta"] < 0.3]
    rows.append({
        "claim_eta_tracks_prediction_accuracy": max(gaps) < 0.15,
        "max_gap_usable_harvesters": max(gaps),
        "max_gap_low_eta_note": max(low) if low else 0.0,
    })
    return emit("eta_validation_fig25", rows)


if __name__ == "__main__":
    run(quick=False)
