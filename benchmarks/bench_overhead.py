"""Fig. 14 — component overheads (wall time per call on this host; the
paper's absolute MSP430 numbers do not transfer, the *structure* does:
classifier + utility test ≪ one DNN layer ≪ whole DNN)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import energy, kmeans as km
from repro.core.scheduler import SimConfig, TaskSpec, simulate
from repro.kernels import ops

from .common import agile, dataset, emit, profiles, timeit


def run(quick: bool = True) -> list[dict]:
    ds = dataset("esc10")
    model = agile("esc10")
    x1 = jnp.asarray(ds.x_test[:1])

    # one DNN unit (first conv layer) vs whole DNN vs classifier
    state = model._initial_state(x1)

    def one_unit():
        s, f = model._run_unit(state, 0)
        jax.block_until_ready(f)

    def whole_dnn():
        s = state
        for u in range(model.n_units):
            s, f = model._run_unit(s, u)
        jax.block_until_ready(f)

    uc = model.bank[0]
    feats0 = model._run_unit(state, 0)[1]
    classify_jit = jax.jit(km.classify)
    adapt_jit = jax.jit(km.adapt, static_argnames=("weight",))

    def classify():
        out = classify_jit(uc, feats0)
        jax.block_until_ready(out[0])

    def classify_adapt():
        pred, d1, d2, idx, margin = classify_jit(uc, feats0)
        new = adapt_jit(uc, feats0, idx, weight=32.0)
        jax.block_until_ready(new.centroids)

    # scheduler pick overhead: one simulated 3-job decision point
    prof = list(profiles("esc10"))[:3]
    task = TaskSpec(
        0, 1.0, 2.0, np.full(model.n_units, 0.1),
        np.full(model.n_units, 1e-3), prof,
    )
    harv = energy.Harvester("battery", 1.0, 0.0, 1.0)

    def sched():
        simulate([task], harv, 1.0, sim=SimConfig(policy="zygarde",
                                                  horizon=3.0))

    cap = energy.Capacitor()

    def energy_manager():
        cap.charge(1e-3)
        cap.discharge(5e-4)

    rows = [
        {"component": "dnn_unit0", "us": timeit(one_unit, label="dnn_unit0")},
        {"component": "dnn_whole", "us": timeit(whole_dnn, repeats=8,
                                                label="dnn_whole")},
        {"component": "kmeans_classify", "us": timeit(
            classify, label="kmeans_classify")},
        {"component": "classify_plus_adapt", "us": timeit(
            classify_adapt, label="classify_plus_adapt")},
        {"component": "scheduler_3jobs", "us": timeit(
            sched, repeats=5, label="scheduler_3jobs")},
        {"component": "energy_manager", "us": timeit(
            energy_manager, repeats=200, label="energy_manager")},
    ]
    by = {r["component"]: r["us"] for r in rows}
    rows.append({
        "component": "claim_classifier_much_cheaper_than_dnn",
        "value": by["kmeans_classify"] < 0.5 * by["dnn_whole"],
        "detail": f"{by['dnn_whole'] / max(by['kmeans_classify'], 1e-9):.1f}x",
    })
    return emit("overhead_fig14", rows)


if __name__ == "__main__":
    run()
