"""Fleet-simulator throughput: scalar python loop vs one jitted
``vmap``/``scan`` call vs the Pallas fleet_priority inner step.

Sweeps the paper's scheduler grid (policy × eta × harvester × capacitor ×
seed) at 1000 device-configs and reports devices/sec for each execution
path.  The scalar number extrapolates from a sample of grid points (running
all 1000 through the python event loop would take minutes); the batched
numbers time the full fleet after a warm-up call, so compilation is
excluded.  On this CPU container the Pallas path runs in ``interpret``
mode — it validates the kernel against the jnp path rather than racing it;
on a TPU backend the same call compiles to Mosaic.
"""
from __future__ import annotations

import time

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, SimConfig, TaskSpec, simulate

from .common import emit


def _task(n_jobs=25, n_units=4, exit_at=1):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _grid(task, horizon):
    return fleet.SweepGrid(
        task=task,
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.2, 0.5, 0.8, 0.9, 1.0),
        harvesters=(energy.Harvester("h", 0.95, 0.95, 0.08),
                    energy.Harvester("sun", 0.9, 0.9, 0.05)),
        capacitors=tuple(energy.Capacitor(capacitance_f=c)
                         for c in (0.01, 0.025, 0.05, 0.1, 0.2)),
        seeds=(0, 1, 2, 3, 4),
        horizon=horizon,
    )


def _time_fleet(cfg, statics, use_pallas):
    res = fleet.simulate_fleet(cfg, statics, use_pallas=use_pallas)
    res.released.block_until_ready()          # warm-up: compile + run
    t0 = time.perf_counter()
    res = fleet.simulate_fleet(cfg, statics, use_pallas=use_pallas)
    res.released.block_until_ready()
    return time.perf_counter() - t0, res


def run(quick: bool = True) -> None:
    horizon = 20.0 if quick else 120.0
    n_scalar = 4 if quick else 16
    task = _task()
    grid = _grid(task, horizon)
    cfg, statics, meta = fleet.build(grid)
    n_dev = cfg.n_devices

    # scalar python event loop: sample grid points, extrapolate
    sample = meta[:: max(1, len(meta) // n_scalar)][:n_scalar]
    harvs = {h.name: h for h in grid.harvesters}
    t0 = time.perf_counter()
    for m in sample:
        simulate(
            [task], harvs[m["harvester"]], m["eta"],
            cap=energy.Capacitor(capacitance_f=m["capacitance_f"]),
            sim=SimConfig(policy=m["policy"], horizon=horizon,
                          seed=m["seed"]),
        )
    scalar_s = (time.perf_counter() - t0) / len(sample)
    scalar_rate = 1.0 / scalar_s

    vmap_t, res_v = _time_fleet(cfg, statics, use_pallas=False)
    pallas_t, res_p = _time_fleet(cfg, statics, use_pallas=True)
    assert (np.asarray(res_v.scheduled) == np.asarray(res_p.scheduled)).all()

    rows = [
        dict(mode="scalar_loop", devices=len(sample),
             wall_s=round(scalar_s * n_dev, 3),
             devices_per_sec=round(scalar_rate, 1), speedup=1.0),
        dict(mode="vmap_scan", devices=n_dev, wall_s=round(vmap_t, 3),
             devices_per_sec=round(n_dev / vmap_t, 1),
             speedup=round(n_dev / vmap_t / scalar_rate, 1)),
        dict(mode="pallas_interpret", devices=n_dev,
             wall_s=round(pallas_t, 3),
             devices_per_sec=round(n_dev / pallas_t, 1),
             speedup=round(n_dev / pallas_t / scalar_rate, 1)),
    ]
    emit("fleet_throughput", rows)


if __name__ == "__main__":
    run()
