"""Fleet-simulator throughput: scalar python loop vs one jitted
``vmap``/``scan`` call vs the Pallas fleet_priority inner step.

Sweeps the paper's scheduler grid (policy × eta × harvester × capacitor ×
seed) at 1000 device-configs and reports devices/sec for each execution
path, then re-times the batched path on a K=4 multi-task workload (four
contending streams per device) against the K=1 baseline — the throughput
axis the task-set refactor added (rows carry ``n_tasks`` and
``device_steps_per_sec`` so the two are comparable per simulated step).
The scalar number extrapolates from a sample of grid points (running all
1000 through the python event loop would take minutes); the batched
numbers time the full fleet after a warm-up call, so compilation is
excluded.  On this CPU container the Pallas path runs in ``interpret``
mode — it validates the kernel against the jnp path rather than racing it;
on a TPU backend the same call compiles to Mosaic.
"""
from __future__ import annotations

import time

import numpy as np

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, SimConfig, TaskSpec, simulate

from .common import emit


def _task(n_jobs=25, n_units=4, exit_at=1, task_id=0, period=1.0,
          deadline=2.0, unit_t=0.1):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    return TaskSpec(
        task_id=task_id, period=period, deadline=deadline,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _task_set(k=4, n_jobs=25):
    """K contending streams with staggered periods/deadlines (audio+camera
    style); unit times stay multiples of the K=1 task's fragment time so
    the fixed timestep — and therefore the step count — matches the K=1
    baseline and the rates are comparable."""
    return tuple(
        _task(n_jobs=n_jobs, task_id=i, period=1.0 + 0.25 * i,
              deadline=2.0 + 0.5 * i, n_units=3 + i % 2)
        for i in range(k)
    )


def _grid(task, horizon):
    return fleet.SweepGrid(
        task=task,
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.2, 0.5, 0.8, 0.9, 1.0),
        harvesters=(energy.Harvester("h", 0.95, 0.95, 0.08),
                    energy.Harvester("sun", 0.9, 0.9, 0.05)),
        capacitors=tuple(energy.Capacitor(capacitance_f=c)
                         for c in (0.01, 0.025, 0.05, 0.1, 0.2)),
        seeds=(0, 1, 2, 3, 4),
        horizon=horizon,
    )


def _time_fleet(cfg, statics, use_pallas):
    res = fleet.simulate_fleet(cfg, statics, use_pallas=use_pallas)
    res.released.block_until_ready()          # warm-up: compile + run
    t0 = time.perf_counter()
    res = fleet.simulate_fleet(cfg, statics, use_pallas=use_pallas)
    res.released.block_until_ready()
    return time.perf_counter() - t0, res


def run(quick: bool = True) -> None:
    horizon = 20.0 if quick else 120.0
    n_scalar = 4 if quick else 16
    task = _task()
    grid = _grid(task, horizon)
    cfg, statics, meta = fleet.build(grid)
    n_dev = cfg.n_devices

    # scalar python event loop: sample grid points, extrapolate
    sample = meta[:: max(1, len(meta) // n_scalar)][:n_scalar]
    harvs = {h.name: h for h in grid.harvesters}
    t0 = time.perf_counter()
    for m in sample:
        simulate(
            [task], harvs[m["harvester"]], m["eta"],
            cap=energy.Capacitor(capacitance_f=m["capacitance_f"]),
            sim=SimConfig(policy=m["policy"], horizon=horizon,
                          seed=m["seed"]),
        )
    scalar_s = (time.perf_counter() - t0) / len(sample)
    scalar_rate = 1.0 / scalar_s

    vmap_t, res_v = _time_fleet(cfg, statics, use_pallas=False)
    pallas_t, res_p = _time_fleet(cfg, statics, use_pallas=True)
    assert (np.asarray(res_v.scheduled) == np.asarray(res_p.scheduled)).all()

    # multi-task axis: same grid shape, K=4 contending streams per device
    grid_k4 = _grid(_task_set(4), horizon)
    cfg4, statics4, _ = fleet.build(grid_k4)
    assert statics4.n_steps == statics.n_steps
    k4_t, res_k4 = _time_fleet(cfg4, statics4, use_pallas=False)
    assert (np.asarray(res_k4.task_scheduled).sum(axis=1)
            == np.asarray(res_k4.scheduled)).all()

    def dsteps(wall: float, statics_) -> float:
        return round(n_dev * statics_.n_steps / wall, 1)

    rows = [
        dict(mode="scalar_loop", devices=len(sample), n_tasks=1,
             wall_s=round(scalar_s * n_dev, 3),
             devices_per_sec=round(scalar_rate, 1), speedup=1.0),
        dict(mode="vmap_scan", devices=n_dev, n_tasks=1,
             wall_s=round(vmap_t, 3),
             devices_per_sec=round(n_dev / vmap_t, 1),
             device_steps_per_sec=dsteps(vmap_t, statics),
             speedup=round(n_dev / vmap_t / scalar_rate, 1)),
        dict(mode="pallas_interpret", devices=n_dev, n_tasks=1,
             wall_s=round(pallas_t, 3),
             devices_per_sec=round(n_dev / pallas_t, 1),
             device_steps_per_sec=dsteps(pallas_t, statics),
             speedup=round(n_dev / pallas_t / scalar_rate, 1)),
        dict(mode="vmap_scan_multitask", devices=n_dev, n_tasks=4,
             wall_s=round(k4_t, 3),
             devices_per_sec=round(n_dev / k4_t, 1),
             device_steps_per_sec=dsteps(k4_t, statics4),
             k1_relative=round(vmap_t / k4_t, 3)),
    ]
    emit("fleet_throughput", rows)


if __name__ == "__main__":
    run()
