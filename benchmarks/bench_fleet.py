"""Fleet-simulator throughput: scalar python loop vs one jitted
``vmap``/``scan`` call vs the Pallas fleet_priority inner step vs the
fused whole-horizon kernel (``mode="fused"``: one ``pallas_call`` per
run, :mod:`repro.kernels.fleet_step`).

Sweeps the paper's scheduler grid (policy × eta × harvester × capacitor ×
seed) at 1000 device-configs and reports devices/sec for each execution
path, then re-times the batched path on a K=4 multi-task workload (four
contending streams per device) against the K=1 baseline — the throughput
axis the task-set refactor added (rows carry ``n_tasks`` and
``device_steps_per_sec`` so the two are comparable per simulated step).
The scalar number extrapolates from a sample of grid points (running all
1000 through the python event loop would take minutes); the batched paths
are AOT-compiled and timed by :mod:`repro.launch.profiling`, so every row
carries the compile-vs-steady split explicitly.  On this CPU container the
Pallas path runs in ``interpret`` mode — it validates the kernel against
the jnp path rather than racing it; on a TPU backend the same call
compiles to Mosaic.

Observability rows: ``vmap_scan_telemetry`` re-times the batched path
with the default (``"counters"``) telemetry tier and reports
``telemetry_overhead_pct``, which CI gates below 5% absolutely
(``benchmarks/check_regression.py``); ``vmap_scan_telemetry_full``
reports the opt-in ``"full"`` tier's cost as
``telemetry_full_overhead_pct`` (informational — per-step event
descriptors are honestly expensive on a CPU scan).  Both overheads come
from *paired adjacent* base/telemetry runs in one process — the median of
per-pair ratios, so clock drift on a noisy runner cancels — not from two
AOT measurements minutes apart.  The bench also streams a full-tier
telemetry JSONL (``experiments/telemetry_fleet.jsonl``) from a segmented
16-device run and round-trips it through ``repro.telemetry.report``.
"""
from __future__ import annotations

import io
import time

import numpy as np

import jax

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, SimConfig, TaskSpec, simulate
from repro.launch import profiling
from repro.telemetry import TelemetryConfig, TelemetryLogger
from repro.telemetry import report as tel_report

from . import common
from .common import emit


def _task(n_jobs=25, n_units=4, exit_at=1, task_id=0, period=1.0,
          deadline=2.0, unit_t=0.1):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    prof = JobProfile(margins, passes, np.ones(n_units, bool))
    return TaskSpec(
        task_id=task_id, period=period, deadline=deadline,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _task_set(k=4, n_jobs=25):
    """K contending streams with staggered periods/deadlines (audio+camera
    style); unit times stay multiples of the K=1 task's fragment time so
    the fixed timestep — and therefore the step count — matches the K=1
    baseline and the rates are comparable."""
    return tuple(
        _task(n_jobs=n_jobs, task_id=i, period=1.0 + 0.25 * i,
              deadline=2.0 + 0.5 * i, n_units=3 + i % 2)
        for i in range(k)
    )


def _grid(task, horizon):
    return fleet.SweepGrid(
        task=task,
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.2, 0.5, 0.8, 0.9, 1.0),
        harvesters=(energy.Harvester("h", 0.95, 0.95, 0.08),
                    energy.Harvester("sun", 0.9, 0.9, 0.05)),
        capacitors=tuple(energy.Capacitor(capacitance_f=c)
                         for c in (0.01, 0.025, 0.05, 0.1, 0.2)),
        seeds=(0, 1, 2, 3, 4),
        horizon=horizon,
    )


def _measure_fleet(cfg, statics, label, *, mode=None, repeats=5):
    """AOT compile + steady-state timing of one simulate_fleet variant
    (roofline-joined under ``--profile``); returns (Measurement, result)."""
    meas = profiling.measure(
        lambda c: fleet.simulate_fleet(c, statics, mode=mode),
        cfg, label=label, repeats=repeats, warmup=1)
    if common.PROFILE:
        meas = profiling.roofline_join(meas)
    meas.extra.pop("_compiled", None)
    res = fleet.simulate_fleet(cfg, statics, mode=mode)
    return meas, res


def _paired_overhead(cfg, statics, tcfg, repeats=9):
    """Telemetry overhead via paired adjacent wall-time runs.

    The full tier ends in a host-side event fold, so it cannot be
    AOT-lowered by :func:`repro.launch.profiling.measure`; both tiers are
    therefore timed the same way — alternating uninstrumented/instrumented
    calls in one loop, reporting the median per-pair ratio.  Returns
    ``(base_s, tel_s, overhead_pct, result)``."""
    def run_base():
        res = fleet.simulate_fleet(cfg, statics)
        jax.block_until_ready(res)
        return res

    def run_tel():
        res, tel = fleet.simulate_fleet(cfg, statics, telemetry=tcfg)
        jax.block_until_ready(res)
        return res

    run_base()
    res_t = run_tel()
    base_t, tel_t = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_base()
        base_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_tel()
        tel_t.append(time.perf_counter() - t0)
    ratios = np.array(tel_t) / np.array(base_t)
    return (float(np.median(base_t)), float(np.median(tel_t)),
            float(100.0 * (np.median(ratios) - 1.0)), res_t)


def _row(meas, *, mode, devices, n_tasks, statics, **extra):
    wall = meas.steady_s
    row = dict(mode=mode, devices=devices, n_tasks=n_tasks,
               wall_s=round(wall, 3),
               compile_s=round(meas.compile_s, 3),
               devices_per_sec=round(devices / wall, 1),
               device_steps_per_sec=round(
                   devices * statics.n_steps / wall, 1))
    if meas.roofline is not None:
        row.update({f"roofline_{k}": v for k, v in meas.roofline.items()})
    row.update(extra)
    return row


def _emit_telemetry_jsonl(cfg, statics, n_devices=16, n_segments=6):
    """Stream a segmented telemetry run to experiments/telemetry_fleet.jsonl
    (per-segment summaries via the hook, ring events drained at the end)
    and round-trip it through the text dashboard."""
    small = jax.tree.map(lambda x: x[:n_devices], cfg)
    tcfg = TelemetryConfig(ring_size=256, level="full")
    path = common.OUT_DIR / "telemetry_fleet.jsonl"
    common.OUT_DIR.mkdir(exist_ok=True)
    with TelemetryLogger(path, label="fleet_throughput") as log:
        log.meta(statics, tcfg, n_devices=small.n_devices)

        def hook(seg, t_end, c, carry, telemetry=None):
            log.segment(seg, telemetry)
            return None

        _, _, tel = fleet.run_segments(small, statics,
                                       n_segments=n_segments, hook=hook,
                                       telemetry=tcfg)
        log.drain_rings(tel)
    # the dashboard must render what the logger wrote (CI acceptance)
    tel_report.render(path, out=io.StringIO())
    return path


def run(quick: bool = True) -> None:
    horizon = 20.0 if quick else 120.0
    n_scalar = 4 if quick else 16
    task = _task()
    grid = _grid(task, horizon)
    cfg, statics, meta = fleet.build(grid)
    n_dev = cfg.n_devices

    # scalar python event loop: sample grid points, extrapolate
    sample = meta[:: max(1, len(meta) // n_scalar)][:n_scalar]
    harvs = {h.name: h for h in grid.harvesters}
    t0 = time.perf_counter()
    for m in sample:
        simulate(
            [task], harvs[m["harvester"]], m["eta"],
            cap=energy.Capacitor(capacitance_f=m["capacitance_f"]),
            sim=SimConfig(policy=m["policy"], horizon=horizon,
                          seed=m["seed"]),
        )
    scalar_s = (time.perf_counter() - t0) / len(sample)
    scalar_rate = 1.0 / scalar_s

    vmap_m, res_v = _measure_fleet(cfg, statics, "fleet_vmap_scan")
    pallas_m, res_p = _measure_fleet(cfg, statics, "fleet_pallas",
                                     mode="pallas")
    assert (np.asarray(res_v.scheduled) == np.asarray(res_p.scheduled)).all()

    # telemetry overhead, both tiers: bit-exact results, default tier
    # gated < 5% absolutely by check_regression, full tier informational
    reps = 9 if quick else 15
    base_s, tel_s, overhead_pct, res_t = _paired_overhead(
        cfg, statics, TelemetryConfig(ring_size=128), repeats=reps)
    assert (np.asarray(res_v.scheduled) == np.asarray(res_t.scheduled)).all()
    fbase_s, ftel_s, full_pct, res_f = _paired_overhead(
        cfg, statics, TelemetryConfig(ring_size=128, level="full"),
        repeats=reps)
    assert (np.asarray(res_v.scheduled) == np.asarray(res_f.scheduled)).all()

    # multi-task axis: same grid shape, K=4 contending streams per device
    grid_k4 = _grid(_task_set(4), horizon)
    cfg4, statics4, _ = fleet.build(grid_k4)
    assert statics4.n_steps == statics.n_steps
    k4_m, res_k4 = _measure_fleet(cfg4, statics4, "fleet_vmap_k4")
    assert (np.asarray(res_k4.task_scheduled).sum(axis=1)
            == np.asarray(res_k4.scheduled)).all()

    # fused mode: the whole horizon in ONE pallas_call (interpret on CPU —
    # this validates the fused dispatch shape and bit-exactness; the
    # throughput claim belongs to compiled TPU backends)
    fused_m, res_fu = _measure_fleet(cfg, statics, "fleet_fused",
                                     mode="fused", repeats=3)
    assert (np.asarray(res_v.scheduled)
            == np.asarray(res_fu.scheduled)).all()

    jsonl = _emit_telemetry_jsonl(cfg, statics)
    print(f"# telemetry stream -> {jsonl}")

    rows = [
        dict(mode="scalar_loop", devices=len(sample), n_tasks=1,
             wall_s=round(scalar_s * n_dev, 3),
             devices_per_sec=round(scalar_rate, 1), speedup=1.0),
        _row(vmap_m, mode="vmap_scan", devices=n_dev, n_tasks=1,
             statics=statics,
             speedup=round(n_dev / vmap_m.steady_s / scalar_rate, 1)),
        _row(pallas_m, mode="pallas_interpret", devices=n_dev, n_tasks=1,
             statics=statics,
             speedup=round(n_dev / pallas_m.steady_s / scalar_rate, 1)),
        dict(mode="vmap_scan_telemetry", devices=n_dev, n_tasks=1,
             wall_s=round(tel_s, 3),
             devices_per_sec=round(n_dev / tel_s, 1),
             device_steps_per_sec=round(n_dev * statics.n_steps / tel_s, 1),
             telemetry_overhead_pct=round(overhead_pct, 2)),
        dict(mode="vmap_scan_telemetry_full", devices=n_dev, n_tasks=1,
             wall_s=round(ftel_s, 3),
             devices_per_sec=round(n_dev / ftel_s, 1),
             device_steps_per_sec=round(n_dev * statics.n_steps / ftel_s, 1),
             telemetry_full_overhead_pct=round(full_pct, 2)),
        _row(k4_m, mode="vmap_scan_multitask", devices=n_dev, n_tasks=4,
             statics=statics4,
             k1_relative=round(vmap_m.steady_s / k4_m.steady_s, 3)),
    ]
    # fused row APPENDED LAST: check_regression matches rows positionally,
    # so existing baselines keep their indices.  The rate rides its own
    # key (fused_device_steps_per_sec) so the gate can band it separately
    # from the compiled-path expectations.
    fused_row = _row(fused_m, mode="fused_interpret", devices=n_dev,
                     n_tasks=1, statics=statics,
                     speedup=round(n_dev / fused_m.steady_s / scalar_rate, 1))
    fused_row["fused_device_steps_per_sec"] = fused_row.pop(
        "device_steps_per_sec")
    rows.append(fused_row)
    emit("fleet_throughput", rows)


if __name__ == "__main__":
    run()
