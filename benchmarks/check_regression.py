"""CI benchmark-regression gate.

Compares fresh ``experiments/BENCH_<name>.json`` artifacts (written by
``benchmarks/run.py``) against the committed baselines under
``experiments/baselines/`` and exits nonzero when a gated metric regressed
beyond its tolerance band:

* **throughput keys** (:data:`THROUGHPUT_KEYS` — device-steps/sec and
  friends) are machine-dependent, so the band is wide: a fresh value must
  stay above ``(1 - throughput_tolerance)`` of the baseline (default 0.75,
  i.e. a 4x slowdown trips the gate — CI runners are noisy, the gate is
  for order-of-magnitude rot, not percent-level tuning).
* **score keys** (``score`` / ``*_score`` / ``gain``) are seeded and
  deterministic, so the band is tight: fresh must stay within
  ``score_tolerance`` (default 0.005) below the baseline.

Rows are matched positionally per bench and verified by their identity
keys (``mode`` / ``n_segments`` / ``budget`` / ``devices``): a structural
mismatch means the benchmark changed shape and the baselines must be
regenerated — run with ``--update`` to copy the fresh artifacts over the
baselines (then commit them).

One metric is gated *absolutely* rather than against a baseline: any
fresh row carrying ``telemetry_overhead_pct`` (the default-tier telemetry
cost on the vmap fleet path, measured by ``benchmarks/bench_fleet.py``
with paired adjacent runs) must stay below ``--telemetry-overhead-max``
(default 5%).  The opt-in full tier reports
``telemetry_full_overhead_pct`` on its own row, which is informational
and ungated — its double-digit cost is documented, not defended.

Usage::

    PYTHONPATH=src python -m benchmarks.run --smoke   # write fresh JSONs
    python -m benchmarks.check_regression             # gate them
    python -m benchmarks.check_regression --update    # re-baseline
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FRESH_DIR = ROOT / "experiments"
BASELINE_DIR = FRESH_DIR / "baselines"

#: higher-is-better machine-dependent metrics, gated with the wide band
#: (fused_device_steps_per_sec is the fused-kernel fleet mode — interpret
#: mode on CPU runners, so only the wide band is meaningful there)
THROUGHPUT_KEYS = ("device_steps_per_sec", "devices_per_sec",
                   "candidates_per_sec", "windows_per_sec",
                   "jobs_per_sec", "fused_device_steps_per_sec",
                   "stream_jobs_per_sec", "requests_per_sec")
#: lower-is-better machine-dependent metrics, gated with the same wide
#: band mirrored (fresh must stay below (1 + tolerance) x baseline).  A
#: zero on either side skips the gate: ``serve_peak_bytes`` degrades to 0
#: on backends without memory_stats (CPU), where it means "unmeasured",
#: not "no memory".
LOWER_IS_BETTER_KEYS = ("serve_peak_bytes",)
#: row fields that identify a row (checked, never gated)
IDENTITY_KEYS = ("mode", "n_segments", "budget", "devices", "n_tasks",
                 "n_chunks")


def _is_score_key(key: str) -> bool:
    return key == "score" or key.endswith("_score") or key == "gain"


def _iter_rows(doc: dict):
    """Yield (bench_name, row_index, row_dict) from a BENCH json."""
    for bench, rows in sorted(doc.get("rows", {}).items()):
        for i, row in enumerate(rows):
            yield bench, i, row


def compare_docs(name: str, base: dict, fresh: dict, *,
                 throughput_tolerance: float,
                 score_tolerance: float) -> list[str]:
    """Return a list of human-readable violations (empty = pass)."""
    problems: list[str] = []
    if not fresh.get("ok", False):
        problems.append(f"{name}: fresh run reported ok=false")
    base_rows = list(_iter_rows(base))
    fresh_rows = {(b, i): row for b, i, row in _iter_rows(fresh)}
    for bench, i, brow in base_rows:
        where = f"{name}:{bench}[{i}]"
        frow = fresh_rows.get((bench, i))
        if frow is None:
            problems.append(f"{where}: row missing from fresh results "
                            "(benchmark changed shape? re-baseline with "
                            "--update)")
            continue
        for key in IDENTITY_KEYS:
            if key in brow and brow.get(key) != frow.get(key):
                problems.append(
                    f"{where}: identity key {key!r} changed "
                    f"({brow.get(key)!r} -> {frow.get(key)!r}); "
                    "re-baseline with --update")
        for key, bval in brow.items():
            if not isinstance(bval, (int, float)) or isinstance(bval, bool):
                continue
            fval = frow.get(key)
            if not isinstance(fval, (int, float)) or isinstance(fval, bool):
                continue
            if key in THROUGHPUT_KEYS:
                floor = (1.0 - throughput_tolerance) * bval
                if fval < floor:
                    problems.append(
                        f"{where}: {key} regressed {bval:g} -> {fval:g} "
                        f"(floor {floor:g} at tolerance "
                        f"{throughput_tolerance:g})")
            elif key in LOWER_IS_BETTER_KEYS:
                if bval <= 0 or fval <= 0:
                    continue          # 0 = unmeasured on this backend
                ceil = (1.0 + throughput_tolerance) * bval
                if fval > ceil:
                    problems.append(
                        f"{where}: {key} grew {bval:g} -> {fval:g} "
                        f"(ceiling {ceil:g} at tolerance "
                        f"{throughput_tolerance:g})")
            elif _is_score_key(key):
                if fval < bval - score_tolerance:
                    problems.append(
                        f"{where}: {key} regressed {bval:g} -> {fval:g} "
                        f"(allowed drop {score_tolerance:g})")
    return problems


def absolute_gates(name: str, fresh: dict, *,
                   telemetry_overhead_max: float) -> list[str]:
    """Gates on fresh values alone (no baseline needed): the default-tier
    telemetry overhead must stay under the budget on every row reporting
    it."""
    problems: list[str] = []
    for bench, i, row in _iter_rows(fresh):
        pct = row.get("telemetry_overhead_pct")
        if isinstance(pct, (int, float)) and not isinstance(pct, bool) \
                and pct >= telemetry_overhead_max:
            problems.append(
                f"{name}:{bench}[{i}]: telemetry_overhead_pct {pct:g} "
                f"exceeds the {telemetry_overhead_max:g}% budget")
    return problems


def check(fresh_dir: Path = FRESH_DIR, baseline_dir: Path = BASELINE_DIR, *,
          throughput_tolerance: float = 0.75,
          score_tolerance: float = 0.005,
          telemetry_overhead_max: float = 5.0,
          update: bool = False, out=sys.stdout) -> int:
    """Gate every baselined bench; returns a process exit code."""
    if update:
        # copy every fresh artifact over (or into) the baselines — also the
        # bootstrap path when no baseline exists yet
        baseline_dir.mkdir(parents=True, exist_ok=True)
        n = 0
        for src in sorted(fresh_dir.glob("BENCH_*.json")):
            shutil.copyfile(src, baseline_dir / src.name)
            n += 1
        print(f"updated {n} baselines under {baseline_dir}", file=out)
        return 0 if n else 1
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"no baselines under {baseline_dir} — nothing to gate",
              file=out)
        return 1
    problems: list[str] = []
    checked = 0
    for path in baselines:
        name = path.stem.removeprefix("BENCH_")
        fresh_path = fresh_dir / path.name
        if not fresh_path.exists():
            problems.append(
                f"{name}: no fresh {path.name} under {fresh_dir} "
                "(did benchmarks/run.py cover it?)")
            continue
        base = json.loads(path.read_text())
        fresh = json.loads(fresh_path.read_text())
        problems.extend(compare_docs(
            name, base, fresh,
            throughput_tolerance=throughput_tolerance,
            score_tolerance=score_tolerance))
        checked += 1
    for fresh_path in sorted(fresh_dir.glob("BENCH_*.json")):
        problems.extend(absolute_gates(
            fresh_path.stem.removeprefix("BENCH_"),
            json.loads(fresh_path.read_text()),
            telemetry_overhead_max=telemetry_overhead_max))
    extra = [p.name for p in sorted(fresh_dir.glob("BENCH_*.json"))
             if not (baseline_dir / p.name).exists()]
    if extra:
        print(f"note: {len(extra)} fresh artifacts have no baseline "
              f"(ungated): {', '.join(extra)}", file=out)
    if problems:
        print(f"benchmark regression gate: {len(problems)} violation(s) "
              f"across {checked} baselined bench(es):", file=out)
        for p in problems:
            print(f"  FAIL {p}", file=out)
        return 1
    print(f"benchmark regression gate: {checked} baselined bench(es) ok",
          file=out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", type=Path, default=FRESH_DIR)
    ap.add_argument("--baseline-dir", type=Path, default=BASELINE_DIR)
    ap.add_argument("--throughput-tolerance", type=float, default=0.75,
                    help="allowed fractional throughput drop (0.75 = fresh "
                         "must stay above 25%% of baseline)")
    ap.add_argument("--score-tolerance", type=float, default=0.005,
                    help="allowed absolute drop on deterministic scores")
    ap.add_argument("--telemetry-overhead-max", type=float, default=5.0,
                    help="absolute budget (percent) for the default-tier "
                         "telemetry overhead on the vmap fleet path")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh artifacts over the baselines")
    args = ap.parse_args(argv)
    return check(args.fresh_dir, args.baseline_dir,
                 throughput_tolerance=args.throughput_tolerance,
                 score_tolerance=args.score_tolerance,
                 telemetry_overhead_max=args.telemetry_overhead_max,
                 update=args.update)


if __name__ == "__main__":
    raise SystemExit(main())
