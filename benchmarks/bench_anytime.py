"""Anytime big-model serving: throughput and depth-vs-deadline rows.

One :class:`repro.serve.anytime.AnytimeServeEngine` serves a seeded
request trace through a small trained transformer (qwen1.5 family,
4 units) and emits:

* a throughput row — ``requests_per_sec`` through the jitted
  continuous-batching scan (machine-dependent, gated with the wide
  band by ``check_regression``);
* one row per deadline-tightness level — ``mean_depth``, on-time rate
  and the deterministic ``score`` (on-time full-depth-agreement
  fraction, gated with the tight band) plus ``depth_score``
  (``1 - mean_depth/n_units``, the optional-compute saving, also
  tight-gated so depth-control regressions trip CI);
* a fixed-depth EDF reference row on the tight trace, so the anytime
  advantage stays visible in the artifact.

The model is trained for a few seconds (seeded) so the exit margins are
informative — without training every margin is noise and the depth
sweep gates nothing.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import anytime as A
from repro.models import transformer as T
from repro.serve import AnytimeConfig, AnytimeRequest, AnytimeServeEngine
from repro.train import make_train_step
from repro.train.optimizer import adamw_init

from .common import emit

_SEED = 0
_N_REQ = 16
_N_TOKENS = 6


@functools.lru_cache(maxsize=None)
def _trained_model(train_steps: int = 40):
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=4, vocab=64, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, exit_every=1)
    params = T.init_params(cfg, jax.random.PRNGKey(_SEED))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    rng = np.random.default_rng(_SEED)
    for _ in range(train_steps):
        start = rng.integers(0, cfg.vocab, size=(16, 1))
        toks = (start + np.arange(17)) % cfg.vocab
        params, opt, _ = step(params, opt, {"tokens": jnp.asarray(toks)})
    return cfg, params


def _knobs(cfg, params, engine):
    rng = np.random.default_rng(_SEED + 1)
    start = rng.integers(0, cfg.vocab, size=(8, 1))
    toks = (start + np.arange(17)) % cfg.vocab
    unit_logits = jax.jit(
        lambda b: A.anytime_forward(cfg, params, engine.heads, b)
    )({"tokens": jnp.asarray(toks)})
    U, B, S, V = unit_logits.shape
    thr, use = A.calibrate_thresholds(unit_logits.reshape(U, B * S, V))
    return engine.default_knobs(exit_thr=thr,
                                use_exit_thr=use.astype(jnp.float32))


def _requests(cfg, deadline: float):
    rng = np.random.default_rng(_SEED + 2)
    reqs = []
    for i in range(_N_REQ):
        start = int(rng.integers(0, cfg.vocab))
        reqs.append(AnytimeRequest(
            prompt=[start, (start + 1) % cfg.vocab], n_tokens=_N_TOKENS,
            release=0.25 * i, deadline=0.25 * i + deadline))
    return reqs


def _engine(cfg, params, policy: str) -> AnytimeServeEngine:
    scfg = AnytimeConfig(policy=policy, batch_slots=4, max_steps=256,
                         prompt_len=2, max_new_tokens=8)
    return AnytimeServeEngine(cfg, params, serve_cfg=scfg, seed=_SEED)


def _row(mode, deadline, res, n_units, wall_s=None):
    row = dict(mode=mode, deadline_s=deadline,
               on_time=res.on_time, n_requests=res.n_requests,
               mean_depth=round(res.mean_depth, 3),
               depth_score=round(1.0 - res.mean_depth / n_units, 4),
               score=round(res.score, 4))
    if wall_s is not None:
        row["wall_s"] = round(wall_s, 3)
        row["requests_per_sec"] = round(res.n_requests / wall_s, 2)
    return row


def run(quick: bool = True) -> None:
    cfg, params = _trained_model()
    engine = _engine(cfg, params, "anytime")
    knobs = _knobs(cfg, params, engine)

    # throughput: one warm run of the medium-tightness trace (compile
    # amortised by the cold run)
    reqs = _requests(cfg, 1.6)
    engine.run(reqs, knobs=knobs)                       # cold: compiles
    t0 = time.perf_counter()
    res = engine.run(reqs, knobs=knobs)                 # timed, warm
    wall = time.perf_counter() - t0
    rows = [_row("anytime_throughput", 1.6, res, cfg.n_units, wall)]

    # depth control vs deadline tightness: tighter budgets must cut
    # optional depth (monotone mean_depth), looser ones may afford it
    depths = []
    for deadline in (3.0, 1.6, 1.3):
        r = engine.run(_requests(cfg, deadline), knobs=knobs)
        depths.append(r.mean_depth)
        rows.append(_row(f"anytime_deadline_{deadline}", deadline, r,
                         cfg.n_units))
    assert all(d1 >= d2 - 1e-9 for d1, d2 in zip(depths, depths[1:])), (
        f"mean depth not monotone in deadline tightness: {depths}")

    # fixed-depth EDF reference on the tight trace
    edf = _engine(cfg, params, "edf")
    r_edf = edf.run(_requests(cfg, 1.3), knobs=edf.default_knobs())
    rows.append(_row("edf_deadline_1.3", 1.3, r_edf, cfg.n_units))

    anytime_tight = rows[3]
    assert anytime_tight["score"] > r_edf.score, (
        "anytime depth control lost to fixed-depth EDF on the tight "
        f"trace: {anytime_tight['score']} < {r_edf.score:.4f}")

    emit("anytime", rows)


if __name__ == "__main__":
    run()
