"""Table 5 — RTC vs CHRT remanence timekeeper for systems 2-4.
Paper claim: the batteryless CHRT clock loses < 0.1% of schedulable tasks
(positive clock error dominates and is partly self-compensating)."""
from __future__ import annotations

import numpy as np

from repro.core import energy
from repro.core.scheduler import CHRTClock, Clock, SimConfig, TaskSpec, simulate

from .common import emit, profiles

SYSTEMS = ((2, 0.71, 0.60), (3, 0.51, 0.42), (4, 0.38, 0.31))


def run(quick: bool = True) -> list[dict]:
    profs = list(profiles("mnist"))
    n_units = profs[0].n_units
    # repeat the profile stream to get enough jobs for a stable percentage
    reps = 3 if quick else 10
    stream = profs * reps
    rows = []
    for sysid, eta, power in SYSTEMS:
        harv = energy.calibrate_harvester(eta, power, name="solar")
        out = {}
        for clock_name, clock in (("rtc", Clock()), ("chrt", CHRTClock())):
            # light load with generous slack: the paper's Table-5 systems
            # schedule ~all jobs, so clock error is the only differentiator
            task = TaskSpec(
                0, period=1.0, deadline=4.0,
                unit_time=np.full(n_units, 0.08),
                unit_energy=np.full(n_units, 5e-3),
                profiles=stream,
            )
            res = simulate(
                [task], harv, eta,
                sim=SimConfig(policy="zygarde", clock=clock,
                              horizon=len(stream) * 1.0 + 4.0, seed=13),
            )
            out[clock_name] = res
        rtc, chrt = out["rtc"], out["chrt"]
        loss = (rtc.scheduled - chrt.scheduled) / max(rtc.scheduled, 1)
        rows.append({
            "system": sysid, "eta": eta,
            "reboots": chrt.reboots,
            "scheduled_rtc": rtc.scheduled,
            "scheduled_chrt": chrt.scheduled,
            "loss_fraction": round(loss, 4),
            "claim_loss_below_2pct": abs(loss) <= 0.02,
        })
    return emit("clock_table5", rows)


if __name__ == "__main__":
    run(quick=False)
