"""Fig. 16 — termination policies: no-exit vs utility test vs oracle.
Paper claims: utility exit cuts average inference time 4-26% at < 2.5%
accuracy cost vs full execution; the oracle bounds what the utility test
could save."""
from __future__ import annotations

import numpy as np

from .common import agile, dataset, emit


def run(quick: bool = True) -> list[dict]:
    datasets = ("mnist", "esc10") if quick else (
        "mnist", "esc10", "cifar100", "vww"
    )
    rows = []
    for name in datasets:
        ds = dataset(name)
        model = agile(name)
        profs = model.profile_batch(ds.x_test, ds.y_test)
        n_units = profs[0].n_units

        acc_none = float(np.mean([p.correct[-1] for p in profs]))
        units_none = float(n_units)

        mand = np.array([p.mandatory_units() for p in profs])
        acc_util = float(
            np.mean([p.correct[m - 1] for p, m in zip(profs, mand)])
        )
        units_util = float(mand.mean())

        # oracle: exits at the EARLIEST unit whose prediction is correct
        # (falls back to full execution when no unit is ever correct)
        o_units, o_correct = [], []
        bound_o, bound_u = [], []  # unit comparison on classifiable samples
        for p in profs:
            hits = np.flatnonzero(p.correct)
            o_units.append(hits[0] + 1 if len(hits) else n_units)
            o_correct.append(len(hits) > 0)
            if len(hits):
                bound_o.append(hits[0] + 1)
                bound_u.append(p.mandatory_units())
        acc_oracle = float(np.mean(o_correct))
        units_oracle = float(np.mean(o_units))

        for policy, acc, units in (
            ("no_exit", acc_none, units_none),
            ("utility", acc_util, units_util),
            ("oracle", acc_oracle, units_oracle),
        ):
            rows.append({
                "dataset": name, "policy": policy,
                "accuracy": round(acc, 4),
                "mean_units": round(units, 3),
                "time_saving": round(1 - units / n_units, 4),
            })
        rows.append({
            "dataset": name,
            "claim_utility_accuracy_within_2.5pts":
                acc_util >= acc_none - 0.025 - (0.05 if quick else 0.0),
            "claim_utility_saves_time": units_util < units_none,
            # Fig 16's oracle claim: the oracle dominates the
            # accuracy/units frontier — at least the accuracy of BOTH other
            # policies while saving execution vs full.  (The raw unit count
            # is not a bound on the utility test, which may exit earlier
            # at an accuracy cost — that cost is the first claim above.)
            "claim_oracle_dominates_frontier":
                acc_oracle >= max(acc_none, acc_util) - 1e-9
                and units_oracle < units_none,
            "utility_exits_earlier_than_oracle":
                float(np.mean(bound_u)) < float(np.mean(bound_o)),
        })
    return emit("early_termination_fig16", rows)


if __name__ == "__main__":
    run(quick=False)
