"""Adaptation benches: policy-search throughput/quality (``adapt_tune``)
and the Fig. 24 environment-shift recovery claim (``adaptation_fig24``).

``run`` — the objective scores a whole candidate population with one jitted
fleet simulation (population × harvester-pattern × seed devices), so the
headline number is *candidate evaluations per second* — the metric that
tells you how big a search budget a deployment sweep can afford.  Each
driver then runs the same seeded budget and reports its best score against
the paper-default constants (measured eta, E_opt = 0.7 × capacity).

``run_fig24`` — semi-supervised centroid adaptation under environment
shift.  Paper claim: without adaptation, accuracy drops (~8%) when the
deployment environment changes; enabling runtime centroid adaptation
recovers more than half of the lost accuracy.  (Formerly the separate
``bench_adaptation`` module; both benches keep their registered names.)
"""
from __future__ import annotations

import copy
import time

import numpy as np

from repro import adapt
from repro.core import energy
from repro.core.agile import AgileCNN
from repro.core.scheduler import JobProfile, TaskSpec
from repro.data import make_dataset

from .common import emit, trained


def _task(n_jobs=30, n_units=4, exit_at=1, correct_from=2):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    prof = JobProfile(margins, passes, correct)
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _problem(horizon: float) -> adapt.TuneProblem:
    return adapt.TuneProblem(
        task=_task(),
        harvesters=(energy.Harvester("solar", 0.95, 0.95, 0.08),
                    energy.Harvester("rf", 0.85, 0.85, 0.05),
                    energy.Harvester("piezo", 0.90, 0.90, 0.06)),
        seeds=(0, 1),
        horizon=horizon,
    )


def run(quick: bool = True) -> None:
    horizon = 30.0 if quick else 120.0
    budget = 64 if quick else 256
    pop = 16
    problem = _problem(horizon)
    objective = problem.objective()
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = problem.score(problem.default_params())

    # objective throughput: candidates/sec at the driver's population size
    # (devices/sec = candidates/sec × harvester-seed cells); warm call first
    # so compilation is excluded
    x = {"eta": np.full(pop, 0.5, np.float32),
         "e_opt_fraction": np.full(pop, 0.5, np.float32)}
    objective(x)
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        objective(x)
    per_call = (time.perf_counter() - t0) / reps
    rows = [dict(
        mode="objective", pop_size=pop, wall_s=round(per_call, 4),
        candidates_per_sec=round(pop / per_call, 1),
        devices_per_sec=round(pop * problem.n_cells / per_call, 1),
    )]

    for driver in sorted(adapt.DRIVERS):
        t0 = time.perf_counter()
        res = adapt.tune(objective, space, budget, driver=driver, seed=0,
                         pop_size=pop)
        wall = time.perf_counter() - t0
        rows.append(dict(
            mode=f"tune_{driver}", budget=budget, wall_s=round(wall, 3),
            candidates_per_sec=round(res.n_evals / wall, 1),
            best_score=round(res.best_score, 4),
            default_score=round(default_score, 4),
            gain=round(res.best_score - default_score, 4),
        ))
    emit("adapt_tune", rows)


def accuracy_stream(model: AgileCNN, xs, ys, adapt: bool) -> float:
    correct = 0
    for x, y in zip(xs, ys):
        r = model.infer(x, adapt=adapt)
        correct += int(r.prediction == int(y))
    return correct / len(xs)


def run_fig24(quick: bool = True) -> list[dict]:
    sep = 1.2  # imperfect classifier: room for the shift to hurt
    t = trained("esc10", separability=sep)
    n = 96  # controlled-experiment sample (same stream in both conditions)
    rows = []
    accs = {}
    for do_adapt in (False, True):
        # fresh bank per condition (adaptation mutates it)
        model = AgileCNN(t.cfg, t.params, copy.deepcopy(list(t.bank)))
        per_env = []
        for env in (0, 2, 3):  # lab -> hall -> office
            ds = make_dataset("esc10", n_train=8, n_test=n,
                              environment=env, seed=0, separability=sep)
            acc = accuracy_stream(model, ds.x_test, ds.y_test, do_adapt)
            per_env.append(acc)
            rows.append({
                "adapt": do_adapt, "environment": env,
                "accuracy": round(acc, 4),
            })
        accs[do_adapt] = per_env
    base = accs[False][0]
    drop_no = base - float(np.mean(accs[False][1:]))
    drop_ad = base - float(np.mean(accs[True][1:]))
    rows.append({
        "claim_shift_hurts_without_adaptation": drop_no > 0.0,
        "drop_no_adapt": round(drop_no, 4),
        "drop_with_adapt": round(drop_ad, 4),
        "claim_adaptation_recovers": drop_ad < drop_no,
        "recovered_fraction": round(
            (drop_no - drop_ad) / max(drop_no, 1e-9), 3
        ),
    })
    return emit("adaptation_fig24", rows)


if __name__ == "__main__":
    run()
    run_fig24(quick=False)
