"""Policy-search throughput + quality: candidates/sec through the batched
fleet objective and the tuned-vs-paper-default on-time accuracy gap.

The objective scores a whole candidate population with one jitted fleet
simulation (population × harvester-pattern × seed devices), so the headline
number is *candidate evaluations per second* — the metric that tells you how
big a search budget a deployment sweep can afford.  Each driver then runs
the same seeded budget and reports its best score against the paper-default
constants (measured eta, E_opt = 0.7 × capacity).
"""
from __future__ import annotations

import time

import numpy as np

from repro import adapt
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec

from .common import emit


def _task(n_jobs=30, n_units=4, exit_at=1, correct_from=2):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    prof = JobProfile(margins, passes, correct)
    return TaskSpec(
        task_id=0, period=1.0, deadline=2.0,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


def _problem(horizon: float) -> adapt.TuneProblem:
    return adapt.TuneProblem(
        task=_task(),
        harvesters=(energy.Harvester("solar", 0.95, 0.95, 0.08),
                    energy.Harvester("rf", 0.85, 0.85, 0.05),
                    energy.Harvester("piezo", 0.90, 0.90, 0.06)),
        seeds=(0, 1),
        horizon=horizon,
    )


def run(quick: bool = True) -> None:
    horizon = 30.0 if quick else 120.0
    budget = 64 if quick else 256
    pop = 16
    problem = _problem(horizon)
    objective = problem.objective()
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = problem.score(problem.default_params())

    # objective throughput: candidates/sec at the driver's population size
    # (devices/sec = candidates/sec × harvester-seed cells); warm call first
    # so compilation is excluded
    x = {"eta": np.full(pop, 0.5, np.float32),
         "e_opt_fraction": np.full(pop, 0.5, np.float32)}
    objective(x)
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        objective(x)
    per_call = (time.perf_counter() - t0) / reps
    rows = [dict(
        mode="objective", pop_size=pop, wall_s=round(per_call, 4),
        candidates_per_sec=round(pop / per_call, 1),
        devices_per_sec=round(pop * problem.n_cells / per_call, 1),
    )]

    for driver in sorted(adapt.DRIVERS):
        t0 = time.perf_counter()
        res = adapt.tune(objective, space, budget, driver=driver, seed=0,
                         pop_size=pop)
        wall = time.perf_counter() - t0
        rows.append(dict(
            mode=f"tune_{driver}", budget=budget, wall_s=round(wall, 3),
            candidates_per_sec=round(res.n_evals / wall, 1),
            best_score=round(res.best_score, 4),
            default_score=round(default_score, 4),
            gain=round(res.best_score - default_score, 4),
        ))
    emit("adapt_tune", rows)


if __name__ == "__main__":
    run()
