"""Live-serving throughput: vectorized fleet engine vs the scalar engine.

One jitted :class:`repro.serve.fleet_engine.FleetServeEngine` scan serves
``D x J`` live jobs — every unit executed through the real agile CNN,
utility-tested against the evolving centroid bank, with online k-means
adaptation — and is raced against the scalar :class:`ServeEngine` python
event loop on the same workload (sampled and extrapolated: the scalar
loop would take minutes at fleet scale).  The default shape, 128 devices
x 100 jobs = 12800 live jobs, is the paper-scale target: one call, >=
10^4 jobs across >= 10^2 devices, at >= 20x the scalar rate.

Rows carry ``jobs_per_sec`` (gated with the wide throughput band by
``check_regression``) and the live fleet's ``accuracy_score`` on
scheduled jobs (seeded + deterministic, gated with the tight score
band).  The fleet is also re-timed with ``adapt=False`` to price the
adaptation/propagation hook.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import energy
from repro.serve import FleetServeEngine, Request, ServeConfig, ServeEngine

from .common import agile, dataset, emit

_PERIOD = 2.0


def _requests(n_jobs):
    ds = dataset("mnist")
    xs, ys = np.asarray(ds.x_test), np.asarray(ds.y_test)
    return [Request(xs[i % len(xs)], int(ys[i % len(ys)]),
                    release=i * _PERIOD) for i in range(n_jobs)]


def _config(n_jobs, adapt):
    return ServeConfig(policy="zygarde", period=_PERIOD, deadline=1.5,
                       horizon=n_jobs * _PERIOD + 2.0, adapt=adapt,
                       start_charged=True, sim_dt=0.05)


def _fresh_model():
    m = agile("mnist")
    return type(m)(m.cfg, m.params, [b for b in m.bank])


def run(quick: bool = True) -> None:
    n_dev = 128 if quick else 256
    n_jobs = 100
    n_scalar = 4 if quick else 8
    harv = energy.Harvester("battery", 1.0, 0.0, 1.0)   # persistent power
    reqs = _requests(n_jobs)

    # scalar python event loop, sampled and extrapolated per job
    t0 = time.perf_counter()
    eng = ServeEngine([_fresh_model()], harv, eta=1.0,
                      config=_config(n_scalar, adapt=True))
    res_s = eng.run([reqs[:n_scalar]])
    scalar_s = (time.perf_counter() - t0) / n_scalar
    scalar_rate = 1.0 / scalar_s

    rows = [dict(mode="scalar_loop", devices=1, jobs=n_scalar,
                 wall_s=round(scalar_s * n_scalar, 3),
                 jobs_per_sec=round(scalar_rate, 2), speedup=1.0,
                 accuracy_score=round(
                     float(res_s.correct) / max(float(res_s.scheduled), 1),
                     4))]

    for adapt in (True, False):
        feng = FleetServeEngine([_fresh_model()], harv, eta=1.0,
                                config=_config(n_jobs, adapt=adapt))
        feng.run([reqs], n_devices=n_dev)                 # warm-up: compile
        fres = feng.run([reqs], n_devices=n_dev)          # timed, warm cache
        fleet = fres.fleet
        sched = float(np.asarray(fleet.scheduled).sum())
        acc = float(np.asarray(fleet.correct).sum()) / max(sched, 1.0)
        rows.append(dict(
            mode=f"fleet_live_adapt_{'on' if adapt else 'off'}",
            devices=n_dev, jobs=fres.jobs,
            wall_s=round(fres.wall_s, 3),
            jobs_per_sec=round(fres.jobs_per_sec, 1),
            speedup=round(fres.jobs_per_sec / scalar_rate, 1),
            accuracy_score=round(acc, 4)))

    live = rows[1]
    assert live["jobs"] >= 10_000 and live["devices"] >= 100
    assert live["speedup"] >= 20.0, (
        f"live fleet {live['jobs_per_sec']} jobs/s is only "
        f"{live['speedup']}x the scalar engine (need >= 20x)")
    emit("serve", rows)


if __name__ == "__main__":
    run()
