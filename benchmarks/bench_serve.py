"""Live-serving throughput: vectorized fleet engine vs the scalar engine.

One jitted :class:`repro.serve.fleet_engine.FleetServeEngine` scan serves
``D x J`` live jobs — every unit executed through the real agile CNN,
utility-tested against the evolving centroid bank, with online k-means
adaptation — and is raced against the scalar :class:`ServeEngine` python
event loop on the same workload (sampled and extrapolated: the scalar
loop would take minutes at fleet scale).  The default shape, 128 devices
x 100 jobs = 12800 live jobs, is the paper-scale target: one call, >=
10^4 jobs across >= 10^2 devices, at >= 20x the scalar rate.

Rows carry ``jobs_per_sec`` (gated with the wide throughput band by
``check_regression``) and the live fleet's ``accuracy_score`` on
scheduled jobs (seeded + deterministic, gated with the tight score
band).  The fleet is also re-timed with ``adapt=False`` to price the
adaptation/propagation hook.

Two streaming rows exercise :meth:`FleetServeEngine.run_stream`, the
donated chunked path whose resident footprint is O(chunk) rather than
O(total jobs):

* ``fleet_stream_adapt_off`` — the monolithic adapt-off workload chunked
  4x (128 devices x 4 chunks x 3200 jobs), bit-exact vs ``run`` and
  gated to stay at least at the monolithic rate.
* ``fleet_stream_1m`` — >= 1e6 live jobs in ONE ``run_stream`` call
  (4096 devices x 245 jobs each), impossible monolithically without
  materialising the full O(total-jobs) feature tables.

Both report the compile/steady split the same way
:mod:`repro.launch.profiling` does — ``run_stream`` AOT-compiles its
chunk runners (``jit -> lower -> compile``), so ``compile_s`` is the
one-off cost and ``stream_jobs_per_sec`` times staging + execution only.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import energy
from repro.serve import FleetServeEngine, Request, ServeConfig, ServeEngine

from .common import agile, dataset, emit

_PERIOD = 2.0


def _requests(n_jobs):
    ds = dataset("mnist")
    xs, ys = np.asarray(ds.x_test), np.asarray(ds.y_test)
    return [Request(xs[i % len(xs)], int(ys[i % len(ys)]),
                    release=i * _PERIOD) for i in range(n_jobs)]


def _config(n_jobs, adapt):
    return ServeConfig(policy="zygarde", period=_PERIOD, deadline=1.5,
                       horizon=n_jobs * _PERIOD + 2.0, adapt=adapt,
                       start_charged=True, sim_dt=0.05)


def _fresh_model():
    m = agile("mnist")
    return type(m)(m.cfg, m.params, [b for b in m.bank])


def _stream_row(mode, res, n_dev):
    """Flat row for one run_stream call: steady throughput with the
    compile split held out, plus the O(chunk) memory evidence."""
    sched = float(np.asarray(res.fleet.scheduled).sum())
    acc = float(np.asarray(res.fleet.correct).sum()) / max(sched, 1.0)
    return dict(
        mode=mode, devices=n_dev, jobs=res.jobs, n_chunks=res.n_chunks,
        wall_s=round(res.wall_s, 3),
        stream_jobs_per_sec=round(res.jobs_per_sec, 1),
        compile_s=round(res.compile_s, 3),
        serve_peak_bytes=int(res.peak_bytes),
        chunk_table_bytes=int(res.chunk_table_bytes),
        accuracy_score=round(acc, 4))


def run(quick: bool = True) -> None:
    n_dev = 128 if quick else 256
    n_jobs = 100
    n_scalar = 4 if quick else 8
    harv = energy.Harvester("battery", 1.0, 0.0, 1.0)   # persistent power
    reqs = _requests(n_jobs)

    # scalar python event loop, sampled and extrapolated per job
    t0 = time.perf_counter()
    eng = ServeEngine([_fresh_model()], harv, eta=1.0,
                      config=_config(n_scalar, adapt=True))
    res_s = eng.run([reqs[:n_scalar]])
    scalar_s = (time.perf_counter() - t0) / n_scalar
    scalar_rate = 1.0 / scalar_s

    rows = [dict(mode="scalar_loop", devices=1, jobs=n_scalar,
                 wall_s=round(scalar_s * n_scalar, 3),
                 jobs_per_sec=round(scalar_rate, 2), speedup=1.0,
                 accuracy_score=round(
                     float(res_s.correct) / max(float(res_s.scheduled), 1),
                     4))]

    for adapt in (True, False):
        feng = FleetServeEngine([_fresh_model()], harv, eta=1.0,
                                config=_config(n_jobs, adapt=adapt))
        feng.run([reqs], n_devices=n_dev)                 # warm-up: compile
        fres = feng.run([reqs], n_devices=n_dev)          # timed, warm cache
        fleet = fres.fleet
        sched = float(np.asarray(fleet.scheduled).sum())
        acc = float(np.asarray(fleet.correct).sum()) / max(sched, 1.0)
        rows.append(dict(
            mode=f"fleet_live_adapt_{'on' if adapt else 'off'}",
            devices=n_dev, jobs=fres.jobs,
            wall_s=round(fres.wall_s, 3),
            jobs_per_sec=round(fres.jobs_per_sec, 1),
            speedup=round(fres.jobs_per_sec / scalar_rate, 1),
            accuracy_score=round(acc, 4)))

    live = rows[1]
    assert live["jobs"] >= 10_000 and live["devices"] >= 100
    assert live["speedup"] >= 20.0, (
        f"live fleet {live['jobs_per_sec']} jobs/s is only "
        f"{live['speedup']}x the scalar engine (need >= 20x)")

    # streaming: the same adapt-off workload chunked through donated
    # windows — one cold call, compile split out by run_stream itself
    seng = FleetServeEngine([_fresh_model()], harv, eta=1.0,
                            config=_config(n_jobs, adapt=False))
    sres = seng.run_stream([reqs], n_devices=n_dev, n_chunks=4)
    rows.append(_stream_row("fleet_stream_adapt_off", sres, n_dev))
    mono_off = rows[2]
    assert sres.jobs == mono_off["jobs"], "stream/mono workload mismatch"
    assert sres.jobs_per_sec >= 0.7 * mono_off["jobs_per_sec"], (
        f"streaming serve {sres.jobs_per_sec:.1f} jobs/s fell below the "
        f"monolithic rate {mono_off['jobs_per_sec']} jobs/s")

    # million-job row: one run_stream call, >= 1e6 released jobs, resident
    # tables bounded by the chunk window (total_jobs cycles the base
    # stream; coarser units keep dt at 0.1 so the horizon stays ~5k steps)
    m_dev, m_jobs, m_chunks = 4096, 245, 8
    mcfg = ServeConfig(policy="zygarde", period=_PERIOD, deadline=1.5,
                       horizon=m_jobs * _PERIOD + 2.0, adapt=False,
                       start_charged=True, sim_dt=0.1,
                       unit_time=[0.4] * _fresh_model().n_units)
    meng = FleetServeEngine([_fresh_model()], harv, eta=1.0, config=mcfg)
    mres = meng.run_stream([reqs], n_devices=m_dev, total_jobs=m_jobs,
                           n_chunks=m_chunks)
    assert mres.jobs >= 1_000_000, (
        f"million-job row only released {mres.jobs} jobs")
    rows.append(_stream_row("fleet_stream_1m", mres, m_dev))

    emit("serve", rows)


if __name__ == "__main__":
    run()
