"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §6); each prints
``bench,key=value,...`` CSV rows.  Every module run writes a
machine-readable ``experiments/BENCH_<name>.json`` (wall time + the rows it
emitted, which carry throughput / devices-per-sec where applicable) so the
perf trajectory can be tracked across PRs —
``benchmarks/check_regression.py`` gates those artifacts against the
committed baselines under ``experiments/baselines/`` in CI.

``--full`` runs the 4-dataset variants; ``--smoke`` runs a fast subset
(the fleet-throughput, kernel, live-serving, policy-search and forecast
benches) as a CI canary so the benchmark entrypoints can't silently rot.
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from . import (
    bench_adapt,
    bench_adaptation,
    bench_capacitor,
    bench_classifiers,
    bench_clock,
    bench_early_termination,
    bench_eta,
    bench_fleet,
    bench_fleet_segments,
    bench_forecast,
    bench_kernels,
    bench_loss_functions,
    bench_overhead,
    bench_scheduler,
    bench_serve,
    common,
    roofline,
)

BENCHES = (
    ("overhead_fig14", bench_overhead),
    ("loss_functions_fig15", bench_loss_functions),
    ("early_termination_fig16", bench_early_termination),
    ("scheduler_figs17_20", bench_scheduler),
    ("fleet_throughput", bench_fleet),
    ("fleet", bench_fleet_segments),
    ("kernels", bench_kernels),
    ("serve", bench_serve),
    ("adapt_tune", bench_adapt),
    ("forecast", bench_forecast),
    ("capacitor_fig21", bench_capacitor),
    ("clock_table5", bench_clock),
    ("adaptation_fig24", bench_adaptation),
    ("eta_validation_fig25", bench_eta),
    ("classifiers_table7", bench_classifiers),
    ("roofline", roofline),
)

SMOKE_BENCHES = ("fleet_throughput", "fleet", "kernels", "serve",
                 "adapt_tune", "forecast")


def write_bench_json(name: str, wall_s: float, rows: dict,
                     ok: bool) -> None:
    common.OUT_DIR.mkdir(exist_ok=True)
    path = common.OUT_DIR / f"BENCH_{name}.json"
    path.write_text(json.dumps(
        dict(bench=name, ok=ok, wall_s=round(wall_s, 3), rows=rows),
        indent=2, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all four datasets (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast CI subset: {', '.join(SMOKE_BENCHES)}")
    ap.add_argument("--only", nargs="*", help="subset of benchmark names")
    args = ap.parse_args()

    selected = args.only or (SMOKE_BENCHES if args.smoke else None)
    if args.only:
        known = {name for name, _ in BENCHES}
        unknown = sorted(set(args.only) - known)
        if unknown:
            raise SystemExit(
                f"unknown benchmark name(s): {', '.join(unknown)}\n"
                f"available: {', '.join(name for name, _ in BENCHES)}")
    failures = []
    for name, mod in BENCHES:
        if selected and name not in selected:
            continue
        t0 = time.time()
        print(f"# --- {name} ---")
        common.drain_rows()
        ok = True
        try:
            mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            ok = False
        wall = time.time() - t0
        write_bench_json(name, wall, common.drain_rows(), ok)
        print(f"# {name} done in {wall:.1f}s")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("# all benchmarks complete -> experiments/BENCH_<name>.json")


if __name__ == "__main__":
    main()
