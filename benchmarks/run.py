"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One registered callable per paper table/figure (see DESIGN.md §6); each
prints ``bench,key=value,...`` CSV rows.  Every bench run writes a
machine-readable ``experiments/BENCH_<name>.json`` (wall time, the rows it
emitted — which carry throughput / devices-per-sec where applicable — and
a ``timings`` section with the cold-vs-steady split of every labelled
:func:`benchmarks.common.timeit` call) so the perf trajectory can be
tracked across PRs — ``benchmarks/check_regression.py`` gates those
artifacts against the committed baselines under ``experiments/baselines/``
in CI.

``--full`` runs the 4-dataset variants; ``--smoke`` runs a fast subset
(the fleet-throughput, kernel, live-serving, policy-search and forecast
benches) as a CI canary so the benchmark entrypoints can't silently rot.
``--profile`` captures a ``jax.profiler`` trace per bench under
``experiments/traces/<name>/`` and tells the bench modules (via
``common.PROFILE``) to attach the HLO-cost roofline attribution to their
measurements (:mod:`repro.launch.profiling`).
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from . import (
    bench_adapt,
    bench_anytime,
    bench_capacitor,
    bench_classifiers,
    bench_clock,
    bench_early_termination,
    bench_eta,
    bench_fleet,
    bench_fleet_segments,
    bench_forecast,
    bench_kernels,
    bench_loss_functions,
    bench_overhead,
    bench_scheduler,
    bench_serve,
    common,
    roofline,
)

BENCHES = (
    ("overhead_fig14", bench_overhead.run),
    ("loss_functions_fig15", bench_loss_functions.run),
    ("early_termination_fig16", bench_early_termination.run),
    ("scheduler_figs17_20", bench_scheduler.run),
    ("fleet_throughput", bench_fleet.run),
    ("fleet", bench_fleet_segments.run),
    ("kernels", bench_kernels.run),
    ("serve", bench_serve.run),
    ("anytime", bench_anytime.run),
    ("adapt_tune", bench_adapt.run),
    ("forecast", bench_forecast.run),
    ("capacitor_fig21", bench_capacitor.run),
    ("clock_table5", bench_clock.run),
    ("adaptation_fig24", bench_adapt.run_fig24),
    ("eta_validation_fig25", bench_eta.run),
    ("classifiers_table7", bench_classifiers.run),
    ("roofline", roofline.run),
)

SMOKE_BENCHES = ("fleet_throughput", "fleet", "kernels", "serve",
                 "anytime", "adapt_tune", "forecast")


def write_bench_json(name: str, wall_s: float, rows: dict, timings: dict,
                     ok: bool) -> None:
    common.OUT_DIR.mkdir(exist_ok=True)
    path = common.OUT_DIR / f"BENCH_{name}.json"
    path.write_text(json.dumps(
        dict(bench=name, ok=ok, wall_s=round(wall_s, 3), rows=rows,
             timings=timings),
        indent=2, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all four datasets (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help=f"fast CI subset: {', '.join(SMOKE_BENCHES)}")
    ap.add_argument("--only", nargs="*", help="subset of benchmark names")
    ap.add_argument("--profile", action="store_true",
                    help="capture a jax.profiler trace per bench under "
                         "experiments/traces/ and attach roofline "
                         "attribution to measurements")
    args = ap.parse_args()

    selected = args.only or (SMOKE_BENCHES if args.smoke else None)
    if args.only:
        known = {name for name, _ in BENCHES}
        unknown = sorted(set(args.only) - known)
        if unknown:
            raise SystemExit(
                f"unknown benchmark name(s): {', '.join(unknown)}\n"
                f"available: {', '.join(name for name, _ in BENCHES)}")
    common.PROFILE = bool(args.profile)
    failures = []
    for name, bench_fn in BENCHES:
        if selected and name not in selected:
            continue
        t0 = time.time()
        print(f"# --- {name} ---")
        common.drain_rows()
        common.drain_timings()
        ok = True
        try:
            if args.profile:
                from repro.launch import profiling

                with profiling.trace(common.OUT_DIR / "traces" / name):
                    bench_fn(quick=not args.full)
            else:
                bench_fn(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            ok = False
        wall = time.time() - t0
        write_bench_json(name, wall, common.drain_rows(),
                         common.drain_timings(), ok)
        print(f"# {name} done in {wall:.1f}s")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("# all benchmarks complete -> experiments/BENCH_<name>.json")


if __name__ == "__main__":
    main()
