"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §6); each prints
``bench,key=value,...`` CSV rows and appends to
``experiments/bench_results.json``.  ``--full`` runs the 4-dataset variants.
"""
from __future__ import annotations

import argparse
import time
import traceback

from . import (
    bench_adaptation,
    bench_capacitor,
    bench_classifiers,
    bench_clock,
    bench_early_termination,
    bench_eta,
    bench_fleet,
    bench_loss_functions,
    bench_overhead,
    bench_scheduler,
    roofline,
)

BENCHES = (
    ("overhead_fig14", bench_overhead),
    ("loss_functions_fig15", bench_loss_functions),
    ("early_termination_fig16", bench_early_termination),
    ("scheduler_figs17_20", bench_scheduler),
    ("fleet_throughput", bench_fleet),
    ("capacitor_fig21", bench_capacitor),
    ("clock_table5", bench_clock),
    ("adaptation_fig24", bench_adaptation),
    ("eta_validation_fig25", bench_eta),
    ("classifiers_table7", bench_classifiers),
    ("roofline", roofline),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all four datasets (slower)")
    ap.add_argument("--only", nargs="*", help="subset of benchmark names")
    args = ap.parse_args()

    failures = []
    for name, mod in BENCHES:
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---")
        try:
            mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("# all benchmarks complete -> experiments/bench_results.json")


if __name__ == "__main__":
    main()
