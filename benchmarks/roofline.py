"""§Roofline (assignment deliverable g) — aggregate the dry-run records in
``experiments/dryrun/`` into the per-(arch x shape) roofline table:
the three terms in seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and a one-line "what would move the dominant term" note."""
from __future__ import annotations

import json
from pathlib import Path

from .common import OUT_DIR, emit

DRYRUN_DIR = OUT_DIR / "dryrun"

NOTES = {
    ("compute",): "compute-bound: raise MXU utilisation (larger per-chip "
                  "tiles, bf16 accumulation where safe)",
    ("memory",): "memory-bound: fuse attention (flash-style Pallas kernel), "
                 "keep softmax intermediates in VMEM, fewer f32 round-trips",
    ("collective",): "collective-bound: reduce-scatter instead of all-reduce "
                     "for grads, overlap all-to-all with expert compute",
}


def load_records(multi_pod: bool = False) -> list[dict]:
    recs = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        is_multi = f.stem.endswith("__multipod")
        if is_multi != multi_pod:
            continue
        recs.append(json.loads(f.read_text()))
    return recs


def table_rows(multi_pod: bool = False) -> list[dict]:
    rows = []
    for rec in load_records(multi_pod):
        if rec.get("status") == "skip":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"],
                "status": "skip", "reason": rec.get("reason", "")[:70],
            })
            continue
        r = rec["roofline"]
        mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "step": rec["step_kind"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"],
            "useful_flops_ratio": round(rec["useful_flops_ratio"], 3),
            "hbm_args_GiB": round(arg_gb, 2),
            "hbm_temp_GiB": round(mem_gb, 2),
            "fits_16GiB": bool(arg_gb + mem_gb < 16.0),
            "note": NOTES[(r["dominant"],)],
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    cols = ["arch", "shape", "step", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_flops_ratio",
            "hbm_args_GiB", "hbm_temp_GiB", "fits_16GiB"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP — "
                       f"{r['reason']} |" + " |" * (len(cols) - 3))
            continue
        out.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(out)


def run(quick: bool = True) -> list[dict]:
    rows = table_rows(multi_pod=False)
    if not rows:
        return emit("roofline", [{
            "error": "no dry-run records; run python -m repro.launch.dryrun "
                     "--all first"
        }])
    (OUT_DIR / "roofline_table.md").write_text(markdown_table(rows))
    multi = table_rows(multi_pod=True)
    if multi:
        (OUT_DIR / "roofline_table_multipod.md").write_text(
            markdown_table(multi)
        )
    ok = [r for r in rows if r.get("status") != "skip"]
    summary = [{
        "n_single_pod_records": len(rows),
        "n_multi_pod_records": len(multi),
        "n_skips": len(rows) - len(ok),
        "dominant_memory": sum(r["dominant"] == "memory" for r in ok),
        "dominant_collective": sum(r["dominant"] == "collective"
                                   for r in ok),
        "dominant_compute": sum(r["dominant"] == "compute" for r in ok),
        "all_fit_hbm": all(r["fits_16GiB"] for r in ok),
        "table": "experiments/roofline_table.md",
    }]
    return emit("roofline", summary + rows)


if __name__ == "__main__":
    run()
