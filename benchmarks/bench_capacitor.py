"""Fig. 21 — capacitor-size sweep on the RF (eta=0.51) system.
Paper claim: both too-small (re-execution after failures) and too-large
(long charge time) capacitors miss more deadlines; 50 mF is the sweet spot.
"""
from __future__ import annotations

import numpy as np

from repro.core import energy
from repro.core.scheduler import SimConfig, TaskSpec, simulate

from .common import emit, profiles

CAPS_MF = (0.1, 1.0, 50.0, 470.0)


def run(quick: bool = True) -> list[dict]:
    profs = list(profiles("mnist"))
    n_units = profs[0].n_units
    harv = energy.calibrate_harvester(0.51, 0.075, name="rf")
    rows = []
    for cap_mf in CAPS_MF:
        cap = energy.Capacitor(capacitance_f=cap_mf * 1e-3)
        task = TaskSpec(
            0, period=1.0, deadline=2.0,
            unit_time=np.full(n_units, 0.12),
            unit_energy=np.full(n_units, 8e-3),
            profiles=profs,
        )
        res = simulate(
            [task], harv, eta=0.51, cap=cap,
            sim=SimConfig(policy="zygarde",
                          horizon=len(profs) * 1.0 + 4.0, seed=11),
        )
        rows.append({
            "capacitor_mF": cap_mf,
            "capacity_J": round(cap.capacity_j, 4),
            "scheduled": res.scheduled,
            "released": res.released,
            "deadline_misses": res.deadline_misses,
            "reboots": res.reboots,
        })
    by = {r["capacitor_mF"]: r["scheduled"] for r in rows}
    rows.append({
        "claim_small_caps_reexecute_and_miss": by[0.1] < by[50.0]
        and by[1.0] < by[50.0],
        "claim_large_cap_pays_charge_time": by[470.0] < by[50.0],
        "claim_50mF_best": by[50.0] == max(by.values()),
        "optimal_C_formula_mF": round(
            1e3 * energy.optimal_capacitance(0.075, 1.0), 2
        ),
    })
    return emit("capacitor_fig21", rows)


if __name__ == "__main__":
    run()
