"""Figs. 17-20 — real-time scheduling across the seven energy systems
(Table 4) and policies {EDF, EDF-M, Zygarde}.

Paper claims reproduced here:
  * EDF-M schedules ~9-34% more jobs than EDF under intermittent power;
  * Zygarde matches EDF-M's schedule count and raises the number of
    correct results by executing optional units when eta*E is high;
  * Solar systems (more power) schedule more jobs than RF at equal eta.
"""
from __future__ import annotations

import numpy as np

from repro.core import energy
from repro.core.scheduler import SimConfig, TaskSpec, simulate

from .common import emit, profiles

# Table 4: (system id, source, eta, average power W) — power rescaled to the
# simulated workload's per-unit energy budget.
SYSTEMS = (
    (1, "battery", 1.00, None),
    (2, "solar", 0.71, 0.60),
    (3, "solar", 0.51, 0.42),
    (4, "solar", 0.38, 0.31),
    (5, "rf", 0.71, 0.058),
    (6, "rf", 0.51, 0.071),
    (7, "rf", 0.38, 0.080),
)

POLICIES = ("edf", "edf-m", "zygarde")


def make_harvester(source: str, eta: float, power: float | None):
    if source == "battery":
        return energy.Harvester("battery", 1.0, 0.0, 1.0)
    # power numbers from Table 4 are mW-scale; normalise so that the solar
    # systems comfortably power the workload and RF is marginal, as in the
    # paper's setups.
    return energy.calibrate_harvester(eta, power, name=source)


def run(quick: bool = True) -> list[dict]:
    datasets = ("mnist", "esc10") if quick else (
        "mnist", "esc10", "cifar100", "vww"
    )
    rows = []
    for name in datasets:
        # separability 1.2: utility tests are imperfect, so deeper (optional)
        # units genuinely improve correctness — the regime of Figs 17-20
        profs = list(profiles(name, separability=1.2))
        n_units = profs[0].n_units
        # full execution just fits on persistent power (U = 0.9); energy
        # outages push the *effective* utilisation past 1 on systems 2-7,
        # which is where early termination buys schedulability (Figs 17-20)
        unit_t = 0.27 / n_units
        period, deadline = 0.3, 0.72
        task_args = dict(
            period=period, deadline=deadline,
            unit_time=np.full(n_units, unit_t),
            unit_energy=np.full(n_units, 2.5e-3),
        )
        horizon = len(profs) * period + 3.0
        for sysid, source, eta, power in SYSTEMS:
            harv = make_harvester(source, eta, power)
            for policy in POLICIES:
                task = TaskSpec(task_id=0, profiles=profs, **task_args)
                res = simulate(
                    [task], harv, eta,
                    sim=SimConfig(policy=policy, horizon=horizon, seed=7),
                )
                rows.append({
                    "dataset": name, "system": sysid, "source": source,
                    "eta": eta, "policy": policy,
                    "released": res.released,
                    "scheduled": res.scheduled,
                    "correct": res.correct,
                    "optional_units": res.optional_units,
                    "reboots": res.reboots,
                })

        def get(sysid, policy, field):
            for r in rows:
                if (r.get("dataset") == name and r.get("system") == sysid
                        and r.get("policy") == policy):
                    return r[field]
            return None

        inter = [s for s, *_ in SYSTEMS if s != 1]
        gains = [
            (get(s, "edf-m", "scheduled") - get(s, "edf", "scheduled"))
            / max(get(s, "edf", "scheduled"), 1)
            for s in inter
        ]
        zyg_extra = [
            get(s, "zygarde", "correct") - get(s, "edf-m", "correct")
            for s in inter
        ]
        rows.append({
            "dataset": name,
            "claim_edfm_schedules_more_than_edf": min(gains) >= 0.0,
            "mean_edfm_gain_pct": round(100 * float(np.mean(gains)), 1),
            "claim_zygarde_correct_ge_edfm": sum(zyg_extra) >= 0,
            "zygarde_extra_correct_total": int(sum(zyg_extra)),
            "claim_zygarde_runs_optional": any(
                get(s, "zygarde", "optional_units") > 0 for s in (1, 2, 5)
            ),
        })
    return emit("scheduler_figs17_20", rows)


if __name__ == "__main__":
    run(quick=False)
