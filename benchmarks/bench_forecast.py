"""Forecast-aware vs feedback-only online adaptation, plus forecaster
ingest throughput.

Two claims feed the CI regression gate (``benchmarks/check_regression.py``):

* **demo scores** — the seeded nonstationary solar -> RF -> occluded demo
  (``examples/online_adapt.py``): the forecast-aware controller's
  scalarized score must stay at or above the PR-4 feedback-only
  controller's, which itself beats the best statically tuned constants.
  Both numbers are fully deterministic, so the gate holds them to a tight
  tolerance.
* **ingest throughput** — windows/sec of the fleet-batched
  featurize -> L1-classify -> centroid-adapt pipeline
  (:meth:`repro.adapt.HarvestForecaster.observe` over ``(D, W, F)``
  batches through the Pallas ``fleet_l1_topk2`` / ``fleet_centroid_update``
  dispatch), the hot path when a whole fleet's windows stream through one
  shared forecaster.
"""
from __future__ import annotations

import importlib.util
import pathlib
import time

import numpy as np

from repro import adapt

from .common import emit


def _load_demo():
    path = (pathlib.Path(__file__).resolve().parent.parent / "examples"
            / "online_adapt.py")
    spec = importlib.util.spec_from_file_location("online_adapt_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _ingest_row(n_dev: int, n_win: int, n_steps: int) -> dict:
    rng = np.random.default_rng(0)
    fc = adapt.HarvestForecaster(n_clusters=4)
    batch = rng.random((n_dev, n_win, len(adapt.FEATURES))).astype(np.float32)
    eta = batch[:, :, 0].astype(np.float64)
    supply = batch[:, :, 2].astype(np.float64)
    fc.observe(batch, eta, supply)          # warmup: spawn + compile
    t0 = time.perf_counter()
    for _ in range(n_steps):
        fc.observe(batch, eta, supply)
        fc.predict(horizon=4.0)
    wall = time.perf_counter() - t0
    windows = n_dev * n_win * n_steps
    return dict(mode="forecaster_ingest", devices=n_dev, windows_per_obs=n_win,
                steps=n_steps, wall_s=round(wall, 3),
                windows_per_sec=round(windows / wall, 1))


def run(quick: bool = True) -> None:
    demo = _load_demo()
    t0 = time.perf_counter()
    out = demo.run_demo()
    wall = time.perf_counter() - t0
    fb, fc = out["online"], out["forecast"]
    rows = [
        dict(mode="demo_feedback", score=round(fb["score"], 4),
             correct=fb["correct"], misses=fb["misses"],
             best_static_score=round(out["best_static"]["score"], 4)),
        dict(mode="demo_forecast", score=round(fc["score"], 4),
             correct=fc["correct"], misses=fc["misses"],
             margin_over_feedback=round(fc["score"] - fb["score"], 4),
             beats_feedback=bool(fc["score"] >= fb["score"]),
             wall_s=round(wall, 3)),
        _ingest_row(n_dev=64, n_win=8, n_steps=4 if quick else 32),
    ]
    emit("forecast", rows)


if __name__ == "__main__":
    run()
