"""Pallas kernel micro-benchmarks: each serving-path kernel vs its
pure-jnp oracle from :mod:`repro.kernels.ref`.

Times the four kernels the fleet/serving hot loops lean on —
``fleet_priority`` (scheduler pick + capacitor update), ``l1_topk2``
(top-2 L1 cluster distances for the utility test), ``centroid_update``
(weighted online k-means step) and ``pairwise_l1`` (full distance
matrix) — at fleet-shaped operand sizes, against the jitted reference
implementations.  Every pairing is verified for numerical agreement
before it is timed, so the rows double as a correctness sweep.

On this CPU container the kernels run in ``interpret=True`` mode (the
kernel body executes as traced JAX ops), so the interesting number is
that interpret overhead stays within an order of magnitude of the jnp
path — on a TPU backend the same calls compile to Mosaic and the ratio
flips.  Timings are informational, not gated; the regression gate only
checks the rows keep their shape.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import policy as P
from repro.core.step import select_and_charge
from repro.kernels import ops, ref

from .common import emit, timeit


def _block(fn):
    """Wrap ``fn`` so each timed call synchronizes on its outputs."""
    return lambda *a: jax.block_until_ready(fn(*a))


def _kmeans_operands(rng, n_rows, k=64, f=128):
    """Lane-aligned (rows, features) operands shared by the k-means trio."""
    x = jnp.asarray(rng.normal(size=(n_rows, f)), jnp.float32)
    cents = jnp.asarray(rng.normal(size=(k, f)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, k, size=n_rows), jnp.int32)
    return x, cents, assign


def _priority_operands(rng, n_dev, q=8, n_tasks=2):
    """One synthetic fleet pick step: (D, Q) queues with mixed policies,
    partially-active slots, a few locked (forced) devices."""
    f32, i32 = jnp.float32, jnp.int32
    d = dict(
        policy=jnp.asarray(rng.integers(0, len(P.POLICY_IDS), n_dev), i32),
        active=jnp.asarray(rng.random((n_dev, q)) < 0.7, f32),
        laxity=jnp.asarray(rng.uniform(-0.5, 2.0, (n_dev, q)), f32),
        release=jnp.asarray(rng.uniform(0.0, 5.0, (n_dev, q)), f32),
        utility=jnp.asarray(rng.uniform(0.0, 0.5, (n_dev, q)), f32),
        mandatory=jnp.asarray(rng.random((n_dev, q)) < 0.5, f32),
        alpha=jnp.full((n_dev,), 0.6, f32),
        beta=jnp.full((n_dev,), 0.4, f32),
        eta=jnp.asarray(rng.uniform(0.2, 1.0, n_dev), f32),
        persistent=jnp.asarray(rng.random(n_dev) < 0.2, f32),
        energy=jnp.asarray(rng.uniform(0.0, 0.1, n_dev), f32),
        e_opt=jnp.full((n_dev,), 0.02, f32),
        charge=jnp.asarray(rng.uniform(0.0, 5e-3, n_dev), f32),
        capacity=jnp.full((n_dev,), 0.1, f32),
        gate_e=jnp.asarray(rng.uniform(1e-3, 5e-3, (n_dev, q)), f32),
        drain=jnp.asarray(rng.uniform(1e-4, 1e-3, (n_dev, q)), f32),
        forced=jnp.where(jnp.asarray(rng.random(n_dev) < 0.1),
                         jnp.asarray(rng.integers(0, q, n_dev), i32), -1),
        task=jnp.asarray(rng.integers(0, n_tasks, (n_dev, q)), i32),
        rr_cursor=jnp.asarray(rng.integers(0, n_tasks, n_dev), i32),
    )
    return d


def _fleet_priority_ref(policy, active, laxity, release, utility, mandatory,
                        alpha, beta, eta, persistent, energy, e_opt, charge,
                        capacity, gate_e, drain, forced, task, rr_cursor,
                        n_tasks):
    """The batched jnp pick (the vmap frontend's math, sans Pallas)."""
    task_rank = jnp.mod(task - rr_cursor[:, None], n_tasks).astype(
        jnp.float32)
    scores, thr = P.policy_scores(
        policy[:, None], active, laxity, release, utility, mandatory,
        alpha[:, None], beta[:, None], eta[:, None], energy[:, None],
        e_opt[:, None], persistent[:, None], task_rank)
    return select_and_charge(scores, thr[:, 0], forced, energy, charge,
                             capacity, gate_e, drain)


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)
    n_rows = 512 if quick else 4096
    n_dev = 1024 if quick else 8192
    repeats = 10 if quick else 30
    rows = []

    def row(kernel, shape, pallas_fn, ref_fn, args, check):
        check(pallas_fn(*args), ref_fn(*args))
        us_p = timeit(_block(pallas_fn), *args, repeats=repeats,
                      label=f"{kernel}_pallas")
        us_r = timeit(_block(ref_fn), *args, repeats=repeats,
                      label=f"{kernel}_jnp")
        rows.append(dict(mode=kernel, shape=shape,
                         pallas_us=round(us_p, 1), jnp_us=round(us_r, 1),
                         jnp_relative=round(us_r / us_p, 3)))

    x, cents, assign = _kmeans_operands(rng, n_rows)

    def chk_topk2(a, b):
        (d1p, d2p, ip), (d1r, d2r, ir) = a, b
        assert np.allclose(d1p, d1r, atol=1e-4) and np.array_equal(ip, ir)
        assert np.allclose(d2p, d2r, atol=1e-4)

    row("l1_topk2", f"{n_rows}x128,k64",
        jax.jit(ops.l1_topk2), jax.jit(ref.l1_topk2_ref),
        (x, cents), chk_topk2)

    row("pairwise_l1", f"{n_rows}x128,k64",
        jax.jit(ops.pairwise_l1), jax.jit(ref.pairwise_l1_ref),
        (x, cents),
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-3))

    row("centroid_update", f"{n_rows}x128,k64,w32",
        jax.jit(ops.centroid_update), jax.jit(ref.centroid_update_ref),
        (cents, x, assign, 32.0),
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5))

    pri = _priority_operands(rng, n_dev)
    order = list(pri)   # fleet_priority's positional signature

    def chk_pick(a, b):
        sel_p, picked_p, run_p, e_p = a
        sel_r, picked_r, run_r, e_r = b
        assert np.array_equal(sel_p, sel_r)
        assert np.array_equal(np.asarray(picked_p, bool),
                              np.asarray(picked_r, bool))
        assert np.array_equal(np.asarray(run_p, bool),
                              np.asarray(run_r, bool))
        np.testing.assert_allclose(e_p, e_r, atol=1e-7)

    row("fleet_priority", f"D={n_dev},Q=8,K=2",
        lambda *a: ops.fleet_priority(*a, n_tasks=2),
        jax.jit(lambda *a: _fleet_priority_ref(*a, n_tasks=2)),
        tuple(pri[k] for k in order), chk_pick)

    emit("kernels", rows)


if __name__ == "__main__":
    run()
