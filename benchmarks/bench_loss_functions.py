"""Fig. 15 — layer-aware loss vs contrastive vs cross-entropy under early
termination.  Paper claims: layer-aware achieves (a) higher accuracy and
(b) fewer executed units than both baselines when early exit is active."""
from __future__ import annotations

import numpy as np

from .common import agile, dataset, emit

LOSSES = ("layer_aware", "contrastive", "cross_entropy")


def evaluate(name: str, loss: str) -> dict:
    ds = dataset(name)
    model = agile(name, loss)
    profs = model.profile_batch(ds.x_test, ds.y_test)
    mand = np.array([p.mandatory_units() for p in profs])
    acc_exit = float(np.mean([p.correct[m - 1] for p, m in zip(profs, mand)]))
    acc_full = float(np.mean([p.correct[p.n_units - 1] for p in profs]))
    return {
        "dataset": name,
        "loss": loss,
        "acc_early_exit": round(acc_exit, 4),
        "acc_full": round(acc_full, 4),
        "mean_units": round(float(mand.mean()), 3),
        "n_units": profs[0].n_units,
        "exit_time_saving": round(1.0 - mand.mean() / profs[0].n_units, 4),
    }


def run(quick: bool = True) -> list[dict]:
    datasets = ("mnist", "esc10") if quick else (
        "mnist", "esc10", "cifar100", "vww"
    )
    rows = [evaluate(d, l) for d in datasets for l in LOSSES]
    for d in datasets:
        by = {r["loss"]: r for r in rows if r["dataset"] == d}
        rows.append({
            "dataset": d,
            "claim_layer_aware_acc_ge_cross_entropy":
                by["layer_aware"]["acc_early_exit"]
                >= by["cross_entropy"]["acc_early_exit"] - 0.02,
            "claim_layer_aware_fewer_units_than_ce":
                by["layer_aware"]["mean_units"]
                <= by["cross_entropy"]["mean_units"] + 0.25,
        })
    return emit("loss_functions_fig15", rows)


if __name__ == "__main__":
    run(quick=False)
