"""Repo documentation checks, run as the CI docs lane.

Two gates, both fast and dependency-free:

1. **Intra-repo links** — every relative markdown link in `README.md`
   and `docs/*.md` must resolve to an existing file or directory
   (anchors are stripped; external `http(s)://` / `mailto:` links are
   skipped — this gate is about repo rot, not the internet).
2. **Example smoke** — every `examples/*.py` module must exit 0 on
   `--help` with `PYTHONPATH=src`.  This catches import-time breakage
   and argparse rot in the documented entrypoints without paying for a
   full run.

Usage::

    python tools/check_docs.py            # both gates
    python tools/check_docs.py --links    # links only
    python tools/check_docs.py --examples # example smoke only
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must resolve too.  Nested parens don't occur in our docs.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

_EXTERNAL = ("http://", "https://", "mailto:")


def doc_pages():
    pages = [os.path.join(REPO, "README.md")]
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        pages.extend(
            os.path.join(docs_dir, n)
            for n in sorted(os.listdir(docs_dir))
            if n.endswith(".md")
        )
    return pages


def check_links() -> list[str]:
    """Return a list of "page:line: broken link" failure strings."""
    failures = []
    for page in doc_pages():
        base = os.path.dirname(page)
        rel_page = os.path.relpath(page, REPO)
        with open(page, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                for target in _LINK_RE.findall(line):
                    if target.startswith(_EXTERNAL):
                        continue
                    path = target.split("#", 1)[0]
                    if not path:  # pure in-page anchor
                        continue
                    resolved = os.path.normpath(os.path.join(base, path))
                    if not os.path.exists(resolved):
                        failures.append(
                            f"{rel_page}:{lineno}: broken link -> {target}"
                        )
                    elif os.path.commonpath([resolved, REPO]) != REPO:
                        failures.append(
                            f"{rel_page}:{lineno}: link escapes repo -> {target}"
                        )
    return failures


def check_examples() -> list[str]:
    """Return failures from running every examples/*.py with --help."""
    failures = []
    ex_dir = os.path.join(REPO, "examples")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    for name in sorted(os.listdir(ex_dir)):
        if not name.endswith(".py"):
            continue
        proc = subprocess.run(
            [sys.executable, os.path.join(ex_dir, name), "--help"],
            capture_output=True,
            text=True,
            env=env,
            cwd=REPO,
            timeout=120,
        )
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
            failures.append(
                f"examples/{name} --help exited {proc.returncode}:\n  "
                + "\n  ".join(tail)
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--links", action="store_true", help="link check only")
    ap.add_argument(
        "--examples", action="store_true", help="example --help smoke only"
    )
    args = ap.parse_args(argv)
    run_links = args.links or not args.examples
    run_examples = args.examples or not args.links

    failures = []
    if run_links:
        link_failures = check_links()
        n_pages = len(doc_pages())
        print(
            f"links: {n_pages} pages checked, {len(link_failures)} broken"
        )
        failures.extend(link_failures)
    if run_examples:
        ex_failures = check_examples()
        n_ex = len(
            [n for n in os.listdir(os.path.join(REPO, "examples"))
             if n.endswith(".py")]
        )
        print(f"examples: {n_ex} modules smoked, {len(ex_failures)} failed")
        failures.extend(ex_failures)

    for f in failures:
        print(f"FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
