"""Hypothesis property tests for the shared priority math in
:mod:`repro.core.policy` — the single source of truth behind the scalar
simulator, the vmapped fleet path and the Pallas kernel.

Properties:

* priority monotonicity — a closer deadline strictly raises the EDF key;
  under a fixed laxity, *lower* utility (less classifier confidence) raises
  zeta (Eq. 6 spends ``1 - beta * psi``: confident jobs can afford to
  wait), and the mandatory flag adds exactly gamma = 1;
* NEG-sentinel dominance — inactive slots and EDF-M optional work sit at
  the NEG floor, strictly below any bounded active/mandatory score and
  below the idle thresholds;
* ``exit_test`` strict-inequality consistency with
  :func:`repro.core.utility.calibrate_threshold` (a margin exactly at the
  threshold does NOT exit, matching the calibration curve's ``margin > t``);
* float-vs-jnp-vs-``(D, Q)``-array agreement — the same expressions give
  the same numbers for python scalars, jnp scalars and batched arrays.

Wired through ``tests/_hypothesis_fallback`` so the suite still collects
(with these marked skipped) when the ``test`` extra is absent.
"""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core import policy as P
from repro.core.kmeans import UnitClassifier, classify
from repro.core.utility import calibrate_threshold

# bounded, finite operating ranges (scores must stay far above NEG)
finite = dict(allow_nan=False, allow_infinity=False)
laxities = st.floats(-5.0, 50.0, **finite)
deadlines = st.floats(0.0, 1e3, **finite)
releases = st.floats(0.0, 1e3, **finite)
utilities = st.floats(0.0, 1.0, **finite)
alphas = st.floats(1e-3, 2.0, **finite)
betas = st.floats(0.0, 2.0, **finite)
etas = st.floats(0.0, 1.0, **finite)
energies = st.floats(0.0, 1.0, **finite)


# --------------------------------------------------------------------------- #
# Monotonicity.
# --------------------------------------------------------------------------- #


@given(deadlines, deadlines, releases)
@settings(max_examples=50, deadline=None)
def test_edf_key_closer_deadline_wins(d1, d2, release):
    """Strictly earlier deadline => strictly higher EDF key (the release
    tie-break perturbation must never overturn a genuine deadline gap)."""
    lo, hi = sorted((d1, d2))
    if hi - lo < 1e-3:   # below the documented _TIE * release resolution
        hi = lo + 1e-3
    assert P.edf_key(lo, release) > P.edf_key(hi, release)


@given(deadlines, releases, releases)
@settings(max_examples=50, deadline=None)
def test_edf_key_deadline_tie_breaks_by_release(deadline, r1, r2):
    lo, hi = sorted((r1, r2))
    if hi - lo < 1e-3:
        hi = lo + 1e-3
    assert P.edf_key(deadline, lo) > P.edf_key(deadline, hi)


@given(laxities, utilities, utilities, alphas, betas)
@settings(max_examples=50, deadline=None)
def test_zeta_lower_utility_higher_priority(laxity, u1, u2, alpha, beta):
    """Eq. 6 spends (1 - beta * psi): under a fixed laxity the LESS
    confident job ranks at least as high, strictly when beta > 0."""
    lo, hi = sorted((u1, u2))
    z_confident = P.zeta_priority(laxity, hi, True, alpha, beta)
    z_unsure = P.zeta_priority(laxity, lo, True, alpha, beta)
    assert z_unsure >= z_confident
    if beta * (hi - lo) > 1e-9:
        assert z_unsure > z_confident


@given(laxities, laxities, utilities, alphas, betas)
@settings(max_examples=50, deadline=None)
def test_zeta_smaller_laxity_higher_priority(l1, l2, util, alpha, beta):
    lo, hi = sorted((l1, l2))
    if hi - lo < 1e-6:
        hi = lo + 1e-6
    assert (P.zeta_priority(lo, util, True, alpha, beta)
            > P.zeta_priority(hi, util, True, alpha, beta))


@given(laxities, utilities, alphas, betas)
@settings(max_examples=50, deadline=None)
def test_zeta_mandatory_adds_exactly_gamma(laxity, util, alpha, beta):
    m = P.zeta_priority(laxity, util, True, alpha, beta)
    o = P.zeta_priority(laxity, util, False, alpha, beta)
    assert m - o == pytest.approx(1.0)


@given(laxities, utilities, alphas, betas, etas, energies)
@settings(max_examples=50, deadline=None)
def test_zeta_intermittent_gate(laxity, util, alpha, beta, eta, energy):
    """Eq. 7: with the energy gate closed, optional work scores exactly 0
    and mandatory work keeps the gamma-less Eq. 6 base; with it open, both
    recover Eq. 6 (minus gamma for optional units)."""
    e_opt = 0.5
    z6 = P.zeta_priority(laxity, util, True, alpha, beta)   # base + gamma
    z7m = P.zeta_intermittent_priority(laxity, util, True, alpha, beta,
                                       eta, energy, e_opt)
    z7o = P.zeta_intermittent_priority(laxity, util, False, alpha, beta,
                                       eta, energy, e_opt)
    if eta * energy >= e_opt:
        assert z7m == pytest.approx(z6)
        assert z7o == pytest.approx(z6 - 1.0)
    else:
        assert z7o == 0.0
        assert z7m == pytest.approx(z6 - 1.0)


# --------------------------------------------------------------------------- #
# NEG-sentinel dominance.
# --------------------------------------------------------------------------- #


@given(deadlines, releases, utilities, st.integers(0, 3))
@settings(max_examples=50, deadline=None)
def test_neg_sentinel_dominance(deadline, release, util, policy_id):
    """Inactive slots are pinned to NEG and can never outrank an active
    slot with bounded inputs, under every policy; the idle threshold sits
    strictly above NEG so an all-inactive queue never gets picked."""
    active = jnp.array([1.0, 0.0])
    args = dict(
        policy_id=jnp.int32(policy_id),
        active=active,
        laxity=jnp.array([deadline, deadline]),
        release=jnp.array([release, release]),
        utility=jnp.array([util, util]),
        mandatory=jnp.array([1.0, 1.0]),
        alpha=jnp.float32(0.5), beta=jnp.float32(1.0),
        eta=jnp.float32(0.8), energy=jnp.float32(0.9),
        e_opt=jnp.float32(0.5), persistent=jnp.float32(0.0),
    )
    scores, thr = P.policy_scores(**args, task_rank=jnp.array([0.0, 0.0]))
    # the sentinel survives the f32 round-trip (compare in f32 terms)
    assert float(scores[1]) == pytest.approx(P.NEG, rel=1e-6)
    assert float(scores[0]) > 0.5 * P.NEG
    if policy_id != 0:   # deadline-keyed policies idle only on empty queues
        assert float(thr) < 0.4 * P.NEG
        assert float(scores[0]) > float(thr)
    # all-inactive queue: nothing clears the threshold
    scores0, thr0 = P.policy_scores(
        **{**args, "active": jnp.zeros(2)}, task_rank=jnp.zeros(2))
    assert float(jnp.max(scores0)) <= float(thr0)


@given(deadlines, releases, deadlines, releases)
@settings(max_examples=50, deadline=None)
def test_edfm_optional_work_never_schedulable(d_opt, r_opt, d_mand, r_mand):
    """EDF-M pins optional (post-exit) work at NEG: any mandatory slot with
    bounded deadline/release dominates it."""
    opt = P.edfm_key(d_opt, r_opt, False)
    mand = P.edfm_key(d_mand, r_mand, True)
    assert opt == P.NEG
    assert mand > opt


def test_rr_key_task_rotation_dominates_release():
    """The task-rotation rank outweighs any in-horizon release gap, and
    rank 0 degenerates to the plain FIFO key bit-for-bit."""
    assert P.rr_key(123.25, 0.0) == -123.25
    # a task one rotation step closer wins despite a much older release
    assert P.rr_key(999.0, 0.0) > P.rr_key(0.0, 1.0)
    # within a task (same rank), FIFO by release
    assert P.rr_key(1.0, 2.0) > P.rr_key(5.0, 2.0)


# --------------------------------------------------------------------------- #
# exit_test ↔ calibrate_threshold strict-inequality consistency.
# --------------------------------------------------------------------------- #


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_exit_test_matches_calibration_curve(seed):
    """calibrate_threshold's trade-off curve is computed with the strict
    ``margin > t`` rule; re-evaluating exit_test on the same margins must
    reproduce every curve point's exit fraction — including thresholds that
    sit exactly on a margin value (quantiles of the margins themselves),
    where a >= rule would disagree."""
    rng = np.random.default_rng(seed)
    n, d, k = 64, 4, 3
    uc = UnitClassifier(
        centroids=jnp.asarray(rng.normal(size=(k, d)), jnp.float32),
        labels=jnp.arange(k, dtype=jnp.int32) % 2,
        feature_idx=jnp.arange(d, dtype=jnp.int32),
        counts=jnp.ones((k,), jnp.float32),
        threshold=jnp.float32(0.1),
    )
    feats = rng.normal(size=(n, d)).astype(np.float32)
    labels = rng.integers(0, 2, size=n)
    thr, curve = calibrate_threshold(uc, feats, labels, grid=12)
    margin = np.asarray(classify(uc, jnp.asarray(feats))[4])
    for t, frac, _acc in curve:
        assert np.mean(np.asarray(P.exit_test(margin, t))) == (
            pytest.approx(frac))
    # the chosen threshold comes from the curve and obeys the same rule
    assert float(thr) in [t for t, _, _ in curve]
    # strictness at the boundary: a margin exactly at the threshold stays
    assert not bool(P.exit_test(float(thr), float(thr)))


# --------------------------------------------------------------------------- #
# float vs jnp vs (D, Q) array agreement.
# --------------------------------------------------------------------------- #


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_policy_scores_scalar_vs_batched_agreement(seed):
    """One (D, Q) policy_scores call must agree elementwise with D*Q python
    float evaluations of the underlying priority functions — the guarantee
    that lets the scalar simulator, the vmapped fleet and the Pallas kernel
    share one implementation."""
    rng = np.random.default_rng(seed)
    D, Q = 4, 3
    laxity = rng.uniform(-2, 20, (D, Q))
    release = rng.uniform(0, 30, (D, Q))
    util = rng.uniform(0, 1, (D, Q))
    mand = rng.integers(0, 2, (D, Q)).astype(float)
    rank = rng.integers(0, 4, (D, Q)).astype(float)
    policy_id = rng.integers(0, 4, (D,))
    eta = rng.uniform(0.1, 1.0, (D,))
    energy = rng.uniform(0, 1, (D,))
    e_opt = rng.uniform(0.1, 0.9, (D,))
    persistent = rng.integers(0, 2, (D,)).astype(float)
    alpha, beta = 0.5, 1.0

    scores, _ = P.policy_scores(
        jnp.asarray(policy_id)[:, None], jnp.ones((D, Q)),
        jnp.asarray(laxity), jnp.asarray(release), jnp.asarray(util),
        jnp.asarray(mand), alpha, beta, jnp.asarray(eta)[:, None],
        jnp.asarray(energy)[:, None], jnp.asarray(e_opt)[:, None],
        jnp.asarray(persistent)[:, None], jnp.asarray(rank))
    scores = np.asarray(scores)

    for i in range(D):
        for q in range(Q):
            if policy_id[i] == 0:
                if persistent[i]:
                    want = P.zeta_priority(
                        laxity[i, q], util[i, q], mand[i, q], alpha, beta)
                else:
                    want = P.zeta_intermittent_priority(
                        laxity[i, q], util[i, q], mand[i, q], alpha, beta,
                        eta[i], energy[i], e_opt[i])
            elif policy_id[i] == 1:
                want = P.edf_key(laxity[i, q], release[i, q])
            elif policy_id[i] == 2:
                want = P.edfm_key(laxity[i, q], release[i, q], mand[i, q])
            else:
                want = P.rr_key(release[i, q], rank[i, q])
            # python-float and jnp-scalar evaluations agree with the batch
            assert scores[i, q] == pytest.approx(float(want), rel=1e-5)


@given(laxities, utilities, alphas, betas)
@settings(max_examples=25, deadline=None)
def test_priority_fns_float_jnp_agree(laxity, util, alpha, beta):
    """The pure functions accept python floats, numpy and jnp scalars
    interchangeably (the polymorphism the three call sites rely on)."""
    as_float = P.zeta_priority(laxity, util, True, alpha, beta)
    as_np = P.zeta_priority(np.float64(laxity), np.float64(util), True,
                            np.float64(alpha), np.float64(beta))
    as_jnp = P.zeta_priority(jnp.float32(laxity), jnp.float32(util), True,
                             jnp.float32(alpha), jnp.float32(beta))
    assert as_float == pytest.approx(float(as_np), rel=1e-6)
    assert as_float == pytest.approx(float(as_jnp), rel=1e-4, abs=1e-4)
    e = P.edf_key(laxity, util)
    assert float(P.edf_key(jnp.float32(laxity), jnp.float32(util))) == (
        pytest.approx(e, rel=1e-4, abs=1e-4))
