"""Intermittent execution substrate: the SONIC-style contract —
run-with-power-failures == run-without, bit-exactly."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core import energy
from repro.core.intermittent import (
    FRAMStore,
    Fragment,
    fragment_unit,
    run_intermittent,
)

PERSISTENT = energy.Harvester("battery", 1.0, 0.0, 10.0)


def counter_fragments(n=8, time_s=0.05, energy_j=2e-3):
    """n fragments, each appends its index and updates a running hash."""
    frags = []
    for i in range(n):
        def fn(state, i=i):
            return {
                "seq": state["seq"] + [i],
                "acc": state["acc"] * 31 + i,
                "arr": state["arr"] + jnp.float32(i),
            }
        frags.append(Fragment(fn, time_s, energy_j, f"f{i}"))
    return frags


def init_state():
    return {"seq": [], "acc": 7, "arr": jnp.zeros((4,), jnp.float32)}


def test_persistent_run_completes():
    frags = counter_fragments()
    out, stats = run_intermittent(frags, init_state(), PERSISTENT)
    assert out["seq"] == list(range(8))
    assert stats.reboots == 0
    assert stats.fragments_run == 8
    assert stats.off_time == 0.0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_failure_run_bit_exact(seed):
    """The central idempotence contract: intermittent result == persistent."""
    frags = counter_fragments(n=10, energy_j=4e-2)
    ref, _ = run_intermittent(frags, init_state(), PERSISTENT)
    weak = energy.Harvester("weak", 0.7, 0.7, 0.06)
    cap = energy.Capacitor(capacitance_f=0.02)
    out, stats = run_intermittent(
        frags, init_state(), weak, cap, seed=seed, max_wall=1e4
    )
    assert out["seq"] == ref["seq"]
    assert out["acc"] == ref["acc"]
    np.testing.assert_array_equal(np.asarray(out["arr"]),
                                  np.asarray(ref["arr"]))
    assert stats.fragments_run == 10


def test_snapshot_restores_from_fram():
    fram = FRAMStore()
    frags = counter_fragments(n=6, energy_j=3e-2)
    weak = energy.Harvester("weak", 0.6, 0.6, 0.05)
    out, stats = run_intermittent(
        frags, init_state(), weak, energy.Capacitor(capacitance_f=0.02),
        fram=fram, seed=1, max_wall=1e4,
    )
    assert fram.commits >= stats.fragments_run + 1  # init + per-fragment
    assert out["seq"] == list(range(6))


def test_fragment_unit_splits_costs():
    calls = []
    frags = fragment_unit(lambda s: calls.append(1) or s + 1, 4, 0.4, 8e-3)
    assert len(frags) == 4
    assert sum(f.time_s for f in frags) == pytest.approx(0.4)
    assert sum(f.energy_j for f in frags) == pytest.approx(8e-3)
    out, _ = run_intermittent(frags, 0, PERSISTENT)
    assert out == 1 and calls == [1]  # unit function applied exactly once


@given(st.integers(0, 500), st.floats(0.55, 0.95), st.floats(0.02, 0.2))
@settings(max_examples=15, deadline=None)
def test_idempotence_property(seed, p_stay, power):
    frags = counter_fragments(n=6, energy_j=2.5e-2)
    ref, _ = run_intermittent(frags, init_state(), PERSISTENT)
    harv = energy.Harvester("h", p_stay, p_stay, power)
    out, stats = run_intermittent(
        frags, init_state(), harv, energy.Capacitor(capacitance_f=0.02),
        seed=seed, max_wall=2e4,
    )
    if stats.fragments_run == 6:  # completed within the wall-clock budget
        assert out["seq"] == ref["seq"]
        assert out["acc"] == ref["acc"]
    assert stats.busy_time <= stats.wall_time + 1e-9
