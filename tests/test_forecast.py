"""Harvest-pattern forecasting (`repro.adapt.forecast`).

Four layers:

* kernel-dispatch parity: the fleet-shaped ``(D, W, F)`` classify/update
  entry points (:func:`repro.core.kmeans.classify_batch` /
  :func:`repro.core.kmeans.online_update`, backed by the padded Pallas
  wrappers in :mod:`repro.kernels.ops`) match a numpy oracle and run
  under ``jax.jit``;
* hypothesis property tests for the forecaster — the spawned cluster
  count never exceeds ``n_clusters`` (and member counts are monotone),
  predictions never leave the envelope of the (eta, supply) values fed in
  (they are convex combinations of observed per-window statistics), and
  the whole pipeline is deterministic: two forecasters fed the same
  stream agree exactly;
* integration: both controller compositions (feedback and forecast) run
  per-device over ``fleet.run_segments`` on a multi-device fleet spanning
  a CHRT ``clock_drift`` axis, producing per-device histories;
* the seeded nonstationary regression: on the solar -> RF -> occluded
  trace of ``examples/online_adapt.py``, the forecast-aware controller
  must beat the PR-4 feedback-only controller — anticipation dominates
  reaction once the regime cycle has been seen.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_fallback import given, settings, st
from repro import adapt, fleet
from repro.core import energy, kmeans
from repro.core.scheduler import JobProfile, TaskSpec
from repro.fleet import grid as fgrid


# --------------------------------------------------------------------------- #
# Fleet-shaped kernel dispatch.
# --------------------------------------------------------------------------- #


def test_classify_batch_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    x = rng.random((5, 3, 6)).astype(np.float32)      # (D, W, F)
    c = rng.random((4, 6)).astype(np.float32)
    idx, d1, d2, margin = kmeans.classify_batch(jnp.asarray(c),
                                                jnp.asarray(x))
    ref = np.abs(x[:, :, None, :] - c[None, None]).sum(-1)   # (D, W, k)
    assert idx.shape == (5, 3)
    np.testing.assert_array_equal(np.asarray(idx), ref.argmin(-1))
    np.testing.assert_allclose(np.asarray(d1), ref.min(-1), rtol=1e-5)
    part = np.partition(ref, 1, axis=-1)
    np.testing.assert_allclose(np.asarray(d2), part[..., 1], rtol=1e-5)
    assert np.all(np.asarray(margin) >= 0.0)
    # 2-D batches work too (the per-segment online path)
    idx2, *_ = kmeans.classify_batch(jnp.asarray(c), jnp.asarray(x[:, 0]))
    np.testing.assert_array_equal(np.asarray(idx2), np.asarray(idx)[:, 0])


def test_online_update_matches_weighted_mean_and_ignores_negatives():
    rng = np.random.default_rng(1)
    x = rng.random((7, 6)).astype(np.float32)
    c = rng.random((3, 6)).astype(np.float32)
    assign = np.array([0, 0, 1, -1, 1, 2, 0], np.int32)
    w = 4.0
    new_c, new_n = kmeans.online_update(
        jnp.asarray(c), jnp.zeros(3), jnp.asarray(x), jnp.asarray(assign), w)
    for j in range(3):
        members = x[assign == j]
        want = (w * c[j] + members.sum(0)) / (w + len(members))
        np.testing.assert_allclose(np.asarray(new_c)[j], want, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(new_n), [3, 2, 1])


def test_batched_entry_points_are_jit_safe():
    @jax.jit
    def step(c, n, x):
        idx, *_ = kmeans.classify_batch(c, x)
        return kmeans.online_update(c, n, x, idx, 8.0)

    rng = np.random.default_rng(2)
    c, n = step(jnp.asarray(rng.random((4, 6), ), jnp.float32),
                jnp.zeros(4),
                jnp.asarray(rng.random((3, 5, 6)), jnp.float32))
    assert c.shape == (4, 6) and float(jnp.sum(n)) == 15.0


# --------------------------------------------------------------------------- #
# Forecaster properties.
# --------------------------------------------------------------------------- #


def _feed(fc: adapt.HarvestForecaster, stream: np.ndarray) -> None:
    """Feed an (n_steps, D, F) feature stream window by window."""
    for feats in stream:
        fc.observe(feats.astype(np.float32), feats[:, 0], feats[:, 2])


def _stream(draws, n_steps: int, n_dev: int) -> np.ndarray:
    vals = np.asarray(draws, np.float64).reshape(n_steps, n_dev, 1)
    # six O(1) feature columns derived deterministically from one draw
    cols = [vals, 1.0 - vals, vals ** 2, 0.5 * vals, vals ** 3, 1.0 - vals ** 2]
    return np.concatenate(cols, axis=-1)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=2,
             max_size=24),
    st.integers(min_value=1, max_value=6),
)
def test_cluster_count_bounded_and_counts_monotone(draws, n_clusters):
    fc = adapt.HarvestForecaster(n_clusters=n_clusters, spawn_radius=0.4)
    stream = _stream(draws, len(draws), 1)
    prev_counts = np.zeros(n_clusters)
    for feats in stream:
        fc.observe(feats.astype(np.float32), feats[:, 0], feats[:, 2])
        assert 1 <= fc.n_born <= n_clusters
        assert fc.centroids.shape == (n_clusters, feats.shape[-1])
        counts = np.asarray(fc.counts, np.float64)
        assert np.all(counts >= prev_counts - 1e-6)
        prev_counts = counts
    assert fc.stats_n.sum() == pytest.approx(len(draws))


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=2,
             max_size=24),
    st.floats(min_value=0.5, max_value=8.0),
)
def test_prediction_bounded_by_observed_range(draws, horizon):
    """Predicted (eta, supply) are convex combinations of the per-window
    statistics fed to observe(), so they stay in the observed envelope."""
    fc = adapt.HarvestForecaster(n_clusters=3, spawn_radius=0.4)
    stream = _stream(draws, len(draws), 1)
    _feed(fc, stream)
    pred = fc.predict(horizon)
    etas, supplies = stream[:, :, 0], stream[:, :, 2]
    assert etas.min() - 1e-9 <= pred["eta"][0] <= etas.max() + 1e-9
    assert supplies.min() - 1e-9 <= pred["supply"][0] <= supplies.max() + 1e-9
    assert 0.0 <= pred["confidence"][0] <= 1.0
    assert 0.0 <= pred["w_stay"][0] <= 1.0


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_forecaster_deterministic_under_fixed_seed(seed):
    """Two forecasters fed the bit-identical stream agree exactly — the
    whole pipeline (featurize, Pallas classify/update, host bookkeeping)
    has no hidden randomness."""
    rng = np.random.default_rng(seed)
    stream = rng.random((10, 2, 6))
    fc1 = adapt.HarvestForecaster(n_clusters=3)
    fc2 = adapt.HarvestForecaster(n_clusters=3)
    _feed(fc1, stream)
    _feed(fc2, stream)
    np.testing.assert_array_equal(fc1.centroids, fc2.centroids)
    np.testing.assert_array_equal(fc1.trans, fc2.trans)
    p1, p2 = fc1.predict(2.0), fc2.predict(2.0)
    for key in p1:
        np.testing.assert_array_equal(p1[key], p2[key])


def test_forecaster_validation_and_empty_predict():
    with pytest.raises(ValueError, match="n_clusters"):
        adapt.HarvestForecaster(n_clusters=0)
    fc = adapt.HarvestForecaster()
    pred = fc.predict()
    assert pred["eta"].size == 0 and pred["confidence"].size == 0


def test_window_features_shapes_and_prior():
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    ev = np.stack([harv.sample_events(np.random.default_rng(s), 60, init=1)
                   for s in range(3)]).astype(np.float32)
    f = adapt.window_features(ev, t_end=40.0, slot_s=1.0, window_s=10.0,
                              n_windows=3)
    assert f.shape == (3, 3, len(adapt.FEATURES))
    assert np.all(f >= 0.0) and np.all(np.isfinite(f))
    # nothing observed yet: the all-zero patternless prior
    f0 = adapt.window_features(ev, t_end=0.0, slot_s=1.0, window_s=10.0)
    assert np.all(f0 == 0.0)
    # windows ending before the trace starts are empty too — a negative
    # slice end must not wrap around and leak future slots into features
    ev_future = np.zeros((1, 60), np.float32)
    ev_future[:, 10:] = 1.0          # all the energy arrives after t_end
    f_early = adapt.window_features(ev_future, t_end=5.0, slot_s=1.0,
                                    window_s=10.0, n_windows=3)
    assert np.all(f_early[:, :2] == 0.0)      # the two pre-trace windows
    assert f_early[0, 2, adapt.FEATURES.index("amp")] == 0.0


def test_duration_model_anticipates_regime_switch():
    """On a deterministic alternating regime the forecaster learns the stay
    duration and shifts its supply prediction toward the successor before
    the switch happens."""
    fc = adapt.HarvestForecaster(n_clusters=2)
    rich = np.array([[0.9, 0.9, 0.9, 0.5, 0.1, 0.1]], np.float32)
    lean = np.array([[0.1, 0.1, 0.1, 0.05, 0.6, 0.4]], np.float32)
    preds = []
    for t in range(40):
        feats = rich if (t // 10) % 2 == 0 else lean
        fc.observe(feats, feats[:, 0], feats[:, 2] * 0.06)
        preds.append(fc.predict(horizon=2.0))
    assert fc.n_born == 2
    # learned stay duration: exactly 10 observations
    assert fc.dur_sum[:2] / np.maximum(fc.dur_n[:2], 1) == pytest.approx(
        [10.0, 10.0])
    # mid-stay (t=24, rich regime): predict the rich supply
    assert preds[24]["supply"][0] == pytest.approx(0.9 * 0.06, rel=0.05)
    # end of stay (t=29): prediction has moved toward the lean successor
    assert preds[29]["supply"][0] < 0.5 * preds[24]["supply"][0]


# --------------------------------------------------------------------------- #
# Integration: controller compositions over run_segments (with drift axis).
# --------------------------------------------------------------------------- #


def _drift_fleet(horizon: float = 60.0):
    """A 3-device fleet sharing one bursty harvester but spanning a CHRT
    clock-drift axis."""
    n_units = 4
    prof = JobProfile(np.linspace(0.1, 0.5, n_units),
                      np.array([False, True, True, True]),
                      np.ones(n_units, bool))
    task = TaskSpec(task_id=0, period=1.0, deadline=2.0,
                    unit_time=np.full(n_units, 0.1),
                    unit_energy=np.full(n_units, 5e-3),
                    profiles=[prof] * (int(horizon) + 2))
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    devices = [
        fgrid.device_config(task, harv, 0.5, energy.Capacitor(),
                            policy="zygarde", horizon=horizon,
                            events=fgrid.sample_events(harv, horizon, s),
                            clock_drift=drift)
        for s, drift in enumerate((0.0, 0.01, -0.01))
    ]
    statics = fleet.FleetStatics(dt=0.025, horizon=horizon, slot_s=1.0)
    return fgrid.stack_configs(devices), statics


@pytest.mark.parametrize("arm", ["feedback", "forecast"])
def test_controllers_run_per_device_under_clock_drift(arm):
    cfg, statics = _drift_fleet()
    if arm == "feedback":
        adapter = adapt.OnlineAdapter(statics, cfg, window_s=15.0)
    else:
        adapter = adapt.OnlineAdapter(statics, cfg, controllers=[
            adapt.EtaController(window_s=15.0),
            adapt.ForecastController(window_s=8.0, horizon_s=10.0),
        ])
    res, _ = fleet.run_segments(cfg, statics, 12, hook=adapter.hook)
    assert len(adapter.history) == 12
    last = adapter.history[-1]
    d = cfg.n_devices
    assert last["eta_hat"].shape == (d,)
    assert last["e_opt_frac"].shape == (d,)
    assert adapter.eta_hat.shape == (d,)
    assert np.all(np.asarray(res.released) > 0)
    assert np.all(np.isfinite(np.asarray(res.correct, np.float64)))
    if arm == "forecast":
        assert last["cluster"].shape == (d,)
        assert np.all((last["confidence"] >= 0) & (last["confidence"] <= 1))
        # the tunable exit-threshold substrate was actually engaged
        assert any(h["depth"] is not None for h in adapter.history
                   if "depth" in h)


def test_controller_list_reuse_resets_state_between_adapters():
    """Constructing a second adapter over the same controller list starts
    fresh trajectories: the eta estimator and the forecaster are rebuilt by
    reset(), not carried over (an injected forecaster IS carried — that's
    the warm-start path)."""
    cfg, statics = _drift_fleet()
    controllers = [adapt.EtaController(), adapt.ForecastController()]
    adapter = adapt.OnlineAdapter(statics, cfg, controllers=controllers)
    fleet.run_segments(cfg, statics, 2, hook=adapter.hook)
    assert adapter.eta_hat is not None
    assert controllers[1].forecaster.n_obs > 0
    adapter2 = adapt.OnlineAdapter(statics, cfg, controllers=controllers)
    assert adapter2.eta_hat is None
    assert controllers[1].forecaster.n_obs == 0
    # explicit injection keeps the learned statistics across trajectories
    warm = adapt.HarvestForecaster()
    fc = adapt.ForecastController(forecaster=warm)
    adapt.OnlineAdapter(statics, cfg, controllers=[fc])
    assert fc.forecaster is warm


def test_forecast_controller_falls_back_to_feedback_before_confidence():
    """With an unconfident forecaster (first segments), the forecast
    controller's E_opt must equal the feedback controller's exactly — the
    blend degrades to the PR-4 law, so the anticipatory arm can never be
    worse during warmup."""
    cfg, statics = _drift_fleet()
    fb = adapt.OnlineAdapter(statics, cfg)
    fc = adapt.OnlineAdapter(statics, cfg, controllers=[
        adapt.EtaController(),
        adapt.ForecastController(conf_min=2.0),   # exit_thr never engages
    ])
    # run one segment each on identical inputs
    fleet.run_segments(cfg, statics, 2, hook=fb.hook)
    fleet.run_segments(cfg, statics, 2, hook=fc.hook)
    f0, c0 = fb.history[0], fc.history[0]
    # first segment: no transition statistics -> confidence 0 -> same E_opt
    assert c0["confidence"] == pytest.approx(np.zeros(cfg.n_devices))
    np.testing.assert_allclose(c0["e_opt_frac"], f0["e_opt_frac"], rtol=1e-9)
    np.testing.assert_allclose(c0["supply_hat"], f0["supply_hat"], rtol=1e-9)


# --------------------------------------------------------------------------- #
# The nonstationary regression: forecast beats feedback.
# --------------------------------------------------------------------------- #


def test_forecast_beats_feedback_on_nonstationary_trace(online_adapt_demo):
    """Pins the example's seeded win: once the solar -> RF -> occluded
    cycle has been observed, anticipating the next regime (banking the
    reserve and shrinking the mandatory prefix *before* the blackout)
    beats reacting to the current one.  Fully deterministic."""
    _, out = online_adapt_demo
    assert out["forecast"]["score"] >= out["online"]["score"] + 0.02
    # the anticipation mechanism actually engaged: confident clusters and
    # a moving mandatory/optional boundary
    conf = np.array([h["confidence"][0] for h in out["forecast_history"]])
    depth = np.array([h["depth"][0] for h in out["forecast_history"]])
    assert conf.max() > 0.8
    assert depth.max() > 0.3 and depth.min() < 0.05
    # fewer blackout misses than the reactive arm
    assert out["forecast"]["misses"] < out["online"]["misses"]
