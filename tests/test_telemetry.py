"""Telemetry contract tests: numerics-neutrality, tier equivalence
against the in-scan reference fold, ring overflow, and the JSONL export
round-trip.

The load-bearing claims, in order:

1. Enabling telemetry cannot change the simulation — the ``FleetResult``
   (and the serve outcome) are asserted *bit-exact* against the
   uninstrumented run, at both collection tiers.
2. The fast collection paths (:mod:`repro.telemetry.trace` — telescoped
   counters, packed per-step descriptors, sparse host event fold) are
   equivalent to the simplest possible implementation: folding
   :func:`repro.telemetry.state.record_step` at every step inside the
   scan (``_scan_steps_tel_reference``).  Integer fields must match
   exactly; float accumulators to summation-order tolerance.
3. Ring overflow keeps the *latest* events and the monotone head keeps
   the true total.
4. What the :class:`repro.telemetry.TelemetryLogger` writes, ``read_jsonl``
   reads back and ``repro.telemetry.report`` renders without error.
"""
import io
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import fleet
from repro.core import energy
from repro.fleet.simulator import (
    _scan_steps,
    _scan_steps_tel,
    _scan_steps_tel_reference,
    init_fleet,
)
from repro.telemetry import (
    EVENT_KINDS,
    TelemetryConfig,
    TelemetryLogger,
    init_fleet_telemetry,
    read_jsonl,
    summarize,
)
from repro.telemetry import report as tel_report

from _workloads import make_task

#: telemetry fields that must be integer-exact vs the reference fold
INT_FIELDS = ("c_release", "c_miss", "c_sched", "c_retired", "c_power_fail",
              "c_reboot", "c_knob", "exit_hist", "occ_sum", "occ_max",
              "n_steps", "ring_kind", "ring_head")
#: the fields the default "counters" tier collects
COUNTER_FIELDS = ("c_release", "c_miss", "c_sched", "c_reboot",
                  "c_power_fail", "occ_sum", "occ_max", "energy_sum",
                  "energy_min", "n_steps")


def _grid(horizon=6.0, seeds=(0, 1)):
    """A small intermittent-power grid (16 devices by default) that
    actually produces misses, power failures, and reboots."""
    return fleet.SweepGrid(
        task=make_task(n_jobs=10),
        policies=("zygarde", "edf"),
        etas=(0.5, 0.9),
        harvesters=(energy.Harvester("rf", 0.93, 0.93, 0.07),),
        capacitors=(energy.Capacitor(capacitance_f=0.01),
                    energy.Capacitor(capacitance_f=0.05)),
        seeds=seeds,
        horizon=horizon,
    )


@pytest.fixture(scope="module")
def built():
    cfg, statics, _ = fleet.build(_grid())
    return cfg, statics


def _assert_tel_close(tel, ref, fields):
    for f in fields:
        a = np.asarray(getattr(tel, f))
        b = np.asarray(getattr(ref, f))
        if f in INT_FIELDS:
            np.testing.assert_array_equal(a, b, err_msg=f)
        elif f in ("slack_sum", "energy_sum", "ring_val"):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4,
                                       err_msg=f)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=f)


@pytest.mark.parametrize("level", ["counters", "full"])
def test_fleet_result_bit_exact(built, level):
    """Enabling telemetry changes nothing: every FleetResult leaf equal."""
    cfg, statics = built
    plain = fleet.simulate_fleet(cfg, statics)
    res, tel = fleet.simulate_fleet(
        cfg, statics, telemetry=TelemetryConfig(ring_size=32, level=level))
    for f, a, b in zip(plain._fields, plain, res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    assert int(np.asarray(tel.n_steps)[0]) == statics.n_steps


@pytest.mark.parametrize("level", ["counters", "full"])
@pytest.mark.parametrize("n_segments", [1, 3])
def test_trace_matches_reference(built, level, n_segments):
    """The fast collection path == record_step folded at every step."""
    cfg, statics = built
    tcfg = TelemetryConfig(ring_size=64, level=level)
    tel = init_fleet_telemetry(tcfg, cfg)
    ref = init_fleet_telemetry(tcfg, cfg)
    st = sr = init_fleet(cfg, statics)
    sizes = [len(c) for c in
             np.array_split(np.arange(statics.n_steps), n_segments)]
    i0 = 0
    for n in sizes:
        st, tel = _scan_steps_tel(cfg, st, tel, jnp.int32(i0), statics, n,
                                  False, tcfg)
        sr, ref = _scan_steps_tel_reference(cfg, sr, ref, jnp.int32(i0),
                                            statics, n, False, tcfg)
        i0 += n
    # the instrumented carry is bit-exact vs the uninstrumented scan
    plain = _scan_steps(cfg, init_fleet(cfg, statics), jnp.int32(0),
                        statics, statics.n_steps, False)
    for f, a, b in zip(st._fields, st, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    if level == "full":
        _assert_tel_close(tel, ref, ref._fields)
    else:
        _assert_tel_close(tel, ref, COUNTER_FIELDS)
        # everything the counters tier doesn't collect stays at init
        init = init_fleet_telemetry(tcfg, cfg)
        for f in ("c_retired", "slack_sum", "slack_min", "exit_hist",
                  "ring_head", "ring_kind"):
            np.testing.assert_array_equal(
                np.asarray(getattr(tel, f)), np.asarray(getattr(init, f)),
                err_msg=f)


def test_ring_overflow_keeps_latest(built):
    """A tiny ring overflows: the head counts every push, the buffer holds
    the newest events — matching the reference fold slot for slot."""
    cfg, statics = built
    tcfg = TelemetryConfig(ring_size=4, level="full")
    tel = init_fleet_telemetry(tcfg, cfg)
    ref = init_fleet_telemetry(tcfg, cfg)
    st = init_fleet(cfg, statics)
    _, tel = _scan_steps_tel(cfg, st, tel, jnp.int32(0), statics,
                             statics.n_steps, False, tcfg)
    _, ref = _scan_steps_tel_reference(cfg, st, ref, jnp.int32(0), statics,
                                       statics.n_steps, False, tcfg)
    heads = np.asarray(tel.ring_head)
    assert heads.max() > 4, "workload produced too few events to overflow"
    _assert_tel_close(tel, ref, ("ring_head", "ring_kind", "ring_t",
                                 "ring_val"))


@pytest.mark.parametrize("level", ["counters", "full"])
def test_serve_bit_exact(trained_cnn, mnist_tiny, level):
    """FleetServeEngine: telemetry on/off produces identical serve output."""
    from repro.core.agile import AgileCNN
    from repro.serve import FleetServeEngine, Request, ServeConfig

    ds = mnist_tiny
    reqs = [Request(ds.x_test[i], int(ds.y_test[i]), release=i * 2.0)
            for i in range(4)]
    scfg = ServeConfig(policy="zygarde", period=2.0, deadline=1.5,
                       horizon=10.0, adapt=False, start_charged=True,
                       sim_dt=0.05)
    harv = energy.Harvester("battery", 1.0, 0.0, 1.0)

    def engine():
        model = AgileCNN(trained_cnn.cfg, trained_cnn.params,
                         list(trained_cnn.bank))
        return FleetServeEngine([model], harv, eta=1.0, config=scfg,
                                feature_batch=1)

    base = engine().run([reqs], n_devices=2)
    out = engine().run([reqs], n_devices=2,
                       telemetry=TelemetryConfig(ring_size=16, level=level))
    for f in ("units", "pred", "correct", "margin", "exit_unit", "sched"):
        np.testing.assert_array_equal(getattr(base, f), getattr(out, f),
                                      err_msg=f)
    for f, a, b in zip(base.fleet._fields, base.fleet, out.fleet):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    assert base.telemetry is None
    tel = out.telemetry
    assert tel is not None
    assert (np.asarray(tel.n_steps) == np.asarray(tel.n_steps)[0]).all()
    assert np.asarray(tel.c_release).sum() > 0
    if level == "full":
        assert np.asarray(tel.c_retired).sum() > 0


def test_jsonl_roundtrip_and_report(built, tmp_path):
    """Segmented run -> JSONL stream -> read_jsonl -> report.render."""
    cfg, statics = built
    tcfg = TelemetryConfig(ring_size=32, level="full")
    path = tmp_path / "telemetry.jsonl"
    segments = []

    with TelemetryLogger(path, label="unit_test") as log:
        log.meta(statics, tcfg, n_devices=cfg.n_devices)

        def hook(seg, t_end, c, carry, telemetry=None):
            segments.append(telemetry)
            log.segment(seg, telemetry)
            # rewrite a tunable knob so knob-update telemetry fires
            return c._replace(eta=c.eta * 0.99) if seg == 0 else None

        _, _, tel = fleet.run_segments(cfg, statics, n_segments=3,
                                       hook=hook, telemetry=tcfg)
        n_events = log.drain_rings(tel)

    assert len(segments) == 3 and all(s is not None for s in segments)
    assert n_events > 0
    # the hook's knob rewrite was stamped into the telemetry
    assert np.asarray(tel.c_knob).sum() > 0

    records = read_jsonl(path)
    kinds = {r["event"] for r in records}
    assert {"meta", "summary"} <= kinds
    events = [r for r in records if r["event"] in EVENT_KINDS]
    assert events, "no ring events in the stream"
    assert any(r["event"] == "knob_update" for r in events)
    assert all({"device", "t", "val"} <= r.keys() for r in events)
    # every line is valid standalone JSON (streamable)
    for line in path.read_text().splitlines():
        json.loads(line)

    out = io.StringIO()
    tel_report.render(path, out=out)
    text = out.getvalue()
    assert "unit_test" in text and "segment" in text.lower()

    # the cumulative summary agrees with the last segment summary
    final = summarize(tel, statics.horizon)
    assert final.n_devices == cfg.n_devices
    np.testing.assert_allclose(final.miss_rate.mean(),
                               segments[-1].miss_rate.mean(), rtol=1e-6)


def test_summary_feeds_adapter_hook(built):
    """run_segments passes a TelemetrySummary to telemetry-aware hooks
    (the OnlineAdapter integration surface)."""
    cfg, statics = built
    seen = []

    def hook(seg, t_end, c, carry, telemetry=None):
        seen.append(telemetry)
        return None

    fleet.run_segments(cfg, statics, n_segments=2, hook=hook,
                       telemetry=TelemetryConfig(ring_size=8))
    assert len(seen) == 2
    for s in seen:
        assert s is not None
        assert s.miss_rate.shape == (cfg.n_devices,)
    # without telemetry the same hook still runs, receiving None
    seen.clear()
    fleet.run_segments(cfg, statics, n_segments=2, hook=hook)
    assert seen == [None, None]
