"""End-to-end serving engine: live agile execution under the Zygarde
scheduler + energy simulation (paper §9-style runs, scaled down)."""
import numpy as np
import pytest

from repro.core import energy
from repro.serve import Request, ServeConfig, ServeEngine


def make_requests(ds, n, period=1.0):
    return [
        Request(ds.x_test[i], int(ds.y_test[i]), release=i * period)
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def harvester():
    return energy.Harvester("solar", 0.95, 0.95, 0.2)


def test_persistent_serving_schedules_all(agile_model, mnist_tiny):
    n = 12
    eng = ServeEngine(
        [agile_model], energy.Harvester("battery", 1.0, 0.0, 1.0), eta=1.0,
        config=ServeConfig(policy="zygarde", period=1.0, deadline=2.0,
                           horizon=n * 1.0 + 5, adapt=False),
    )
    res = eng.run([make_requests(mnist_tiny, n)])
    assert res.released == n
    assert res.scheduled == n
    assert res.correct > 0


def test_intermittent_serving_degrades_gracefully(
    agile_model, mnist_tiny, harvester
):
    n = 12
    eng = ServeEngine(
        [agile_model], harvester, eta=0.7,
        cap=energy.Capacitor(capacitance_f=0.02),
        config=ServeConfig(policy="zygarde", period=1.0, deadline=2.0,
                           horizon=n * 1.0 + 5, adapt=False, seed=2,
                           unit_energy=np.full(agile_model.n_units, 2e-2)),
    )
    res = eng.run([make_requests(mnist_tiny, n)])
    assert 0 < res.scheduled <= n
    assert res.correct <= res.scheduled


def test_zygarde_vs_edf_on_overload(agile_model, mnist_tiny):
    """Multi-task overload (paper §9.2): the imprecise policy completes at
    least as many jobs as full-execution EDF."""
    n = 10
    results = {}
    for policy in ("edf", "zygarde"):
        eng = ServeEngine(
            [agile_model, agile_model],
            energy.Harvester("battery", 1.0, 0.0, 1.0), eta=1.0,
            config=ServeConfig(
                policy=policy, period=1.0, deadline=1.5, horizon=n + 4,
                adapt=False,
                unit_time=np.full(agile_model.n_units, 0.3),
            ),
        )
        res = eng.run([
            make_requests(mnist_tiny, n),
            make_requests(mnist_tiny, n),
        ])
        results[policy] = res
    assert results["zygarde"].scheduled >= results["edf"].scheduled
    assert results["zygarde"].scheduled > 0


def test_lazy_profile_runs_model_on_demand(agile_model, mnist_tiny):
    from repro.serve.engine import DynamicJobProfile

    p = DynamicJobProfile(agile_model, mnist_tiny.x_test[0],
                          int(mnist_tiny.y_test[0]), adapt=False)
    assert p._exec_units == 0
    _ = p.passes[0]  # touching unit 0 executes exactly one unit
    assert p._exec_units == 1
    m = p.mandatory_units()
    assert p._exec_units >= m
