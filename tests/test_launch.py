"""Launcher drivers (train/serve CLIs) — reduced-scale end-to-end runs."""
import subprocess
import sys

from _subproc import sub_env


def run_module(args, timeout=600):
    out = subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True, text=True, timeout=timeout, env=sub_env(),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_driver_reduced():
    out = run_module([
        "repro.launch.train", "--arch", "qwen1.5-0.5b", "--reduced",
        "--steps", "6", "--batch", "4", "--seq", "32", "--log-every", "5",
    ])
    assert "step     0" in out
    assert "done: 6 steps" in out
    # loss is finite and printed
    losses = [float(l.split("loss")[1].split()[0])
              for l in out.splitlines() if "loss" in l]
    assert losses and all(l == l for l in losses)  # not NaN


def test_train_driver_checkpoint(tmp_path):
    out = run_module([
        "repro.launch.train", "--arch", "xlstm-125m", "--reduced",
        "--steps", "4", "--batch", "2", "--seq", "16",
        "--ckpt-every", "4", "--ckpt-path", str(tmp_path / "ck"),
    ])
    assert "checkpoint ->" in out
    assert (tmp_path / "ck_4.npz").exists()


def test_dryrun_cli_single_combo(tmp_path):
    """The dryrun CLI end to end on the smallest (arch, shape)."""
    out_file = tmp_path / "rec.json"
    run_module([
        "repro.launch.dryrun", "--arch", "xlstm-125m",
        "--shape", "decode_32k", "--out", str(out_file),
    ], timeout=900)
    import json

    rec = json.loads(out_file.read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
