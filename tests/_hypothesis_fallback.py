"""Graceful degradation when ``hypothesis`` is not installed.

``pyproject.toml`` lists hypothesis under the ``test`` extra, but the suite
must still *collect* in bare environments (CI images, accelerator containers
without the extra).  Importing this module either re-exports the real
``given``/``settings``/``strategies`` or — mirroring a per-test
``pytest.importorskip`` — substitutes decorators that mark each property test
as skipped while letting every plain test in the module run.

Usage in a test module::

    from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed (pip install "
                                    "'zygarde-repro[test]')")

    def given(*_args, **_kwargs):
        def decorate(fn):
            return _SKIP(fn)

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: every strategy constructor
        returns ``None`` — the skipped tests never draw from them."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _Strategies()
