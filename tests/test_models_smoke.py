"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates its REDUCED variant (<= 4 layers,
d_model <= 512, <= 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs; decode paths are checked
for prefill/decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.train import make_train_step
from repro.train.optimizer import adamw_init


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32
        )
    }
    if cfg.is_encoder_decoder or cfg.n_frontend_tokens:
        nf = (
            cfg.n_enc_tokens if cfg.is_encoder_decoder
            else cfg.n_frontend_tokens
        )
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, nf, cfg.d_model)), dtype=jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nans(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = T.forward(cfg, params, batch, remat=False)
    S_out = S + (0 if cfg.is_encoder_decoder else cfg.n_frontend_tokens)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))
    if cfg.n_experts:
        assert float(aux) > 0.0  # router balance loss is live


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    batch = make_batch(cfg, 2, 16)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["total"]))
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params,
    )
    assert max(jax.tree.leaves(moved)) > 0.0
    # and no leaf went NaN
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_loss_decreases_over_steps(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    batch = make_batch(cfg, 2, 16)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    first = None
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first  # overfits a fixed batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch, key):
    """decode_step after prefill reproduces the full-sequence forward."""
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    B, S = 2, 24
    batch = make_batch(cfg, B, S)

    logits_full, _ = T.forward(cfg, params, batch, remat=False)
    # prefill on the first S-1 tokens, then one decode step with token S-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : S - 1]
    lg_pre, state = T.prefill(
        cfg, params, pre_batch, cache_len=S + cfg.n_frontend_tokens
    )
    np.testing.assert_allclose(
        np.asarray(lg_pre),
        np.asarray(logits_full[:, -2]),
        rtol=2e-2, atol=2e-2,
    )
    lg_dec, state = T.decode_step(cfg, params, state, batch["tokens"][:, -1])
    np.testing.assert_allclose(
        np.asarray(lg_dec),
        np.asarray(logits_full[:, -1]),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ["glm4-9b", "recurrentgemma-9b"])
def test_sliding_window_decode_matches_windowed_forward(arch, key):
    """Ring-buffer decode with a window override matches the windowed
    full-sequence forward (the long_500k serving path)."""
    cfg = get_config(arch).reduced()
    window = 8
    params = T.init_params(cfg, key)
    B, S = 2, 24
    batch = make_batch(cfg, B, S)
    logits_full, _ = T.forward(cfg, params, batch, remat=False, window=window)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : S - 1]
    _, state = T.prefill(cfg, params, pre, window=window)
    lg, _ = T.decode_step(
        cfg, params, state, batch["tokens"][:, -1], window=window
    )
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, -1]), rtol=2e-2, atol=2e-2
    )


def test_unit_forward_covers_all_layers(key):
    cfg = get_config("stablelm-3b").reduced()
    params = T.init_params(cfg, key)
    batch = make_batch(cfg, 2, 16)
    x, enc = T.embed_inputs(cfg, params, batch)
    ref, _ = T.forward(cfg, params, batch, remat=False)
    for u in range(cfg.n_units):
        x, pooled = T.unit_forward(cfg, params, x, u, enc_out=enc)
        assert pooled.shape == (2, cfg.d_model)
    out = T.readout(cfg, params, x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2
    )


def test_vocab_padding_roundtrip(key):
    import dataclasses

    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b").reduced(), vocab=300, vocab_pad=128
    )
    assert cfg.padded_vocab == 384
    params = T.init_params(cfg, key)
    assert params["lm_head"].shape == (cfg.d_model, 384)
    batch = make_batch(cfg, 2, 8)
    logits, _ = T.forward(cfg, params, batch, remat=False)
    assert logits.shape[-1] == 384
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["dbrx-132b", "recurrentgemma-9b",
                                  "xlstm-125m", "glm4-9b",
                                  "seamless-m4t-medium"])
def test_unrolled_decode_matches_scan(arch, key):
    """The production serving path (unroll=True, per-layer cache buffers)
    is numerically identical to the scanned path (§Perf P3-H3)."""
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    s1 = T.init_decode_state(cfg, B, S)
    s2 = T.init_decode_state(cfg, B, S, stacked=False)
    if cfg.is_encoder_decoder:
        enc = jnp.zeros((B, cfg.n_enc_tokens, cfg.d_model),
                        jnp.float32)
        s1["enc_out"] = s2["enc_out"] = enc.astype(s1["enc_out"].dtype)
    toks = batch["tokens"]
    for t in range(5):
        l1, s1 = T.decode_step(cfg, params, s1, toks[:, t])
        l2, s2 = T.decode_step(cfg, params, s2, toks[:, t], unroll=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_microbatched_train_step_matches_fused(key):
    """Gradient accumulation (§Perf P1-H3) reproduces the fused step."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    batch = make_batch(cfg, 4, 16)
    p1, _, m1 = jax.jit(make_train_step(cfg, microbatches=1))(
        params, opt, batch
    )
    p2, _, m2 = jax.jit(make_train_step(cfg, microbatches=2))(
        params, opt, batch
    )
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_remat_grouping_matches_ungrouped(key):
    """remat_every grouping (§Perf P1-H2) does not change the forward."""
    import dataclasses

    cfg = dataclasses.replace(
        get_config("glm4-9b").reduced(), n_layers=4, remat_every=2
    )
    params = T.init_params(cfg, key)
    batch = make_batch(cfg, 2, 16)
    l_remat, _ = T.forward(cfg, params, batch, remat=True)
    l_plain, _ = T.forward(cfg, params, batch, remat=False)
    np.testing.assert_allclose(
        np.asarray(l_remat), np.asarray(l_plain), rtol=1e-5, atol=1e-5
    )
