"""Shared workload builders + tolerance calibration for the parity suites.

One home for the task/profile generators and the calibrated
discretization bounds that ``tests/test_fleet.py`` and
``tests/test_parity.py`` both use, so the tolerance story lives in exactly
one place:

* **bit-exact**: fleet vs the step-core scalar frontend
  (:func:`repro.core.scheduler.simulate_stepped`) — both run
  :mod:`repro.core.step` on the same fixed clock, so equality is exact and
  no bound applies;
* **calibrated** (:func:`per_task_bound`): fleet/stepped vs the
  *event-driven* :func:`repro.core.scheduler.simulate` — the fixed
  timestep quantizes execution and drains fragment energy continuously, so
  energy-starved boundary jobs can land on the other side of a deadline.
  Empirically (48 seeded runs per mode) the per-task deviation stays
  <= 1 job under persistent power and <= 3 jobs (<= 25% of a task's
  releases) under intermittent power; the bounds add headroom on top while
  still failing loudly on any systematic task-row mix-up (which mis-counts
  whole streams, not boundary jobs).

Workload note: unit times are quantized to multiples of ``4 * DT`` so one
fleet timestep is exactly one fragment of every task — the regime the
simulator documents as its fidelity envelope.
"""
from __future__ import annotations

import numpy as np

from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec

DT = 0.005          # fleet timestep; unit times are multiples of 4*DT
HORIZON = 12.0
TASK_SET_SEEDS = {1: 11, 2: 22, 4: 44}

# (harvester, eta) per persistence mode: `persistent` takes the Eq. 6 zeta
# fast path (eta = 1, p_stay_on = 1), `intermittent` the eta-gated Eq. 7
MODES = {
    "persistent": (energy.Harvester("battery", 1.0, 0.0, 10.0), 1.0),
    "intermittent": (energy.Harvester("rf", 0.93, 0.93, 0.07), 0.7),
}


def profile(n_units=4, exit_at=None, correct_from=0) -> JobProfile:
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    if exit_at is not None:
        passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    return JobProfile(margins, passes, correct)


def make_task(n_jobs=20, period=1.0, deadline=2.0, unit_t=0.1, unit_e=1e-3,
              n_units=4, exit_at=1) -> TaskSpec:
    return TaskSpec(
        task_id=0,
        period=period,
        deadline=deadline,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, unit_e),
        profiles=[profile(n_units, exit_at) for _ in range(n_jobs)],
    )


def random_task_set(seed: int, k: int) -> list[TaskSpec]:
    """K tasks with distinct periods/deadlines/depths; full-execution
    utilization of the whole set ~0.6 so even EDF (no early exit) is loaded
    but not hopeless."""
    rng = np.random.default_rng(seed)
    tasks = []
    for tid in range(k):
        n_units = int(rng.integers(3, 6))
        period = float(rng.choice([0.8, 1.0, 1.2, 1.6]))
        deadline = period * float(rng.uniform(1.5, 2.5))
        grains = max(1, round(0.6 * period / (k * n_units) / (4 * DT)))
        unit_t = grains * 4 * DT
        unit_e = float(rng.uniform(4e-3, 1e-2))
        exit_at = int(rng.integers(0, n_units - 1))
        correct_from = int(rng.integers(0, n_units))
        n_jobs = int(np.ceil(HORIZON / period)) + 1
        profiles = []
        for _ in range(n_jobs):
            margins = np.sort(rng.uniform(0.05, 0.6, n_units))
            passes = np.zeros(n_units, bool)
            passes[exit_at:] = True
            correct = np.zeros(n_units, bool)
            correct[correct_from:] = True
            profiles.append(JobProfile(margins, passes, correct))
        tasks.append(TaskSpec(
            task_id=tid, period=period, deadline=deadline,
            unit_time=np.full(n_units, unit_t),
            unit_energy=np.full(n_units, unit_e),
            profiles=profiles,
        ))
    return tasks


def per_task_bound(released, mode: str) -> np.ndarray:
    """Calibrated event-driven-vs-discretized bound (see module docstring).
    Applies ONLY to comparisons against the event-driven ``simulate()``;
    fleet vs ``simulate_stepped`` is asserted exactly."""
    rel = np.maximum(np.asarray(released, np.float64), 1.0)
    if mode == "persistent":
        return np.maximum(2.0, np.ceil(0.1 * rel))
    return np.maximum(3.0, np.ceil(0.35 * rel))
