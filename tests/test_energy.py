"""Energy model (paper §3, §5.3): h(N), KW distance, eta-factor, harvesters,
capacitor, schedulability — unit + hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import energy


# --------------------------------------------------------------------------- #
# h(N) — conditional energy events (Eq. 1).
# --------------------------------------------------------------------------- #


def test_h_curve_alternating():
    """A strictly alternating trace: after 1 event the next never occurs."""
    trace = np.tile([1, 0], 500)
    assert energy.conditional_energy_event(trace, 1) == pytest.approx(0.0)
    assert energy.conditional_energy_event(trace, -1) == pytest.approx(1.0)
    # runs of length 2 never happen
    assert np.isnan(energy.conditional_energy_event(trace, 2))


def test_h_curve_constant_on():
    trace = np.ones(1000, dtype=np.int8)
    for n in (1, 5, 19):
        assert energy.conditional_energy_event(trace, n) == pytest.approx(1.0)
        assert np.isnan(energy.conditional_energy_event(trace, -n))


def test_h_curve_iid():
    rng = np.random.default_rng(0)
    trace = (rng.random(200_000) < 0.5).astype(np.int8)
    h = energy.conditional_energy_event
    assert h(trace, 1) == pytest.approx(0.5, abs=0.02)
    assert h(trace, -3) == pytest.approx(0.5, abs=0.02)


# --------------------------------------------------------------------------- #
# eta-factor (Eqs. 2-3).
# --------------------------------------------------------------------------- #


def test_eta_persistent_is_one():
    h = energy.Harvester("p", 1.0, 0.0, 1.0)
    tr = h.sample_events(np.random.default_rng(0), 5000, init=1)
    assert energy.eta_factor(tr) == pytest.approx(1.0, abs=1e-6)


def test_eta_random_is_near_zero():
    h = energy.Harvester("r", 0.5, 0.5, 1.0)
    tr = h.sample_events(np.random.default_rng(0), 50_000)
    assert energy.eta_factor(tr) < 0.1


def test_eta_monotone_in_burstiness():
    """More bursty (higher stay-probability) => higher eta (paper Fig. 25)."""
    etas = []
    for p in (0.55, 0.7, 0.85, 0.95, 0.99):
        h = energy.Harvester("h", p, p, 1.0)
        tr = h.sample_events(np.random.default_rng(3), 60_000)
        etas.append(energy.eta_factor(tr))
    assert all(b > a - 0.02 for a, b in zip(etas, etas[1:]))
    assert etas[-1] > etas[0] + 0.3


@given(st.floats(0.05, 0.95))
@settings(max_examples=15, deadline=None)
def test_eta_bounds(p_stay):
    h = energy.Harvester("h", p_stay, p_stay, 1.0)
    tr = h.sample_events(np.random.default_rng(1), 5000)
    eta = energy.eta_factor(tr)
    assert 0.0 <= eta <= 1.0


def test_calibrate_harvester_hits_target():
    for target in (0.38, 0.51, 0.71):
        h = energy.calibrate_harvester(target, 0.6)
        tr = h.sample_events(np.random.default_rng(42), 40_000)
        assert energy.eta_factor(tr) == pytest.approx(target, abs=0.08)


def test_kw_distance_properties():
    a = energy.ideal_h_curve()
    r = energy.random_h_curve()
    assert energy.kw_distance(a, a) == pytest.approx(0.0)
    assert energy.kw_distance(a, r) > 0
    assert energy.kw_distance(a, r) == pytest.approx(
        energy.kw_distance(r, a)
    )


# --------------------------------------------------------------------------- #
# Capacitor.
# --------------------------------------------------------------------------- #


def test_capacitor_capacity_50mF():
    cap = energy.Capacitor()  # paper default: 50 mF, 1.8-3.3 V
    expected = 0.5 * 0.05 * (3.3 ** 2 - 1.8 ** 2)
    assert cap.capacity_j == pytest.approx(expected)


@given(
    st.lists(st.tuples(st.booleans(), st.floats(0, 0.2)), min_size=1,
             max_size=60)
)
@settings(max_examples=50, deadline=None)
def test_capacitor_invariants(ops):
    cap = energy.Capacitor(capacitance_f=0.01)
    for is_charge, amount in ops:
        if is_charge:
            stored = cap.charge(amount)
            assert 0.0 <= stored <= amount + 1e-12
        else:
            ok = cap.discharge(amount)
            if not ok:
                assert cap.energy_j < amount
        assert -1e-12 <= cap.energy_j <= cap.capacity_j + 1e-12


def test_optimal_capacitance_formula():
    # C = sqrt(2 P dT / V^2), paper §8.6
    c = energy.optimal_capacitance(0.5, 2.0, v=3.3)
    assert c == pytest.approx(np.sqrt(2 * 0.5 * 2.0 / 3.3 ** 2))


# --------------------------------------------------------------------------- #
# Schedulability (paper §5.3).
# --------------------------------------------------------------------------- #


def test_expected_outage_geometric():
    assert energy.expected_outage_slots(0.5) == pytest.approx(1.0)
    assert energy.expected_outage_slots(0.9) == pytest.approx(9.0)
    assert energy.expected_outage_slots(0.0) == pytest.approx(0.0)


def test_min_energy_task_period():
    # T_E >= (eta/(1-eta)) / (1 - U)
    t = energy.min_energy_task_period(0.5, 0.5)
    assert t == pytest.approx(2.0)
    assert energy.min_energy_task_period(0.5, 1.0) == float("inf")


@given(st.floats(0.0, 0.95), st.floats(0.01, 0.99), st.floats(0.1, 100.0))
@settings(max_examples=60, deadline=None)
def test_schedulability_consistent(eta, util, period):
    ok = energy.is_schedulable([util], eta, period)
    # schedulable iff the N+1-task utilisation test holds
    expected = util + energy.expected_outage_slots(eta) / period <= 1.0
    assert ok == expected
