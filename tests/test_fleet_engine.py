"""Scalar <-> fleet live-serving parity (the fleet_engine harness).

The vectorized live path (:class:`repro.serve.fleet_engine.FleetServeEngine`)
claims *bit-exactness* against the event-driven scalar
:class:`repro.serve.ServeEngine` on workloads where the two clocks
coincide: persistent power, charged start, unit times commensurate with
the fixed step.  These tests pin that contract — same units executed,
same exit units, same predictions, same margins (bitwise), same
scheduled/miss sets — across policies, adaptation on/off, device counts
and segmented scans, plus the row-classifier's bit-equality with the
scalar k-means/Pallas path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import energy, kmeans as km
from repro.serve import FleetServeEngine, Request, ServeConfig, ServeEngine


def _persistent():
    return energy.Harvester("battery", 1.0, 0.0, 1.0)


def _fresh_model(trained_cnn, threshold=None):
    """A private AgileCNN (adaptation mutates ``bank`` in place); an
    optional uniform threshold override forces (or forbids) early exit."""
    from repro.core.agile import AgileCNN

    bank = [uc if threshold is None
            else uc._replace(threshold=jnp.float32(threshold))
            for uc in trained_cnn.bank]
    return AgileCNN(trained_cnn.cfg, trained_cnn.params, bank)


def _requests(ds, n, period):
    return [Request(ds.x_test[i], int(ds.y_test[i]), release=i * period)
            for i in range(n)]


def _cfg(policy, n, adapt, period=2.0, deadline=1.5):
    """The clock-commensurate parity recipe: dt=0.05 divides the 0.2s
    units, releases and deadlines; charged persistent power removes the
    energy gate's dependence on harvest-sample timing."""
    return ServeConfig(policy=policy, period=period, deadline=deadline,
                       horizon=n * period + 2.0, adapt=adapt,
                       start_charged=True, sim_dt=0.05)


def _scalar_run(trained_cnn, cfg, reqs, threshold):
    eng = ServeEngine([_fresh_model(trained_cnn, threshold)], _persistent(),
                      eta=1.0, config=cfg)
    res = eng.run([reqs])
    jobs = eng.jobs_
    units = np.array([j.unit for j in jobs])
    sched = np.array([0 <= j.mandatory_done_time <= j.deadline
                      for j in jobs])
    profs = eng.profiles_[0]
    pred = np.array([p._preds[u - 1] if u > 0 else -1
                     for p, u in zip(profs, units)])
    margin = np.array([p._margins[u - 1] if u > 0 else 0.0
                       for p, u in zip(profs, units)], np.float32)
    return res, units, sched, pred, margin


def _fleet_run(trained_cnn, cfg, reqs, threshold, n_devices=1, **kw):
    eng = FleetServeEngine([_fresh_model(trained_cnn, threshold)],
                           _persistent(), eta=1.0, config=cfg,
                           feature_batch=1)
    return eng, eng.run([reqs], n_devices=n_devices, **kw)


def test_row_classifier_matches_kmeans_classify(trained_cnn, mnist_tiny):
    """classify_unit (plain-jnp row math on the padded stacked bank) is
    bitwise the scalar path: km.classify -> Pallas l1_topk2 (interpret)."""
    from repro.serve.fleet_engine import ServeTables, classify_unit

    model = _fresh_model(trained_cnn)
    eng = FleetServeEngine([model], _persistent(), eta=1.0,
                           config=_cfg("zygarde", 4, False))
    _, _, tables, _, _ = eng.build([_requests(mnist_tiny, 4, 2.0)],
                                   n_devices=1)
    tables = ServeTables(*(jax.tree.map(np.asarray, tables)))
    feats = model.unit_features([r.x for r in _requests(mnist_tiny, 4, 2.0)])
    for u, uc in enumerate(model.bank):
        pred_s, _, _, idx_s, margin_s = km.classify(uc, jnp.asarray(feats[u]))
        for j in range(4):
            m, ci, p = classify_unit(eng.bank0, tables, jnp.int32(0),
                                     jnp.int32(u), jnp.int32(j))
            assert int(ci) == int(idx_s[j])
            assert int(p) == int(pred_s[j])
            assert np.float32(m) == np.float32(margin_s[j])


@pytest.mark.parametrize("policy", ["zygarde", "edf"])
@pytest.mark.parametrize("adapt", [False, True])
def test_live_parity_scalar_vs_fleet(trained_cnn, mnist_tiny, policy, adapt):
    """One device, live fleet == scalar engine bit-for-bit: units, exits,
    schedule, predictions and margins.  ``adapt=True`` lowers the bank
    thresholds so every job exits early and adapts the centroids — the
    hardest case (classification at step t depends on every earlier
    adaptation); under EDF adaptation still fires at the first bank pass
    (the q_apass latch) even though EDF never exits early."""
    n = 6
    thr = 0.02 if adapt else None
    cfg = _cfg(policy, n, adapt)
    reqs = _requests(mnist_tiny, n, cfg.period)
    res, units, sched, pred, margin = _scalar_run(trained_cnn, cfg, reqs,
                                                  thr)
    _, fres = _fleet_run(trained_cnn, cfg, reqs, thr)
    assert np.array_equal(units, fres.units[0, 0, :n])
    assert np.array_equal(sched, fres.sched[0, 0, :n])
    assert np.array_equal(pred, fres.pred[0, 0, :n])
    assert np.array_equal(margin, fres.margin[0, 0, :n])
    f = fres.fleet
    assert int(res.scheduled) == int(f.scheduled[0])
    assert int(res.correct) == int(f.correct[0])
    assert int(res.deadline_misses) == int(f.deadline_misses[0])
    assert int(res.units_executed) == int(f.units_executed[0])
    if adapt:
        assert (fres.exit_unit[0, 0, :n] >= 0).all()


def test_live_parity_many_devices(trained_cnn, mnist_tiny):
    """D=4 devices on the same stream: every device reproduces the scalar
    run (per-device banks adapt independently from the same start)."""
    n = 5
    cfg = _cfg("zygarde", n, True)
    reqs = _requests(mnist_tiny, n, cfg.period)
    _, units, sched, pred, margin = _scalar_run(trained_cnn, cfg, reqs, 0.02)
    _, fres = _fleet_run(trained_cnn, cfg, reqs, 0.02, n_devices=4)
    for d in range(4):
        assert np.array_equal(units, fres.units[d, 0, :n])
        assert np.array_equal(sched, fres.sched[d, 0, :n])
        assert np.array_equal(pred, fres.pred[d, 0, :n])
        assert np.array_equal(margin, fres.margin[d, 0, :n])


def test_segmented_scan_bit_identity(trained_cnn, mnist_tiny):
    """n_segments=3 (carry materialised at boundaries) is bit-identical to
    the monolithic scan — the checkpoint/resume contract."""
    n = 5
    cfg = _cfg("zygarde", n, True)
    reqs = _requests(mnist_tiny, n, cfg.period)
    _, f1 = _fleet_run(trained_cnn, cfg, reqs, 0.02, n_segments=1)
    _, f3 = _fleet_run(trained_cnn, cfg, reqs, 0.02, n_segments=3)
    for a, b in [(f1.units, f3.units), (f1.pred, f3.pred),
                 (f1.margin, f3.margin), (f1.sched, f3.sched),
                 (f1.exit_unit, f3.exit_unit)]:
        assert np.array_equal(a, b)
    for la, lb in zip(jax.tree.leaves(f1.carry), jax.tree.leaves(f3.carry)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("threshold", [None, 10.0])
def test_miss_sets_match_under_overload(trained_cnn, mnist_tiny, threshold):
    """Deadline tighter than full execution (0.7s vs 0.8s of units): the
    scalar and fleet paths agree on exactly *which* jobs miss.  (Unit
    counts are outside the parity domain here — at expiry the event loop
    lets the in-flight unit run to its boundary while the fixed-step path
    drops the job at the deadline tick — but the miss *set* must match.)
    With the utility test disabled (threshold=10) nothing can exit early,
    so every released job must miss on both sides."""
    n = 5
    cfg = _cfg("zygarde", n, False, period=1.0, deadline=0.7)
    reqs = _requests(mnist_tiny, n, cfg.period)
    res, _, sched, _, _ = _scalar_run(trained_cnn, cfg, reqs, threshold)
    _, fres = _fleet_run(trained_cnn, cfg, reqs, threshold)
    assert np.array_equal(sched, fres.sched[0, 0, :n])
    assert int(res.deadline_misses) == int(fres.fleet.deadline_misses[0])
    if threshold == 10.0:
        assert not sched.any()
        assert int(res.deadline_misses) == n


def test_shared_bank_collaborative_adaptation(trained_cnn, mnist_tiny):
    """bank_mode='shared': one global bank absorbs every device's exits
    (collaborative semantics — documented as distinct from the sequential
    scalar updates, so aggregates, not bitwise parity)."""
    n = 4
    cfg = _cfg("zygarde", n, True)
    reqs = _requests(mnist_tiny, n, cfg.period)
    eng = FleetServeEngine([_fresh_model(trained_cnn, 0.02)], _persistent(),
                           eta=1.0, config=cfg, bank_mode="shared",
                           feature_batch=1)
    fres = eng.run([reqs], n_devices=3)
    assert int(np.asarray(fres.fleet.released).sum()) == 3 * n
    assert (fres.exit_unit[:, 0, :n] >= 0).all()
    # the single shared bank gained mass (counts only ever grow)
    assert (np.asarray(fres.carry.bank.counts).sum()
            > float(np.asarray(eng.bank0.counts).sum()))
