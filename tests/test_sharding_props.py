"""Property tests for the sharding-spec layer (hypothesis)."""
import numpy as np
from _hypothesis_fallback import given, settings, st

from jax.sharding import PartitionSpec as P

from repro.models.common import sanitize_dim

AXES = {"data": 16, "model": 16, "pod": 2}


@given(
    st.integers(1, 1 << 20),
    st.lists(st.sampled_from(["data", "model", "pod"]), max_size=3,
             unique=True),
)
@settings(max_examples=200, deadline=None)
def test_sanitize_dim_divisibility(dim, axes):
    """Whatever sanitize_dim keeps must divide the dimension."""
    kept = sanitize_dim(tuple(axes) if axes else None, dim, AXES)
    if kept is None:
        return
    names = (kept,) if isinstance(kept, str) else kept
    total = int(np.prod([AXES[a] for a in names]))
    assert dim % total == 0
    # kept axes are a prefix-respecting subset of the requested ones
    assert all(a in axes for a in names)


@given(st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_sanitize_dim_greedy_prefix(dim):
    """Axes are consumed greedily in order: if the first axis doesn't
    divide, later ones may still apply only if divisibility holds with the
    accumulated product."""
    kept = sanitize_dim(("data", "model"), dim, AXES)
    if dim % 16:
        assert kept is None or "data" not in (
            (kept,) if isinstance(kept, str) else kept
        )
    if dim % 256 == 0:
        assert kept == ("data", "model")


def test_param_specs_cover_every_leaf_rank():
    """Every spec has exactly the rank of its leaf (P padding contract)."""
    import jax

    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import param_specs
    from repro.models import transformer as T

    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    for arch in ASSIGNED_ARCHS[:4]:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda cfg=cfg: T.init_params(cfg, jax.random.key(0))
        )
        specs = param_specs(mesh, shapes)
        for leaf, spec in zip(
            jax.tree.leaves(shapes),
            jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
        ):
            assert len(spec) <= leaf.ndim
