"""Loop-aware HLO cost model vs closed-form counts (single CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze_hlo


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    M, K, N = 64, 128, 32

    def f(x, w):
        return x @ w

    txt = compile_text(
        f,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32),
    )
    cost = HloCostModel(txt).entry_cost()
    assert cost.dot_flops == pytest.approx(2 * M * K * N, rel=1e-6)


def test_scan_multiplies_body_flops():
    """The whole point of the loop-aware model: a scanned matmul counts
    trip_count x body FLOPs (XLA's own cost_analysis counts it once)."""
    M, K, T = 32, 64, 10

    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None

        y, _ = jax.lax.scan(body, x, None, length=T)
        return y

    txt = compile_text(
        f,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, K), jnp.float32),
    )
    cost = HloCostModel(txt).entry_cost()
    want = 2 * M * K * K * T
    assert cost.dot_flops == pytest.approx(want, rel=1e-6)
    # elementwise tanh adds < 5% on top of the dots here
    assert cost.flops < want * 1.1


def test_nested_scan_trip_product():
    def f(x, w):
        def inner(x, _):
            return x @ w, None

        def outer(x, _):
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = compile_text(
        f,
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    cost = HloCostModel(txt).entry_cost()
    assert cost.dot_flops == pytest.approx(2 * 8 * 16 * 16 * 15, rel=1e-6)


def test_batched_dot_general():
    B, M, K, N = 4, 16, 32, 8

    def f(x, w):
        return jnp.einsum("bmk,bkn->bmn", x, w)

    txt = compile_text(
        f,
        jax.ShapeDtypeStruct((B, M, K), jnp.float32),
        jax.ShapeDtypeStruct((B, K, N), jnp.float32),
    )
    cost = HloCostModel(txt).entry_cost()
    assert cost.dot_flops == pytest.approx(2 * B * M * K * N, rel=1e-6)


def test_bytes_scale_with_scan_trips():
    def mk(T):
        def f(x, w):
            def body(x, _):
                return jnp.tanh(x @ w), None

            y, _ = jax.lax.scan(body, x, None, length=T)
            return y
        return f

    sds = (jax.ShapeDtypeStruct((64, 64), jnp.float32),) * 2
    b1 = HloCostModel(compile_text(mk(2), *sds)).entry_cost().bytes
    b2 = HloCostModel(compile_text(mk(20), *sds)).entry_cost().bytes
    assert b2 > 5 * b1


def test_elementwise_flops_counted():
    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0

    txt = compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    cost = HloCostModel(txt).entry_cost()
    assert cost.dot_flops == 0
    assert cost.flops >= 128 * 128  # at least one pass over the data


def test_analyze_hlo_dict_keys():
    txt = compile_text(
        lambda x: x + 1.0, jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    d = analyze_hlo(txt)
    for k in ("flops", "dot_flops", "bytes", "ici_bytes", "coll_counts"):
        assert k in d
    assert d["ici_bytes"] == 0.0  # single device: no collectives
