"""Documentation hygiene in tier-1: every relative link in README.md and
docs/*.md must resolve inside the repo.

The heavier example `--help` smoke (subprocess per module) lives in the CI
docs lane (``python tools/check_docs.py``); the link check is cheap enough
to gate every test run.
"""
import importlib.util
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent


def load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_pages_exist():
    mod = load_check_docs()
    pages = [pathlib.Path(p).name for p in mod.doc_pages()]
    assert "README.md" in pages
    # the documented layer map + the tentpole how-to must be present
    for required in ("architecture.md", "anytime_serving.md",
                     "benchmarks.md"):
        assert required in pages


def test_no_broken_intra_repo_links():
    mod = load_check_docs()
    failures = mod.check_links()
    assert not failures, "\n".join(failures)


def test_readme_links_every_docs_page():
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    for page in sorted((REPO / "docs").glob("*.md")):
        assert f"docs/{page.name}" in readme, (
            f"README.md does not link docs/{page.name}")
