"""Anytime serving of the big-model configs: early-exit heads over the
registered transformer families + the deadline-aware continuous-batching
engine (`docs/anytime_serving.md`).

The load-bearing contract is *bit-exactness at full depth*: with fresh
(ones-init) heads, the last row of the anytime readouts must equal the
stock forward / decode outputs exactly — under ``jit``, like every other
parity claim in this repo — so enabling anytime serving can never change
what the model computes, only how much of it the scheduler charges for.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import adapt
from repro.configs import get_config
from repro.models import anytime as A
from repro.models import transformer as T
from repro.serve import (
    AnytimeConfig,
    AnytimeRequest,
    AnytimeServeEngine,
)
from repro.telemetry import TelemetryConfig

# one family per step-core path: attention+GQA (qwen), partial-RoPE +
# sliding-window (glm), recurrent xLSTM — the three configs the engine
# acceptance covers
ANYTIME_ARCHS = ("xlstm-125m", "qwen1.5-0.5b", "glm4-9b")


def token_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)}


# --------------------------------------------------------------------- #
# Full-depth bit-exactness (sequence + decode paths), per family.
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", ANYTIME_ARCHS)
def test_sequence_full_depth_bit_exact(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    heads = A.init_heads(cfg)
    batch = token_batch(cfg)

    ref = jax.jit(lambda p, b: T.forward(cfg, p, b, remat=False)[0])(
        params, batch)
    got = jax.jit(lambda p, b: A.anytime_forward(cfg, p, heads, b))(
        params, batch)

    B, S = batch["tokens"].shape
    assert got.shape == (cfg.n_units, B, S, cfg.vocab)
    assert bool(jnp.isfinite(got).all())
    # exact equality, not a tolerance: the final unit reads the stock head
    np.testing.assert_array_equal(np.asarray(got[-1]), np.asarray(ref))


@pytest.mark.parametrize("arch", ANYTIME_ARCHS)
def test_decode_full_depth_bit_exact(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    heads = A.init_heads(cfg)
    B, L = 2, 8
    s_ref = T.init_decode_state(cfg, B, L, cache_len=L, stacked=False)
    s_any = T.init_decode_state(cfg, B, L, cache_len=L, stacked=False)

    step_ref = jax.jit(lambda p, s, t: T.decode_step(
        cfg, p, s, t, unroll=True))
    step_any = jax.jit(lambda p, s, t: A.unit_decode_step(
        cfg, p, heads, s, t))

    rng = np.random.default_rng(1)
    for _ in range(4):
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
        l_ref, s_ref = step_ref(params, s_ref, tok)
        ul, s_any = step_any(params, s_any, tok)
        assert ul.shape == (cfg.n_units, B, cfg.vocab)
        np.testing.assert_array_equal(np.asarray(ul[-1]),
                                      np.asarray(l_ref))
    # the decode states advanced identically too
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        s_ref, s_any)


# --------------------------------------------------------------------- #
# The utility test: threshold sweep -> monotone depth.
# --------------------------------------------------------------------- #


def test_select_depth_monotone_in_threshold():
    """Raising the margin threshold can only deepen execution."""
    rng = np.random.default_rng(0)
    U, N = 4, 256
    margin = jnp.asarray(rng.exponential(2.0, (U, N)), jnp.float32)
    use = jnp.ones((U,), jnp.float32)
    prev = None
    for t in np.linspace(0.0, float(margin.max()) + 1.0, 9):
        depth, exit_unit = A.select_depth(
            margin, jnp.full((U,), t, jnp.float32), use, mandatory=1)
        assert int(depth.min()) >= 1 and int(depth.max()) <= U
        mean = float(depth.mean())
        if prev is not None:
            assert mean >= prev - 1e-9
        prev = mean
    # threshold above every margin => the sweep ends at full depth
    assert prev == pytest.approx(U)


def test_take_at_depth_picks_unit_rows():
    U, N, V = 3, 5, 7
    vals = jnp.arange(U * N * V, dtype=jnp.float32).reshape(U, N, V)
    depth = jnp.asarray([1, 2, 3, 1, 2], jnp.int32)
    out = A.take_at_depth(vals, depth)
    for i in range(N):
        np.testing.assert_array_equal(
            np.asarray(out[i]), np.asarray(vals[int(depth[i]) - 1, i]))


# --------------------------------------------------------------------- #
# Engine behavior (tiny random-init qwen-family model).
# --------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def tiny_model():
    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b").reduced(),
        n_layers=4, vocab=64, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, exit_every=1)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def tiny_requests(n=6):
    return [
        AnytimeRequest(prompt=(1 + i % 4, 2), n_tokens=3,
                       release=0.3 * i, deadline=0.3 * i + 2.5)
        for i in range(n)
    ]


def make_engine(tiny_model, policy="anytime"):
    cfg, params = tiny_model
    return AnytimeServeEngine(
        cfg, params,
        serve_cfg=AnytimeConfig(
            policy=policy, batch_slots=2, max_steps=160,
            prompt_len=2, max_new_tokens=4))


def result_arrays(res):
    return (res.status, res.finish, res.tardiness, res.agree,
            res.tokens, res.depth_sum)


def test_engine_serves_and_segments_bit_exact(tiny_model):
    eng = make_engine(tiny_model)
    reqs = tiny_requests()
    res1 = eng.run(reqs, n_segments=1)
    res4 = eng.run(reqs, n_segments=4)
    assert res1.completed == len(reqs)
    for a, b in zip(result_arrays(res1), result_arrays(res4)):
        np.testing.assert_array_equal(a, b)


def test_engine_telemetry_is_neutral(tiny_model):
    eng = make_engine(tiny_model)
    reqs = tiny_requests()
    plain = eng.run(reqs)
    with_tel = eng.run(reqs, telemetry=TelemetryConfig(level="full"))
    assert plain.telemetry is None
    assert with_tel.telemetry is not None
    for a, b in zip(result_arrays(plain), result_arrays(with_tel)):
        np.testing.assert_array_equal(a, b)
    # the exit-depth histogram saw every generated token
    hist = np.asarray(jax.device_get(with_tel.telemetry.exit_hist))
    assert hist.sum() == with_tel.tokens.sum()


def test_engine_edf_runs_full_depth(tiny_model):
    """Fixed-depth EDF charges every token the full stack and therefore
    agrees with full depth by construction."""
    eng = make_engine(tiny_model, policy="edf")
    res = eng.run(tiny_requests())
    assert res.completed == res.n_requests
    assert res.mean_depth == pytest.approx(eng.n_units)
    assert res.agreement == pytest.approx(1.0)


def test_engine_depth_monotone_in_threshold(tiny_model):
    """The engine-level threshold sweep mirrors select_depth: a permissive
    threshold exits shallow, an unreachable one runs full depth."""
    eng = make_engine(tiny_model)
    reqs = tiny_requests()
    depths = []
    for thr in (-1e9, 1.0, 1e9):
        knobs = eng.default_knobs(
            exit_thr=jnp.full((eng.n_units,), thr, jnp.float32))
        depths.append(eng.run(reqs, knobs=knobs).mean_depth)
    assert depths[0] <= depths[1] + 1e-9 <= depths[2] + 2e-9
    assert depths[0] == pytest.approx(eng.mandatory)
    assert depths[2] == pytest.approx(eng.n_units)


def test_engine_tune_smoke(tiny_model):
    """adapt.tune over the engine's score_fn: the vmapped objective scores
    a population and returns in-bounds knobs."""
    eng = make_engine(tiny_model)
    reqs = tiny_requests(4)
    space = adapt.anytime_space(eng)
    objective = adapt.make_anytime_objective(eng, reqs)
    result = adapt.tune(objective, space, budget=6, driver="random",
                        seed=0)
    assert set(result.best_params) == set(space.names)
    assert np.isfinite(result.best_score)
    knobs = adapt.knobs_from_params(eng, result.best_params)
    res = eng.run(reqs, knobs=knobs)
    assert res.score == pytest.approx(float(result.best_score), abs=1e-6)
